//! SLO specs: per-class tail-latency targets the `slo-score` DSE objective
//! optimizes against (`--slo "interactive=p99<5,batch=p99<50"`).

use crate::des::DesReport;

/// One target: class `class` must keep p99 job latency under `p99_ms`.
/// Class `*` targets the whole-run p99 across every class.
#[derive(Debug, Clone, PartialEq)]
pub struct SloTarget {
    pub class: String,
    pub p99_ms: f64,
}

/// A parsed `--slo` spec: a conjunction of per-class targets.
#[derive(Debug, Clone, PartialEq)]
pub struct SloSpec {
    pub targets: Vec<SloTarget>,
}

/// Violations are scaled by this per second of p99 overshoot, so any
/// violated candidate scores worse than any compliant one (makespans are
/// milliseconds) while staying continuous — ties among violators still
/// break toward the least-violating architecture.
const VIOLATION_PER_S: f64 = 1e6;
/// Deadline misses (from trace deadlines) are penalized per missed-rate
/// unit on the same scale.
const MISS_RATE_PENALTY: f64 = 1e3;

impl SloSpec {
    /// Parse `class=p99<MS[,class=p99<MS...]`. Rejects non-finite or
    /// non-positive bounds, duplicate classes, and malformed clauses with
    /// an error naming the accepted grammar.
    pub fn parse(spec: &str) -> Result<SloSpec, String> {
        let grammar = "CLASS=p99<MS[,CLASS=p99<MS...] (CLASS '*' = all classes)";
        let bad = |why: String| format!("bad slo spec '{spec}': {why} (want {grammar})");
        let mut targets: Vec<SloTarget> = Vec::new();
        for clause in spec.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                return Err(bad("empty clause".to_string()));
            }
            let (class, bound) = clause
                .split_once('=')
                .ok_or_else(|| bad(format!("clause '{clause}' has no '='")))?;
            let ms_str = bound
                .strip_prefix("p99<")
                .ok_or_else(|| bad(format!("bound '{bound}' must be 'p99<MS'")))?;
            let p99_ms: f64 = ms_str
                .parse()
                .map_err(|_| bad(format!("'{ms_str}' is not a number")))?;
            if !p99_ms.is_finite() || p99_ms <= 0.0 {
                return Err(bad(format!("target must be finite and > 0 ms, got '{ms_str}'")));
            }
            let class = class.trim();
            if class.is_empty() {
                return Err(bad(format!("clause '{clause}' has an empty class")));
            }
            if targets.iter().any(|t| t.class == class) {
                return Err(bad(format!("class '{class}' appears twice")));
            }
            targets.push(SloTarget { class: class.to_string(), p99_ms });
        }
        Ok(SloSpec { targets })
    }

    /// Render back to the spec grammar. Parameters print with shortest-
    /// round-trip float formatting, so `parse(spec()) == self` bit-for-bit
    /// (the wire codecs ship this string).
    pub fn spec(&self) -> String {
        self.targets
            .iter()
            .map(|t| format!("{}=p99<{}", t.class, t.p99_ms))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// SLO penalty for a DES report: 0.0 when every target holds and no
    /// deadline was missed, else a continuous positive penalty that
    /// dominates any makespan. Targets naming a class the report never saw
    /// contribute nothing (an absent class has no tail to violate).
    pub fn penalty(&self, rep: &DesReport) -> f64 {
        let mut p = 0.0;
        for t in &self.targets {
            let target_s = t.p99_ms * 1e-3;
            if t.class == "*" {
                p += (rep.p99_job_latency_s - target_s).max(0.0) * VIOLATION_PER_S;
                continue;
            }
            for c in &rep.classes {
                if c.class == t.class && c.jobs > 0 {
                    p += (c.p99_latency_s - target_s).max(0.0) * VIOLATION_PER_S;
                }
            }
        }
        let deadline_jobs: u64 = rep.classes.iter().map(|c| c.deadline_jobs).sum();
        if deadline_jobs > 0 {
            let misses: u64 = rep.classes.iter().map(|c| c.deadline_misses).sum();
            p += misses as f64 / deadline_jobs as f64 * MISS_RATE_PENALTY;
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::ClassStats;

    fn report(classes: Vec<ClassStats>, p99: f64) -> DesReport {
        DesReport {
            scenario: "t".into(),
            seed: 0,
            nodes: Vec::new(),
            jobs_released: 4,
            jobs_completed: 4,
            makespan_s: 0.01,
            mean_job_latency_s: 0.0,
            p50_job_latency_s: 0.0,
            p99_job_latency_s: p99,
            max_job_latency_s: p99,
            throughput_jobs_per_s: 0.0,
            events: 0,
            classes,
        }
    }

    fn class(name: &str, p99_s: f64, dj: u64, dm: u64) -> ClassStats {
        ClassStats {
            class: name.into(),
            jobs: 2,
            mean_latency_s: p99_s,
            p99_latency_s: p99_s,
            deadline_jobs: dj,
            deadline_misses: dm,
        }
    }

    #[test]
    fn parse_round_trips_and_validates() {
        let s = SloSpec::parse("interactive=p99<5,batch=p99<50.5").unwrap();
        assert_eq!(s.targets.len(), 2);
        assert_eq!(SloSpec::parse(&s.spec()).unwrap(), s);
        for bad in [
            "", "x", "a=p99<", "a=p99<nan", "a=p99<-1", "a=p99<0", "a=p50<5", "=p99<5",
            "a=p99<5,a=p99<9",
        ] {
            assert!(SloSpec::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn penalty_zero_when_met_positive_when_violated() {
        let slo = SloSpec::parse("fast=p99<1").unwrap();
        let ok = report(vec![class("fast", 0.0005, 0, 0)], 0.0005);
        assert_eq!(slo.penalty(&ok), 0.0);
        let bad = report(vec![class("fast", 0.0030, 0, 0)], 0.0030);
        assert!(slo.penalty(&bad) > 1e3, "2 ms overshoot must dominate a makespan");
        // star targets the overall tail
        let star = SloSpec::parse("*=p99<1").unwrap();
        assert!(star.penalty(&bad) > 0.0);
        assert_eq!(star.penalty(&ok), 0.0);
    }

    #[test]
    fn deadline_misses_penalize_even_without_targets_hit() {
        let slo = SloSpec::parse("fast=p99<100").unwrap();
        let missed = report(vec![class("fast", 0.0005, 4, 1)], 0.0005);
        let clean = report(vec![class("fast", 0.0005, 4, 0)], 0.0005);
        assert!(slo.penalty(&missed) > slo.penalty(&clean));
    }
}
