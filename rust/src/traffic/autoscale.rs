//! Elastic replicas: an autoscaler controller that runs *inside* the DES,
//! adjusting each CU's active replica count from observed backlog — the
//! `replicate` pass as a runtime knob instead of a static design choice.
//!
//! The model is activation, not re-layout: the fabric provisions
//! `max_replicas` copies, the controller clocks between `min_replicas` and
//! `max_replicas` of them, and an active count of `r` serves chunks `r`
//! times faster (perfect striping, no migration cost). Coarse, but it
//! answers the DSE question that matters: does a smaller always-on design
//! plus elasticity meet the tail, or does the workload need static width?

use crate::util::{
    f64_from_bits_json, f64_to_bits_json, u64_from_str_json, u64_to_str_json, Json,
};

/// Controller policy (see the module docs). Evaluated on a fixed simulated-
/// time interval per CU; one step up or down per tick.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscalePolicy {
    /// Controller period, simulated seconds.
    pub interval_s: f64,
    /// Scale up when a CU's input backlog (elems; pending output elems for
    /// source-like CUs) reaches this.
    pub scale_up_backlog: u64,
    /// Scale down when backlog is at or below this.
    pub scale_down_backlog: u64,
    /// Active-replica floor (>= 1).
    pub min_replicas: u32,
    /// Active-replica ceiling (>= min).
    pub max_replicas: u32,
}

impl AutoscalePolicy {
    /// Parse `INTERVAL_S:UP:DOWN:MIN:MAX` (the `--autoscale` flag).
    pub fn parse(spec: &str) -> Result<AutoscalePolicy, String> {
        let form = "INTERVAL_S:UP_BACKLOG:DOWN_BACKLOG:MIN_REPLICAS:MAX_REPLICAS";
        let bad = |why: String| format!("bad autoscale spec '{spec}': {why} (want {form})");
        let parts: Vec<&str> = spec.split(':').collect();
        let [iv, up, down, min, max] = parts.as_slice() else {
            return Err(bad(format!("{} fields", parts.len())));
        };
        let interval_s: f64 =
            iv.parse().map_err(|_| bad(format!("interval '{iv}' is not a number")))?;
        if !interval_s.is_finite() || interval_s <= 0.0 {
            return Err(bad("interval must be finite and > 0".to_string()));
        }
        let uint = |s: &str, what: &str| -> Result<u64, String> {
            s.parse().map_err(|_| bad(format!("{what} '{s}' is not a non-negative integer")))
        };
        let scale_up_backlog = uint(up, "up threshold")?;
        let scale_down_backlog = uint(down, "down threshold")?;
        if scale_down_backlog >= scale_up_backlog {
            return Err(bad("down threshold must be below up threshold".to_string()));
        }
        let min_replicas = uint(min, "min replicas")? as u32;
        let max_replicas = uint(max, "max replicas")? as u32;
        if min_replicas == 0 || max_replicas < min_replicas {
            return Err(bad("need 1 <= min <= max replicas".to_string()));
        }
        Ok(AutoscalePolicy {
            interval_s,
            scale_up_backlog,
            scale_down_backlog,
            min_replicas,
            max_replicas,
        })
    }

    /// Render back to the [`AutoscalePolicy::parse`] form
    /// (shortest-round-trip float, so `parse(spec()) == self` bit-for-bit).
    pub fn spec(&self) -> String {
        format!(
            "{}:{}:{}:{}:{}",
            self.interval_s,
            self.scale_up_backlog,
            self.scale_down_backlog,
            self.min_replicas,
            self.max_replicas
        )
    }

    /// Wire codec (travels inside [`crate::des::DesConfig::to_json`];
    /// floats as raw bit patterns so reconstructed values `Debug`-render —
    /// and therefore cache-key — byte-identically).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("interval_s", f64_to_bits_json(self.interval_s)),
            ("scale_up_backlog", u64_to_str_json(self.scale_up_backlog)),
            ("scale_down_backlog", u64_to_str_json(self.scale_down_backlog)),
            ("min_replicas", u64_to_str_json(self.min_replicas as u64)),
            ("max_replicas", u64_to_str_json(self.max_replicas as u64)),
        ])
    }

    /// Inverse of [`AutoscalePolicy::to_json`].
    pub fn from_json(j: &Json) -> Option<AutoscalePolicy> {
        Some(AutoscalePolicy {
            interval_s: f64_from_bits_json(j.get("interval_s"))?,
            scale_up_backlog: u64_from_str_json(j.get("scale_up_backlog"))?,
            scale_down_backlog: u64_from_str_json(j.get("scale_down_backlog"))?,
            min_replicas: u64_from_str_json(j.get("min_replicas"))? as u32,
            max_replicas: u64_from_str_json(j.get("max_replicas"))? as u32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spec_round_trips() {
        let p = AutoscalePolicy::parse("0.0005:256:16:1:4").unwrap();
        assert_eq!(p.min_replicas, 1);
        assert_eq!(p.max_replicas, 4);
        assert_eq!(AutoscalePolicy::parse(&p.spec()).unwrap(), p);
    }

    #[test]
    fn bad_specs_are_rejected_with_the_form() {
        for bad in [
            "", "1:2:3", "x:256:16:1:4", "inf:256:16:1:4", "0:256:16:1:4", "0.1:16:256:1:4",
            "0.1:256:16:0:4", "0.1:256:16:4:1", "0.1:256:16:1:x",
        ] {
            let err = AutoscalePolicy::parse(bad).unwrap_err();
            assert!(err.contains("INTERVAL_S"), "'{bad}' -> {err}");
        }
    }

    #[test]
    fn json_codec_round_trips_debug_identically() {
        let p = AutoscalePolicy::parse("0.001:128:8:2:6").unwrap();
        let back = AutoscalePolicy::from_json(&Json::parse(&p.to_json().to_string()).unwrap())
            .expect("decodes");
        assert_eq!(back, p);
        assert_eq!(format!("{back:?}"), format!("{p:?}"));
    }
}
