//! `traffic` — production traffic modeling and SLO-aware optimization.
//!
//! The DES started life as a throughput benchmark: toy arrival shapes,
//! deterministic or exponential service, one anonymous job class, scored by
//! makespan. Production serving traffic is none of those things — service
//! times are heavy-tailed, load is diurnal, requests carry priorities and
//! deadlines, and the number that matters is a per-class p99, not a mean.
//! This subsystem closes that gap:
//!
//! * **[`trace`]** — trace-driven replay: `--scenario trace:<file>` parses
//!   a checksummed file of timestamped, class-tagged, deadline-tagged jobs
//!   ([`TraceJob`]); scenario identity is content-hashed, so cache keys are
//!   path- and process-independent.
//! * **[`slo`]** — [`SloSpec`] (`--slo "interactive=p99<5"`): per-class
//!   tail targets that the `slo-score` DSE objective scores against, so
//!   `olympus dse` can pick the architecture that *meets the tail* over
//!   the one that merely drains the batch fastest.
//! * **[`autoscale`]** — [`AutoscalePolicy`] (`--autoscale`): an elastic-
//!   replica controller inside the DES, turning the `replicate` pass into
//!   a runtime knob.
//!
//! Heavy-tailed service itself lives on
//! [`crate::des::ServiceDist`] (`LogNormal`/`Pareto`), and per-class
//! latency/deadline accounting on [`crate::des::DesReport`]; this module
//! holds the traffic-shaping vocabulary those consume.

pub mod autoscale;
pub mod slo;
pub mod trace;

pub use autoscale::AutoscalePolicy;
pub use slo::{SloSpec, SloTarget};
pub use trace::{
    load_trace_scenario, parse_trace, render_trace, scenario_from_spec, trace_scenario, TraceJob,
};
