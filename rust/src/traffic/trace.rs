//! Trace-driven replay: a checksummed text file of timestamped,
//! class-tagged, deadline-tagged jobs that the DES replays verbatim.
//!
//! Format (`olympus des --scenario trace:<file>`):
//!
//! ```text
//! olympus-trace v1 crc=7d4a1f0e9c2b5a63
//! # comments and blank lines are ignored
//! # AT_S CLASS [DEADLINE_MS|-] [PRIO]
//! 0.000  interactive  5    2
//! 0.0004 batch        -
//! 0.0010 interactive  5    2
//! ```
//!
//! * `AT_S` — arrival instant in seconds (rounded to integer picoseconds).
//! * `CLASS` — free-form class name; per-class p99 / deadline-miss stats
//!   are reported under it.
//! * `DEADLINE_MS` — optional completion deadline in milliseconds (`-` =
//!   none).
//! * `PRIO` — optional integer priority (default 0, higher = more urgent):
//!   a backlogged job's data is admitted ahead of lower-priority data.
//!
//! The `crc=` header is FNV-1a 64 over everything after the first newline,
//! byte-for-byte. A stale checksum fails parsing with the expected value in
//! the error, so authoring by hand is a two-step paste. The resulting
//! scenario's identity (name, `Debug` rendering, and therefore every cache
//! key it reaches) is derived from the *content*, never the path — two
//! copies of the same trace hit the same cache entry.

use std::path::Path;

use crate::des::{ArrivalProcess, WorkloadScenario, PS_PER_S};
use crate::util::{fnv1a_64, ContentHash};

/// One replayed job. Times are integer picoseconds so traces hash, compare
/// and `Debug`-render without float-formatting ambiguity.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceJob {
    /// Arrival instant, ps.
    pub at_ps: u64,
    /// Traffic class (per-class stats key).
    pub class: String,
    /// Optional completion deadline (relative to arrival), ps.
    pub deadline_ps: Option<u64>,
    /// Priority (higher = admitted first under backlog).
    pub prio: u32,
}

/// Parse trace text (see the module docs for the format). Validates the
/// header, the checksum, and every field; jobs come back sorted by arrival.
pub fn parse_trace(text: &str) -> Result<Vec<TraceJob>, String> {
    let (header, body) = text
        .split_once('\n')
        .ok_or_else(|| "trace is empty (want an 'olympus-trace v1 crc=<hex>' header)".to_string())?;
    let header = header.trim_end_matches('\r');
    let crc_hex = header
        .strip_prefix("olympus-trace v1 crc=")
        .ok_or_else(|| format!("bad trace header '{header}' (want 'olympus-trace v1 crc=<hex>')"))?;
    let want = u64::from_str_radix(crc_hex.trim(), 16)
        .map_err(|_| format!("bad trace crc '{crc_hex}' (want 16 hex digits)"))?;
    let got = fnv1a_64(body.as_bytes());
    if got != want {
        return Err(format!(
            "trace checksum mismatch: header says {want:016x}, body hashes to {got:016x} \
             (update the header after editing)"
        ));
    }

    let mut jobs = Vec::new();
    for (i, raw) in body.lines().enumerate() {
        let lineno = i + 2; // 1-based, after the header
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        let bad = |why: String| {
            format!("trace line {lineno} '{line}': {why} (want AT_S CLASS [DEADLINE_MS|-] [PRIO])")
        };
        if fields.len() < 2 || fields.len() > 4 {
            return Err(bad(format!("{} fields", fields.len())));
        }
        let at_s: f64 = fields[0]
            .parse()
            .map_err(|_| bad(format!("arrival '{}' is not a number", fields[0])))?;
        if !at_s.is_finite() || at_s < 0.0 {
            return Err(bad("arrival must be finite and >= 0".to_string()));
        }
        let class = fields[1].to_string();
        let deadline_ps = match fields.get(2) {
            None | Some(&"-") => None,
            Some(d) => {
                let ms: f64 =
                    d.parse().map_err(|_| bad(format!("deadline '{d}' is not a number")))?;
                if !ms.is_finite() || ms <= 0.0 {
                    return Err(bad("deadline must be finite and > 0 ms".to_string()));
                }
                Some((ms * 1e-3 * PS_PER_S).round() as u64)
            }
        };
        let prio = match fields.get(3) {
            None => 0u32,
            Some(p) => p
                .parse()
                .map_err(|_| bad(format!("priority '{p}' is not a small non-negative integer")))?,
        };
        jobs.push(TraceJob { at_ps: (at_s * PS_PER_S).round() as u64, class, deadline_ps, prio });
    }
    if jobs.is_empty() {
        return Err("trace has no jobs".to_string());
    }
    jobs.sort_by_key(|j| j.at_ps);
    Ok(jobs)
}

/// Render `jobs` back to the checksummed file format (the inverse of
/// [`parse_trace`] up to comments/ordering) — used to author traces
/// programmatically in tests and tools.
pub fn render_trace(jobs: &[TraceJob]) -> String {
    let mut body = String::new();
    for j in jobs {
        let at_s = j.at_ps as f64 / PS_PER_S;
        body.push_str(&format!("{at_s} {}", j.class));
        match j.deadline_ps {
            Some(d) => body.push_str(&format!(" {}", d as f64 / PS_PER_S * 1e3)),
            None => body.push_str(" -"),
        }
        if j.prio != 0 {
            body.push_str(&format!(" {}", j.prio));
        }
        body.push('\n');
    }
    format!("olympus-trace v1 crc={:016x}\n{body}", fnv1a_64(body.as_bytes()))
}

/// Wrap parsed jobs as a [`WorkloadScenario`]. The name embeds a content
/// hash of the jobs, so identity is path-independent: identical content on
/// two paths is one scenario (and one cache key).
pub fn trace_scenario(mut jobs: Vec<TraceJob>) -> WorkloadScenario {
    jobs.sort_by_key(|j| j.at_ps);
    let parts: Vec<String> = jobs
        .iter()
        .map(|j| {
            format!(
                "{}:{}:{}:{}",
                j.at_ps,
                j.class,
                j.deadline_ps.map(|d| d.to_string()).unwrap_or_default(),
                j.prio
            )
        })
        .collect();
    let refs: Vec<&str> = parts.iter().map(String::as_str).collect();
    let hex = ContentHash::of_parts(&refs).to_hex();
    WorkloadScenario {
        name: format!("trace-{}job-{}", jobs.len(), &hex[..12]),
        arrivals: ArrivalProcess::Trace { jobs },
    }
}

/// Load a trace file into a scenario.
pub fn load_trace_scenario(path: &Path) -> Result<WorkloadScenario, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read trace '{}': {e}", path.display()))?;
    parse_trace(&text).map(trace_scenario)
}

/// Resolve a CLI/protocol scenario spec, including `trace:<file>` (the one
/// spec form that touches the filesystem — [`WorkloadScenario::parse`]
/// itself stays pure).
pub fn scenario_from_spec(spec: &str) -> Result<WorkloadScenario, String> {
    match spec.strip_prefix("trace:") {
        Some(path) => load_trace_scenario(Path::new(path)),
        None => WorkloadScenario::parse(spec),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> String {
        render_trace(&[
            TraceJob {
                at_ps: 0,
                class: "interactive".into(),
                deadline_ps: Some(5_000_000),
                prio: 2,
            },
            TraceJob { at_ps: 400_000, class: "batch".into(), deadline_ps: None, prio: 0 },
            TraceJob {
                at_ps: 1_000_000,
                class: "interactive".into(),
                deadline_ps: Some(5_000_000),
                prio: 2,
            },
        ])
    }

    #[test]
    fn render_parse_round_trips() {
        let text = sample();
        let jobs = parse_trace(&text).expect("parses");
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[0].class, "interactive");
        assert_eq!(jobs[0].deadline_ps, Some(5_000_000));
        assert_eq!(jobs[0].prio, 2);
        assert_eq!(jobs[1].deadline_ps, None);
        assert_eq!(render_trace(&jobs), text);
    }

    #[test]
    fn checksum_mismatch_is_rejected_with_expected_value() {
        let text = sample().replace("interactive", "interactivx");
        let err = parse_trace(&text).unwrap_err();
        assert!(err.contains("checksum mismatch"), "{err}");
        assert!(err.contains("body hashes to"), "{err}");
    }

    #[test]
    fn bad_fields_fail_structured() {
        let mk = |body: &str| {
            format!("olympus-trace v1 crc={:016x}\n{body}", fnv1a_64(body.as_bytes()))
        };
        for (body, want) in [
            ("x cls\n", "not a number"),
            ("-1 cls\n", ">= 0"),
            ("0.1 cls nan\n", "deadline"),
            ("0.1 cls 0\n", "> 0 ms"),
            ("0.1 cls - -3\n", "priority"),
            ("0.1\n", "fields"),
            ("# only comments\n", "no jobs"),
        ] {
            let err = parse_trace(&mk(body)).unwrap_err();
            assert!(err.contains(want), "body {body:?} -> {err}");
        }
        assert!(parse_trace("nonsense\n0 a\n").unwrap_err().contains("header"));
    }

    #[test]
    fn scenario_identity_is_content_based() {
        let a = trace_scenario(parse_trace(&sample()).unwrap());
        let b = trace_scenario(parse_trace(&sample()).unwrap());
        assert_eq!(a, b);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        // flipping one job's deadline changes the name (and thus every key)
        let mut jobs = parse_trace(&sample()).unwrap();
        jobs[1].deadline_ps = Some(1_000_000);
        let c = trace_scenario(jobs);
        assert_ne!(a.name, c.name);
    }

    #[test]
    fn jobs_come_back_sorted() {
        let body = "0.002 b\n0.001 a\n";
        let text = format!("olympus-trace v1 crc={:016x}\n{body}", fnv1a_64(body.as_bytes()));
        let jobs = parse_trace(&text).unwrap();
        assert!(jobs.windows(2).all(|w| w[0].at_ps <= w[1].at_ps));
        assert_eq!(jobs[0].class, "a");
    }
}
