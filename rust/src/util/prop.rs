//! Tiny property-testing helper (proptest is not vendored).
//!
//! [`check`] runs a property over `n` seeded random cases; on failure it
//! reports the failing seed so the case can be replayed deterministically,
//! and performs a simple "shrink" by retrying the property with smaller
//! size hints.

use super::rng::Rng;

/// Run `prop(rng, size)` for `n` cases with growing size hints (4..=max).
/// `prop` returns `Err(msg)` to signal a failure.
///
/// Panics with the seed + size of the first failure (after shrinking to the
/// smallest failing size for that seed).
pub fn check<F>(name: &str, n: usize, max_size: usize, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..n {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 4 + (case * max_size.saturating_sub(4)) / n.max(1);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            // shrink: find the smallest failing size for this seed
            let mut smallest = (size, msg);
            let mut s = 4;
            while s < smallest.0 {
                let mut rng = Rng::new(seed);
                if let Err(m) = prop(&mut rng, s) {
                    smallest = (s, m);
                    break;
                }
                s += 1 + s / 2;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, size {}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, 100, |rng, size| {
            let a = rng.range(0, size + 1) as i64;
            let b = rng.range(0, size + 1) as i64;
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, 10, |_, _| Err("nope".into()));
    }

    #[test]
    fn deterministic_replay() {
        // same case index => same seed => same generated values
        let mut first = Vec::new();
        check("record", 3, 10, |rng, _| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("record", 3, 10, |rng, _| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
