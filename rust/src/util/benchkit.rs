//! Micro-benchmark harness (criterion is not vendored; see DESIGN.md §2).
//!
//! Each bench target is a plain binary (`harness = false`) that builds a
//! [`Bench`], registers closures, and calls [`Bench::run`]. Reporting:
//! median / p10 / p90 wall time over timed iterations after warmup, plus an
//! optional derived throughput column. Output is both human-readable and
//! machine-greppable (`BENCH\t<name>\t<median_ns>\t...`).

use std::time::Instant;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters: usize,
    /// Optional (value, unit) throughput, e.g. (12.3, "GB/s").
    pub throughput: Option<(f64, String)>,
}

/// Bench harness configuration.
pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
    results: Vec<Sample>,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        // Keep runs short: benches are regenerated for every paper figure.
        let (warmup, iters) = match std::env::var("BENCH_FAST") {
            Ok(_) => (1, 5),
            Err(_) => (3, 15),
        };
        Self { name: name.to_string(), warmup, iters, results: Vec::new() }
    }

    pub fn with_iters(mut self, warmup: usize, iters: usize) -> Self {
        self.warmup = warmup;
        self.iters = iters;
        self
    }

    /// Time `f`, which returns an optional throughput annotation computed
    /// from its own work (e.g. bytes moved / simulated seconds).
    pub fn bench_with_throughput<F>(&mut self, name: &str, mut f: F)
    where
        F: FnMut() -> Option<(f64, String)>,
    {
        let mut tp = None;
        for _ in 0..self.warmup {
            tp = f();
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            tp = f();
            times.push(t0.elapsed().as_nanos() as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
        self.results.push(Sample {
            name: name.to_string(),
            median_ns: q(0.5),
            p10_ns: q(0.1),
            p90_ns: q(0.9),
            iters: self.iters,
            throughput: tp,
        });
    }

    /// Time a plain closure.
    pub fn bench<F, R>(&mut self, name: &str, mut f: F)
    where
        F: FnMut() -> R,
    {
        self.bench_with_throughput(name, || {
            std::hint::black_box(f());
            None
        });
    }

    /// Print the report table and the grep-friendly lines.
    pub fn run(self) {
        let _ = self.finish();
    }

    /// Like [`Bench::run`], but hand the recorded samples back to the
    /// caller — the `bench_snapshot` target serializes them into the
    /// checked-in `BENCH_DES.json` perf trajectory.
    pub fn finish(self) -> Vec<Sample> {
        println!("\n== bench: {} ==", self.name);
        println!("{:<44} {:>12} {:>12} {:>12}  throughput", "case", "median", "p10", "p90");
        for s in &self.results {
            let tp = s
                .throughput
                .as_ref()
                .map(|(v, u)| format!("{v:.2} {u}"))
                .unwrap_or_default();
            println!(
                "{:<44} {:>12} {:>12} {:>12}  {}",
                s.name,
                fmt_ns(s.median_ns),
                fmt_ns(s.p10_ns),
                fmt_ns(s.p90_ns),
                tp
            );
        }
        for s in &self.results {
            let (tv, tu) = s
                .throughput
                .as_ref()
                .map(|(v, u)| (format!("{v}"), u.clone()))
                .unwrap_or((String::new(), String::new()));
            println!(
                "BENCH\t{}\t{}\t{}\t{}\t{}\t{}\t{}",
                self.name, s.name, s.median_ns, s.p10_ns, s.p90_ns, tv, tu
            );
        }
        self.results
    }
}

/// Pretty-print nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_samples() {
        let mut b = Bench::new("t").with_iters(1, 3);
        b.bench("noop", || 1 + 1);
        assert_eq!(b.results.len(), 1);
        assert_eq!(b.results[0].iters, 3);
        assert!(b.results[0].median_ns >= 0.0);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.20 s");
    }
}
