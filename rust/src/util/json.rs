//! Minimal JSON: parse + serialize. Covers the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (sufficient for `manifest.json`,
//! platform files and report emission).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// JSON parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["k"]`-style access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

/// An `f64` as its raw bit pattern in 16 hex digits: round-trips
/// *bit-identically* through JSON, including the infinities (infeasible
/// scores) and signed zeros plain JSON numbers cannot carry. Used by every
/// wire/disk codec whose decoded value must hash — or `Debug`-render —
/// byte-identically on another process.
pub fn f64_to_bits_json(x: f64) -> Json {
    Json::Str(format!("{:016x}", x.to_bits()))
}

/// Inverse of [`f64_to_bits_json`]; `None` marks an undecodable value.
pub fn f64_from_bits_json(j: &Json) -> Option<f64> {
    let s = j.as_str()?;
    u64::from_str_radix(s, 16).ok().map(f64::from_bits)
}

/// A `u64` as a decimal string: JSON numbers are f64-backed here, so values
/// above 2^53 would silently round — unacceptable for wire codecs whose
/// decoded value must hash byte-identically on another process (a DES seed
/// is a full u64).
pub fn u64_to_str_json(x: u64) -> Json {
    Json::Str(x.to_string())
}

/// Inverse of [`u64_to_str_json`]; `None` marks an undecodable value.
pub fn u64_from_str_json(j: &Json) -> Option<u64> {
    j.as_str()?.parse().ok()
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(*v.get("d"), Json::Null);
        assert_eq!(*v.get("missing"), Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"kernels":[{"hlo":"x.hlo.txt","input_shapes":[[1024],[1024]],"name":"vecadd"}],"z":-1.25}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(5.0).as_usize(), Some(5));
        assert_eq!(Json::Num(5.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }
}
