//! In-tree substrates replacing unavailable third-party crates (the build is
//! fully offline — see DESIGN.md §2): JSON, a seeded PRNG, a micro-bench
//! harness and a tiny property-testing helper.

pub mod benchkit;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;

pub use hash::{fnv1a_64, ContentHash, Fnv64};
pub use json::Json;
pub use rng::Rng;
