//! In-tree substrates replacing unavailable third-party crates (the build is
//! fully offline — see DESIGN.md §2): JSON, a seeded PRNG, a micro-bench
//! harness and a tiny property-testing helper.

pub mod benchkit;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;

pub use hash::{fnv1a_64, ContentHash, Fnv64};
pub use json::{f64_from_bits_json, f64_to_bits_json, u64_from_str_json, u64_to_str_json, Json};
pub use rng::Rng;
