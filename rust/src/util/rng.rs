//! Seeded PRNG (xoshiro256**): deterministic workload generation for tests,
//! property checks and benches. Not cryptographic.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Lemire's multiply-shift rejection method.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[-1, 1)`.
    pub fn f32_pm1(&mut self) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32
    }

    /// Standard normal N(0, 1) via Box-Muller (one draw per call; the
    /// sibling variate is discarded so the stream stays a pure function of
    /// call count, which keeps replays bit-identical under refactors).
    pub fn gaussian(&mut self) -> f64 {
        // u1 in (0, 1]: flip the [0,1) draw so ln never sees zero
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Vec of n uniform f32 in [-1, 1).
    pub fn vecf32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32_pm1()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
        // all residues hit eventually
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gaussian();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
