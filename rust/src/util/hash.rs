//! Stable content hashing for the evaluation cache.
//!
//! `std::hash` is explicitly *not* stable across releases/platforms, and the
//! service's content-addressed cache keys must mean the same thing in every
//! process that computes them (a client may precompute a key, a disk dump may
//! outlive a binary). FNV-1a is tiny, allocation-free and bit-stable; two
//! independently seeded 64-bit lanes give a 128-bit key, which is plenty for
//! a cache that only ever holds thousands of entries (not adversarial input).

use std::fmt;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
/// Arbitrary odd constant decorrelating the second lane from the first.
const LANE2_SEED: u64 = 0x9e37_79b9_7f4a_7c15;

/// Incremental FNV-1a over byte slices.
#[derive(Debug, Clone, Copy)]
pub struct Fnv64 {
    state: u64,
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64 { state: FNV_OFFSET }
    }

    pub fn with_seed(seed: u64) -> Self {
        Fnv64 { state: FNV_OFFSET ^ seed }
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// One-shot FNV-1a of a byte slice.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(bytes);
    h.finish()
}

/// A 128-bit stable content hash (two seeded FNV-1a lanes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ContentHash(pub u128);

impl ContentHash {
    /// Hash a sequence of labeled parts. Each part is fed with a 0xFF
    /// terminator so `["ab", "c"]` and `["a", "bc"]` cannot collide.
    pub fn of_parts(parts: &[&str]) -> ContentHash {
        let mut lo = Fnv64::new();
        let mut hi = Fnv64::with_seed(LANE2_SEED);
        for p in parts {
            lo.write(p.as_bytes());
            lo.write(&[0xFF]);
            hi.write(p.as_bytes());
            hi.write(&[0xFF]);
        }
        ContentHash(((hi.finish() as u128) << 64) | lo.finish() as u128)
    }

    /// 32-hex-digit rendering (the `key` field of service responses).
    pub fn to_hex(self) -> String {
        format!("{:032x}", self.0)
    }

    /// Inverse of [`ContentHash::to_hex`]: parse exactly 32 hex digits.
    /// Wire payloads (gossiped journal records, precomputed `key` fields)
    /// carry keys in hex; anything else is `None`, never a panic.
    pub fn from_hex(hex: &str) -> Option<ContentHash> {
        if hex.len() != 32 || !hex.bytes().all(|b| b.is_ascii_hexdigit()) {
            return None;
        }
        u128::from_str_radix(hex, 16).ok().map(ContentHash)
    }
}

impl fmt::Display for ContentHash {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:032x}", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a_64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a_64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn parts_are_prefix_free() {
        assert_ne!(ContentHash::of_parts(&["ab", "c"]), ContentHash::of_parts(&["a", "bc"]));
        assert_ne!(ContentHash::of_parts(&["ab"]), ContentHash::of_parts(&["ab", ""]));
        assert_eq!(ContentHash::of_parts(&["x", "y"]), ContentHash::of_parts(&["x", "y"]));
    }

    #[test]
    fn hex_is_32_digits() {
        let h = ContentHash::of_parts(&["hello"]);
        let hex = h.to_hex();
        assert_eq!(hex.len(), 32);
        assert_eq!(hex, h.to_string());
    }

    #[test]
    fn hex_round_trips_and_rejects_garbage() {
        let h = ContentHash::of_parts(&["hello"]);
        assert_eq!(ContentHash::from_hex(&h.to_hex()), Some(h));
        for probe in [ContentHash(0), ContentHash(u128::MAX)] {
            assert_eq!(ContentHash::from_hex(&probe.to_hex()), Some(probe));
        }
        assert_eq!(ContentHash::from_hex("too short"), None);
        assert_eq!(ContentHash::from_hex(&"f".repeat(33)), None);
        assert_eq!(ContentHash::from_hex(&"g".repeat(32)), None);
    }
}
