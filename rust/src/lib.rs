//! # Olympus — Platform-Aware FPGA System Architecture Generation based on MLIR
//!
//! Reproduction of Soldavini & Pilato (2023). The crate implements:
//!
//! * an MLIR-subset IR core ([`ir`]) with a parser/printer for the generic
//!   operation syntax used by the paper's Figures 1–2;
//! * the Olympus dialect ([`dialect`]): `olympus.make_channel`,
//!   `olympus.kernel`, `olympus.pc` and the `!olympus.channel<iN>` type;
//! * analyses ([`analysis`]) and transformation passes ([`passes`]) —
//!   sanitize, channel reassignment, replication, bus widening, the Iris
//!   bus optimization and Mnemosyne-style PLM sharing;
//! * a pluggable design-space-search framework ([`search`]): search spaces
//!   over pipeline schedules, two-fidelity evaluators and budgeted drivers
//!   (exhaustive, seeded random, successive-halving multi-fidelity,
//!   iterative greedy);
//! * platform models ([`platform`]) for the Xilinx Alveo U280 and friends;
//! * a hardware lowering ([`lower`]) producing an architecture netlist,
//!   Vitis `.cfg`, Verilog stubs and a generated host API;
//! * a cycle-approximate platform simulator ([`sim`]) standing in for the
//!   Alveo card, plus a host runtime ([`host`]);
//! * a deterministic discrete-event queueing simulator ([`des`]) scoring
//!   architectures under contention + workload scenarios (the `des-score`
//!   DSE objective);
//! * production traffic modeling ([`traffic`]): heavy-tailed service +
//!   diurnal arrivals, checksummed trace replay with priority classes and
//!   deadlines, per-class p99 / deadline-miss reporting, an in-DES elastic
//!   replica autoscaler, and the SLO-aware `slo-score` DSE objective;
//! * a PJRT runtime ([`runtime`]) that loads AOT-compiled JAX/Pallas kernels
//!   (HLO text in `artifacts/`) and executes them for kernel compute units;
//! * a concurrent DSE job service ([`service`]): `olympus serve` daemon with
//!   a newline-delimited-JSON TCP protocol, a std-thread worker pool, a
//!   content-addressed single-flight evaluation cache (memory + on-disk
//!   journal tiers), and distributed evaluation — `olympus worker` daemons
//!   each own a rendezvous-hash shard of the candidate key space and a
//!   coordinator (`serve --workers`) routes evaluations to shard owners
//!   with local failover ([`service::remote`]);
//! * observability ([`obs`]): a leveled structured JSON logger, a
//!   process-wide metrics registry (latency histograms, per-verb counters,
//!   DES throughput) behind the `metrics` proto verb / `olympus stats`, and
//!   Chrome-trace export of DES timelines (`olympus des --trace`) — all
//!   zero-perturbation: results are bit-identical with it on or off.
//!
//! See `DESIGN.md` for the paper → module map.

pub mod analysis;
pub mod coordinator;
pub mod des;
pub mod dialect;
pub mod host;
pub mod ir;
pub mod iris;
pub mod lower;
pub mod mnemosyne;
pub mod obs;
pub mod passes;
pub mod platform;
pub mod runtime;
pub mod search;
pub mod service;
pub mod sim;
pub mod traffic;
pub mod util;
pub mod workload;
