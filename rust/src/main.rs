//! `olympus` — the Fig 3 flow CLI.
//!
//! ```text
//! olympus platforms
//! olympus opt   <file.mlir> [--platform u280] [--pipeline "sanitize,iris"]
//! olympus dse   <file.mlir> [--platform u280 | --platforms u280,generic-ddr,...]
//!               [--objective analytic|des-score|slo-score]
//!               [--slo "CLASS=p99<MS,..."] [--jobs N]
//!               [--driver exhaustive|random|successive-halving|iterative]
//!               [--budget N] [--search-seed N] [--cache-dir DIR]
//! olympus des   <file.mlir> [--platform u280] [--pipeline ...] [--scenario SPEC] [--seed N]
//!               [--slo "CLASS=p99<MS,..."] [--autoscale IV:UP:DOWN:MIN:MAX]
//!               [--service-dist DIST] [--cache-dir DIR] [--trace trace.json]
//! olympus lower <file.mlir> [--platform u280] [--pipeline ...] [--out DIR]
//! olympus run   <file.mlir> [--platform u280] [--pipeline ...] [--artifacts DIR] [--seed N]
//! olympus serve [--addr 127.0.0.1:7878] [--jobs N] [--cache-capacity N] [--cache-dir DIR]
//!               [--workers host:port,host:port,...]
//! olympus worker [--addr 127.0.0.1:7900] [--jobs N] [--cache-capacity N] [--cache-dir DIR]
//! olympus submit <file.mlir> [--addr ...] [--cmd dse|des|flow] [--platform ...]
//!               [--priority N] [--deadline-ms N] [...]
//! olympus join  <worker host:port> [--addr coordinator]
//! olympus leave <worker host:port> [--addr coordinator]
//! olympus cache-stats [--addr ...]
//! olympus stats [host:port] [--raw]
//! ```
//!
//! Every subcommand accepts `--log-level off|error|warn|info|debug`
//! (default `info`, or the `OLYMPUS_LOG` env var): structured JSON event
//! lines on stderr. Logging is pure observability — results are
//! bit-identical at every level.
//!
//! `des` replays the lowered design through the discrete-event queueing
//! simulator. `--scenario` specs: `closed:<jobs>`, `poisson:<hz>:<jobs>`,
//! `bursty:<hz>:<on_s>:<off_s>:<jobs>`,
//! `diurnal:<hz>:<amplitude>:<period_s>:<jobs>`, or `trace:<file>` to
//! replay a recorded production trace with per-job classes, priorities and
//! deadlines (default `closed:4`). `--service-dist` picks the CU service
//! distribution (`deterministic | exponential | lognormal:SIGMA |
//! pareto:ALPHA`); `--autoscale` runs an elastic-replica controller inside
//! the simulation; `--slo` scores design-space candidates by SLO
//! violations (p99 targets + deadline misses) instead of raw makespan —
//! see README "Production traffic & SLOs".
//!
//! `dse --platforms` (also accepted by searching `des` runs and `submit`)
//! makes the platform itself a search axis: every strategy is scored on
//! every listed platform, the table shows `platform/strategy` rows plus
//! one `best[platform]` line per platform, and the flow lowers onto the
//! overall winner — see README "Platforms & back-ends".
//!
//! `run` executes the lowered design on the platform simulator with seeded
//! random host buffers and prints the simulation report.
//!
//! `serve` runs the long-lived DSE job service (newline-delimited JSON over
//! TCP, worker pool, content-addressed evaluation cache — see README
//! "Running as a service"); `submit` is the matching thin client.
//! `--cache-dir` persists the evaluation caches to disk: a restarted
//! daemon (and repeated single-shot `dse`/`des` runs) answers previously
//! evaluated work from the journal instead of recomputing it.
//!
//! `stats` queries a daemon's `metrics` verb and renders one fleet-wide
//! table: the coordinator plus every remote worker it is configured with,
//! including response-shard routing (`rshard`) and journal-gossip
//! (`g_sent`/`g_recv`) columns (`--raw` prints the aggregated JSON
//! instead, for scripts and CI).
//! `des --trace FILE` additionally exports the DES timeline as Chrome
//! trace-event JSON, viewable in Perfetto — see README "Observability".
//!
//! `worker` runs a remote evaluation daemon, and `serve --workers` turns a
//! daemon into the coordinator of that fleet: whole jobs route to the
//! worker owning each response key's rendezvous-hash shard, DSE candidate
//! evaluations route the same way one level down, and workers gossip their
//! persisted journals to each other so a rebuilt worker re-warms from its
//! neighbors. `join`/`leave` resize the fleet at runtime (re-rendezvoused
//! shard map under a bumped epoch, no restart) — see README "Distributed
//! evaluation" and PROTOCOL.md for the wire format. (clap is not vendored
//! in this offline build; argument parsing is hand-rolled.)

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use olympus::coordinator::{render_dse_table, run_flow};
use olympus::dialect::{ChannelView, ParamType};
use olympus::host::Device;
use olympus::ir::{parse_module, print_module, Module};
use olympus::platform::{builtin, builtin_names, PlatformSpec};
use olympus::runtime::{KernelRegistry, PjrtRuntime};
use olympus::util::{Json, Rng};

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Args { positional, flags }
}

/// Parse + validate `--platforms` (the cross-platform search axis): a
/// comma-separated list of builtin names or JSON platform files. Two or
/// more entries make the platform itself a search dimension — the DSE
/// scores every strategy on every listed platform and the flow lowers
/// onto the winner. Mutually exclusive with `--platform`; duplicates are
/// rejected (they would only pad the table with identical rows). `None`
/// when the flag is absent.
fn load_platforms(args: &Args) -> Result<Option<Vec<PlatformSpec>>> {
    let Some(list) = args.flags.get("platforms") else { return Ok(None) };
    if args.flags.contains_key("platform") {
        bail!(
            "--platform and --platforms are mutually exclusive; --platforms searches the \
             listed platforms and lowers onto the winner"
        );
    }
    let mut specs: Vec<PlatformSpec> = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for name in list.split(',').map(|s| s.trim()).filter(|s| !s.is_empty()) {
        let spec = match builtin(name) {
            Some(p) => p,
            None => PlatformSpec::load(Path::new(name)).with_context(|| {
                format!(
                    "--platforms entry '{name}' is neither a builtin ({:?}) nor a readable \
                     platform file",
                    builtin_names()
                )
            })?,
        };
        if !seen.insert(spec.name.clone()) {
            bail!("--platforms lists platform '{}' more than once", spec.name);
        }
        specs.push(spec);
    }
    if specs.is_empty() {
        bail!("--platforms names no platforms (e.g. --platforms u280,generic-ddr)");
    }
    Ok(Some(specs))
}

fn load_platform(args: &Args) -> Result<PlatformSpec> {
    let name = args.flags.get("platform").map(|s| s.as_str()).unwrap_or("u280");
    if let Some(p) = builtin(name) {
        return Ok(p);
    }
    // not a builtin: treat as a JSON platform file (Fig 3 "platform info")
    PlatformSpec::load(Path::new(name)).with_context(|| {
        format!(
            "'{name}' is neither a builtin ({:?}) nor a readable platform file",
            builtin_names()
        )
    })
}

fn load_module(path: &str) -> Result<Module> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("read input IR '{path}'"))?;
    let m = parse_module(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let errs = olympus::ir::verify_module(&m);
    if !errs.is_empty() {
        bail!("{path}: structural verification failed: {errs:?}");
    }
    let derrs = olympus::dialect::verify_dialect(&m, false);
    if !derrs.is_empty() {
        bail!("{path}: dialect verification failed: {derrs:?}");
    }
    Ok(m)
}

fn usage() -> ! {
    eprintln!(
        "usage: olympus <platforms|opt|dse|des|lower|run|serve|worker|submit|join|leave|\
         cache-stats|stats> \
         [input.mlir] [--platform NAME|file.json] [--platforms NAME,NAME,...] [--pipeline P] \
         [--objective analytic|des-score|slo-score] [--slo CLASS=p99<MS,...] \
         [--driver exhaustive|random|successive-halving|iterative] [--budget N] \
         [--search-seed N] \
         [--scenario closed:N|poisson:HZ:N|bursty:HZ:ON:OFF:N|diurnal:HZ:AMP:PERIOD:N|trace:FILE] \
         [--service-dist deterministic|exponential|lognormal:SIGMA|pareto:ALPHA] \
         [--calendar wheel|heap] \
         [--autoscale INTERVAL_S:UP:DOWN:MIN:MAX] [--priority N] [--deadline-ms N] [--out DIR] \
         [--artifacts DIR] [--seed N] [--jobs N] [--addr HOST:PORT] [--factors 2,4] \
         [--cache-dir DIR] [--workers HOST:PORT,...] [--trace FILE] \
         [--log-level off|error|warn|info|debug]"
    );
    std::process::exit(2)
}

/// Parse + validate `--factors`: entries must be integers >= 1, the list
/// must not be empty, and it is normalized (sorted, deduplicated) so
/// `--factors 4,2,2` addresses the same search space — and the same cache
/// keys — as `--factors 2,4`. `None` when the flag is absent.
fn factors_from_args(args: &Args) -> Result<Option<Vec<u64>>> {
    let Some(fs) = args.flags.get("factors") else { return Ok(None) };
    let mut factors = Vec::new();
    for part in fs.split(',').map(|s| s.trim()).filter(|s| !s.is_empty()) {
        factors.push(part.parse::<u64>().with_context(|| {
            format!("--factors wants integers >= 1 (e.g. 2,4), got '{part}'")
        })?);
    }
    if factors.is_empty() {
        bail!("--factors was given but names no factors (e.g. --factors 2,4)");
    }
    let factors = olympus::search::normalize_factors(&factors).map_err(|e| anyhow::anyhow!(e))?;
    Ok(Some(factors))
}

/// Flags that configure the design-space search. They only mean something
/// to the searching commands (`dse`, and `des` without an explicit
/// pipeline); anywhere else they would be silently dead, so
/// [`reject_search_flags`] turns them into loud errors.
const SEARCH_FLAGS: [&str; 5] = ["driver", "budget", "search-seed", "factors", "platforms"];

/// Reject any search flag present in `args`; `context` explains why the
/// flags are dead here (e.g. which command, or "with an explicit
/// --pipeline").
fn reject_search_flags(args: &Args, context: &str) -> Result<()> {
    for flag in SEARCH_FLAGS {
        if args.flags.contains_key(flag) {
            bail!("--{flag} configures the design-space search and is not supported {context}");
        }
    }
    Ok(())
}

/// Build the search driver from `--driver` / `--budget` / `--search-seed`.
fn driver_from_args(args: &Args) -> Result<olympus::search::DriverKind> {
    let name = args.flags.get("driver").map(|s| s.as_str()).unwrap_or("exhaustive");
    let budget = match args.flags.get("budget") {
        Some(v) => Some(v.parse::<usize>().context("--budget wants a candidate count")?),
        None => None,
    };
    let seed = match args.flags.get("search-seed") {
        Some(v) => Some(v.parse::<u64>().context("--search-seed wants an integer")?),
        None => None,
    };
    olympus::search::DriverKind::from_flags(name, budget, seed).map_err(|e| anyhow::anyhow!(e))
}

/// Parse a `--scenario` spec (see the crate docs above). `trace:<file>`
/// specs resolve against the local filesystem.
fn parse_scenario(spec: &str) -> Result<olympus::des::WorkloadScenario> {
    olympus::traffic::scenario_from_spec(spec).map_err(|e| anyhow::anyhow!(e))
}

/// Parse `--slo` when present.
fn slo_from_args(args: &Args) -> Result<Option<olympus::traffic::SloSpec>> {
    match args.flags.get("slo") {
        Some(s) => olympus::traffic::SloSpec::parse(s).map(Some).map_err(|e| anyhow::anyhow!(e)),
        None => Ok(None),
    }
}

/// Parse `--seed`: a bad value is a loud, contextual error — silently
/// falling back to a default seed would make a run irreproducible without
/// any hint why.
fn seed_from_args(args: &Args) -> Result<Option<u64>> {
    match args.flags.get("seed") {
        Some(s) => s
            .parse::<u64>()
            .map(Some)
            .with_context(|| format!("--seed wants a non-negative integer, got '{s}'")),
        None => Ok(None),
    }
}

/// Shared `--scenario` / `--seed` handling for the DES-facing commands.
fn scenario_and_config(
    args: &Args,
) -> Result<(olympus::des::WorkloadScenario, olympus::des::DesConfig)> {
    let scenario = match args.flags.get("scenario") {
        Some(s) => parse_scenario(s)?,
        None => olympus::des::WorkloadScenario::closed_loop(4),
    };
    let mut cfg = olympus::des::DesConfig::default();
    if let Some(seed) = seed_from_args(args)? {
        cfg.seed = seed;
    }
    if let Some(spec) = args.flags.get("service-dist") {
        cfg.service_dist =
            olympus::des::ServiceDist::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(spec) = args.flags.get("autoscale") {
        cfg.autoscale = Some(
            olympus::traffic::AutoscalePolicy::parse(spec).map_err(|e| anyhow::anyhow!(e))?,
        );
    }
    if let Some(spec) = args.flags.get("calendar") {
        cfg.calendar = olympus::des::CalendarKind::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
    }
    Ok((scenario, cfg))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let cmd = argv[0].clone();
    let args = parse_args(&argv[1..]);
    if let Some(spec) = args.flags.get("log-level") {
        match olympus::obs::Level::parse(spec) {
            Some(l) => olympus::obs::set_level(l),
            None => bail!("--log-level wants off|error|warn|info|debug, got '{spec}'"),
        }
    }
    match cmd.as_str() {
        "platforms" => {
            for n in builtin_names() {
                let p = builtin(n).unwrap();
                println!(
                    "{:<14} {:>3} mem channels, {:>7.1} GB/s peak, {}",
                    p.name,
                    p.num_pcs(),
                    p.total_bandwidth_gbs(),
                    p.resources
                );
            }
            Ok(())
        }
        "opt" => {
            reject_search_flags(&args, "by 'opt' (only 'dse' and 'des' search)")?;
            let input = args.positional.first().unwrap_or_else(|| usage());
            let m = load_module(input)?;
            let plat = load_platform(&args)?;
            let pipeline = args.flags.get("pipeline").map(|s| s.as_str());
            let r = run_flow(m, &plat, pipeline)?;
            for rec in &r.records {
                let remarks: Vec<Json> =
                    rec.remarks.iter().map(|m| m.as_str().into()).collect();
                olympus::obs::info(
                    "pass",
                    &[
                        ("name", rec.name.into()),
                        ("changed", rec.changed.into()),
                        ("remarks", Json::Arr(remarks)),
                    ],
                );
            }
            print!("{}", print_module(&r.module));
            Ok(())
        }
        "dse" => {
            let input = args.positional.first().unwrap_or_else(|| usage());
            let m = load_module(input)?;
            let mut flow = match load_platforms(&args)? {
                Some(specs) => {
                    olympus::coordinator::Flow::new(specs[0].clone()).with_platforms(specs)
                }
                None => olympus::coordinator::Flow::new(load_platform(&args)?),
            };
            if let Some(jobs) = args.flags.get("jobs") {
                flow = flow.with_jobs(jobs.parse().context("--jobs wants a thread count")?);
            }
            if let Some(factors) = factors_from_args(&args)? {
                flow.dse_factors = factors;
            }
            flow = flow.with_driver(driver_from_args(&args)?);
            match args.flags.get("objective").map(|s| s.as_str()) {
                Some("des-score") => {
                    if args.flags.contains_key("slo") {
                        bail!("--slo only scores under --objective slo-score");
                    }
                    let (scenario, cfg) = scenario_and_config(&args)?;
                    flow = flow.with_objective(olympus::passes::DseObjective::des_score_with(
                        scenario, cfg,
                    ));
                }
                Some("slo-score") => {
                    let (scenario, cfg) = scenario_and_config(&args)?;
                    let slo = slo_from_args(&args)?.ok_or_else(|| {
                        anyhow::anyhow!(
                            "--objective slo-score requires --slo \"CLASS=p99<MS[,...]\" \
                             (`*` targets all classes)"
                        )
                    })?;
                    flow = flow.with_objective(olympus::passes::DseObjective::slo_score_with(
                        scenario, cfg, slo,
                    ));
                }
                // the analytic objective replays nothing: reject the DES
                // flags instead of silently ignoring them
                None | Some("analytic") => {
                    for flag in ["scenario", "seed", "slo", "autoscale", "service-dist", "calendar"]
                    {
                        if args.flags.contains_key(flag) {
                            bail!(
                                "--{flag} only configures the des-score/slo-score objectives; \
                                 add --objective des-score|slo-score or drop --{flag}"
                            );
                        }
                    }
                }
                Some(other) => {
                    bail!("unknown objective '{other}' (want analytic | des-score | slo-score)")
                }
            }
            if let Some(dir) = args.flags.get("cache-dir") {
                flow = flow.with_cache_dir(Path::new(dir))?;
            }
            let r = flow.run(m, "app")?;
            print!("{}", render_dse_table(r.dse.as_ref().unwrap()));
            Ok(())
        }
        "des" => {
            let input = args.positional.first().unwrap_or_else(|| usage());
            if args.flags.contains_key("objective") {
                // the DES command always scores with the DES (or its SLO
                // penalty): an --objective here would be silently dead
                bail!(
                    "--objective is fixed by 'des' (des-score, or slo-score with --slo); \
                     use 'dse --objective ...' to choose"
                );
            }
            let m = load_module(input)?;
            let pipeline = args.flags.get("pipeline").map(|s| s.as_str());
            let (scenario, cfg) = scenario_and_config(&args)?;
            let slo = slo_from_args(&args)?;
            let mut flow = match load_platforms(&args)? {
                Some(specs) => {
                    olympus::coordinator::Flow::new(specs[0].clone()).with_platforms(specs)
                }
                None => olympus::coordinator::Flow::new(load_platform(&args)?),
            }
            .with_scenario(scenario.clone());
            flow.des_config = cfg.clone();
            match pipeline {
                Some(p) => {
                    // an explicit pipeline skips the DSE entirely: search
                    // flags would be silently dead, so reject them instead
                    reject_search_flags(
                        &args,
                        "with an explicit --pipeline (drop --pipeline to search)",
                    )?;
                    if args.flags.contains_key("cache-dir") {
                        bail!(
                            "--cache-dir warms the design-space search and is not supported \
                             with an explicit --pipeline (drop --pipeline to search)"
                        );
                    }
                    if slo.is_some() {
                        bail!(
                            "--slo scores design-space candidates; drop --pipeline to search \
                             (the replay report prints per-class latency either way)"
                        );
                    }
                    flow = flow.with_pipeline(p);
                }
                // no explicit pipeline: the DSE picks the design, and for a
                // DES-centric command it scores candidates with the DES too
                // (by SLO violations instead of makespan when --slo is given)
                None => {
                    if let Some(factors) = factors_from_args(&args)? {
                        flow.dse_factors = factors;
                    }
                    let objective = match slo {
                        Some(slo) => olympus::passes::DseObjective::slo_score_with(
                            scenario, cfg, slo,
                        ),
                        None => olympus::passes::DseObjective::des_score_with(scenario, cfg),
                    };
                    flow = flow.with_objective(objective).with_driver(driver_from_args(&args)?);
                    if let Some(dir) = args.flags.get("cache-dir") {
                        flow = flow.with_cache_dir(Path::new(dir))?;
                    }
                }
            }
            if let Some(f) = args.flags.get("trace") {
                flow = flow.with_trace(Path::new(f));
            }
            let r = flow.run(m, "app")?;
            if let Some(dse) = &r.dse {
                print!("{}", render_dse_table(dse));
            }
            print!("{}", r.des.as_ref().expect("scenario was set"));
            Ok(())
        }
        "lower" => {
            reject_search_flags(&args, "by 'lower' (only 'dse' and 'des' search)")?;
            let input = args.positional.first().unwrap_or_else(|| usage());
            let m = load_module(input)?;
            let plat = load_platform(&args)?;
            let pipeline = args.flags.get("pipeline").map(|s| s.as_str());
            let out = PathBuf::from(args.flags.get("out").cloned().unwrap_or("out".into()));
            std::fs::create_dir_all(&out)?;
            let r = run_flow(m, &plat, pipeline)?;
            std::fs::write(out.join("design.mlir"), print_module(&r.module))?;
            std::fs::write(out.join("link.cfg"), &r.cfg)?;
            std::fs::write(out.join("olympus_top.v"), &r.verilog)?;
            std::fs::write(out.join("host_driver.rs"), &r.driver)?;
            std::fs::write(
                out.join("report.json"),
                olympus::coordinator::flow_report_json(&r).to_string(),
            )?;
            println!(
                "wrote design.mlir, link.cfg, olympus_top.v, host_driver.rs, report.json to {}",
                out.display()
            );
            println!(
                "bandwidth: {:.1}% efficient, {:.2} GB/s achievable; resources: {:.1}% ({})",
                r.bandwidth.aggregate_efficiency * 100.0,
                r.bandwidth.achieved_gbs,
                r.resources.utilization * 100.0,
                r.resources.binding
            );
            Ok(())
        }
        "run" => {
            reject_search_flags(&args, "by 'run' (only 'dse' and 'des' search)")?;
            let input = args.positional.first().unwrap_or_else(|| usage());
            let m = load_module(input)?;
            let plat = load_platform(&args)?;
            let pipeline = args.flags.get("pipeline").map(|s| s.as_str());
            let artifacts =
                PathBuf::from(args.flags.get("artifacts").cloned().unwrap_or("artifacts".into()));
            let seed: u64 = seed_from_args(&args)?.unwrap_or(42);

            // channel payload sizes (for synthetic host buffers), pre-opt
            let mut sizes: Vec<(String, usize)> = Vec::new();
            {
                let mut sane = m.clone();
                let mut ctx = olympus::passes::PassContext::new(plat.clone());
                olympus::passes::parse_pipeline("sanitize", &mut ctx)?.run(&mut sane, &ctx)?;
                for ch in ChannelView::all(&sane) {
                    let name =
                        sane.op(ch.op).str_attr("name").unwrap_or("ch").to_string();
                    let elems = match ch.param_type(&sane) {
                        Some(ParamType::Complex) => (ch.depth(&sane) / 4).max(1) as usize,
                        _ => ch.depth(&sane) as usize,
                    };
                    sizes.push((name, elems));
                }
            }

            let r = run_flow(m, &plat, pipeline)?;
            let rt = Arc::new(PjrtRuntime::cpu()?);
            let registry = KernelRegistry::load(rt, &artifacts)?;
            let mut dev = Device::program(r.arch.clone(), registry)?;
            dev.set_utilization(r.resources.utilization);
            let mut rng = Rng::new(seed);
            let names: Vec<String> =
                dev.channel_names().iter().map(|s| s.to_string()).collect();
            for name in &names {
                // feed every read-side channel (clones included)
                let base = name.split('.').next().unwrap_or(name);
                if let Some((_, elems)) = sizes.iter().find(|(n, _)| n == base || n == name) {
                    let data = rng.vecf32(*elems);
                    let _ = dev.write_buffer(name, &data);
                }
            }
            let metrics = dev.run()?;
            println!("{metrics}");
            for name in &names {
                if let Ok(out) = dev.read_buffer(name) {
                    let sum: f32 = out.iter().sum();
                    println!("output '{name}': {} elems, checksum {sum:.4}", out.len());
                }
            }
            Ok(())
        }
        "serve" | "worker" => {
            // the daemon's search behavior comes from each request's
            // fields, not from startup flags
            reject_search_flags(
                &args,
                &format!("by '{cmd}' (send driver/budget/factors per request)"),
            )?;
            use olympus::service::{ServeOptions, Server};
            // distinct defaults so a laptop coordinator + worker don't
            // collide; both honor an explicit --addr
            let default_addr = if cmd == "worker" { "127.0.0.1:7900" } else { "127.0.0.1:7878" };
            let addr = args.flags.get("addr").cloned().unwrap_or_else(|| default_addr.into());
            let parse_n = |key: &str, default: usize| -> Result<usize> {
                match args.flags.get(key) {
                    Some(v) => v.parse().with_context(|| format!("--{key} wants a number")),
                    None => Ok(default),
                }
            };
            let remote_workers: Vec<String> = match args.flags.get("workers") {
                None => Vec::new(),
                Some(_) if cmd == "worker" => bail!(
                    "--workers configures the coordinator ('olympus serve'); \
                     a worker evaluates locally"
                ),
                Some(list) => {
                    let addrs: Vec<String> = list
                        .split(',')
                        .map(|s| s.trim().to_string())
                        .filter(|s| !s.is_empty())
                        .collect();
                    if addrs.is_empty() {
                        bail!("--workers names no addresses (e.g. --workers h1:7900,h2:7900)");
                    }
                    addrs
                }
            };
            let opts = ServeOptions {
                workers: parse_n("jobs", 0)?,
                cache_capacity: parse_n("cache-capacity", 0)?,
                dse_threads: parse_n("dse-threads", 1)?,
                cache_dir: args.flags.get("cache-dir").map(PathBuf::from),
                remote_workers,
            };
            let server = Server::bind(&addr, opts)?;
            // the address line is the startup handshake scripts wait for
            // (stdout is line-buffered, so it flushes even into a pipe)
            let banner = if cmd == "worker" { "olympus-worker" } else { "olympus-serve" };
            println!("{banner} listening on {}", server.addr());
            server.wait();
            Ok(())
        }
        "submit" => {
            let input = args.positional.first().unwrap_or_else(|| usage());
            let ir = std::fs::read_to_string(input)
                .with_context(|| format!("read input IR '{input}'"))?;
            let cmd = args.flags.get("cmd").cloned().unwrap_or_else(|| "dse".to_string());
            let mut fields: Vec<(&str, Json)> =
                vec![("cmd", cmd.as_str().into()), ("ir", ir.into())];
            if let Some(p) = args.flags.get("platform") {
                if builtin(p).is_some() {
                    fields.push(("platform", p.as_str().into()));
                } else {
                    // custom board: ship the full spec inline
                    let spec = PlatformSpec::load(Path::new(p))?;
                    fields.push(("platform_json", spec.to_json()));
                }
            }
            if let Some(list) = args.flags.get("platforms") {
                if args.flags.contains_key("platform") {
                    bail!(
                        "--platform and --platforms are mutually exclusive; --platforms \
                         searches the listed platforms and lowers onto the winner"
                    );
                }
                // the wire carries names, so only builtins can ride the
                // axis; a custom board ships its one spec via --platform
                let mut names: Vec<Json> = Vec::new();
                let mut seen = std::collections::BTreeSet::new();
                for name in list.split(',').map(|s| s.trim()).filter(|s| !s.is_empty()) {
                    if builtin(name).is_none() {
                        bail!(
                            "--platforms entry '{name}' is not a builtin ({:?}); submitted \
                             platform axes carry builtin names only — use --platform \
                             file.json for a single custom board",
                            builtin_names()
                        );
                    }
                    if !seen.insert(name.to_string()) {
                        bail!("--platforms lists platform '{name}' more than once");
                    }
                    names.push(name.into());
                }
                if names.is_empty() {
                    bail!("--platforms names no platforms (e.g. --platforms u280,generic-ddr)");
                }
                fields.push(("platforms", Json::Arr(names)));
            }
            for key in ["pipeline", "objective", "driver", "slo", "autoscale"] {
                if let Some(v) = args.flags.get(key) {
                    fields.push((key, v.as_str().into()));
                }
            }
            if let Some(spec) = args.flags.get("scenario") {
                if spec.starts_with("trace:") {
                    // resolve the trace against the *client's* filesystem and
                    // ship the jobs inline; the daemon never sees the file,
                    // and the response key depends only on trace content
                    let sc = parse_scenario(spec)?;
                    fields.push(("scenario_json", sc.to_json()));
                } else {
                    fields.push(("scenario", spec.as_str().into()));
                }
            }
            if let Some(p) = args.flags.get("priority") {
                let p: u64 = p.parse().context("--priority wants a non-negative integer")?;
                fields.push(("priority", p.into()));
            }
            if let Some(d) = args.flags.get("deadline-ms") {
                let d: u64 = d.parse().context("--deadline-ms wants milliseconds")?;
                fields.push(("deadline_ms", d.into()));
            }
            if let Some(seed) = args.flags.get("seed") {
                let seed: u64 = seed.parse().context("--seed wants an integer")?;
                fields.push(("seed", seed.into()));
            }
            if let Some(budget) = args.flags.get("budget") {
                let budget: u64 = budget.parse().context("--budget wants a candidate count")?;
                fields.push(("budget", budget.into()));
            }
            if let Some(seed) = args.flags.get("search-seed") {
                let seed: u64 = seed.parse().context("--search-seed wants an integer")?;
                fields.push(("search_seed", seed.into()));
            }
            if let Some(factors) = factors_from_args(&args)? {
                fields.push(("factors", factors.into()));
            }
            let v = roundtrip(&args, Json::obj(fields))?;
            if args.flags.contains_key("raw") {
                println!("{v}");
                return Ok(());
            }
            let result = v.get("result");
            if let Some(table) = result.get("table").as_str() {
                print!("{table}");
            }
            if let Some(report) = result.get("des_report").as_str() {
                print!("{report}");
            }
            if result.get("table").as_str().is_none() && result.get("des_report").as_str().is_none()
            {
                println!("{result}");
            }
            if v.get("cached") == &Json::Bool(true) {
                olympus::obs::info("served-from-cache", &[("key", v.get("key").clone())]);
            }
            Ok(())
        }
        "join" | "leave" => {
            reject_search_flags(&args, &format!("by '{cmd}'"))?;
            let worker = args.positional.first().unwrap_or_else(|| usage());
            let v = roundtrip(
                &args,
                Json::obj(vec![("cmd", cmd.as_str().into()), ("worker", worker.as_str().into())]),
            )?;
            let result = v.get("result");
            let members: Vec<String> = result
                .get("workers")
                .as_arr()
                .map(|ws| ws.iter().filter_map(|w| w.as_str().map(str::to_string)).collect())
                .unwrap_or_default();
            println!(
                "{cmd} {worker}: shard map epoch {} over {} worker(s) [{}]",
                result.get("epoch").as_u64().unwrap_or(0),
                result.get("total").as_u64().unwrap_or(0),
                members.join(", ")
            );
            Ok(())
        }
        "cache-stats" => {
            reject_search_flags(&args, "by 'cache-stats'")?;
            let v = roundtrip(&args, Json::obj(vec![("cmd", "cache-stats".into())]))?;
            println!("{}", v.get("result"));
            Ok(())
        }
        "stats" => {
            reject_search_flags(&args, "by 'stats'")?;
            run_stats(&args)
        }
        _ => usage(),
    }
}

/// Send one request line to the service named by `--addr` (default
/// coordinator port) and parse the response.
fn roundtrip(args: &Args, request: Json) -> Result<Json> {
    let addr = args.flags.get("addr").map(|s| s.as_str()).unwrap_or("127.0.0.1:7878");
    roundtrip_addr(addr, request)
}

/// Send one request line to the service at `addr` and parse the response,
/// failing loudly on protocol-level errors.
fn roundtrip_addr(addr: &str, request: Json) -> Result<Json> {
    use std::io::{BufRead, BufReader, Write};
    let mut stream = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connect to olympus-serve at {addr}"))?;
    stream.write_all(request.to_string().as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).context("read response")?;
    let v = Json::parse(line.trim())
        .map_err(|e| anyhow::anyhow!("malformed response from service: {e}"))?;
    if v.get("ok") != &Json::Bool(true) {
        bail!(
            "service error [{}]: {}",
            v.get("error").get("code").as_str().unwrap_or("?"),
            v.get("error").get("message").as_str().unwrap_or("?")
        );
    }
    Ok(v)
}

/// `olympus stats [host:port] [--raw]`: query the coordinator's `metrics`
/// verb, fan out to every remote worker it reports, and render one
/// fleet-wide table (or, with `--raw`, the aggregated JSON for scripts).
fn run_stats(args: &Args) -> Result<()> {
    let addr = args
        .positional
        .first()
        .cloned()
        .or_else(|| args.flags.get("addr").cloned())
        .unwrap_or_else(|| "127.0.0.1:7878".to_string());
    let metrics_req = || Json::obj(vec![("cmd", "metrics".into())]);
    let coord = roundtrip_addr(&addr, metrics_req())?.get("result").clone();
    let worker_addrs: Vec<String> = coord
        .get("remote")
        .get("workers")
        .as_arr()
        .map(|ws| ws.iter().filter_map(|w| w.as_str().map(str::to_string)).collect())
        .unwrap_or_default();
    // an unreachable worker gets a row, not an error: stats must describe
    // a degraded fleet, not fail with it
    let workers: Vec<(String, Option<Json>)> = worker_addrs
        .iter()
        .map(|w| {
            let m = roundtrip_addr(w, metrics_req()).ok().map(|v| v.get("result").clone());
            (w.clone(), m)
        })
        .collect();
    if args.flags.contains_key("raw") {
        let rows: Vec<Json> = workers
            .iter()
            .map(|(a, m)| {
                Json::obj(vec![
                    ("addr", a.as_str().into()),
                    ("metrics", m.clone().unwrap_or(Json::Null)),
                ])
            })
            .collect();
        println!("{}", Json::obj(vec![("coordinator", coord), ("workers", Json::Arr(rows))]));
        return Ok(());
    }
    println!(
        "{:<28} {:>8} {:>7} {:>7} {:>7} {:>7} {:>7} {:>7} {:>7} {:>9} {:>9} {:>9} {:>11} {:>6}",
        "node", "uptime_s", "reqs", "local", "remote", "hits", "rshard", "g_sent", "g_recv",
        "p50", "p95", "p99", "des ev/s", "cal"
    );
    print_stats_row(&format!("{addr} (coordinator)"), Some(&coord));
    for (w, m) in &workers {
        print_stats_row(w, m.as_ref());
    }
    Ok(())
}

/// One `olympus stats` table row from a node's `metrics` result.
fn print_stats_row(node: &str, m: Option<&Json>) {
    use olympus::util::benchkit::fmt_ns;
    let Some(m) = m else {
        println!("{node:<28} {:>8}", "unreachable");
        return;
    };
    let uptime_s = m.get("uptime_ms").as_u64().unwrap_or(0) / 1000;
    let reqs: u64 = m
        .get("requests")
        .as_obj()
        .map(|o| o.values().filter_map(Json::as_u64).sum())
        .unwrap_or(0);
    let h = m.get("histograms");
    let count = |name: &str| h.get(name).get("count").as_u64().unwrap_or(0);
    let lat = h.get("request_latency");
    let q = |key: &str| match lat.get(key).as_f64() {
        Some(ns) if lat.get("count").as_u64().unwrap_or(0) > 0 => fmt_ns(ns),
        _ => "-".to_string(),
    };
    let evs = m.get("des").get("last_events_per_sec").as_f64().unwrap_or(0.0);
    let cal = m.get("des").get("calendar").as_str().unwrap_or("-");
    // response-shard routing lives on the coordinator's remote block and
    // gossip on every node; both print "-" where they don't apply
    let opt = |v: &Json| v.as_u64().map(|n| n.to_string()).unwrap_or_else(|| "-".to_string());
    let rshard = opt(m.get("remote").get("resp_shard_hits"));
    let gsent = opt(m.get("gossip").get("records_sent"));
    let grecv = opt(m.get("gossip").get("records_received"));
    println!(
        "{node:<28} {uptime_s:>8} {reqs:>7} {:>7} {:>7} {:>7} {rshard:>7} {gsent:>7} \
         {grecv:>7} {:>9} {:>9} {:>9} {evs:>11.0} {cal:>6}",
        count("eval_local"),
        count("eval_remote"),
        count("eval_cache_hit"),
        q("p50_ns"),
        q("p95_ns"),
        q("p99_ns"),
    );
}
