//! Bus-widening pass (paper §V-B, Fig 7).
//!
//! When data widths divide the PC width, a kernel is replicated so multiple
//! instances share the full word: a 64-bit-input kernel on a 256-bit PC
//! becomes 4 instances, each reading one 64-bit *lane*. The pass:
//!
//! 1. widens each of the kernel's global stream channels to
//!    `elem_bits × lanes`, with a multi-lane layout (Fig 7b);
//! 2. replaces the kernel with an `olympus.super_node` whose region holds
//!    `lanes` clones of the kernel (Fig 7a's dashed super-node);
//! 3. data movers later split the lanes and feed the right instance.
//!
//! Options: `bus-widen.width` (bits, default: the platform's widest memory
//! port), `bus-widen.max-lanes` (0 = unbounded).

use anyhow::Result;

use crate::analysis::{analyze_resources, Dfg};
use crate::dialect::{
    ChannelView, KernelView, Layout, LayoutField, ParamType, OP_KERNEL, OP_SUPER_NODE,
};
use crate::ir::{Attribute, Module, OpId, Operation, Region, Type, ValueId};

use super::manager::{Pass, PassContext, PassOutcome};

pub struct BusWiden;

/// Compute feasible lanes for one kernel: every global stream operand must
/// have the same `width / elem_bits` ratio >= 2.
fn feasible_lanes(m: &Module, k: &KernelView, width: u64) -> Option<u32> {
    let op = m.op(k.op);
    let mut lanes: Option<u32> = None;
    let mut n_mem_stream = 0;
    for &v in &op.operands {
        let ch = ChannelView::from_value(m, v)?;
        if ch.param_type(m) != Some(ParamType::Stream) {
            return None; // only pure-stream kernels are widened
        }
        if !ch.is_global(m) {
            return None; // internal channels would need matched widening
        }
        n_mem_stream += 1;
        let eb = ch.elem_bits(m) as u64;
        if eb == 0 || width % eb != 0 {
            return None;
        }
        let l = (width / eb) as u32;
        match lanes {
            None => lanes = Some(l),
            Some(prev) if prev == l => {}
            _ => return None, // mixed widths: Iris handles those instead
        }
    }
    if n_mem_stream == 0 {
        return None;
    }
    lanes.filter(|&l| l >= 2)
}

/// Widen channel `ch` to `lanes` lanes, preserving PC terminals. Returns the
/// new channel value.
fn widen_channel(m: &mut Module, ch: ChannelView, lanes: u32) -> ValueId {
    let old = m.op(ch.op).clone();
    let old_val = old.results[0];
    let elem_bits = ch.elem_bits(m).max(1);
    let name = old.attrs.get("name").and_then(|a| a.as_str()).unwrap_or("ch").to_string();
    let old_layout = ch.layout(m);
    let words = old_layout.as_ref().map(|l| l.depth).unwrap_or_else(|| ch.depth(m));

    let mut clone = old.clone();
    clone.results.clear();
    let fields = (0..lanes)
        .map(|j| LayoutField {
            array: format!("{name}.l{j}"),
            elem_bits,
            count: 1,
            offset_bits: j * elem_bits,
        })
        .collect();
    let layout = Layout {
        word_bits: elem_bits * lanes,
        depth: words.div_ceil(lanes as u64).max(1),
        lanes,
        fields,
    };
    clone.attrs.insert("layout".into(), layout.to_attr());

    let pos = m.top.iter().position(|&o| o == ch.op).unwrap_or(m.top.len());
    let id = m.insert_top_at(pos, clone);
    let v = m.new_result(id, 0, Type::channel_of(Type::int(elem_bits * lanes)));
    m.op_mut(id).results.push(v);
    // move all uses (kernel + pc) to the widened channel, drop the old op
    m.replace_all_uses(old_val, v);
    m.erase_op(ch.op);
    v
}

impl Pass for BusWiden {
    fn name(&self) -> &'static str {
        "bus-widen"
    }

    fn run(&self, m: &mut Module, ctx: &PassContext) -> Result<PassOutcome> {
        let default_width =
            ctx.platform.pcs.iter().map(|p| p.width_bits).max().unwrap_or(256) as u64;
        let width = ctx.opt_u64("bus-widen.width", default_width);
        let max_lanes = ctx.opt_u64("bus-widen.max-lanes", 0);

        let kernels: Vec<KernelView> = KernelView::all(m);
        if kernels.is_empty() {
            return Ok(PassOutcome::unchanged());
        }
        let plat = &ctx.platform;
        let mut changed = false;
        let mut remarks = Vec::new();

        for k in kernels {
            let Some(mut lanes) = feasible_lanes(m, &k, width) else { continue };
            if max_lanes >= 2 {
                lanes = lanes.min(max_lanes as u32);
            }
            // shrink lanes until the replicated kernels fit the fabric
            let base = analyze_resources(m, plat, &Dfg::build(m));
            let kres = k.resources(m);
            while lanes >= 2 {
                let extra = kres * (lanes as u64 - 1);
                if (base.total + extra).fits(&plat.resources, plat.util_limit) {
                    break;
                }
                lanes /= 2;
            }
            if lanes < 2 {
                continue;
            }

            let kop = m.op(k.op).clone();
            // widen every operand channel
            let mut new_operands = Vec::with_capacity(kop.operands.len());
            for &v in &kop.operands {
                let ch = ChannelView::from_value(m, v).expect("checked in feasible_lanes");
                new_operands.push(widen_channel(m, ch, lanes));
            }

            // build the super-node at the kernel's position
            let mut sn = Operation::new(OP_SUPER_NODE);
            sn.operands = new_operands.clone();
            sn.attrs = kop.attrs.clone();
            sn.attrs.insert("lanes".into(), Attribute::Int(lanes as i64));
            let pos = m.top.iter().position(|&o| o == k.op).unwrap_or(m.top.len());
            let sn_id: OpId = m.insert_top_at(pos, sn);
            // region with `lanes` kernel clones
            let mut members = Vec::new();
            for lane in 0..lanes {
                let mut clone = Operation::new(OP_KERNEL);
                clone.operands = new_operands.clone();
                clone.attrs = kop.attrs.clone();
                clone.attrs.insert("lane".into(), Attribute::Int(lane as i64));
                members.push(m.insert_op(clone));
            }
            m.op_mut(sn_id).regions.push(Region { ops: members });
            m.erase_op(k.op);

            changed = true;
            remarks.push(format!(
                "kernel '{}' widened to {lanes} lanes on a {width}-bit bus",
                kop.attrs.get("callee").and_then(|a| a.as_str()).unwrap_or("?")
            ));
        }
        Ok(PassOutcome { changed, remarks })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::dialect::PcView;
    use crate::ir::verify_module;
    use crate::passes::sanitize::Sanitize;
    use crate::platform::builtin;

    fn ctx() -> PassContext {
        PassContext::new(builtin("u280").unwrap())
    }

    #[test]
    fn fig7_widen_128() {
        let mut m = fig4a_module();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let c = ctx().with_opt("bus-widen.width", "128");
        let out = BusWiden.run(&mut m, &c).unwrap();
        assert!(out.changed);
        assert!(verify_module(&m).is_empty());
        // kernel replaced by a super-node with 4 lanes (128 / 32)
        assert!(KernelView::all(&m).is_empty());
        let sns = m.top_ops_named(OP_SUPER_NODE);
        assert_eq!(sns.len(), 1);
        let sn = m.op(sns[0]);
        assert_eq!(sn.int_attr("lanes"), Some(4));
        assert_eq!(sn.regions[0].ops.len(), 4);
        // channels widened: 128-bit words, 4-lane layout, depth / 4
        for ch in ChannelView::all(&m) {
            let l = ch.layout(&m).unwrap();
            assert_eq!(l.word_bits, 128);
            assert_eq!(l.lanes, 4);
            assert_eq!(l.depth, 256);
            assert_eq!(l.fields.len(), 4);
            assert!((l.efficiency() - 1.0).abs() < 1e-9);
            // encapsulatedType still records the logical 32-bit element
            assert_eq!(ch.elem_bits(&m), 32);
        }
        // PC terminals survived the rewiring
        assert_eq!(PcView::all(&m).len(), 3);
    }

    #[test]
    fn indivisible_width_is_skipped() {
        let mut m = fig4a_module();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let c = ctx().with_opt("bus-widen.width", "48");
        let out = BusWiden.run(&mut m, &c).unwrap();
        assert!(!out.changed);
        assert_eq!(KernelView::all(&m).len(), 1);
    }

    #[test]
    fn max_lanes_caps_replication() {
        let mut m = fig4a_module();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let c = ctx()
            .with_opt("bus-widen.width", "256")
            .with_opt("bus-widen.max-lanes", "2");
        BusWiden.run(&mut m, &c).unwrap();
        let sn = m.top_ops_named(OP_SUPER_NODE)[0];
        assert_eq!(m.op(sn).int_attr("lanes"), Some(2));
    }

    #[test]
    fn resource_pressure_shrinks_lanes() {
        use crate::dialect::{DfgBuilder, KernelEst, ParamType, ResourceVec};
        let mut b = DfgBuilder::new();
        let a = b.channel(32, ParamType::Stream, 1024);
        let o = b.channel(32, ParamType::Stream, 1024);
        // ~26% of U280 LUTs per kernel: only 2 extra copies fit under 80%
        b.kernel(
            "big",
            &[a],
            &[o],
            KernelEst { latency: 1, ii: 1, res: ResourceVec::new(0, 340_000, 0, 0, 0) },
        );
        let mut m = b.finish();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let c = ctx().with_opt("bus-widen.width", "256");
        BusWiden.run(&mut m, &c).unwrap();
        let sns = m.top_ops_named(OP_SUPER_NODE);
        assert_eq!(sns.len(), 1);
        // 8 lanes don't fit; halved to 2 (8 -> 4 -> 2)
        assert_eq!(m.op(sns[0]).int_attr("lanes"), Some(2));
    }

    #[test]
    fn internal_channels_block_widening() {
        use crate::dialect::{DfgBuilder, ParamType};
        let mut b = DfgBuilder::new();
        let x = b.channel(32, ParamType::Stream, 64);
        let y = b.channel(32, ParamType::Stream, 64);
        let z = b.channel(32, ParamType::Stream, 64);
        b.kernel("k1", &[x], &[y], Default::default());
        b.kernel("k2", &[y], &[z], Default::default());
        let mut m = b.finish();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let out = BusWiden.run(&mut m, &ctx()).unwrap();
        assert!(!out.changed, "kernels with internal channels are not widened");
    }
}
