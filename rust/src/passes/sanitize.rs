//! Sanitize pass (paper §V-A, Fig 4).
//!
//! 1. Gives every channel a stable `name` attribute (`ch0`, `ch1`, …) used
//!    by layouts, the Iris packer and the simulator.
//! 2. Creates a scalar layout (one element per word, Fig 4c) for every
//!    channel that has none.
//! 3. Creates one `olympus.pc` terminal with `id = 0` for every channel
//!    touching global memory that lacks one.
//!
//! After this pass the IR "could immediately be passed to the hardware
//! lowering step to create [a] working, but inefficient, design" (Fig 4b).

use anyhow::Result;

use crate::dialect::{ChannelView, Layout, ParamType};
use crate::ir::{Attribute, Module, OpBuilder};

use super::manager::{Pass, PassContext, PassOutcome};

pub struct Sanitize;

impl Pass for Sanitize {
    fn name(&self) -> &'static str {
        "sanitize"
    }

    fn run(&self, m: &mut Module, _ctx: &PassContext) -> Result<PassOutcome> {
        let mut changed = false;
        let mut remarks = Vec::new();

        // 1. names
        for (i, ch) in ChannelView::all(m).into_iter().enumerate() {
            if m.op(ch.op).str_attr("name").is_none() {
                m.op_mut(ch.op).set_attr("name", Attribute::Str(format!("ch{i}")));
                changed = true;
            }
        }

        // 2. layouts
        let mut n_layouts = 0;
        for ch in ChannelView::all(m) {
            if ch.layout(m).is_none() {
                let name = m.op(ch.op).str_attr("name").unwrap_or("ch").to_string();
                let elem_bits = ch.elem_bits(m).max(1);
                let words = match ch.param_type(m) {
                    // complex: depth is bytes -> words of elem_bits
                    Some(ParamType::Complex) => (ch.depth(m) * 8).div_ceil(elem_bits as u64),
                    _ => ch.depth(m),
                };
                ch.set_layout(m, &Layout::scalar(&name, elem_bits, words.max(1)));
                n_layouts += 1;
                changed = true;
            }
        }
        if n_layouts > 0 {
            remarks.push(format!("created {n_layouts} scalar layouts"));
        }

        // 3. PC terminals for global channels (one Dfg build instead of a
        // per-channel uses_of scan — keeps sanitize linear in module size)
        let mut n_pcs = 0;
        let dfg = crate::analysis::Dfg::build(m);
        let need_pc: Vec<_> = dfg
            .memory_channels
            .iter()
            .filter(|b| b.pcs.is_empty())
            .map(|b| b.channel.value(m))
            .collect();
        for v in need_pc {
            let mut b = OpBuilder::new(m);
            b.op(crate::dialect::OP_PC).operand(v).attr("id", 0i64).build();
            n_pcs += 1;
            changed = true;
        }
        if n_pcs > 0 {
            remarks.push(format!("inserted {n_pcs} pc terminals (all id=0)"));
        }

        Ok(PassOutcome { changed, remarks })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::dialect::{DfgBuilder, PcView};
    use crate::platform::builtin;

    fn ctx() -> PassContext {
        PassContext::new(builtin("u280").unwrap())
    }

    #[test]
    fn fig4a_to_fig4b() {
        let mut m = fig4a_module();
        let out = Sanitize.run(&mut m, &ctx()).unwrap();
        assert!(out.changed);
        // every channel has a name, a layout, and (being global) a PC with id 0
        for ch in ChannelView::all(&m) {
            assert!(m.op(ch.op).str_attr("name").is_some());
            let l = ch.layout(&m).expect("layout");
            assert_eq!(l.word_bits, 32);
            assert_eq!(l.depth, 1024);
            assert_eq!(l.lanes, 1);
            assert_eq!(l.efficiency(), 1.0);
            assert_eq!(ch.pcs(&m).len(), 1);
        }
        let pcs = PcView::all(&m);
        assert_eq!(pcs.len(), 3);
        assert!(pcs.iter().all(|pc| pc.id(&m) == 0), "all PCs start at id 0");
    }

    #[test]
    fn idempotent() {
        let mut m = fig4a_module();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let before = crate::ir::print_module(&m);
        let out = Sanitize.run(&mut m, &ctx()).unwrap();
        assert!(!out.changed);
        assert_eq!(before, crate::ir::print_module(&m));
    }

    #[test]
    fn internal_channels_get_no_pc() {
        let mut b = DfgBuilder::new();
        let x = b.channel(32, ParamType::Stream, 16);
        let y = b.channel(32, ParamType::Stream, 16);
        let z = b.channel(32, ParamType::Stream, 16);
        b.kernel("k1", &[x], &[y], Default::default());
        b.kernel("k2", &[y], &[z], Default::default());
        let mut m = b.finish();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let chans = ChannelView::all(&m);
        assert_eq!(chans[0].pcs(&m).len(), 1); // x: memory read
        assert_eq!(chans[1].pcs(&m).len(), 0); // y: internal
        assert_eq!(chans[2].pcs(&m).len(), 1); // z: memory write
    }

    #[test]
    fn complex_depth_is_bytes() {
        let mut b = DfgBuilder::new();
        let x = b.channel(64, ParamType::Complex, 1024); // 1024 bytes
        b.kernel("k", &[x], &[], Default::default());
        let mut m = b.finish();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let l = ChannelView::all(&m)[0].layout(&m).unwrap();
        assert_eq!(l.depth, 1024 * 8 / 64);
    }

    use crate::dialect::ParamType;
}
