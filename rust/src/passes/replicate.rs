//! Replication pass (paper §V-B, Fig 6).
//!
//! Clones the entire DFG up to the resource-utilization limit. Every
//! operator is replicated under a new identifier; replicated PC terminals
//! keep the *same* physical id (paper: "Each replicated PC node is given
//! the same id") — a later `channel-reassign` may spread them.
//!
//! Options: `replicate.factor` — total number of copies wanted (0 = auto:
//! as many as fit under the platform utilization limit).

use std::collections::HashMap;

use anyhow::Result;

use crate::analysis::{analyze_resources, Dfg};
use crate::dialect::{OP_KERNEL, OP_MAKE_CHANNEL, OP_PC, OP_SUPER_NODE};
use crate::ir::{Attribute, Module, Region, ValueId};

use super::manager::{Pass, PassContext, PassOutcome};

pub struct Replicate;

/// Clone every top-level olympus op `extra` more times; returns #clones made.
pub fn replicate_dfg(m: &mut Module, extra: u64) -> usize {
    let base: Vec<_> = m.top.clone();
    let mut made = 0;
    for r in 1..=extra {
        let mut vmap: HashMap<ValueId, ValueId> = HashMap::new();
        for &src in &base {
            let op = m.op(src).clone();
            match op.name.as_str() {
                OP_MAKE_CHANNEL => {
                    let mut clone = op.clone();
                    clone.results.clear();
                    if let Some(Attribute::Str(n)) = clone.attrs.get("name").cloned() {
                        // `#` as the replica separator: `.` is reserved for
                        // Iris/lane slot suffixes (`ch0.2`), whose *base* the
                        // movers extract by splitting at the first `.`.
                        clone.attrs.insert("name".into(), Attribute::Str(format!("{n}#r{r}")));
                    }
                    // Layout fields refer to channels by base name; rename
                    // every base (the whole DFG is cloned, so every referenced
                    // channel gets the same #r suffix) — else clone movers
                    // would route into the originals' FIFOs.
                    if let Some(attr) = clone.attrs.get("layout") {
                        if let Some(mut l) = crate::dialect::Layout::from_attr(attr) {
                            for f in &mut l.fields {
                                f.array = match f.array.split_once('.') {
                                    Some((base, rest)) => format!("{base}#r{r}.{rest}"),
                                    None => format!("{}#r{r}", f.array),
                                };
                            }
                            clone.attrs.insert("layout".into(), l.to_attr());
                        }
                    }
                    // Iris bus channels list their members by name.
                    if let Some(Attribute::Array(members)) =
                        clone.attrs.get("iris_members").cloned()
                    {
                        let renamed = members
                            .into_iter()
                            .map(|a| match a {
                                Attribute::Str(s) => Attribute::Str(format!("{s}#r{r}")),
                                other => other,
                            })
                            .collect();
                        clone.attrs.insert("iris_members".into(), Attribute::Array(renamed));
                    }
                    // member channels point at their bus by name
                    if let Some(Attribute::Str(bus)) = clone.attrs.get("via_bus").cloned() {
                        clone.attrs.insert("via_bus".into(), Attribute::Str(format!("{bus}#r{r}")));
                    }
                    clone.attrs.insert("replica".into(), Attribute::Int(r as i64));
                    let id = m.push_top(clone);
                    let ty = m.value_type(op.results[0]).clone();
                    let v = m.new_result(id, 0, ty);
                    m.op_mut(id).results.push(v);
                    vmap.insert(op.results[0], v);
                }
                OP_KERNEL | OP_PC | OP_SUPER_NODE => {
                    let mut clone = op.clone();
                    clone.operands = op
                        .operands
                        .iter()
                        .map(|v| *vmap.get(v).unwrap_or(v))
                        .collect();
                    clone.attrs.insert("replica".into(), Attribute::Int(r as i64));
                    clone.regions.clear();
                    let id = m.push_top(clone);
                    // clone region kernels (super-node members)
                    for (ri, region) in op.regions.iter().enumerate() {
                        let mut new_ops = Vec::new();
                        for &inner in &region.ops {
                            let mut ic = m.op(inner).clone();
                            ic.operands =
                                ic.operands.iter().map(|v| *vmap.get(v).unwrap_or(v)).collect();
                            ic.attrs.insert("replica".into(), Attribute::Int(r as i64));
                            new_ops.push(m.insert_op(ic));
                        }
                        let p = m.op_mut(id);
                        while p.regions.len() <= ri {
                            p.regions.push(Region::default());
                        }
                        p.regions[ri].ops = new_ops;
                    }
                    made += 1;
                }
                _ => {}
            }
        }
    }
    made
}

impl Pass for Replicate {
    fn name(&self) -> &'static str {
        "replicate"
    }

    fn run(&self, m: &mut Module, ctx: &PassContext) -> Result<PassOutcome> {
        let requested = ctx.opt_u64("replicate.factor", 0);
        let dfg = Dfg::build(m);
        if dfg.kernels.is_empty() {
            return Ok(PassOutcome::unchanged());
        }
        let rep = analyze_resources(m, &ctx.platform, &dfg);
        let headroom = rep.replication_headroom.min(1_000_000);
        let factor = if requested == 0 { headroom } else { requested.min(headroom) };
        if factor <= 1 {
            return Ok(PassOutcome::unchanged()
                .remark(format!("no replication (headroom {headroom}, requested {requested})")));
        }
        replicate_dfg(m, factor - 1);
        Ok(PassOutcome::changed(format!(
            "replicated DFG x{factor} (binding resource: {}, utilization {:.1}% -> ~{:.1}%)",
            rep.binding,
            rep.utilization * 100.0,
            rep.utilization * factor as f64 * 100.0
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::dialect::{ChannelView, KernelView, PcView};
    use crate::ir::verify_module;
    use crate::passes::sanitize::Sanitize;
    use crate::platform::builtin;

    fn ctx() -> PassContext {
        PassContext::new(builtin("u280").unwrap())
    }

    #[test]
    fn fig6_replicate_twice() {
        let mut m = fig4a_module();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let c = ctx().with_opt("replicate.factor", "2");
        let out = Replicate.run(&mut m, &c).unwrap();
        assert!(out.changed);
        assert_eq!(KernelView::all(&m).len(), 2);
        assert_eq!(ChannelView::all(&m).len(), 6);
        let pcs = PcView::all(&m);
        assert_eq!(pcs.len(), 6);
        // replicated PCs keep the same id (paper)
        assert!(pcs.iter().all(|pc| pc.id(&m) == 0));
        assert!(verify_module(&m).is_empty());
        // clone channels are renamed
        let names: Vec<String> = ChannelView::all(&m)
            .iter()
            .map(|ch| m.op(ch.op).str_attr("name").unwrap().to_string())
            .collect();
        assert!(names.contains(&"ch0".to_string()));
        assert!(names.contains(&"ch0#r1".to_string()));
    }

    #[test]
    fn auto_factor_respects_headroom() {
        use crate::dialect::{DfgBuilder, KernelEst, ParamType, ResourceVec};
        // kernel using ~30% of U280 LUTs -> headroom under the 80% limit is 2
        let mut b = DfgBuilder::new();
        let a = b.channel(32, ParamType::Stream, 64);
        b.kernel(
            "k",
            &[a],
            &[],
            KernelEst { latency: 1, ii: 1, res: ResourceVec::new(0, 400_000, 0, 0, 0) },
        );
        let mut m = b.finish();
        Sanitize.run(&mut m, &ctx()).unwrap();
        Replicate.run(&mut m, &ctx()).unwrap();
        assert_eq!(KernelView::all(&m).len(), 2, "0.8/0.31 ~ 2 copies fit");
    }

    #[test]
    fn requested_capped_by_headroom() {
        use crate::dialect::{DfgBuilder, KernelEst, ParamType, ResourceVec};
        let mut b = DfgBuilder::new();
        let a = b.channel(32, ParamType::Stream, 64);
        b.kernel(
            "k",
            &[a],
            &[],
            KernelEst { latency: 1, ii: 1, res: ResourceVec::new(0, 400_000, 0, 0, 0) },
        );
        let mut m = b.finish();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let c = ctx().with_opt("replicate.factor", "64");
        Replicate.run(&mut m, &c).unwrap();
        assert_eq!(KernelView::all(&m).len(), 2, "request 64 capped to headroom 2");
    }

    #[test]
    fn oversized_design_is_not_replicated() {
        use crate::dialect::{DfgBuilder, KernelEst, ParamType, ResourceVec};
        let mut b = DfgBuilder::new();
        let a = b.channel(32, ParamType::Stream, 64);
        b.kernel(
            "k",
            &[a],
            &[],
            KernelEst { latency: 1, ii: 1, res: ResourceVec::new(0, 1_200_000, 0, 0, 0) },
        );
        let mut m = b.finish();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let out = Replicate.run(&mut m, &ctx()).unwrap();
        assert!(!out.changed);
        assert_eq!(KernelView::all(&m).len(), 1);
    }
}
