//! Canonicalization: dead-channel elimination + duplicate-PC cleanup.

use anyhow::Result;

use crate::dialect::{ChannelView, PcView};
use crate::ir::Module;

use super::manager::{Pass, PassContext, PassOutcome};

pub struct Canonicalize;

impl Pass for Canonicalize {
    fn name(&self) -> &'static str {
        "canonicalize"
    }

    fn run(&self, m: &mut Module, _ctx: &PassContext) -> Result<PassOutcome> {
        let mut changed = false;
        let mut removed_pcs = 0;
        let mut removed_channels = 0;

        // duplicate PC terminals on the same channel with the same id
        let mut seen: std::collections::HashSet<(crate::ir::ValueId, u32)> =
            std::collections::HashSet::new();
        for pc in PcView::all(m) {
            let Some(&v) = m.op(pc.op).operands.first() else { continue };
            let id = pc.id(m);
            if !seen.insert((v, id)) {
                m.erase_op(pc.op);
                removed_pcs += 1;
                changed = true;
            }
        }

        // channels with no users at all (no kernels, no pc, not a bus member)
        loop {
            let use_map = m.use_map();
            let dead: Vec<_> = ChannelView::all(m)
                .into_iter()
                .filter(|ch| {
                    use_map.get(&ch.value(m)).map(|u| u.is_empty()).unwrap_or(true)
                        && m.op(ch.op).str_attr("via_bus").is_none()
                        && m.op(ch.op).attr("iris_members").is_none()
                })
                .collect();
            if dead.is_empty() {
                break;
            }
            for ch in dead {
                m.erase_op(ch.op);
                removed_channels += 1;
                changed = true;
            }
        }

        let mut out = PassOutcome { changed, remarks: vec![] };
        if removed_pcs > 0 {
            out = out.remark(format!("removed {removed_pcs} duplicate pc terminals"));
        }
        if removed_channels > 0 {
            out = out.remark(format!("removed {removed_channels} dead channels"));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::{DfgBuilder, ParamType};
    use crate::platform::builtin;

    fn ctx() -> PassContext {
        PassContext::new(builtin("u280").unwrap())
    }

    #[test]
    fn removes_dead_channel() {
        let mut b = DfgBuilder::new();
        let _dead = b.channel(32, ParamType::Stream, 8);
        let live = b.channel(32, ParamType::Stream, 8);
        b.kernel("k", &[live], &[], Default::default());
        let mut m = b.finish();
        let out = Canonicalize.run(&mut m, &ctx()).unwrap();
        assert!(out.changed);
        assert_eq!(ChannelView::all(&m).len(), 1);
    }

    #[test]
    fn dedups_pc_terminals() {
        let mut b = DfgBuilder::new();
        let x = b.channel(32, ParamType::Stream, 8);
        b.kernel("k", &[x], &[], Default::default());
        b.pc(x, 0);
        b.pc(x, 0);
        b.pc(x, 1); // different id: kept
        let mut m = b.finish();
        Canonicalize.run(&mut m, &ctx()).unwrap();
        assert_eq!(PcView::all(&m).len(), 2);
    }

    #[test]
    fn keeps_bus_channels() {
        use crate::ir::Attribute;
        let mut b = DfgBuilder::new();
        let x = b.channel(256, ParamType::Stream, 8);
        let mut m = b.finish();
        let ch = ChannelView::all(&m)[0];
        m.op_mut(ch.op).set_attr("iris_members", Attribute::Array(vec![]));
        let out = Canonicalize.run(&mut m, &ctx()).unwrap();
        assert!(!out.changed);
        let _ = x;
    }

    #[test]
    fn idempotent() {
        let mut b = DfgBuilder::new();
        let x = b.channel(32, ParamType::Stream, 8);
        b.kernel("k", &[x], &[], Default::default());
        let mut m = b.finish();
        Canonicalize.run(&mut m, &ctx()).unwrap();
        assert!(!Canonicalize.run(&mut m, &ctx()).unwrap().changed);
    }
}
