//! Pass trait, context, manager and pipeline parsing.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::dialect::verify_dialect;
use crate::ir::{verify_module, Module};
use crate::platform::PlatformSpec;

/// Options + platform shared by all passes in a pipeline.
#[derive(Debug, Clone)]
pub struct PassContext {
    pub platform: PlatformSpec,
    /// Pass-specific options, e.g. `{"factor": "4"}` for `replicate{factor=4}`.
    pub opts: BTreeMap<String, String>,
}

impl PassContext {
    pub fn new(platform: PlatformSpec) -> Self {
        PassContext { platform, opts: BTreeMap::new() }
    }

    pub fn with_opt(mut self, k: &str, v: &str) -> Self {
        self.opts.insert(k.to_string(), v.to_string());
        self
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> u64 {
        self.opts.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.opts.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.opts.get(key).map(|s| s == "true" || s == "1").unwrap_or(default)
    }
}

/// What a pass reports back.
#[derive(Debug, Clone, Default)]
pub struct PassOutcome {
    pub changed: bool,
    /// Human-readable remarks (printed by the CLI with `-v`).
    pub remarks: Vec<String>,
}

impl PassOutcome {
    pub fn changed(msg: impl Into<String>) -> Self {
        PassOutcome { changed: true, remarks: vec![msg.into()] }
    }

    pub fn unchanged() -> Self {
        PassOutcome::default()
    }

    pub fn remark(mut self, msg: impl Into<String>) -> Self {
        self.remarks.push(msg.into());
        self
    }
}

/// A transformation or analysis pass over a module.
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, m: &mut Module, ctx: &PassContext) -> Result<PassOutcome>;
}

/// Per-pass execution record.
#[derive(Debug, Clone)]
pub struct PassRecord {
    pub name: &'static str,
    pub changed: bool,
    pub remarks: Vec<String>,
    pub micros: u128,
}

/// Ordered pass pipeline with verification between passes.
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
    /// Verify structural + dialect invariants after each pass (on by default).
    pub verify_each: bool,
    /// Require PC terminals only on global channels (post-sanitize rule).
    pub strict_pc: bool,
}

impl Default for PassManager {
    fn default() -> Self {
        Self::new()
    }
}

impl PassManager {
    pub fn new() -> Self {
        PassManager { passes: Vec::new(), verify_each: true, strict_pc: false }
    }

    pub fn add(&mut self, pass: Box<dyn Pass>) -> &mut Self {
        self.passes.push(pass);
        self
    }

    pub fn len(&self) -> usize {
        self.passes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.passes.is_empty()
    }

    /// Run all passes in order; fails fast on the first verifier violation.
    pub fn run(&self, m: &mut Module, ctx: &PassContext) -> Result<Vec<PassRecord>> {
        let mut records = Vec::new();
        for pass in &self.passes {
            let t0 = Instant::now();
            let outcome = pass.run(m, ctx)?;
            let micros = t0.elapsed().as_micros();
            if self.verify_each {
                let errs = verify_module(m);
                if !errs.is_empty() {
                    bail!("pass '{}' broke structural invariants: {:?}", pass.name(), errs);
                }
                let derrs = verify_dialect(m, self.strict_pc);
                if !derrs.is_empty() {
                    bail!("pass '{}' broke dialect invariants: {:?}", pass.name(), derrs);
                }
            }
            records.push(PassRecord {
                name: pass.name(),
                changed: outcome.changed,
                remarks: outcome.remarks,
                micros,
            });
        }
        Ok(records)
    }
}

/// Instantiate a pass by name (the `olympus-opt` pass registry).
pub fn make_pass(name: &str) -> Result<Box<dyn Pass>> {
    Ok(match name {
        "sanitize" => Box::new(super::sanitize::Sanitize),
        "channel-reassign" => Box::new(super::channel_reassign::ChannelReassign),
        "replicate" => Box::new(super::replicate::Replicate),
        "bus-widen" => Box::new(super::bus_widen::BusWiden),
        "iris" => Box::new(super::iris::IrisBusOpt),
        "plm-share" => Box::new(super::plm_share::PlmShare),
        "fifo-sizing" => Box::new(super::fifo_sizing::FifoSizing),
        "canonicalize" => Box::new(super::canonicalize::Canonicalize),
        other => bail!("unknown pass '{other}' (see `olympus opt --help` for the registry)"),
    })
}

/// Parse a `pass1,pass2{k=v,k2=v2},pass3` pipeline string. Options apply to
/// the whole context (pass options are namespaced by convention:
/// `replicate.factor`, `bus-widen.width`, ...).
pub fn parse_pipeline(spec: &str, ctx: &mut PassContext) -> Result<PassManager> {
    let mut pm = PassManager::new();
    let mut rest = spec.trim();
    while !rest.is_empty() {
        // pass name up to ',' or '{'
        let end = rest.find(['{', ',']).unwrap_or(rest.len());
        let name = rest[..end].trim();
        if name.is_empty() {
            bail!("empty pass name in pipeline '{spec}'");
        }
        rest = &rest[end..];
        if rest.starts_with('{') {
            let close = rest.find('}').ok_or_else(|| anyhow::anyhow!("unclosed '{{' in pipeline"))?;
            for kv in rest[1..close].split(',') {
                let kv = kv.trim();
                if kv.is_empty() {
                    continue;
                }
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("bad option '{kv}' (want k=v)"))?;
                if k.trim().is_empty() {
                    bail!("bad option '{kv}' in pass '{name}': empty key");
                }
                ctx.opts.insert(format!("{name}.{}", k.trim()), v.trim().to_string());
            }
            rest = &rest[close + 1..];
        }
        pm.add(make_pass(name)?);
        rest = rest.trim_start();
        if let Some(r) = rest.strip_prefix(',') {
            rest = r.trim_start();
        }
    }
    Ok(pm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::builtin;

    #[test]
    fn registry_knows_all_passes() {
        for p in [
            "sanitize",
            "channel-reassign",
            "replicate",
            "bus-widen",
            "iris",
            "plm-share",
            "fifo-sizing",
            "canonicalize",
        ] {
            assert!(make_pass(p).is_ok(), "missing pass {p}");
        }
        assert!(make_pass("bogus").is_err());
    }

    #[test]
    fn pipeline_parsing() {
        let mut ctx = PassContext::new(builtin("u280").unwrap());
        let pm =
            parse_pipeline("sanitize, replicate{factor=4}, bus-widen{width=128}", &mut ctx)
                .unwrap();
        assert_eq!(pm.len(), 3);
        assert_eq!(ctx.opt_u64("replicate.factor", 0), 4);
        assert_eq!(ctx.opt_u64("bus-widen.width", 0), 128);
    }

    #[test]
    fn pipeline_errors() {
        let mut ctx = PassContext::new(builtin("u280").unwrap());
        assert!(parse_pipeline("sanitize, nope", &mut ctx).is_err());
        assert!(parse_pipeline("replicate{factor}", &mut ctx).is_err());
        assert!(parse_pipeline("replicate{factor=2", &mut ctx).is_err());
    }

    #[test]
    fn empty_pipeline_is_a_valid_noop() {
        let mut ctx = PassContext::new(builtin("u280").unwrap());
        let pm = parse_pipeline("", &mut ctx).unwrap();
        assert!(pm.is_empty());
        assert_eq!(pm.len(), 0);
        let pm = parse_pipeline("   ", &mut ctx).unwrap();
        assert!(pm.is_empty());
        // an empty pipeline runs fine and records nothing
        let mut m = crate::dialect::build::fig4a_module();
        assert!(pm.run(&mut m, &ctx).unwrap().is_empty());
    }

    #[test]
    fn unknown_pass_names_the_offender() {
        let mut ctx = PassContext::new(builtin("u280").unwrap());
        let err = parse_pipeline("sanitize, frobnicate{x=1}", &mut ctx).unwrap_err();
        assert!(err.to_string().contains("frobnicate"), "{err}");
        // unknown pass rejected even with valid options attached
        assert!(make_pass("frobnicate").is_err());
    }

    #[test]
    fn malformed_option_blocks() {
        let mut ctx = PassContext::new(builtin("u280").unwrap());
        // empty key
        assert!(parse_pipeline("replicate{=2}", &mut ctx).is_err());
        // leading '{' with no pass name
        assert!(parse_pipeline("{factor=2}", &mut ctx).is_err());
        // unclosed brace reported as such
        let err = parse_pipeline("bus-widen{width=128", &mut ctx).unwrap_err();
        assert!(err.to_string().contains("unclosed"), "{err}");
        // empty option set and trailing commas are tolerated
        let mut ctx2 = PassContext::new(builtin("u280").unwrap());
        let pm = parse_pipeline("replicate{}, sanitize,", &mut ctx2).unwrap();
        assert_eq!(pm.len(), 2);
        // dangling comma-only entries are rejected as empty pass names
        assert!(parse_pipeline(",", &mut ctx2).is_err());
    }

    #[test]
    fn whitespace_and_duplicate_options() {
        let mut ctx = PassContext::new(builtin("u280").unwrap());
        let pm = parse_pipeline(
            "  sanitize ,  replicate{ factor = 4 , factor = 8 }  ",
            &mut ctx,
        )
        .unwrap();
        assert_eq!(pm.len(), 2);
        // last write wins, whitespace trimmed on both key and value
        assert_eq!(ctx.opt_u64("replicate.factor", 0), 8);
    }

    #[test]
    fn ctx_option_accessors() {
        let ctx = PassContext::new(builtin("u280").unwrap())
            .with_opt("a", "7")
            .with_opt("b", "0.5")
            .with_opt("c", "true");
        assert_eq!(ctx.opt_u64("a", 0), 7);
        assert_eq!(ctx.opt_f64("b", 0.0), 0.5);
        assert!(ctx.opt_bool("c", false));
        assert_eq!(ctx.opt_u64("missing", 3), 3);
    }
}
