//! FIFO-sizing pass (an Olympus-opt extension the paper's flow leaves to
//! the backend): memory-facing stream FIFOs don't need to hold the whole
//! transfer — they only rate-decouple the data mover from the kernel, so a
//! double-buffered burst is enough. Shrinking them converts BRAM into
//! replication headroom, like the PLM optimization does.
//!
//! The physical FIFO depth is recorded as a `fifo_depth` attribute; the
//! `depth` attribute keeps its paper semantics (total payload), which the
//! movers and the bandwidth analysis still use.
//!
//! Options: `fifo-sizing.burst` — mover burst length in words (default 64).

use anyhow::Result;

use crate::analysis::Dfg;
use crate::dialect::ParamType;
use crate::ir::{Attribute, Module};

use super::manager::{Pass, PassContext, PassOutcome};

pub struct FifoSizing;

impl Pass for FifoSizing {
    fn name(&self) -> &'static str {
        "fifo-sizing"
    }

    fn run(&self, m: &mut Module, ctx: &PassContext) -> Result<PassOutcome> {
        let burst = ctx.opt_u64("fifo-sizing.burst", 64).max(1);
        let dfg = Dfg::build(m);
        let mut changed = false;
        let mut shrunk = 0u64;
        // memory-facing streams + iris members (their FIFO sits behind the
        // bus unpacker, same double-buffering argument)
        let mut candidates = Vec::new();
        for b in &dfg.memory_channels {
            candidates.push(b.channel);
        }
        for ch in &dfg.internal_channels {
            if m.op(ch.op).str_attr("via_bus").is_some() {
                candidates.push(*ch);
            }
        }
        for ch in candidates {
            if ch.param_type(m) != Some(ParamType::Stream) {
                continue;
            }
            if m.op(ch.op).attr("iris_members").is_some() {
                continue; // bus channels have no on-chip FIFO
            }
            let depth = ch.depth(m);
            let target = 2 * burst;
            let existing = m.op(ch.op).int_attr("fifo_depth").map(|v| v.max(0) as u64);
            if depth > target && existing != Some(target) {
                m.op_mut(ch.op).set_attr("fifo_depth", Attribute::Int(target as i64));
                shrunk += 1;
                changed = true;
            }
        }
        let remark = format!("double-buffered {shrunk} memory-facing FIFOs at {burst}-word bursts");
        Ok(PassOutcome { changed, remarks: vec![remark] })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze_resources;
    use crate::dialect::build::fig4a_module;
    use crate::dialect::ChannelView;
    use crate::passes::sanitize::Sanitize;
    use crate::platform::builtin;

    fn ctx() -> PassContext {
        PassContext::new(builtin("u280").unwrap())
    }

    #[test]
    fn shrinks_memory_fifos() {
        let mut m = fig4a_module();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let out = FifoSizing.run(&mut m, &ctx()).unwrap();
        assert!(out.changed);
        for ch in ChannelView::all(&m) {
            assert_eq!(m.op(ch.op).int_attr("fifo_depth"), Some(128));
            assert_eq!(ch.depth(&m), 1024, "payload depth untouched");
        }
    }

    #[test]
    fn saves_bram() {
        use crate::dialect::{DfgBuilder, ParamType};
        // deep 256-bit stream: full-depth FIFO would burn many BRAM36
        let mut b = DfgBuilder::new();
        let x = b.channel(256, ParamType::Stream, 64 * 1024);
        b.kernel("k", &[x], &[], Default::default());
        let mut m = b.finish();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let plat = builtin("u280").unwrap();
        let before = analyze_resources(&m, &plat, &crate::analysis::Dfg::build(&m));
        FifoSizing.run(&mut m, &ctx()).unwrap();
        let after = analyze_resources(&m, &plat, &crate::analysis::Dfg::build(&m));
        assert!(
            after.total.bram < before.total.bram / 10,
            "before {} after {}",
            before.total.bram,
            after.total.bram
        );
    }

    #[test]
    fn shallow_fifos_untouched() {
        use crate::dialect::{DfgBuilder, ParamType};
        let mut b = DfgBuilder::new();
        let x = b.channel(32, ParamType::Stream, 16);
        b.kernel("k", &[x], &[], Default::default());
        let mut m = b.finish();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let out = FifoSizing.run(&mut m, &ctx()).unwrap();
        assert!(!out.changed);
    }

    #[test]
    fn idempotent() {
        let mut m = fig4a_module();
        Sanitize.run(&mut m, &ctx()).unwrap();
        FifoSizing.run(&mut m, &ctx()).unwrap();
        assert!(!FifoSizing.run(&mut m, &ctx()).unwrap().changed);
    }
}
