//! PLM-optimization pass (paper §V-B "PLM optimization").
//!
//! Runs the Mnemosyne planner over all `small` channels and records the
//! sharing plan in the IR: each shared channel gets `plm_group = <gid>` and
//! group leaders carry `plm_shared_bram_saved` (consumed by the resource
//! analysis, which is how the saved area converts into extra replication
//! headroom — "often to a high enough degree to allow for additional
//! compute unit replication and therefore speedup").

use anyhow::Result;

use crate::dialect::{ChannelView, ParamType};
use crate::ir::{Attribute, Module};
use crate::mnemosyne::{plan_sharing, CompatInfo};

use super::manager::{Pass, PassContext, PassOutcome};

pub struct PlmShare;

/// BRAM36 blocks for a small channel's buffer.
fn brams_of(m: &Module, ch: &ChannelView) -> u64 {
    (ch.depth(m) * ch.elem_bits(m) as u64).div_ceil(36 * 1024)
}

impl Pass for PlmShare {
    fn name(&self) -> &'static str {
        "plm-share"
    }

    fn run(&self, m: &mut Module, _ctx: &PassContext) -> Result<PassOutcome> {
        let smalls: Vec<ChannelView> = ChannelView::all(m)
            .into_iter()
            .filter(|ch| ch.param_type(m) == Some(ParamType::Small))
            .collect();
        if smalls.len() < 2 {
            return Ok(PassOutcome::unchanged());
        }
        let infos: Vec<CompatInfo> = smalls
            .iter()
            .map(|ch| CompatInfo {
                name: m.op(ch.op).str_attr("name").unwrap_or("plm").to_string(),
                brams: brams_of(m, ch),
                phase: m.op(ch.op).int_attr("phase"),
                share_group: m.op(ch.op).str_attr("share_group").map(|s| s.to_string()),
            })
            .collect();
        let plan = plan_sharing(&infos);
        if plan.total_saved() == 0 {
            return Ok(PassOutcome::unchanged().remark("no compatible PLM pairs"));
        }
        let mut changed = false;
        for (gid, group) in plan.groups.iter().enumerate() {
            if group.members.len() < 2 {
                continue;
            }
            for (k, name) in group.members.iter().enumerate() {
                let ch = smalls[infos.iter().position(|i| &i.name == name).unwrap()];
                m.op_mut(ch.op).set_attr("plm_group", Attribute::Int(gid as i64));
                if k == 0 {
                    m.op_mut(ch.op)
                        .set_attr("plm_shared_bram_saved", Attribute::Int(group.saved as i64));
                }
                changed = true;
            }
        }
        Ok(PassOutcome {
            changed,
            remarks: vec![format!(
                "{} sharing group(s), {} BRAM36 saved",
                plan.groups.iter().filter(|g| g.members.len() > 1).count(),
                plan.total_saved()
            )],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{analyze_resources, Dfg};
    use crate::dialect::DfgBuilder;
    use crate::passes::sanitize::Sanitize;
    use crate::platform::builtin;

    fn ctx() -> PassContext {
        PassContext::new(builtin("u280").unwrap())
    }

    /// Two-phase pipeline with two big `small` buffers.
    fn two_phase() -> Module {
        let mut b = DfgBuilder::new();
        let s1 = b.channel(32, ParamType::Small, 36 * 1024); // 32 BRAM36
        let s2 = b.channel(32, ParamType::Small, 36 * 1024);
        let k1in = b.channel(32, ParamType::Stream, 64);
        let k2out = b.channel(32, ParamType::Stream, 64);
        b.kernel("k1", &[k1in], &[s1], Default::default());
        b.kernel("k2", &[s1, s2], &[k2out], Default::default());
        let mut m = b.finish();
        Sanitize.run(&mut m, &ctx()).unwrap();
        // compiler-supplied phases: s1 live in phase 0, s2 in phase 1
        let chans = ChannelView::all(&m);
        m.op_mut(chans[0].op).set_attr("phase", Attribute::Int(0));
        m.op_mut(chans[1].op).set_attr("phase", Attribute::Int(1));
        m
    }

    #[test]
    fn sharing_recorded_and_saves_bram() {
        let mut m = two_phase();
        let plat = builtin("u280").unwrap();
        let before = analyze_resources(&m, &plat, &Dfg::build(&m));
        let out = PlmShare.run(&mut m, &ctx()).unwrap();
        assert!(out.changed);
        let after = analyze_resources(&m, &plat, &Dfg::build(&m));
        assert!(after.total.bram < before.total.bram);
        assert_eq!(before.total.bram - after.total.bram, 32);
        // group attrs present
        let chans = ChannelView::all(&m);
        assert_eq!(m.op(chans[0].op).int_attr("plm_group"), Some(0));
        assert_eq!(m.op(chans[1].op).int_attr("plm_group"), Some(0));
    }

    #[test]
    fn no_phases_no_change() {
        let mut b = DfgBuilder::new();
        let s1 = b.channel(32, ParamType::Small, 4096);
        let s2 = b.channel(32, ParamType::Small, 4096);
        b.kernel("k", &[s1, s2], &[], Default::default());
        let mut m = b.finish();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let out = PlmShare.run(&mut m, &ctx()).unwrap();
        assert!(!out.changed);
    }

    #[test]
    fn single_small_channel_noop() {
        let mut b = DfgBuilder::new();
        let s1 = b.channel(32, ParamType::Small, 4096);
        b.kernel("k", &[s1], &[], Default::default());
        let mut m = b.finish();
        Sanitize.run(&mut m, &ctx()).unwrap();
        assert!(!PlmShare.run(&mut m, &ctx()).unwrap().changed);
    }

    use crate::dialect::ParamType;
    use crate::ir::Module;
}
