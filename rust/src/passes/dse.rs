//! The Fig 3 optimization loop ("Olympus-Opt" box), built on the pluggable
//! [`crate::search`] framework: a [`SearchSpace`](crate::search::SearchSpace)
//! generates candidate pipeline schedules, an
//! [`Evaluator`](crate::search::Evaluator) scores them (analytic or
//! `des-score` fidelity), and a [`DriverKind`] policy decides which points
//! get evaluated:
//!
//! * **`exhaustive`** (default) — every point, bit-identical to the classic
//!   `olympus dse` walk;
//! * **`random`** — a seeded sample under a candidate budget;
//! * **`successive-halving`** — multi-fidelity: screen the whole space with
//!   the cheap analytic objective, promote only the top fraction to full
//!   (DES) evaluation;
//! * **`iterative`** — the Fig 3 greedy loop as the sole candidate.
//!
//! Three objectives are available:
//!
//! * **analytic** (default) — the static bandwidth + resource analyses:
//!   streaming makespan (seconds per app iteration over the bottleneck PC),
//!   tie-broken by resource use. Fast, but blind to compute time, HBM
//!   pseudo-channel contention and FIFO backpressure.
//! * **`des-score`** — every candidate is lowered to an [`Architecture`]
//!   and replayed through the discrete-event queueing simulator
//!   ([`crate::des`]) under a workload scenario; the score is the simulated
//!   scenario makespan. Slower, so candidates are evaluated in parallel
//!   (std threads, one cloned module per worker).
//! * **`slo-score`** — des-score plus an SLO penalty
//!   ([`crate::traffic::SloSpec`], `--slo "class=p99<MS"`): per-class p99
//!   overshoot and trace deadline misses add penalties that dominate any
//!   makespan, so the winner is the cheapest candidate that *meets the
//!   tail* — which can differ from the raw-throughput winner.
//!
//! Candidate pipelines ([`strategies`], expanded by
//! [`StrategyGrid`](crate::search::StrategyGrid)):
//!
//! | strategy          | pipeline                                             |
//! |-------------------|------------------------------------------------------|
//! | `baseline`        | sanitize                                             |
//! | `reassign`        | sanitize, channel-reassign                           |
//! | `iris`            | sanitize, iris, channel-reassign                     |
//! | `widen`           | sanitize, bus-widen, channel-reassign                |
//! | `replicate`       | sanitize, plm-share, replicate, channel-reassign     |
//! | `full`            | sanitize, plm-share, bus-widen, iris, replicate, channel-reassign |
//!
//! `replicate` factors are swept ({2, 4, 8, 16} by default, or
//! [`DseOptions::factors`]) inside the replication strategies.
//!
//! [`Architecture`]: crate::lower::Architecture

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::analysis::{analyze_bandwidth, analyze_resources, Dfg};
use crate::des::{simulate, simulate_arena, DesConfig, EngineArena, WorkloadScenario};
use crate::ir::{parse_module, print_module, Module};
use crate::lower::build_architecture;
use crate::platform::PlatformSpec;
use crate::search::{
    iterative_moves, normalize_factors, run_driver, DriverKind, Evaluator,
    MultiPlatformEvaluator, MultiPlatformGrid, ObjectiveEvaluator, StrategyGrid,
};
use crate::service::cache::EvalCache;
use crate::service::remote::{RemoteEvaluator, WorkerPool};
use crate::traffic::SloSpec;
use crate::util::{f64_from_bits_json, f64_to_bits_json, ContentHash, Json};

/// One evaluated candidate.
#[derive(Debug, Clone)]
pub struct DseCandidate {
    pub strategy: String,
    pub pipeline: String,
    pub makespan_s: f64,
    pub achieved_gbs: f64,
    pub efficiency: f64,
    pub utilization: f64,
    pub fits: bool,
    pub compute_units: usize,
    /// Simulated scenario makespan (des-score objective only).
    pub des_makespan_s: Option<f64>,
    /// Simulated p99 job latency (des-score objective only).
    pub des_p99_latency_s: Option<f64>,
    /// The value the winner was selected on (lower = better; infinite =
    /// infeasible under the objective).
    pub score: f64,
    /// Platform that scored this row (multi-platform searches only; `None`
    /// in classic single-platform reports). Like the row label, it is
    /// stamped by the evaluator layer after cache retrieval and is *not*
    /// part of the cached outcome — the platform fingerprint already
    /// addresses the cache entry.
    pub platform: Option<String>,
}

/// DSE outcome: the winning module + the full decision table, plus search
/// provenance (which driver ran, how much it cost).
pub struct DseReport {
    pub best: Module,
    pub best_strategy: String,
    pub candidates: Vec<DseCandidate>,
    /// Driver that produced this report (`exhaustive`, `random`, ...).
    pub driver: String,
    /// Points ranked at the cheap screening fidelity (multi-fidelity
    /// drivers only; 0 otherwise).
    pub screened: usize,
    /// Full-fidelity evaluations actually computed (cache hits excluded) —
    /// under `des-score` each one is a discrete-event simulation.
    pub full_evals: usize,
    /// Platform names searched when the platform itself was a search axis
    /// ([`run_dse_multi`] with two or more platforms); empty for classic
    /// single-platform reports. Order matches the requested list; the
    /// report renderer derives per-platform winner rows from it.
    pub platforms: Vec<String>,
}

/// How candidates are scored.
#[derive(Debug, Clone)]
pub enum DseObjective {
    /// Static analytic makespan (bandwidth analysis only).
    Analytic,
    /// Discrete-event simulation of `scenario` on each lowered candidate.
    DesScore { scenario: WorkloadScenario, config: DesConfig },
    /// SLO-aware DES: the simulated makespan plus a penalty that dominates
    /// it whenever a per-class p99 target ([`SloSpec`]) is violated or a
    /// trace deadline missed — so the winner is the architecture that
    /// *meets the tail*, not the one that merely drains the batch fastest.
    SloScore { scenario: WorkloadScenario, config: DesConfig, slo: SloSpec },
}

impl Default for DseObjective {
    fn default() -> Self {
        DseObjective::Analytic
    }
}

impl DseObjective {
    /// The standard des-score setup: a 4-iteration closed-loop batch.
    pub fn des_score() -> Self {
        DseObjective::DesScore {
            scenario: WorkloadScenario::closed_loop(4),
            config: DesConfig::default(),
        }
    }

    /// des-score under a caller-chosen scenario.
    pub fn des_score_with(scenario: WorkloadScenario, config: DesConfig) -> Self {
        DseObjective::DesScore { scenario, config }
    }

    /// slo-score: des-score plus SLO violation / deadline-miss penalties.
    pub fn slo_score_with(scenario: WorkloadScenario, config: DesConfig, slo: SloSpec) -> Self {
        DseObjective::SloScore { scenario, config, slo }
    }
}

/// Cached outcome of one candidate evaluation. `Infeasible` records a
/// pipeline the verifier rejected (worth remembering: re-deriving a failure
/// costs as much as deriving a success).
#[derive(Debug, Clone)]
pub enum CandidateOutcome {
    Evaluated { cand: DseCandidate, module: Module },
    Infeasible,
}

/// Content-addressed memo of candidate evaluations, keyed on
/// (module IR, platform spec, pipeline, objective). Shared across DSE runs
/// by the service so overlapping sweeps (same module on many platforms,
/// growing factor lists, CI re-runs) skip re-evaluation entirely.
pub type CandidateCache = EvalCache<CandidateOutcome>;

/// Cache key for one candidate evaluation. `module_fp`/`platform_fp` are the
/// stable fingerprints ([`module_fingerprint`],
/// [`PlatformSpec::fingerprint`]); `objective_desc` is the objective's
/// `Debug` rendering (covers scenario, seed and engine knobs). The driver is
/// deliberately *not* part of this key: a candidate evaluation means the
/// same thing whichever policy asked for it, which is what lets
/// successive-halving reuse work an exhaustive run already paid for.
///
/// [`module_fingerprint`]: crate::ir::module_fingerprint
pub fn candidate_cache_key(
    module_fp: &str,
    platform_fp: &str,
    pipeline: &str,
    objective_desc: &str,
) -> ContentHash {
    ContentHash::of_parts(&["olympus-cand-v1", module_fp, platform_fp, pipeline, objective_desc])
}

fn opt_f64_bits(x: Option<f64>) -> Json {
    match x {
        Some(v) => f64_to_bits_json(v),
        None => Json::Null,
    }
}

/// Serialize a cached outcome for the disk tier of the candidate cache
/// (`--cache-dir`; see [`crate::service::persist`]). The module travels as
/// its printed IR, floats as raw bit patterns, so a warm-started process
/// reconstructs exactly the value a fresh evaluation would produce.
pub fn outcome_to_json(o: &CandidateOutcome) -> Json {
    match o {
        CandidateOutcome::Infeasible => Json::obj(vec![("infeasible", true.into())]),
        CandidateOutcome::Evaluated { cand, module } => Json::obj(vec![
            ("strategy", cand.strategy.as_str().into()),
            ("pipeline", cand.pipeline.as_str().into()),
            ("makespan_s", f64_to_bits_json(cand.makespan_s)),
            ("achieved_gbs", f64_to_bits_json(cand.achieved_gbs)),
            ("efficiency", f64_to_bits_json(cand.efficiency)),
            ("utilization", f64_to_bits_json(cand.utilization)),
            ("fits", cand.fits.into()),
            ("compute_units", cand.compute_units.into()),
            ("des_makespan_s", opt_f64_bits(cand.des_makespan_s)),
            ("des_p99_latency_s", opt_f64_bits(cand.des_p99_latency_s)),
            ("score", f64_to_bits_json(cand.score)),
            ("module", print_module(module).into()),
        ]),
    }
}

/// Inverse of [`outcome_to_json`]. `None` marks a record this build cannot
/// decode (e.g. the stored IR no longer parses after a dialect change);
/// callers count it as corrupt-skipped and re-evaluate — never an error.
pub fn outcome_from_json(j: &Json) -> Option<CandidateOutcome> {
    if j.get("infeasible") == &Json::Bool(true) {
        return Some(CandidateOutcome::Infeasible);
    }
    let module = parse_module(j.get("module").as_str()?).ok()?;
    let opt_f64 = |k: &str| -> Option<Option<f64>> {
        match j.get(k) {
            Json::Null => Some(None),
            v => f64_from_bits_json(v).map(Some),
        }
    };
    let cand = DseCandidate {
        strategy: j.get("strategy").as_str()?.to_string(),
        pipeline: j.get("pipeline").as_str()?.to_string(),
        makespan_s: f64_from_bits_json(j.get("makespan_s"))?,
        achieved_gbs: f64_from_bits_json(j.get("achieved_gbs"))?,
        efficiency: f64_from_bits_json(j.get("efficiency"))?,
        utilization: f64_from_bits_json(j.get("utilization"))?,
        fits: j.get("fits") == &Json::Bool(true),
        compute_units: j.get("compute_units").as_usize()?,
        des_makespan_s: opt_f64("des_makespan_s")?,
        des_p99_latency_s: opt_f64("des_p99_latency_s")?,
        score: f64_from_bits_json(j.get("score"))?,
        // not serialized: the evaluator stamps it after retrieval
        platform: None,
    };
    Some(CandidateOutcome::Evaluated { cand, module })
}

/// Wire codec for remote candidate evaluation (`olympus worker`): the
/// objective travels as JSON (scenario + engine config, floats as raw bit
/// patterns), so the value a worker reconstructs `Debug`-renders — and
/// therefore computes [`candidate_cache_key`]s — byte-identically to the
/// coordinator's. The worker cross-checks the key it derives against the
/// one the coordinator routed by, so any codec skew fails structured
/// instead of silently caching under the wrong address.
pub fn objective_to_json(o: &DseObjective) -> Json {
    match o {
        DseObjective::Analytic => Json::obj(vec![("kind", "analytic".into())]),
        DseObjective::DesScore { scenario, config } => Json::obj(vec![
            ("kind", "des-score".into()),
            ("scenario", scenario.to_json()),
            ("config", config.to_json()),
        ]),
        DseObjective::SloScore { scenario, config, slo } => Json::obj(vec![
            ("kind", "slo-score".into()),
            ("scenario", scenario.to_json()),
            ("config", config.to_json()),
            // the spec grammar round-trips floats shortest-form, so the
            // reconstructed SloSpec Debug-renders byte-identically
            ("slo", slo.spec().into()),
        ]),
    }
}

/// Inverse of [`objective_to_json`]; `None` marks a value this build
/// cannot decode (callers answer with a structured error, never panic).
pub fn objective_from_json(j: &Json) -> Option<DseObjective> {
    match j.get("kind").as_str()? {
        "analytic" => Some(DseObjective::Analytic),
        "des-score" => Some(DseObjective::DesScore {
            scenario: WorkloadScenario::from_json(j.get("scenario"))?,
            config: DesConfig::from_json(j.get("config"))?,
        }),
        "slo-score" => Some(DseObjective::SloScore {
            scenario: WorkloadScenario::from_json(j.get("scenario"))?,
            config: DesConfig::from_json(j.get("config"))?,
            slo: SloSpec::parse(j.get("slo").as_str()?).ok()?,
        }),
        _ => None,
    }
}

/// DSE tuning knobs.
#[derive(Debug, Clone, Default)]
pub struct DseOptions {
    /// Replication factors swept (empty = {2, 4, 8, 16}). Normalized
    /// (sorted, deduplicated) before use; zero factors are rejected.
    pub factors: Vec<u64>,
    pub objective: DseObjective,
    /// Worker threads for candidate evaluation (0 = all available cores).
    pub threads: usize,
    /// Content-addressed evaluation memo (`None` = evaluate everything).
    /// Results are bit-identical with and without a cache; it only skips
    /// recomputation of candidates already evaluated under an identical
    /// (module, platform, pipeline, objective) key.
    pub cache: Option<Arc<CandidateCache>>,
    /// Search policy (exhaustive | random | successive-halving | iterative).
    pub driver: DriverKind,
    /// Remote evaluation pool (`olympus serve --workers`): full-fidelity
    /// candidate evaluations route to the worker owning each key's
    /// consistent-hash shard, falling back to local evaluation when a
    /// worker is unreachable. `None` evaluates everything in-process.
    /// Results are bit-identical either way — routing can only move *where*
    /// a deterministic evaluation runs.
    pub remote: Option<Arc<WorkerPool>>,
}

/// Strategy table (name, pipeline template).
pub fn strategies() -> Vec<(&'static str, &'static str)> {
    vec![
        ("baseline", "sanitize"),
        ("reassign", "sanitize, channel-reassign"),
        ("iris", "sanitize, iris, channel-reassign"),
        ("widen", "sanitize, bus-widen, channel-reassign"),
        (
            "replicate",
            "sanitize, plm-share, fifo-sizing, replicate{factor=FACTOR}, channel-reassign",
        ),
        (
            "full",
            "sanitize, plm-share, fifo-sizing, bus-widen, iris, replicate{factor=FACTOR}, channel-reassign",
        ),
    ]
}

fn evaluate(m: &Module, plat: &PlatformSpec) -> (f64, f64, f64, f64, bool, usize) {
    let dfg = Dfg::build(m);
    let bw = analyze_bandwidth(m, plat, &dfg);
    let res = analyze_resources(m, plat, &dfg);
    (
        bw.makespan_s,
        bw.achieved_gbs,
        bw.aggregate_efficiency,
        res.utilization,
        res.fits,
        dfg.compute_unit_count(m),
    )
}

/// Full candidate evaluation under `objective`; `strategy`/`pipeline` label
/// the row. Pure: same inputs give a bit-identical candidate, which is what
/// lets the service memoize it content-addressed.
pub fn evaluate_candidate(
    m: &Module,
    plat: &PlatformSpec,
    objective: &DseObjective,
    strategy: String,
    pipeline: String,
) -> DseCandidate {
    evaluate_candidate_arena(m, plat, objective, strategy, pipeline, &mut EngineArena::new())
}

/// [`evaluate_candidate`] against a caller-owned DES arena, so a sweep's
/// thousands of simulations reuse one warm allocation set
/// ([`ObjectiveEvaluator`](crate::search::ObjectiveEvaluator) pools them).
/// Bit-identical to the fresh-arena path.
pub fn evaluate_candidate_arena(
    m: &Module,
    plat: &PlatformSpec,
    objective: &DseObjective,
    strategy: String,
    pipeline: String,
    arena: &mut EngineArena,
) -> DseCandidate {
    let (makespan, gbs, eff, util, fits, cus) = evaluate(m, plat);
    let mut cand = DseCandidate {
        strategy,
        pipeline,
        makespan_s: makespan,
        achieved_gbs: gbs,
        efficiency: eff,
        utilization: util,
        fits,
        compute_units: cus,
        des_makespan_s: None,
        des_p99_latency_s: None,
        score: if fits && makespan > 0.0 { makespan } else { f64::INFINITY },
        platform: None,
    };
    let (scenario, config, slo) = match objective {
        DseObjective::Analytic => return cand,
        DseObjective::DesScore { scenario, config } => (scenario, config, None),
        DseObjective::SloScore { scenario, config, slo } => (scenario, config, Some(slo)),
    };
    let mut cfg = config.clone();
    cfg.utilization = util;
    let sim =
        build_architecture(m, plat).and_then(|arch| simulate_arena(&arch, scenario, &cfg, arena));
    match sim {
        Ok(rep) => {
            cand.des_makespan_s = Some(rep.makespan_s);
            cand.des_p99_latency_s = Some(rep.p99_job_latency_s);
            cand.score = if fits
                && rep.makespan_s > 0.0
                && rep.jobs_completed == rep.jobs_released
            {
                // slo-score: any violated target or missed deadline adds a
                // penalty that dominates every makespan, so a compliant
                // candidate always outranks a violating one
                rep.makespan_s + slo.map(|s| s.penalty(&rep)).unwrap_or(0.0)
            } else {
                f64::INFINITY
            };
        }
        Err(_) => cand.score = f64::INFINITY, // unlowerable / wedged candidate
    }
    cand
}

/// The paper's *iterative* optimize loop (Fig 3: "iterates over the
/// Olympus-Opt analyses and transformations"), ported onto the search
/// framework: [`greedy_descent`](crate::search::greedy_descent) screens
/// every move with the analytic fidelity each round and keeps the single
/// best-improving one; stops at a fixpoint (or after `max_rounds`). Returns
/// the final module and the applied pass sequence.
pub fn run_iterative(
    input: &Module,
    plat: &PlatformSpec,
    max_rounds: usize,
) -> Result<(Module, Vec<String>)> {
    let objective = DseObjective::Analytic;
    let evaluator = ObjectiveEvaluator::new(input, plat, &objective, 1, None);
    crate::search::greedy_descent(&evaluator, &iterative_moves(), max_rounds)
}

/// Run DSE over the strategy grid with full control over factors,
/// objective, parallelism and search policy. Candidate evaluation is
/// deterministic regardless of thread count: results land in per-point
/// slots and the winner scan is sequential.
pub fn run_dse_with(
    input: &Module,
    plat: &PlatformSpec,
    opts: &DseOptions,
) -> Result<DseReport> {
    let factors = normalize_factors(&opts.factors).map_err(|e| anyhow::anyhow!(e))?;
    let space = StrategyGrid::new(&factors);
    if let Some(pool) = opts.remote.as_ref().filter(|p| !p.is_empty()) {
        let evaluator = RemoteEvaluator::new(
            pool.clone(),
            input,
            plat,
            &opts.objective,
            opts.threads,
            opts.cache.clone(),
        );
        return run_driver(&opts.driver, &space, &evaluator);
    }
    let evaluator =
        ObjectiveEvaluator::new(input, plat, &opts.objective, opts.threads, opts.cache.clone());
    run_driver(&opts.driver, &space, &evaluator)
}

/// Run DSE with the analytic objective and the exhaustive driver. `factors`
/// are the replication factors swept for the replication strategies
/// (empty = {2, 4, 8, 16}).
pub fn run_dse(input: &Module, plat: &PlatformSpec, factors: &[u64]) -> Result<DseReport> {
    run_dse_with(
        input,
        plat,
        &DseOptions { factors: factors.to_vec(), ..DseOptions::default() },
    )
}

/// Run DSE with the *platform itself as a search axis*: the strategy grid
/// crossed with `platforms` ([`MultiPlatformGrid`]), every (platform,
/// schedule) pair scored by that platform's own evaluator
/// ([`MultiPlatformEvaluator`]) and the winner picked across the whole
/// product space. Candidate rows come back platform-qualified
/// (`u280/widen`) and platform-stamped; [`DseReport::platforms`] records
/// the searched list.
///
/// A one-platform list delegates to [`run_dse_with`] bit-identically
/// (`platforms` stays empty), so callers can route every request through
/// here. Duplicate platform names are rejected — they would evaluate the
/// same sub-space twice under colliding labels.
pub fn run_dse_multi(
    input: &Module,
    platforms: &[PlatformSpec],
    opts: &DseOptions,
) -> Result<DseReport> {
    let mut seen = std::collections::BTreeSet::new();
    for p in platforms {
        if !seen.insert(p.name.as_str()) {
            bail!("platform '{}' listed more than once in the search axis", p.name);
        }
    }
    match platforms {
        [] => bail!("cross-platform DSE needs at least one platform"),
        [only] => return run_dse_with(input, only, opts),
        _ => {}
    }
    let names: Vec<String> = platforms.iter().map(|p| p.name.clone()).collect();

    // The iterative driver grows one schedule move-by-move through
    // `screen_from`, which carries no platform index to partition on — run
    // it per platform and merge, keeping the first-minimum winner rule
    // over the platform-major candidate order.
    if matches!(opts.driver, DriverKind::Iterative { .. }) {
        let mut candidates = Vec::new();
        let mut screened = 0;
        let mut full_evals = 0;
        let mut best: Option<(f64, Module, String)> = None;
        for plat in platforms {
            let rep = run_dse_with(input, plat, opts)?;
            screened += rep.screened;
            full_evals += rep.full_evals;
            let score = rep
                .candidates
                .iter()
                .find(|c| c.strategy == rep.best_strategy)
                .map(|c| c.score)
                .unwrap_or(f64::INFINITY);
            if score.is_finite()
                && best.as_ref().map(|(b, _, _)| score < *b).unwrap_or(true)
            {
                let label = format!("{}/{}", plat.name, rep.best_strategy);
                best = Some((score, rep.best.clone(), label));
            }
            for mut c in rep.candidates {
                c.strategy = format!("{}/{}", plat.name, c.strategy);
                c.platform = Some(plat.name.clone());
                candidates.push(c);
            }
        }
        let (_, best_m, best_strategy) =
            best.ok_or_else(|| anyhow!("no feasible DSE candidate on any platform"))?;
        return Ok(DseReport {
            best: best_m,
            best_strategy,
            candidates,
            driver: opts.driver.name().to_string(),
            screened,
            full_evals,
            platforms: names,
        });
    }

    let factors = normalize_factors(&opts.factors).map_err(|e| anyhow!(e))?;
    let space = MultiPlatformGrid::new(StrategyGrid::new(&factors), names.clone());
    let mut inner: Vec<Box<dyn Evaluator + '_>> = Vec::with_capacity(platforms.len());
    for plat in platforms {
        match opts.remote.as_ref().filter(|p| !p.is_empty()) {
            Some(pool) => inner.push(Box::new(RemoteEvaluator::new(
                pool.clone(),
                input,
                plat,
                &opts.objective,
                opts.threads,
                opts.cache.clone(),
            ))),
            None => inner.push(Box::new(ObjectiveEvaluator::new(
                input,
                plat,
                &opts.objective,
                opts.threads,
                opts.cache.clone(),
            ))),
        }
    }
    let evaluator = MultiPlatformEvaluator::new(names.clone(), inner);
    let mut rep = run_driver(&opts.driver, &space, &evaluator)?;
    rep.platforms = names;
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::dialect::{DfgBuilder, KernelEst, ParamType, ResourceVec};
    use crate::passes::manager::{parse_pipeline, PassContext};
    use crate::platform::builtin;

    #[test]
    fn dse_beats_baseline_on_u280() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let rep = run_dse(&m, &plat, &[2, 4]).unwrap();
        let base = rep
            .candidates
            .iter()
            .find(|c| c.strategy == "baseline")
            .expect("baseline evaluated");
        let best = rep
            .candidates
            .iter()
            .filter(|c| c.fits)
            .min_by(|a, b| a.makespan_s.partial_cmp(&b.makespan_s).unwrap())
            .unwrap();
        assert!(
            best.makespan_s < base.makespan_s / 4.0,
            "optimization should win big: base {} best {} ({})",
            base.makespan_s,
            best.makespan_s,
            best.strategy
        );
        assert_ne!(rep.best_strategy, "baseline");
        assert_eq!(rep.driver, "exhaustive");
    }

    #[test]
    fn all_strategies_evaluated() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let rep = run_dse(&m, &plat, &[2]).unwrap();
        for s in ["baseline", "reassign", "iris", "widen"] {
            assert!(
                rep.candidates.iter().any(|c| c.strategy.starts_with(s)),
                "missing strategy {s}"
            );
        }
        // analytic mode leaves the DES columns empty
        assert!(rep.candidates.iter().all(|c| c.des_makespan_s.is_none()));
        // exhaustive evaluated the whole grid (6 variants + iterative)
        assert_eq!(rep.full_evals, 7);
        assert_eq!(rep.screened, 0);
    }

    #[test]
    fn iterative_loop_reaches_fixpoint_and_improves() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let (opt, applied) = run_iterative(&m, &plat, 8).unwrap();
        assert_eq!(applied[0], "sanitize");
        assert!(applied.len() >= 2, "at least one improving move: {applied:?}");
        let base = {
            let mut b = m.clone();
            let mut ctx = PassContext::new(plat.clone());
            parse_pipeline("sanitize", &mut ctx).unwrap().run(&mut b, &ctx).unwrap();
            evaluate(&b, &plat).0
        };
        let (mk, _, _, _, fits, _) = evaluate(&opt, &plat);
        assert!(fits);
        assert!(mk < base, "iterative must improve: {mk} vs {base}");
        // fixpoint: running again from the result applies nothing new
        let (_, applied2) = run_iterative(&opt, &plat, 8).unwrap();
        assert!(applied2.len() <= applied.len());
    }

    #[test]
    fn dse_table_includes_iterative() {
        let rep = run_dse(&fig4a_module(), &builtin("u280").unwrap(), &[2]).unwrap();
        assert!(rep.candidates.iter().any(|c| c.strategy == "iterative"));
    }

    #[test]
    fn factors_are_deduplicated_and_sorted() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let messy = run_dse(&m, &plat, &[4, 2, 2, 4]).unwrap();
        let clean = run_dse(&m, &plat, &[2, 4]).unwrap();
        assert_eq!(messy.candidates.len(), clean.candidates.len());
        assert_eq!(messy.best_strategy, clean.best_strategy);
        for (a, b) in messy.candidates.iter().zip(&clean.candidates) {
            assert_eq!(a.strategy, b.strategy);
            assert_eq!(a.score, b.score);
        }
        // zero factors are a structured error, not a silent no-op
        assert!(run_dse(&m, &plat, &[0]).is_err());
    }

    #[test]
    fn ddr_only_platform_still_works() {
        let m = fig4a_module();
        let plat = builtin("generic-ddr").unwrap();
        let rep = run_dse(&m, &plat, &[2]).unwrap();
        assert!(!rep.candidates.is_empty());
        // a feasible best exists even without HBM
        assert!(rep.candidates.iter().any(|c| c.fits));
    }

    /// Two 64-bit streams through one kernel: each channel alone saturates
    /// a single PC, so the platform with the fastest *single* memory
    /// channel wins — generic-ddr's 19.2 GB/s DDR4-2400 beats one
    /// 14.4 GB/s HBM pseudo-channel, and replication cannot rescue the
    /// U280 because clones replay the full payload per PC.
    fn low_parallelism_module() -> crate::ir::Module {
        let mut b = DfgBuilder::new();
        let a = b.channel(64, ParamType::Stream, 4096);
        let o = b.channel(64, ParamType::Stream, 4096);
        b.kernel(
            "copy_4096",
            &[a],
            &[o],
            KernelEst { latency: 100, ii: 1, res: ResourceVec::new(4000, 5000, 2, 0, 4) },
        );
        b.finish()
    }

    #[test]
    fn cross_platform_dse_picks_the_platform_per_workload() {
        let plats = [builtin("u280").unwrap(), builtin("generic-ddr").unwrap()];
        let opts = DseOptions { factors: vec![2], ..DseOptions::default() };

        // many parallel streams: u280 spreads them one-per-HBM-PC while
        // generic-ddr piles them onto its 2 DDR channels
        let wide = fig4a_module();
        let rep = run_dse_multi(&wide, &plats, &opts).unwrap();
        assert_eq!(rep.platforms, ["u280", "generic-ddr"]);
        assert_eq!(rep.driver, "exhaustive");
        let win =
            rep.candidates.iter().find(|c| c.strategy == rep.best_strategy).unwrap();
        assert_eq!(win.platform.as_deref(), Some("u280"), "winner {}", rep.best_strategy);
        assert!(rep.best_strategy.starts_with("u280/"), "{}", rep.best_strategy);

        // a single stream pair: no parallelism for the HBM fabric to
        // exploit, so the faster individual DDR channel wins
        let narrow = low_parallelism_module();
        let rep = run_dse_multi(&narrow, &plats, &opts).unwrap();
        let win =
            rep.candidates.iter().find(|c| c.strategy == rep.best_strategy).unwrap();
        assert_eq!(
            win.platform.as_deref(),
            Some("generic-ddr"),
            "winner {}",
            rep.best_strategy
        );

        // every row is platform-stamped and platform-qualified
        for c in &rep.candidates {
            let p = c.platform.as_deref().expect("row stamped with its platform");
            assert!(c.strategy.starts_with(&format!("{p}/")), "{}", c.strategy);
        }
    }

    #[test]
    fn run_dse_multi_rejects_duplicates_and_delegates_single() {
        let m = fig4a_module();
        let u = builtin("u280").unwrap();
        let err = run_dse_multi(&m, &[u.clone(), u.clone()], &DseOptions::default())
            .unwrap_err();
        assert!(err.to_string().contains("more than once"), "{err}");
        // a one-platform list is the classic single-platform search
        let opts = DseOptions { factors: vec![2], ..DseOptions::default() };
        let multi = run_dse_multi(&m, &[u.clone()], &opts).unwrap();
        let single = run_dse(&m, &u, &[2]).unwrap();
        assert!(multi.platforms.is_empty(), "one platform is not an axis");
        assert_eq!(multi.best_strategy, single.best_strategy);
        assert_eq!(multi.candidates.len(), single.candidates.len());
        for (a, b) in multi.candidates.iter().zip(&single.candidates) {
            assert_eq!(a.strategy, b.strategy);
            assert_eq!(a.score.to_bits(), b.score.to_bits());
            assert_eq!(a.platform, None);
        }
    }

    #[test]
    fn multi_platform_and_single_platform_runs_share_the_cache() {
        let m = fig4a_module();
        let plats = [builtin("u280").unwrap(), builtin("generic-ddr").unwrap()];
        let cache = std::sync::Arc::new(CandidateCache::new());
        let opts = |c: Option<std::sync::Arc<CandidateCache>>| DseOptions {
            factors: vec![2],
            cache: c,
            ..DseOptions::default()
        };
        // warm the memo with two classic single-platform runs
        let su = run_dse_with(&m, &plats[0], &opts(Some(cache.clone()))).unwrap();
        let sg = run_dse_with(&m, &plats[1], &opts(Some(cache.clone()))).unwrap();
        let misses = cache.stats().misses;
        assert_eq!(misses, 14, "7 grid points per platform, keyed apart");
        // the multi-platform sweep answers every point from the memo...
        let warm = run_dse_multi(&m, &plats, &opts(Some(cache.clone()))).unwrap();
        assert_eq!(cache.stats().misses, misses, "multi run recomputes nothing");
        assert_eq!(warm.full_evals, 0);
        // ...bit-identically to a cold multi-platform run
        let cold = run_dse_multi(&m, &plats, &opts(None)).unwrap();
        assert_eq!(warm.best_strategy, cold.best_strategy);
        assert_eq!(warm.candidates.len(), cold.candidates.len());
        for (a, b) in warm.candidates.iter().zip(&cold.candidates) {
            assert_eq!(a.strategy, b.strategy);
            assert_eq!(a.platform, b.platform);
            assert_eq!(a.score.to_bits(), b.score.to_bits());
        }
        // labels come back platform-qualified even though the memo
        // journaled them under the single-platform labels...
        assert!(warm.candidates.iter().all(|c| c.strategy.contains('/')));
        // ...and each single-platform table matches its slice of the
        // platform-major multi table
        for (rep, name) in [(&su, "u280"), (&sg, "generic-ddr")] {
            let slice: Vec<_> = warm
                .candidates
                .iter()
                .filter(|c| c.platform.as_deref() == Some(name))
                .collect();
            assert_eq!(slice.len(), rep.candidates.len());
            for (a, b) in slice.iter().zip(&rep.candidates) {
                assert_eq!(a.strategy, format!("{name}/{}", b.strategy));
                assert_eq!(a.score.to_bits(), b.score.to_bits());
            }
        }
    }

    /// A compute-heavy app: big streams, deeply pipelined kernel (II = 8).
    /// The static objective only sees memory beats; the DES sees that the
    /// single CU is the real bottleneck.
    fn compute_heavy_module() -> crate::ir::Module {
        let mut b = DfgBuilder::new();
        let a = b.channel(32, ParamType::Stream, 8192);
        let c = b.channel(32, ParamType::Stream, 8192);
        let o = b.channel(32, ParamType::Stream, 8192);
        b.kernel(
            "vecadd_1024",
            &[a, c],
            &[o],
            KernelEst {
                latency: 4000,
                ii: 8,
                res: ResourceVec::new(4000, 5000, 2, 0, 4),
            },
        );
        b.finish()
    }

    fn des_opts(threads: usize) -> DseOptions {
        DseOptions {
            factors: vec![2],
            objective: DseObjective::des_score_with(
                WorkloadScenario::closed_loop(2),
                DesConfig::default(),
            ),
            threads,
            ..DseOptions::default()
        }
    }

    #[test]
    fn des_score_flips_winner_on_contention_heavy_input() {
        // On a 2-channel DDR board the analytic objective ties widen with
        // iris on beats and keeps iris (first in table order) — it cannot
        // see that the II=8 kernel makes every candidate compute-bound.
        // The DES sees lane-parallel compute and flips the winner.
        let m = compute_heavy_module();
        let plat = builtin("generic-ddr").unwrap();
        let analytic = run_dse(&m, &plat, &[2]).unwrap();
        let des = run_dse_with(&m, &plat, &des_opts(1)).unwrap();
        assert_ne!(
            analytic.best_strategy, des.best_strategy,
            "objectives must disagree on this input (analytic {} vs des {})",
            analytic.best_strategy, des.best_strategy
        );
        // the DES winner must be a compute-parallel strategy
        assert!(
            ["widen", "replicate", "full", "iterative"]
                .iter()
                .any(|s| des.best_strategy.starts_with(s)),
            "des winner {} should parallelize compute",
            des.best_strategy
        );
        // and the des columns are populated with finite values
        let w = des.candidates.iter().find(|c| c.strategy == des.best_strategy).unwrap();
        assert!(w.des_makespan_s.unwrap() > 0.0);
        assert!(w.score.is_finite());
        // compute dominance: the des makespan of the analytic winner is far
        // worse than its own analytic makespan claims
        let iris = des
            .candidates
            .iter()
            .find(|c| c.strategy == analytic.best_strategy)
            .expect("analytic winner scored under des too");
        assert!(
            iris.des_makespan_s.unwrap() > 5.0 * iris.makespan_s,
            "contention/compute must dwarf the static estimate: des {} static {}",
            iris.des_makespan_s.unwrap(),
            iris.makespan_s
        );
    }

    /// Wide (64-bit) streams on the 64-bit-PC DDR board: bus-widen has no
    /// lane headroom (ratio 1) and Iris cannot pack full words, so compute
    /// parallelism can only come from replication; II = 16 makes every
    /// candidate deeply compute-bound.
    fn replication_only_module() -> crate::ir::Module {
        let mut b = DfgBuilder::new();
        let a = b.channel(64, ParamType::Stream, 4096);
        let c = b.channel(64, ParamType::Stream, 4096);
        let o = b.channel(64, ParamType::Stream, 4096);
        b.kernel(
            "wide_mul_4096",
            &[a, c],
            &[o],
            KernelEst { latency: 2000, ii: 16, res: ResourceVec::new(4000, 5000, 2, 0, 4) },
        );
        b.finish()
    }

    #[test]
    fn replica_striping_flips_des_score_winner() {
        use crate::des::DesConfig;
        let m = replication_only_module();
        let plat = builtin("generic-ddr").unwrap();
        let opts_with = |stripe: bool| DseOptions {
            factors: vec![2, 4],
            objective: DseObjective::des_score_with(
                WorkloadScenario::closed_loop(2),
                DesConfig { stripe_replicas: stripe, ..DesConfig::default() },
            ),
            threads: 1,
            ..DseOptions::default()
        };
        let unstriped = run_dse_with(&m, &plat, &opts_with(false)).unwrap();
        let striped = run_dse_with(&m, &plat, &opts_with(true)).unwrap();
        // without striping every replica replays the full job, so
        // replication is pure contention and cannot win...
        assert!(
            !unstriped.best_strategy.starts_with("replicate")
                && !unstriped.best_strategy.starts_with("full"),
            "unstriped winner {}",
            unstriped.best_strategy
        );
        // ...with striping the job splits across replicas and replication
        // wins on throughput: the des-score winner changes because of it
        assert!(
            striped.best_strategy.starts_with("replicate")
                || striped.best_strategy.starts_with("full"),
            "striped winner {}",
            striped.best_strategy
        );
        assert_ne!(unstriped.best_strategy, striped.best_strategy);
        // and the win is real: ~Nx less work per replica
        let best_striped = striped
            .candidates
            .iter()
            .find(|c| c.strategy == striped.best_strategy)
            .unwrap();
        let best_unstriped = unstriped
            .candidates
            .iter()
            .find(|c| c.strategy == unstriped.best_strategy)
            .unwrap();
        assert!(
            best_striped.des_makespan_s.unwrap() < 0.6 * best_unstriped.des_makespan_s.unwrap(),
            "striped {} vs unstriped {}",
            best_striped.des_makespan_s.unwrap(),
            best_unstriped.des_makespan_s.unwrap()
        );
    }

    /// The acceptance pin for `slo-score`: a DSE space where the candidate
    /// that drains the batch fastest does *not* have the tightest tail, so
    /// the two objectives crown different winners. Heavy-tailed (Pareto)
    /// service makes the p99 and makespan orderings disagree on many seeds;
    /// the test walks a pinned seed range, finds the first disagreement, and
    /// places the SLO bound between the two tails — from there the outcome
    /// is structural: the rival complies (score = its makespan, milliseconds)
    /// while the throughput winner pays the 1e6/s overshoot penalty.
    #[test]
    fn slo_score_picks_a_different_winner_than_des_score() {
        use crate::des::ServiceDist;
        let m = replication_only_module();
        let plat = builtin("generic-ddr").unwrap();
        // calibrate the offered load off the single-CU design: one
        // closed-loop iteration under deterministic service measures the
        // per-job service time, so the rate overloads factor 1 (~2x) while
        // factor 4 runs at half load — the replicated designs contend, the
        // flat ones drown, and the interesting ordering is among replicas
        let mut base = m.clone();
        let mut ctx = PassContext::new(plat.clone());
        parse_pipeline("sanitize", &mut ctx).unwrap().run(&mut base, &ctx).unwrap();
        let arch = build_architecture(&base, &plat).unwrap();
        let cal =
            simulate(&arch, &WorkloadScenario::closed_loop(1), &DesConfig::default()).unwrap();
        let scenario = WorkloadScenario::poisson(2.0 / cal.makespan_s, 120);
        let opts = |seed: u64, slo: Option<SloSpec>| {
            let config = DesConfig {
                seed,
                burst_elems: 512,
                service_dist: ServiceDist::Pareto { alpha: 1.4 },
                ..DesConfig::default()
            };
            let objective = match slo {
                Some(s) => DseObjective::slo_score_with(scenario.clone(), config, s),
                None => DseObjective::des_score_with(scenario.clone(), config),
            };
            DseOptions { factors: vec![2, 3, 4], objective, threads: 2, ..DseOptions::default() }
        };
        let mut diverged = false;
        for seed in 0..64_u64 {
            let des = run_dse_with(&m, &plat, &opts(seed, None)).unwrap();
            let w =
                des.candidates.iter().find(|c| c.strategy == des.best_strategy).unwrap();
            let (Some(w_mk), Some(w_p99)) = (w.des_makespan_s, w.des_p99_latency_s) else {
                panic!("des-score winner must carry DES columns")
            };
            // the tightest tail among the losers; a clear (>20%) gap below
            // the winner's tail leaves room to pin an SLO bound between them
            let Some(rival) = des
                .candidates
                .iter()
                .filter(|c| c.score.is_finite() && c.strategy != des.best_strategy)
                .filter(|c| c.des_p99_latency_s.is_some())
                .min_by(|a, b| a.des_p99_latency_s.partial_cmp(&b.des_p99_latency_s).unwrap())
            else {
                continue;
            };
            let r_p99 = rival.des_p99_latency_s.unwrap();
            if r_p99 >= 0.8 * w_p99 {
                continue;
            }
            let t_ms = 0.5 * (r_p99 + w_p99) * 1e3;
            let slo = SloSpec::parse(&format!("*=p99<{t_ms}")).unwrap();
            let rep = run_dse_with(&m, &plat, &opts(seed, Some(slo))).unwrap();
            assert_ne!(
                rep.best_strategy, des.best_strategy,
                "seed {seed}: slo-score must dethrone the makespan winner \
                 (winner p99 {w_p99} vs rival p99 {r_p99}, bound {t_ms} ms)"
            );
            let sw =
                rep.candidates.iter().find(|c| c.strategy == rep.best_strategy).unwrap();
            // the slo winner trades raw throughput for the tail
            assert!(sw.des_p99_latency_s.unwrap() < w_p99);
            assert!(sw.des_makespan_s.unwrap() >= w_mk);
            diverged = true;
            break;
        }
        assert!(diverged, "no seed in 0..64 produced a latency/throughput tension");
    }

    #[test]
    fn candidate_cache_skips_recomputation_bit_identically() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let cache = std::sync::Arc::new(CandidateCache::new());
        let mut opts = des_opts(2);
        opts.cache = Some(cache.clone());
        let cold = run_dse_with(&m, &plat, &opts).unwrap();
        let cold_misses = cache.stats().misses;
        // every variant (6 table entries for factors=[2]) + iterative keyed
        // and evaluated exactly once, feasible or not
        assert_eq!(cold_misses, 7);
        assert!(cold.candidates.len() <= 7);
        assert_eq!(cold.full_evals as u64, cold_misses);
        let warm = run_dse_with(&m, &plat, &opts).unwrap();
        let s = cache.stats();
        assert_eq!(s.misses, cold_misses, "warm run must not recompute anything");
        assert!(s.hits >= cold_misses, "warm run served from cache: {s:?}");
        assert_eq!(warm.full_evals, 0, "warm run computes nothing at full fidelity");
        // cache answers are bit-identical to fresh evaluation
        let plain = run_dse_with(&m, &plat, &des_opts(1)).unwrap();
        for rep in [&warm, &plain] {
            assert_eq!(cold.best_strategy, rep.best_strategy);
            assert_eq!(cold.candidates.len(), rep.candidates.len());
            for (a, b) in cold.candidates.iter().zip(&rep.candidates) {
                assert_eq!(a.strategy, b.strategy);
                assert_eq!(a.score, b.score, "{}", a.strategy);
                assert_eq!(a.des_makespan_s, b.des_makespan_s, "{}", a.strategy);
            }
        }
    }

    #[test]
    fn outcome_codec_round_trips_bit_identically() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let mut opt = m.clone();
        let mut ctx = PassContext::new(plat.clone());
        parse_pipeline("sanitize, iris, channel-reassign", &mut ctx)
            .unwrap()
            .run(&mut opt, &ctx)
            .unwrap();
        let cand = evaluate_candidate(
            &opt,
            &plat,
            &DseObjective::Analytic,
            "iris".to_string(),
            "sanitize, iris, channel-reassign".to_string(),
        );
        // an infinite score (infeasible under the objective) must survive
        // the trip — JSON numbers cannot carry inf, the bit encoding can
        let mut inf_cand = cand.clone();
        inf_cand.score = f64::INFINITY;
        for cand in [cand, inf_cand] {
            let outcome = CandidateOutcome::Evaluated { cand, module: opt.clone() };
            let text = outcome_to_json(&outcome).to_string();
            let back = outcome_from_json(&Json::parse(&text).unwrap()).expect("decodes");
            let (CandidateOutcome::Evaluated { cand: a, module: ma },
                 CandidateOutcome::Evaluated { cand: b, module: mb }) = (&outcome, &back)
            else {
                panic!("variant changed in round trip");
            };
            assert_eq!(a.strategy, b.strategy);
            assert_eq!(a.pipeline, b.pipeline);
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
            assert_eq!(a.achieved_gbs.to_bits(), b.achieved_gbs.to_bits());
            assert_eq!(a.efficiency.to_bits(), b.efficiency.to_bits());
            assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
            assert_eq!(a.score.to_bits(), b.score.to_bits());
            assert_eq!(a.des_makespan_s, b.des_makespan_s);
            assert_eq!(a.des_p99_latency_s, b.des_p99_latency_s);
            assert_eq!((a.fits, a.compute_units), (b.fits, b.compute_units));
            assert_eq!(print_module(ma), print_module(mb), "module survives verbatim");
        }
        // the infeasible marker round-trips too, and garbage decodes to None
        let infeasible = outcome_to_json(&CandidateOutcome::Infeasible).to_string();
        assert!(matches!(
            outcome_from_json(&Json::parse(&infeasible).unwrap()),
            Some(CandidateOutcome::Infeasible)
        ));
        assert!(outcome_from_json(&Json::parse("{}").unwrap()).is_none());
    }

    #[test]
    fn objective_codec_round_trips_debug_identically() {
        use crate::des::ServiceDist;
        let objectives = vec![
            DseObjective::Analytic,
            DseObjective::des_score(),
            DseObjective::des_score_with(
                WorkloadScenario::poisson(1000.0, 8),
                DesConfig {
                    // above 2^53: must survive the wire exactly (u64 fields
                    // travel as decimal strings, not f64-backed numbers)
                    seed: (1u64 << 60) + 3,
                    service_dist: ServiceDist::Exponential,
                    cu_service_dists: vec![("cu_k".to_string(), ServiceDist::Deterministic)],
                    ..DesConfig::default()
                },
            ),
        ];
        for o in &objectives {
            let text = objective_to_json(o).to_string();
            let back = objective_from_json(&Json::parse(&text).unwrap()).expect("decodes");
            // the Debug rendering is the objective slice of every candidate
            // cache key: a worker must reproduce it byte-for-byte
            assert_eq!(format!("{back:?}"), format!("{o:?}"));
        }
        assert!(objective_from_json(&Json::parse("{}").unwrap()).is_none());
        assert!(objective_from_json(&Json::parse(r#"{"kind": "des-score"}"#).unwrap()).is_none());
    }

    #[test]
    fn des_score_is_deterministic_and_thread_invariant() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let a = run_dse_with(&m, &plat, &des_opts(1)).unwrap();
        let b = run_dse_with(&m, &plat, &des_opts(4)).unwrap();
        assert_eq!(a.best_strategy, b.best_strategy);
        assert_eq!(a.candidates.len(), b.candidates.len());
        for (x, y) in a.candidates.iter().zip(&b.candidates) {
            assert_eq!(x.strategy, y.strategy);
            assert_eq!(x.score, y.score, "{}", x.strategy);
            assert_eq!(x.des_makespan_s, y.des_makespan_s, "{}", x.strategy);
            assert_eq!(x.des_p99_latency_s, y.des_p99_latency_s, "{}", x.strategy);
        }
    }
}
