//! The Fig 3 iterative optimization loop ("Olympus-Opt" box): candidate
//! strategies are applied to clones of the input, evaluated with an
//! objective, and the best design is returned.
//!
//! Two objectives are available:
//!
//! * **analytic** (default) — the static bandwidth + resource analyses:
//!   streaming makespan (seconds per app iteration over the bottleneck PC),
//!   tie-broken by resource use. Fast, but blind to compute time, HBM
//!   pseudo-channel contention and FIFO backpressure.
//! * **`des-score`** — every candidate is lowered to an [`Architecture`]
//!   and replayed through the discrete-event queueing simulator
//!   ([`crate::des`]) under a workload scenario; the score is the simulated
//!   scenario makespan. Slower, so candidates are evaluated in parallel
//!   (std threads, one cloned module per worker).
//!
//! Candidate pipelines:
//!
//! | strategy          | pipeline                                             |
//! |-------------------|------------------------------------------------------|
//! | `baseline`        | sanitize                                             |
//! | `reassign`        | sanitize, channel-reassign                           |
//! | `iris`            | sanitize, iris, channel-reassign                     |
//! | `widen`           | sanitize, bus-widen, channel-reassign                |
//! | `replicate`       | sanitize, plm-share, replicate, channel-reassign     |
//! | `full`            | sanitize, plm-share, bus-widen, iris, replicate, channel-reassign |
//!
//! `replicate` factors are swept ({2, 4, 8, 16} by default, or
//! [`DseOptions::factors`]) inside the replication strategies.
//!
//! [`Architecture`]: crate::lower::Architecture

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::analysis::{analyze_bandwidth, analyze_resources, Dfg};
use crate::des::{simulate, DesConfig, WorkloadScenario};
use crate::ir::{module_fingerprint, Module};
use crate::lower::build_architecture;
use crate::platform::PlatformSpec;
use crate::service::cache::EvalCache;
use crate::util::ContentHash;

use super::manager::{parse_pipeline, PassContext};

/// One evaluated candidate.
#[derive(Debug, Clone)]
pub struct DseCandidate {
    pub strategy: String,
    pub pipeline: String,
    pub makespan_s: f64,
    pub achieved_gbs: f64,
    pub efficiency: f64,
    pub utilization: f64,
    pub fits: bool,
    pub compute_units: usize,
    /// Simulated scenario makespan (des-score objective only).
    pub des_makespan_s: Option<f64>,
    /// Simulated p99 job latency (des-score objective only).
    pub des_p99_latency_s: Option<f64>,
    /// The value the winner was selected on (lower = better; infinite =
    /// infeasible under the objective).
    pub score: f64,
}

/// DSE outcome: the winning module + the full decision table.
pub struct DseReport {
    pub best: Module,
    pub best_strategy: String,
    pub candidates: Vec<DseCandidate>,
}

/// How candidates are scored.
#[derive(Debug, Clone)]
pub enum DseObjective {
    /// Static analytic makespan (bandwidth analysis only).
    Analytic,
    /// Discrete-event simulation of `scenario` on each lowered candidate.
    DesScore { scenario: WorkloadScenario, config: DesConfig },
}

impl Default for DseObjective {
    fn default() -> Self {
        DseObjective::Analytic
    }
}

impl DseObjective {
    /// The standard des-score setup: a 4-iteration closed-loop batch.
    pub fn des_score() -> Self {
        DseObjective::DesScore {
            scenario: WorkloadScenario::closed_loop(4),
            config: DesConfig::default(),
        }
    }

    /// des-score under a caller-chosen scenario.
    pub fn des_score_with(scenario: WorkloadScenario, config: DesConfig) -> Self {
        DseObjective::DesScore { scenario, config }
    }
}

/// Cached outcome of one candidate evaluation. `Infeasible` records a
/// pipeline the verifier rejected (worth remembering: re-deriving a failure
/// costs as much as deriving a success).
#[derive(Debug, Clone)]
pub enum CandidateOutcome {
    Evaluated { cand: DseCandidate, module: Module },
    Infeasible,
}

/// Content-addressed memo of candidate evaluations, keyed on
/// (module IR, platform spec, pipeline, objective). Shared across DSE runs
/// by the service so overlapping sweeps (same module on many platforms,
/// growing factor lists, CI re-runs) skip re-evaluation entirely.
pub type CandidateCache = EvalCache<CandidateOutcome>;

/// Cache key for one candidate evaluation. `module_fp`/`platform_fp` are the
/// stable fingerprints ([`module_fingerprint`],
/// [`PlatformSpec::fingerprint`]); `objective_desc` is the objective's
/// `Debug` rendering (covers scenario, seed and engine knobs).
pub fn candidate_cache_key(
    module_fp: &str,
    platform_fp: &str,
    pipeline: &str,
    objective_desc: &str,
) -> ContentHash {
    ContentHash::of_parts(&["olympus-cand-v1", module_fp, platform_fp, pipeline, objective_desc])
}

/// Synthetic pipeline tag keying the Fig 3 iterative-loop candidate.
const ITERATIVE_TAG: &str = "@iterative{max_rounds=8}";

/// DSE tuning knobs.
#[derive(Debug, Clone, Default)]
pub struct DseOptions {
    /// Replication factors swept (empty = {2, 4, 8, 16}).
    pub factors: Vec<u64>,
    pub objective: DseObjective,
    /// Worker threads for candidate evaluation (0 = all available cores).
    pub threads: usize,
    /// Content-addressed evaluation memo (`None` = evaluate everything).
    /// Results are bit-identical with and without a cache; it only skips
    /// recomputation of candidates already evaluated under an identical
    /// (module, platform, pipeline, objective) key.
    pub cache: Option<Arc<CandidateCache>>,
}

/// Strategy table (name, pipeline template).
pub fn strategies() -> Vec<(&'static str, &'static str)> {
    vec![
        ("baseline", "sanitize"),
        ("reassign", "sanitize, channel-reassign"),
        ("iris", "sanitize, iris, channel-reassign"),
        ("widen", "sanitize, bus-widen, channel-reassign"),
        ("replicate", "sanitize, plm-share, fifo-sizing, replicate{factor=FACTOR}, channel-reassign"),
        (
            "full",
            "sanitize, plm-share, fifo-sizing, bus-widen, iris, replicate{factor=FACTOR}, channel-reassign",
        ),
    ]
}

fn evaluate(m: &Module, plat: &PlatformSpec) -> (f64, f64, f64, f64, bool, usize) {
    let dfg = Dfg::build(m);
    let bw = analyze_bandwidth(m, plat, &dfg);
    let res = analyze_resources(m, plat, &dfg);
    (
        bw.makespan_s,
        bw.achieved_gbs,
        bw.aggregate_efficiency,
        res.utilization,
        res.fits,
        dfg.compute_unit_count(m),
    )
}

/// Full candidate evaluation under `objective`; `strategy`/`pipeline` label
/// the row. Pure: same inputs give a bit-identical candidate, which is what
/// lets the service memoize it content-addressed.
pub fn evaluate_candidate(
    m: &Module,
    plat: &PlatformSpec,
    objective: &DseObjective,
    strategy: String,
    pipeline: String,
) -> DseCandidate {
    let (makespan, gbs, eff, util, fits, cus) = evaluate(m, plat);
    let mut cand = DseCandidate {
        strategy,
        pipeline,
        makespan_s: makespan,
        achieved_gbs: gbs,
        efficiency: eff,
        utilization: util,
        fits,
        compute_units: cus,
        des_makespan_s: None,
        des_p99_latency_s: None,
        score: if fits && makespan > 0.0 { makespan } else { f64::INFINITY },
    };
    if let DseObjective::DesScore { scenario, config } = objective {
        let mut cfg = config.clone();
        cfg.utilization = util;
        let sim = build_architecture(m, plat).and_then(|arch| simulate(&arch, scenario, &cfg));
        match sim {
            Ok(rep) => {
                cand.des_makespan_s = Some(rep.makespan_s);
                cand.des_p99_latency_s = Some(rep.p99_job_latency_s);
                cand.score = if fits
                    && rep.makespan_s > 0.0
                    && rep.jobs_completed == rep.jobs_released
                {
                    rep.makespan_s
                } else {
                    f64::INFINITY
                };
            }
            Err(_) => cand.score = f64::INFINITY, // unlowerable / wedged candidate
        }
    }
    cand
}

/// The paper's *iterative* optimize loop (Fig 3: "iterates over the
/// Olympus-Opt analyses and transformations"): starting from sanitized IR,
/// each round evaluates every applicable transformation with the analyses
/// and keeps the single best-improving one; stops at a fixpoint (or after
/// `max_rounds`). Returns the final module and the applied pass sequence.
pub fn run_iterative(
    input: &Module,
    plat: &PlatformSpec,
    max_rounds: usize,
) -> Result<(Module, Vec<String>)> {
    let mut ctx = PassContext::new(plat.clone());
    let mut m = input.clone();
    parse_pipeline("sanitize", &mut ctx)?.run(&mut m, &ctx)?;
    let mut applied = vec!["sanitize".to_string()];
    let moves = [
        "channel-reassign",
        "iris, channel-reassign",
        "bus-widen, channel-reassign",
        "plm-share",
        "fifo-sizing",
        "replicate{factor=2}, channel-reassign",
    ];
    for _ in 0..max_rounds {
        let (cur_makespan, _, _, cur_util, cur_fits, _) = evaluate(&m, plat);
        let mut best: Option<(f64, Module, &str)> = None;
        for mv in moves {
            let mut trial = m.clone();
            let mut tctx = PassContext::new(plat.clone());
            let Ok(pm) = parse_pipeline(mv, &mut tctx) else { continue };
            if pm.run(&mut trial, &tctx).is_err() {
                continue;
            }
            let (mk, _, _, util, fits, _) = evaluate(&trial, plat);
            // objective: makespan, but never trade feasibility away; prefer
            // lower utilization on ties (plm-share/fifo-sizing enablers)
            let improves = (fits || !cur_fits)
                && (mk < cur_makespan * (1.0 - 1e-9)
                    || (mk <= cur_makespan * (1.0 + 1e-9) && util < cur_util - 1e-9));
            if improves && best.as_ref().map(|(b, _, _)| mk < *b).unwrap_or(true) {
                best = Some((mk, trial, mv));
            }
        }
        match best {
            Some((_, next, mv)) => {
                m = next;
                applied.push(mv.to_string());
            }
            None => break, // fixpoint: no transformation helps
        }
    }
    Ok((m, applied))
}

/// Run DSE over the strategy table with full control over factors,
/// objective and parallelism. Candidate evaluation is deterministic
/// regardless of thread count: results land in per-variant slots and the
/// winner scan is sequential.
pub fn run_dse_with(
    input: &Module,
    plat: &PlatformSpec,
    opts: &DseOptions,
) -> Result<DseReport> {
    let default_factors = [2u64, 4, 8, 16];
    let factors =
        if opts.factors.is_empty() { &default_factors[..] } else { &opts.factors[..] };

    // expand the strategy table into concrete (label, pipeline) variants
    let mut variants: Vec<(String, String)> = Vec::new();
    for (name, template) in strategies() {
        if template.contains("FACTOR") {
            for f in factors {
                variants.push((
                    format!("{name}(x{f})"),
                    template.replace("FACTOR", &f.to_string()),
                ));
            }
        } else {
            variants.push((name.to_string(), template.to_string()));
        }
    }

    let n = variants.len();
    let threads = if opts.threads == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    } else {
        opts.threads
    }
    .clamp(1, n);

    // fingerprints are computed once per run; only cache-enabled runs pay
    // for them when a variant actually needs a key
    let module_fp = opts.cache.as_ref().map(|_| module_fingerprint(input));
    let plat_fp = opts.cache.as_ref().map(|_| plat.fingerprint());
    let obj_desc = format!("{:?}", opts.objective);

    // Evaluate one (label, pipeline) variant from scratch.
    let eval_variant = |label: &str, pipeline: &str| -> CandidateOutcome {
        if pipeline == ITERATIVE_TAG {
            // the Fig 3 iterative loop competes as its own candidate
            return match run_iterative(input, plat, 8) {
                Ok((m, applied)) => {
                    let cand = evaluate_candidate(
                        &m,
                        plat,
                        &opts.objective,
                        "iterative".to_string(),
                        applied.join("; "),
                    );
                    CandidateOutcome::Evaluated { cand, module: m }
                }
                Err(_) => CandidateOutcome::Infeasible,
            };
        }
        let mut m = input.clone();
        let mut ctx = PassContext::new(plat.clone());
        let Ok(pm) = parse_pipeline(pipeline, &mut ctx) else {
            return CandidateOutcome::Infeasible;
        };
        if pm.run(&mut m, &ctx).is_err() {
            return CandidateOutcome::Infeasible; // verifier rejected
        }
        let cand =
            evaluate_candidate(&m, plat, &opts.objective, label.to_string(), pipeline.to_string());
        CandidateOutcome::Evaluated { cand, module: m }
    };
    // Same, answered through the content-addressed memo when one is wired
    // in (single-flight: concurrent identical evaluations compute once).
    let memoized = |label: &str, pipeline: &str| -> CandidateOutcome {
        match &opts.cache {
            Some(cache) => {
                let key = candidate_cache_key(
                    module_fp.as_deref().unwrap_or(""),
                    plat_fp.as_deref().unwrap_or(""),
                    pipeline,
                    &obj_desc,
                );
                cache.get_or_compute(key, || eval_variant(label, pipeline)).0
            }
            None => eval_variant(label, pipeline),
        }
    };

    let slots: Mutex<Vec<Option<(DseCandidate, Module)>>> =
        Mutex::new((0..n).map(|_| None).collect());
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let (label, pipeline) = &variants[i];
                if let CandidateOutcome::Evaluated { cand, module } = memoized(label, pipeline) {
                    slots.lock().unwrap()[i] = Some((cand, module));
                }
            });
        }
    });

    let mut candidates = Vec::new();
    let mut best: Option<(f64, Module, String)> = None;
    for slot in slots.into_inner().unwrap() {
        let Some((cand, m)) = slot else { continue };
        if cand.score.is_finite()
            && best.as_ref().map(|(b, _, _)| cand.score < *b).unwrap_or(true)
        {
            best = Some((cand.score, m, cand.strategy.clone()));
        }
        candidates.push(cand);
    }

    if let CandidateOutcome::Evaluated { cand, module } = memoized("iterative", ITERATIVE_TAG) {
        if cand.score.is_finite()
            && best.as_ref().map(|(b, _, _)| cand.score < *b).unwrap_or(true)
        {
            best = Some((cand.score, module, cand.strategy.clone()));
        }
        candidates.push(cand);
    }

    let (_, best_m, best_strategy) =
        best.ok_or_else(|| anyhow::anyhow!("no feasible DSE candidate"))?;
    Ok(DseReport { best: best_m, best_strategy, candidates })
}

/// Run DSE with the analytic objective. `factors` are the replication
/// factors swept for the replication strategies (empty = {2, 4, 8, 16}).
pub fn run_dse(input: &Module, plat: &PlatformSpec, factors: &[u64]) -> Result<DseReport> {
    run_dse_with(
        input,
        plat,
        &DseOptions { factors: factors.to_vec(), ..DseOptions::default() },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::dialect::{DfgBuilder, KernelEst, ParamType, ResourceVec};
    use crate::platform::builtin;

    #[test]
    fn dse_beats_baseline_on_u280() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let rep = run_dse(&m, &plat, &[2, 4]).unwrap();
        let base = rep
            .candidates
            .iter()
            .find(|c| c.strategy == "baseline")
            .expect("baseline evaluated");
        let best = rep
            .candidates
            .iter()
            .filter(|c| c.fits)
            .min_by(|a, b| a.makespan_s.partial_cmp(&b.makespan_s).unwrap())
            .unwrap();
        assert!(
            best.makespan_s < base.makespan_s / 4.0,
            "optimization should win big: base {} best {} ({})",
            base.makespan_s,
            best.makespan_s,
            best.strategy
        );
        assert_ne!(rep.best_strategy, "baseline");
    }

    #[test]
    fn all_strategies_evaluated() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let rep = run_dse(&m, &plat, &[2]).unwrap();
        for s in ["baseline", "reassign", "iris", "widen"] {
            assert!(
                rep.candidates.iter().any(|c| c.strategy.starts_with(s)),
                "missing strategy {s}"
            );
        }
        // analytic mode leaves the DES columns empty
        assert!(rep.candidates.iter().all(|c| c.des_makespan_s.is_none()));
    }

    #[test]
    fn iterative_loop_reaches_fixpoint_and_improves() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let (opt, applied) = run_iterative(&m, &plat, 8).unwrap();
        assert_eq!(applied[0], "sanitize");
        assert!(applied.len() >= 2, "at least one improving move: {applied:?}");
        let base = {
            let mut b = m.clone();
            let mut ctx = PassContext::new(plat.clone());
            parse_pipeline("sanitize", &mut ctx).unwrap().run(&mut b, &ctx).unwrap();
            evaluate(&b, &plat).0
        };
        let (mk, _, _, _, fits, _) = evaluate(&opt, &plat);
        assert!(fits);
        assert!(mk < base, "iterative must improve: {mk} vs {base}");
        // fixpoint: running again from the result applies nothing new
        let (_, applied2) = run_iterative(&opt, &plat, 8).unwrap();
        assert!(applied2.len() <= applied.len());
    }

    #[test]
    fn dse_table_includes_iterative() {
        let rep = run_dse(&fig4a_module(), &builtin("u280").unwrap(), &[2]).unwrap();
        assert!(rep.candidates.iter().any(|c| c.strategy == "iterative"));
    }

    #[test]
    fn ddr_only_platform_still_works() {
        let m = fig4a_module();
        let plat = builtin("generic-ddr").unwrap();
        let rep = run_dse(&m, &plat, &[2]).unwrap();
        assert!(!rep.candidates.is_empty());
        // a feasible best exists even without HBM
        assert!(rep.candidates.iter().any(|c| c.fits));
    }

    /// A compute-heavy app: big streams, deeply pipelined kernel (II = 8).
    /// The static objective only sees memory beats; the DES sees that the
    /// single CU is the real bottleneck.
    fn compute_heavy_module() -> crate::ir::Module {
        let mut b = DfgBuilder::new();
        let a = b.channel(32, ParamType::Stream, 8192);
        let c = b.channel(32, ParamType::Stream, 8192);
        let o = b.channel(32, ParamType::Stream, 8192);
        b.kernel(
            "vecadd_1024",
            &[a, c],
            &[o],
            KernelEst {
                latency: 4000,
                ii: 8,
                res: ResourceVec::new(4000, 5000, 2, 0, 4),
            },
        );
        b.finish()
    }

    fn des_opts(threads: usize) -> DseOptions {
        DseOptions {
            factors: vec![2],
            objective: DseObjective::des_score_with(
                WorkloadScenario::closed_loop(2),
                DesConfig::default(),
            ),
            threads,
            cache: None,
        }
    }

    #[test]
    fn des_score_flips_winner_on_contention_heavy_input() {
        // On a 2-channel DDR board the analytic objective ties widen with
        // iris on beats and keeps iris (first in table order) — it cannot
        // see that the II=8 kernel makes every candidate compute-bound.
        // The DES sees lane-parallel compute and flips the winner.
        let m = compute_heavy_module();
        let plat = builtin("generic-ddr").unwrap();
        let analytic = run_dse(&m, &plat, &[2]).unwrap();
        let des = run_dse_with(&m, &plat, &des_opts(1)).unwrap();
        assert_ne!(
            analytic.best_strategy, des.best_strategy,
            "objectives must disagree on this input (analytic {} vs des {})",
            analytic.best_strategy, des.best_strategy
        );
        // the DES winner must be a compute-parallel strategy
        assert!(
            ["widen", "replicate", "full", "iterative"]
                .iter()
                .any(|s| des.best_strategy.starts_with(s)),
            "des winner {} should parallelize compute",
            des.best_strategy
        );
        // and the des columns are populated with finite values
        let w = des.candidates.iter().find(|c| c.strategy == des.best_strategy).unwrap();
        assert!(w.des_makespan_s.unwrap() > 0.0);
        assert!(w.score.is_finite());
        // compute dominance: the des makespan of the analytic winner is far
        // worse than its own analytic makespan claims
        let iris = des
            .candidates
            .iter()
            .find(|c| c.strategy == analytic.best_strategy)
            .expect("analytic winner scored under des too");
        assert!(
            iris.des_makespan_s.unwrap() > 5.0 * iris.makespan_s,
            "contention/compute must dwarf the static estimate: des {} static {}",
            iris.des_makespan_s.unwrap(),
            iris.makespan_s
        );
    }

    /// Wide (64-bit) streams on the 64-bit-PC DDR board: bus-widen has no
    /// lane headroom (ratio 1) and Iris cannot pack full words, so compute
    /// parallelism can only come from replication; II = 16 makes every
    /// candidate deeply compute-bound.
    fn replication_only_module() -> crate::ir::Module {
        let mut b = DfgBuilder::new();
        let a = b.channel(64, ParamType::Stream, 4096);
        let c = b.channel(64, ParamType::Stream, 4096);
        let o = b.channel(64, ParamType::Stream, 4096);
        b.kernel(
            "wide_mul_4096",
            &[a, c],
            &[o],
            KernelEst { latency: 2000, ii: 16, res: ResourceVec::new(4000, 5000, 2, 0, 4) },
        );
        b.finish()
    }

    #[test]
    fn replica_striping_flips_des_score_winner() {
        use crate::des::DesConfig;
        let m = replication_only_module();
        let plat = builtin("generic-ddr").unwrap();
        let opts_with = |stripe: bool| DseOptions {
            factors: vec![2, 4],
            objective: DseObjective::des_score_with(
                WorkloadScenario::closed_loop(2),
                DesConfig { stripe_replicas: stripe, ..DesConfig::default() },
            ),
            threads: 1,
            cache: None,
        };
        let unstriped = run_dse_with(&m, &plat, &opts_with(false)).unwrap();
        let striped = run_dse_with(&m, &plat, &opts_with(true)).unwrap();
        // without striping every replica replays the full job, so
        // replication is pure contention and cannot win...
        assert!(
            !unstriped.best_strategy.starts_with("replicate")
                && !unstriped.best_strategy.starts_with("full"),
            "unstriped winner {}",
            unstriped.best_strategy
        );
        // ...with striping the job splits across replicas and replication
        // wins on throughput: the des-score winner changes because of it
        assert!(
            striped.best_strategy.starts_with("replicate")
                || striped.best_strategy.starts_with("full"),
            "striped winner {}",
            striped.best_strategy
        );
        assert_ne!(unstriped.best_strategy, striped.best_strategy);
        // and the win is real: ~Nx less work per replica
        let best_striped = striped
            .candidates
            .iter()
            .find(|c| c.strategy == striped.best_strategy)
            .unwrap();
        let best_unstriped = unstriped
            .candidates
            .iter()
            .find(|c| c.strategy == unstriped.best_strategy)
            .unwrap();
        assert!(
            best_striped.des_makespan_s.unwrap() < 0.6 * best_unstriped.des_makespan_s.unwrap(),
            "striped {} vs unstriped {}",
            best_striped.des_makespan_s.unwrap(),
            best_unstriped.des_makespan_s.unwrap()
        );
    }

    #[test]
    fn candidate_cache_skips_recomputation_bit_identically() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let cache = std::sync::Arc::new(CandidateCache::new());
        let mut opts = des_opts(2);
        opts.cache = Some(cache.clone());
        let cold = run_dse_with(&m, &plat, &opts).unwrap();
        let cold_misses = cache.stats().misses;
        // every variant (6 table entries for factors=[2]) + iterative keyed
        // and evaluated exactly once, feasible or not
        assert_eq!(cold_misses, 7);
        assert!(cold.candidates.len() <= 7);
        let warm = run_dse_with(&m, &plat, &opts).unwrap();
        let s = cache.stats();
        assert_eq!(s.misses, cold_misses, "warm run must not recompute anything");
        assert!(s.hits >= cold_misses, "warm run served from cache: {s:?}");
        // cache answers are bit-identical to fresh evaluation
        let plain = run_dse_with(&m, &plat, &des_opts(1)).unwrap();
        for rep in [&warm, &plain] {
            assert_eq!(cold.best_strategy, rep.best_strategy);
            assert_eq!(cold.candidates.len(), rep.candidates.len());
            for (a, b) in cold.candidates.iter().zip(&rep.candidates) {
                assert_eq!(a.strategy, b.strategy);
                assert_eq!(a.score, b.score, "{}", a.strategy);
                assert_eq!(a.des_makespan_s, b.des_makespan_s, "{}", a.strategy);
            }
        }
    }

    #[test]
    fn des_score_is_deterministic_and_thread_invariant() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let a = run_dse_with(&m, &plat, &des_opts(1)).unwrap();
        let b = run_dse_with(&m, &plat, &des_opts(4)).unwrap();
        assert_eq!(a.best_strategy, b.best_strategy);
        assert_eq!(a.candidates.len(), b.candidates.len());
        for (x, y) in a.candidates.iter().zip(&b.candidates) {
            assert_eq!(x.strategy, y.strategy);
            assert_eq!(x.score, y.score, "{}", x.strategy);
            assert_eq!(x.des_makespan_s, y.des_makespan_s, "{}", x.strategy);
            assert_eq!(x.des_p99_latency_s, y.des_p99_latency_s, "{}", x.strategy);
        }
    }
}
