//! The Fig 3 iterative optimization loop ("Olympus-Opt" box): candidate
//! strategies are applied to clones of the input, evaluated with the
//! bandwidth + resource analyses, and the best design is returned.
//!
//! The objective is streaming makespan (seconds per app iteration over the
//! bottleneck PC), tie-broken by resource use. Candidate pipelines:
//!
//! | strategy          | pipeline                                             |
//! |-------------------|------------------------------------------------------|
//! | `baseline`        | sanitize                                             |
//! | `reassign`        | sanitize, channel-reassign                           |
//! | `iris`            | sanitize, iris, channel-reassign                     |
//! | `widen`           | sanitize, bus-widen, channel-reassign                |
//! | `replicate`       | sanitize, plm-share, replicate, channel-reassign     |
//! | `full`            | sanitize, plm-share, bus-widen, iris, replicate, channel-reassign |
//!
//! `replicate` factors are swept (1, 2, 4, …, headroom) inside the
//! replication strategies.

use anyhow::Result;

use crate::analysis::{analyze_bandwidth, analyze_resources, Dfg};
use crate::ir::Module;
use crate::platform::PlatformSpec;

use super::manager::{parse_pipeline, PassContext};

/// One evaluated candidate.
#[derive(Debug, Clone)]
pub struct DseCandidate {
    pub strategy: String,
    pub pipeline: String,
    pub makespan_s: f64,
    pub achieved_gbs: f64,
    pub efficiency: f64,
    pub utilization: f64,
    pub fits: bool,
    pub compute_units: usize,
}

/// DSE outcome: the winning module + the full decision table.
pub struct DseReport {
    pub best: Module,
    pub best_strategy: String,
    pub candidates: Vec<DseCandidate>,
}

/// Strategy table (name, pipeline template).
pub fn strategies() -> Vec<(&'static str, &'static str)> {
    vec![
        ("baseline", "sanitize"),
        ("reassign", "sanitize, channel-reassign"),
        ("iris", "sanitize, iris, channel-reassign"),
        ("widen", "sanitize, bus-widen, channel-reassign"),
        ("replicate", "sanitize, plm-share, fifo-sizing, replicate{factor=FACTOR}, channel-reassign"),
        (
            "full",
            "sanitize, plm-share, fifo-sizing, bus-widen, iris, replicate{factor=FACTOR}, channel-reassign",
        ),
    ]
}

fn evaluate(m: &Module, plat: &PlatformSpec) -> (f64, f64, f64, f64, bool, usize) {
    let dfg = Dfg::build(m);
    let bw = analyze_bandwidth(m, plat, &dfg);
    let res = analyze_resources(m, plat, &dfg);
    (
        bw.makespan_s,
        bw.achieved_gbs,
        bw.aggregate_efficiency,
        res.utilization,
        res.fits,
        dfg.compute_unit_count(m),
    )
}

/// The paper's *iterative* optimize loop (Fig 3: "iterates over the
/// Olympus-Opt analyses and transformations"): starting from sanitized IR,
/// each round evaluates every applicable transformation with the analyses
/// and keeps the single best-improving one; stops at a fixpoint (or after
/// `max_rounds`). Returns the final module and the applied pass sequence.
pub fn run_iterative(
    input: &Module,
    plat: &PlatformSpec,
    max_rounds: usize,
) -> Result<(Module, Vec<String>)> {
    let mut ctx = PassContext::new(plat.clone());
    let mut m = input.clone();
    parse_pipeline("sanitize", &mut ctx)?.run(&mut m, &ctx)?;
    let mut applied = vec!["sanitize".to_string()];
    let moves = [
        "channel-reassign",
        "iris, channel-reassign",
        "bus-widen, channel-reassign",
        "plm-share",
        "fifo-sizing",
        "replicate{factor=2}, channel-reassign",
    ];
    for _ in 0..max_rounds {
        let (cur_makespan, _, _, cur_util, cur_fits, _) = evaluate(&m, plat);
        let mut best: Option<(f64, Module, &str)> = None;
        for mv in moves {
            let mut trial = m.clone();
            let mut tctx = PassContext::new(plat.clone());
            let Ok(pm) = parse_pipeline(mv, &mut tctx) else { continue };
            if pm.run(&mut trial, &tctx).is_err() {
                continue;
            }
            let (mk, _, _, util, fits, _) = evaluate(&trial, plat);
            // objective: makespan, but never trade feasibility away; prefer
            // lower utilization on ties (plm-share/fifo-sizing enablers)
            let improves = (fits || !cur_fits)
                && (mk < cur_makespan * (1.0 - 1e-9)
                    || (mk <= cur_makespan * (1.0 + 1e-9) && util < cur_util - 1e-9));
            if improves && best.as_ref().map(|(b, _, _)| mk < *b).unwrap_or(true) {
                best = Some((mk, trial, mv));
            }
        }
        match best {
            Some((_, next, mv)) => {
                m = next;
                applied.push(mv.to_string());
            }
            None => break, // fixpoint: no transformation helps
        }
    }
    Ok((m, applied))
}

/// Run DSE over the strategy table. `factors` are the replication factors
/// swept for the replication strategies (empty = {2, 4, 8}).
pub fn run_dse(input: &Module, plat: &PlatformSpec, factors: &[u64]) -> Result<DseReport> {
    let default_factors = [2u64, 4, 8, 16];
    let factors = if factors.is_empty() { &default_factors[..] } else { factors };
    let mut candidates = Vec::new();
    let mut best: Option<(f64, Module, String)> = None;

    for (name, template) in strategies() {
        let variants: Vec<(String, String)> = if template.contains("FACTOR") {
            factors
                .iter()
                .map(|f| {
                    (format!("{name}(x{f})"), template.replace("FACTOR", &f.to_string()))
                })
                .collect()
        } else {
            vec![(name.to_string(), template.to_string())]
        };
        for (label, pipeline) in variants {
            let mut m = input.clone();
            let mut ctx = PassContext::new(plat.clone());
            let pm = parse_pipeline(&pipeline, &mut ctx)?;
            if pm.run(&mut m, &ctx).is_err() {
                continue; // infeasible candidate (verifier rejected)
            }
            let (makespan, gbs, eff, util, fits, cus) = evaluate(&m, plat);
            candidates.push(DseCandidate {
                strategy: label.clone(),
                pipeline: pipeline.clone(),
                makespan_s: makespan,
                achieved_gbs: gbs,
                efficiency: eff,
                utilization: util,
                fits,
                compute_units: cus,
            });
            if !fits || makespan <= 0.0 {
                continue;
            }
            if best.as_ref().map(|(b, _, _)| makespan < *b).unwrap_or(true) {
                best = Some((makespan, m, label));
            }
        }
    }
    // the Fig 3 iterative loop competes as its own candidate
    if let Ok((m, applied)) = run_iterative(input, plat, 8) {
        let (makespan, gbs, eff, util, fits, cus) = evaluate(&m, plat);
        candidates.push(DseCandidate {
            strategy: "iterative".to_string(),
            pipeline: applied.join("; "),
            makespan_s: makespan,
            achieved_gbs: gbs,
            efficiency: eff,
            utilization: util,
            fits,
            compute_units: cus,
        });
        if fits
            && makespan > 0.0
            && best.as_ref().map(|(b, _, _)| makespan < *b).unwrap_or(true)
        {
            best = Some((makespan, m, "iterative".to_string()));
        }
    }
    let (_, best_m, best_strategy) =
        best.ok_or_else(|| anyhow::anyhow!("no feasible DSE candidate"))?;
    Ok(DseReport { best: best_m, best_strategy, candidates })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::platform::builtin;

    #[test]
    fn dse_beats_baseline_on_u280() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let rep = run_dse(&m, &plat, &[2, 4]).unwrap();
        let base = rep
            .candidates
            .iter()
            .find(|c| c.strategy == "baseline")
            .expect("baseline evaluated");
        let best = rep
            .candidates
            .iter()
            .filter(|c| c.fits)
            .min_by(|a, b| a.makespan_s.partial_cmp(&b.makespan_s).unwrap())
            .unwrap();
        assert!(
            best.makespan_s < base.makespan_s / 4.0,
            "optimization should win big: base {} best {} ({})",
            base.makespan_s,
            best.makespan_s,
            best.strategy
        );
        assert_ne!(rep.best_strategy, "baseline");
    }

    #[test]
    fn all_strategies_evaluated() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let rep = run_dse(&m, &plat, &[2]).unwrap();
        for s in ["baseline", "reassign", "iris", "widen"] {
            assert!(
                rep.candidates.iter().any(|c| c.strategy.starts_with(s)),
                "missing strategy {s}"
            );
        }
    }

    #[test]
    fn iterative_loop_reaches_fixpoint_and_improves() {
        let m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let (opt, applied) = run_iterative(&m, &plat, 8).unwrap();
        assert_eq!(applied[0], "sanitize");
        assert!(applied.len() >= 2, "at least one improving move: {applied:?}");
        let base = {
            let mut b = m.clone();
            let mut ctx = PassContext::new(plat.clone());
            parse_pipeline("sanitize", &mut ctx).unwrap().run(&mut b, &ctx).unwrap();
            evaluate(&b, &plat).0
        };
        let (mk, _, _, _, fits, _) = evaluate(&opt, &plat);
        assert!(fits);
        assert!(mk < base, "iterative must improve: {mk} vs {base}");
        // fixpoint: running again from the result applies nothing new
        let (_, applied2) = run_iterative(&opt, &plat, 8).unwrap();
        assert!(applied2.len() <= applied.len());
    }

    #[test]
    fn dse_table_includes_iterative() {
        let rep = run_dse(&fig4a_module(), &builtin("u280").unwrap(), &[2]).unwrap();
        assert!(rep.candidates.iter().any(|c| c.strategy == "iterative"));
    }

    #[test]
    fn ddr_only_platform_still_works() {
        let m = fig4a_module();
        let plat = builtin("generic-ddr").unwrap();
        let rep = run_dse(&m, &plat, &[2]).unwrap();
        assert!(!rep.candidates.is_empty());
        // a feasible best exists even without HBM
        assert!(rep.candidates.iter().any(|c| c.fits));
    }
}
