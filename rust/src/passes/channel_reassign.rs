//! Channel-reassignment pass (paper §V-B, Fig 5).
//!
//! Distributes PC terminals across the platform's physical memory channels
//! to increase aggregate bandwidth. Greedy LPT: channels are sorted by
//! descending beat demand and each is placed on the least-loaded compatible
//! physical channel, subject to **capacity**: an HBM pseudo-channel on the
//! U280 fronts a 256 MB bank, so buffers that don't fit (big `complex`
//! regions) fall back to the 16 GB DDR banks — this is the platform
//! awareness of the paper's title.

use anyhow::Result;

use crate::analysis::Dfg;
use crate::dialect::ParamType;
use crate::ir::Module;
use crate::platform::MemKind;

use super::manager::{Pass, PassContext, PassOutcome};

pub struct ChannelReassign;

impl Pass for ChannelReassign {
    fn name(&self) -> &'static str {
        "channel-reassign"
    }

    fn run(&self, m: &mut Module, ctx: &PassContext) -> Result<PassOutcome> {
        let dfg = Dfg::build(m);
        let plat = &ctx.platform;
        // (pc terminal op, beats demanded, bytes stored, wants_hbm)
        let mut work: Vec<(crate::dialect::PcView, u64, u64, bool)> = Vec::new();
        for b in &dfg.memory_channels {
            let ch = b.channel;
            let layout = ch.layout(m);
            let (word_bits, words) = match &layout {
                Some(l) => (l.word_bits.max(1), l.depth),
                None => (ch.elem_bits(m).max(1), ch.depth(m)),
            };
            let bytes = ch.payload_bits(m).div_ceil(8);
            let wants_hbm = ch.param_type(m) != Some(ParamType::Complex);
            for pc in &b.pcs {
                // beats on the *widest* port kind is a fine load proxy
                let beats = words * (word_bits as u64).div_ceil(256);
                work.push((*pc, beats.max(1), bytes, wants_hbm));
            }
        }
        if work.is_empty() {
            return Ok(PassOutcome::unchanged());
        }
        // LPT: biggest demand first
        work.sort_by(|a, b| b.1.cmp(&a.1));

        let hbm_ids = plat.pc_ids(MemKind::Hbm);
        let ddr_ids = plat.pc_ids(MemKind::Ddr);
        let all_ids: Vec<u32> = (0..plat.num_pcs() as u32).collect();
        let mut load = vec![0u64; plat.num_pcs()];
        let mut stored = vec![0u64; plat.num_pcs()];

        let mut changed = false;
        let mut spilled = 0usize;
        for (pc, beats, bytes, wants_hbm) in work {
            let preferred: &[u32] = if wants_hbm && !hbm_ids.is_empty() {
                &hbm_ids
            } else if !wants_hbm && !ddr_ids.is_empty() {
                // complex data prefers the big DDR banks when present
                &ddr_ids
            } else {
                &all_ids
            };
            // capacity filter: buffer must fit the bank alongside what's
            // already placed there (capacity 0 = unspecified = unlimited)
            let fits = |id: u32| {
                let cap = plat.pcs[id as usize].capacity_bytes;
                cap == 0 || stored[id as usize] + bytes <= cap
            };
            let pick = |ids: &[u32]| {
                ids.iter().filter(|&&id| fits(id)).min_by_key(|&&id| load[id as usize]).copied()
            };
            let best = match pick(preferred) {
                Some(id) => id,
                None => {
                    // spill to any channel with room; as a last resort take
                    // the least-loaded port regardless (and report it)
                    spilled += 1;
                    pick(&all_ids).unwrap_or_else(|| {
                        *all_ids.iter().min_by_key(|&&id| load[id as usize]).unwrap()
                    })
                }
            };
            load[best as usize] += beats;
            stored[best as usize] += bytes;
            if pc.id(m) != best {
                pc.set_id(m, best);
                changed = true;
            }
        }
        let used = load.iter().filter(|&&l| l > 0).count();
        let mut remarks = vec![format!("spread PC terminals over {used} physical channels")];
        if spilled > 0 {
            remarks.push(format!(
                "{spilled} buffer(s) spilled off their preferred memory kind (capacity)"
            ));
        }
        Ok(PassOutcome { changed, remarks })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::dialect::PcView;
    use crate::passes::sanitize::Sanitize;
    use crate::platform::builtin;

    fn ctx() -> PassContext {
        PassContext::new(builtin("u280").unwrap())
    }

    #[test]
    fn fig5_distinct_ids() {
        let mut m = fig4a_module();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let out = ChannelReassign.run(&mut m, &ctx()).unwrap();
        assert!(out.changed);
        let mut ids: Vec<u32> = PcView::all(&m).iter().map(|pc| pc.id(&m)).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 3, "paper Fig 5: each PC gets its own id");
        // all on HBM ports (stream channels prefer HBM)
        let hbm = builtin("u280").unwrap().pc_ids(crate::platform::MemKind::Hbm);
        for pc in PcView::all(&m) {
            assert!(hbm.contains(&pc.id(&m)));
        }
    }

    #[test]
    fn improves_bandwidth_report() {
        use crate::analysis::{analyze_bandwidth, Dfg};
        let plat = builtin("u280").unwrap();
        let mut m = fig4a_module();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let before = analyze_bandwidth(&m, &plat, &Dfg::build(&m));
        ChannelReassign.run(&mut m, &ctx()).unwrap();
        let after = analyze_bandwidth(&m, &plat, &Dfg::build(&m));
        assert!(after.makespan_s < before.makespan_s);
        assert!((before.makespan_s / after.makespan_s - 3.0).abs() < 1e-9);
    }

    #[test]
    fn more_channels_than_pcs_balances() {
        use crate::dialect::{DfgBuilder, ParamType};
        let plat = builtin("generic-ddr").unwrap(); // 2 physical channels
        let mut b = DfgBuilder::new();
        for _ in 0..6 {
            let x = b.channel(64, ParamType::Stream, 1000);
            b.kernel("k", &[x], &[], Default::default());
        }
        let mut m = b.finish();
        let ctx = PassContext::new(plat);
        Sanitize.run(&mut m, &ctx).unwrap();
        ChannelReassign.run(&mut m, &ctx).unwrap();
        let mut counts = [0usize; 2];
        for pc in PcView::all(&m) {
            counts[pc.id(&m) as usize] += 1;
        }
        assert_eq!(counts, [3, 3], "equal demand must balance evenly");
    }

    #[test]
    fn noop_without_pcs() {
        let mut m = fig4a_module(); // no sanitize -> no pc nodes
        let out = ChannelReassign.run(&mut m, &ctx()).unwrap();
        assert!(!out.changed);
    }

    #[test]
    fn oversized_stream_spills_to_ddr() {
        use crate::dialect::{DfgBuilder, ParamType};
        // a 512 MB stream cannot live in any 256 MB HBM bank -> DDR
        let mut b = DfgBuilder::new();
        let big = b.channel(32, ParamType::Stream, (512u64 << 20) / 4);
        b.kernel("k", &[big], &[], Default::default());
        let mut m = b.finish();
        Sanitize.run(&mut m, &ctx()).unwrap();
        let out = ChannelReassign.run(&mut m, &ctx()).unwrap();
        assert!(out.remarks.iter().any(|r| r.contains("spilled")), "{:?}", out.remarks);
        let plat = builtin("u280").unwrap();
        let pc = PcView::all(&m)[0];
        assert_eq!(
            plat.pcs[pc.id(&m) as usize].kind,
            crate::platform::MemKind::Ddr,
            "512MB buffer must land on a DDR bank"
        );
    }

    #[test]
    fn complex_channels_prefer_ddr() {
        use crate::dialect::{DfgBuilder, ParamType};
        let mut b = DfgBuilder::new();
        let huge = b.channel(64, ParamType::Complex, 1 << 30); // 1 GB region
        b.kernel("k", &[huge], &[], Default::default());
        let mut m = b.finish();
        Sanitize.run(&mut m, &ctx()).unwrap();
        ChannelReassign.run(&mut m, &ctx()).unwrap();
        let plat = builtin("u280").unwrap();
        let pc = PcView::all(&m)[0];
        assert_eq!(plat.pcs[pc.id(&m) as usize].kind, crate::platform::MemKind::Ddr);
    }
}
