//! Olympus-opt: the pass infrastructure and the paper's transformation
//! passes (§V-B).
//!
//! * [`sanitize`] — Fig 4: insert layouts + `olympus.pc` terminals.
//! * [`channel_reassign`] — Fig 5: spread PC-bound channels across the
//!   platform's physical channels.
//! * [`replicate`] — Fig 6: clone the whole DFG for parallelism under the
//!   resource-utilization limit.
//! * [`bus_widen`] — Fig 7: widen channels to multi-lane words and replicate
//!   kernels per lane under a super-node.
//! * [`iris`] — Fig 8: interleave channels onto shared buses (the Iris
//!   algorithm lives in [`crate::iris`]).
//! * [`fifo_sizing`] — double-buffer memory-facing FIFOs (BRAM saver).
//! * [`plm_share`] — Mnemosyne-style PLM sharing for `small` channels.
//! * [`canonicalize`] — cleanup: drop dead channels, dedup PC terminals.
//! * [`dse`] — the Fig 3 optimize loop, built on the pluggable
//!   [`crate::search`] framework: a search driver (exhaustive | random |
//!   successive-halving | iterative) picks which candidate pipeline
//!   schedules get evaluated, and the best design is kept.

pub mod bus_widen;
pub mod canonicalize;
pub mod channel_reassign;
pub mod dse;
pub mod fifo_sizing;
pub mod iris;
pub mod manager;
pub mod plm_share;
pub mod replicate;
pub mod sanitize;

pub use dse::{
    candidate_cache_key, evaluate_candidate, objective_from_json, objective_to_json,
    outcome_from_json, outcome_to_json, run_dse, run_dse_multi, run_dse_with, run_iterative,
    CandidateCache, CandidateOutcome, DseCandidate, DseObjective, DseOptions, DseReport,
};
pub use manager::{make_pass, parse_pipeline, Pass, PassContext, PassManager, PassOutcome};
