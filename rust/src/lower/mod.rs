//! Hardware lowering (paper §V-C): turn the optimized Olympus DFG into a
//! system architecture.
//!
//! The paper's backend emits a Vivado block design + Vitis `.cfg` + host
//! API library and synthesizes a bitstream. Our backend emits the same
//! *artifacts* — an [`Architecture`] netlist, the `.cfg` connectivity file,
//! structural Verilog stubs and a generated host driver — and then executes
//! the architecture on the in-tree platform simulator ([`crate::sim`])
//! instead of on silicon (DESIGN.md §2, substitution 3).
//!
//! Lowering rules (paper §V-C):
//! * `stream` channels -> FIFOs of the specified depth;
//! * `small` channels -> PLMs in BRAM (shared via Mnemosyne groups);
//! * `complex` channels -> direct AXI ports to the device PCs;
//! * channels with Iris layouts -> data movers with pack/unpack adapters;
//! * channels on `olympus.pc` terminals -> bound to physical PCs (the
//!   `.cfg` `sp=` lines for Vitis).

mod arch;
mod cfg_emit;
mod hdl_emit;
mod host_emit;

pub use arch::{
    build_architecture, Architecture, CuInst, Endpoint, FifoInst, MoverDir, MoverInst, PlmInst,
};
pub use cfg_emit::emit_vitis_cfg;
pub use hdl_emit::emit_verilog;
pub use host_emit::emit_host_driver;
