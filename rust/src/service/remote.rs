//! Distributed candidate evaluation: the coordinator side of
//! `olympus serve --workers`.
//!
//! The content-addressed candidate keys ([`candidate_cache_key`]) are
//! process-independent and — with `--cache-dir` — survive process death, so
//! any `olympus worker` can own a slice of the key space and serve every
//! journal record it holds. This module supplies the two pieces that turn
//! that property into a horizontally scaled service:
//!
//! * **[`WorkerPool`]** — one persistent connection per remote worker,
//!   handshaken with the protocol version and the worker's shard of the
//!   key space ([`PROTO_VERSION`], `shard_map`). Each candidate evaluation
//!   routes to the worker owning its key under **rendezvous (highest-
//!   random-weight) hashing** ([`shard_of`]): adding or removing a worker
//!   only remaps the keys it owned, so warm worker journals keep their
//!   value as the fleet changes.
//! * **[`RemoteEvaluator`]** — a [`Evaluator`] that slots under every
//!   `SearchDriver` unchanged. Full-fidelity evaluations go through the
//!   coordinator's own candidate memo first (single-flight, exactly like
//!   the in-process path), then to the owning worker; cheap analytic
//!   screens and the iterative loop's incremental moves stay local
//!   (microseconds each — a network hop would cost more than it saves).
//!
//! **Failover**: a transport failure retries once on a fresh connection,
//! then the evaluation runs locally — a dead worker degrades throughput,
//! never availability and never the answer. **Determinism**: outcomes
//! travel in the same bit-exact codec the disk journals use
//! ([`outcome_from_json`]: floats as raw bit patterns, modules as printed
//! IR), and the worker cross-checks the routed key against the one it
//! derives itself, so a served result is bit-identical to a single-process
//! run no matter which process computed it. `cache-stats` exposes
//! `remote_hits` / `remote_evals` / `remote_failovers`.

use std::fmt;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::ir::{module_fingerprint, print_module, Module};
use crate::passes::dse::{
    candidate_cache_key, objective_to_json, outcome_from_json, CandidateCache, CandidateOutcome,
    DseCandidate, DseObjective,
};
use crate::platform::PlatformSpec;
use crate::search::{CandidatePoint, Evaluator, ObjectiveEvaluator};
use crate::util::{fnv1a_64, ContentHash, Json};

use super::proto::PROTO_VERSION;

/// Establishing a TCP connection to a worker.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);
/// Waiting for a handshake reply (cheap: parse + validate + echo).
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);
/// Waiting for an evaluation reply. A des-score candidate is a full
/// discrete-event simulation (milliseconds to seconds), so this is tens of
/// times the worst expected evaluation — but deliberately finite: each
/// worker serves its shard over ONE connection guarded by a mutex, so a
/// wedged-but-listening worker head-of-line blocks every evaluation routed
/// to its shard until this deadline fails them over to local compute.
const EVAL_TIMEOUT: Duration = Duration::from_secs(120);
/// Writing a request line.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Coordinator-side counters surfaced through `cache-stats`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RemoteStats {
    /// Evaluations a worker answered from its warm cache.
    pub remote_hits: u64,
    /// Evaluations a worker computed fresh.
    pub remote_evals: u64,
    /// Evaluations that fell back to local compute (worker unreachable or
    /// answering garbage, after the one retry).
    pub remote_failovers: u64,
}

/// Rendezvous (highest-random-weight) owner of `key` among `n` shards:
/// every process ranks the `(key, shard)` pairs with the same stable hash
/// and picks the top one, so the mapping needs no coordination, and
/// removing a shard only remaps the keys that shard owned. Stable across
/// processes and releases — worker journals are addressed by it.
pub fn shard_of(key: ContentHash, n: usize) -> usize {
    let hex = key.to_hex();
    (0..n).max_by_key(|i| fnv1a_64(format!("{hex}#{i}").as_bytes())).unwrap_or(0)
}

/// How a remote call failed.
enum RemoteError {
    /// Socket-level failure (resolve/connect/send/recv): retried, then
    /// failed over.
    Transport(String),
    /// The worker answered but refuses us (handshake rejection, protocol-
    /// version mismatch): failed over per call, and a hard error at
    /// startup — a misconfigured fleet should not boot quietly.
    Protocol(String),
}

impl fmt::Display for RemoteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RemoteError::Transport(m) | RemoteError::Protocol(m) => f.write_str(m),
        }
    }
}

/// One worker connection: reader/writer halves of a handshaken stream.
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// One request line -> one parsed response line.
fn roundtrip(conn: &mut Conn, line: &str) -> Result<Json, String> {
    conn.writer.write_all(line.as_bytes()).map_err(|e| format!("send: {e}"))?;
    conn.writer.write_all(b"\n").map_err(|e| format!("send: {e}"))?;
    conn.writer.flush().map_err(|e| format!("send: {e}"))?;
    let mut resp = String::new();
    match conn.reader.read_line(&mut resp) {
        Ok(0) => Err("connection closed by worker".to_string()),
        Ok(_) => Json::parse(resp.trim()).map_err(|e| format!("malformed response: {e}")),
        Err(e) => Err(format!("recv: {e}")),
    }
}

struct RemoteWorker {
    addr: String,
    conn: Mutex<Option<Conn>>,
}

/// The coordinator's set of remote evaluation workers (`serve --workers`).
/// See the module docs for routing, handshake and failover semantics.
pub struct WorkerPool {
    workers: Vec<RemoteWorker>,
    hits: AtomicU64,
    evals: AtomicU64,
    failovers: AtomicU64,
}

impl fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers.iter().map(|w| w.addr.as_str()).collect::<Vec<_>>())
            .finish()
    }
}

impl WorkerPool {
    /// Build the pool and eagerly handshake every worker. An unreachable
    /// worker is a warning (it is retried per evaluation and failed over
    /// locally meanwhile); a protocol-version mismatch or handshake
    /// rejection is a configuration error and fails the startup.
    pub fn connect(addrs: &[String]) -> Result<WorkerPool> {
        if addrs.is_empty() {
            bail!("--workers names no worker addresses");
        }
        let pool = WorkerPool {
            workers: addrs
                .iter()
                .map(|a| RemoteWorker { addr: a.clone(), conn: Mutex::new(None) })
                .collect(),
            hits: AtomicU64::new(0),
            evals: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
        };
        for index in 0..pool.workers.len() {
            let addr = pool.workers[index].addr.clone();
            match pool.establish(index) {
                Ok(conn) => *pool.workers[index].conn.lock().unwrap() = Some(conn),
                Err(RemoteError::Protocol(msg)) => bail!("worker {addr}: {msg}"),
                Err(RemoteError::Transport(msg)) => crate::obs::warn(
                    "remote-worker-unreachable",
                    &[("worker", addr.as_str().into()), ("error", msg.as_str().into())],
                ),
            }
        }
        Ok(pool)
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// The configured worker addresses, in shard-index order.
    pub fn addrs(&self) -> Vec<String> {
        self.workers.iter().map(|w| w.addr.clone()).collect()
    }

    pub fn stats(&self) -> RemoteStats {
        RemoteStats {
            remote_hits: self.hits.load(Ordering::Relaxed),
            remote_evals: self.evals.load(Ordering::Relaxed),
            remote_failovers: self.failovers.load(Ordering::Relaxed),
        }
    }

    /// Count one local failover (the evaluator performs the local compute).
    fn note_failover(&self) {
        self.failovers.fetch_add(1, Ordering::Relaxed);
    }

    /// The handshake line announcing worker `index`'s shard assignment.
    fn handshake_line(&self, index: usize) -> String {
        let workers: Vec<Json> = self.workers.iter().map(|w| w.addr.as_str().into()).collect();
        Json::obj(vec![
            ("cmd", "handshake".into()),
            ("proto_version", PROTO_VERSION.into()),
            (
                "shard_map",
                Json::obj(vec![
                    ("index", index.into()),
                    ("total", self.workers.len().into()),
                    ("workers", Json::Arr(workers)),
                ]),
            ),
        ])
        .to_string()
    }

    /// Open + handshake a fresh connection to worker `index`.
    fn establish(&self, index: usize) -> Result<Conn, RemoteError> {
        let addr = &self.workers[index].addr;
        let transport = |m: String| RemoteError::Transport(m);
        let sock = addr
            .to_socket_addrs()
            .map_err(|e| transport(format!("resolve {addr}: {e}")))?
            .next()
            .ok_or_else(|| transport(format!("resolve {addr}: no address")))?;
        let writer = TcpStream::connect_timeout(&sock, CONNECT_TIMEOUT)
            .map_err(|e| transport(format!("connect {addr}: {e}")))?;
        let _ = writer.set_nodelay(true);
        let _ = writer.set_write_timeout(Some(WRITE_TIMEOUT));
        let _ = writer.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
        let reader = writer.try_clone().map_err(|e| transport(format!("clone {addr}: {e}")))?;
        let mut conn = Conn { reader: BufReader::new(reader), writer };
        let resp = roundtrip(&mut conn, &self.handshake_line(index))
            .map_err(|e| transport(format!("handshake {addr}: {e}")))?;
        if resp.get("ok") != &Json::Bool(true) {
            return Err(RemoteError::Protocol(format!(
                "handshake rejected [{}]: {}",
                resp.get("error").get("code").as_str().unwrap_or("?"),
                resp.get("error").get("message").as_str().unwrap_or("?")
            )));
        }
        let spoken = resp.get("result").get("proto_version").as_u64();
        if spoken != Some(PROTO_VERSION) {
            return Err(RemoteError::Protocol(format!(
                "protocol version mismatch: worker speaks {spoken:?}, coordinator {PROTO_VERSION}"
            )));
        }
        // handshake done: widen the read timeout to evaluation scale
        let _ = conn.writer.set_read_timeout(Some(EVAL_TIMEOUT));
        Ok(conn)
    }

    /// One request/response against worker `index`, (re)establishing the
    /// connection as needed. A transport failure drops the connection and
    /// retries exactly once on a fresh one before giving up.
    fn call(&self, index: usize, line: &str) -> Result<Json, RemoteError> {
        let mut guard = self.workers[index].conn.lock().unwrap();
        let mut last = String::from("unreachable");
        for _attempt in 0..2 {
            if guard.is_none() {
                match self.establish(index) {
                    Ok(conn) => *guard = Some(conn),
                    Err(RemoteError::Protocol(msg)) => return Err(RemoteError::Protocol(msg)),
                    Err(RemoteError::Transport(msg)) => {
                        last = msg;
                        continue;
                    }
                }
            }
            let started = std::time::Instant::now();
            match roundtrip(guard.as_mut().expect("connection just ensured"), line) {
                Ok(v) => {
                    crate::obs::metrics().remote_rtt.record_duration(started.elapsed());
                    return Ok(v);
                }
                Err(msg) => {
                    *guard = None; // poisoned half-stream: never reuse
                    last = msg;
                }
            }
        }
        Err(RemoteError::Transport(last))
    }

    /// Evaluate one candidate on the worker owning `key`'s shard. Returns
    /// the decoded outcome plus whether the worker *computed* it (`false`
    /// = answered from its warm cache). Every failure mode comes back as a
    /// message; the caller fails over to local evaluation.
    pub fn eval_candidate(
        &self,
        key: ContentHash,
        ir: &str,
        platform_json: &Json,
        objective_json: &Json,
        point: &CandidatePoint,
    ) -> Result<(CandidateOutcome, bool), String> {
        let index = shard_of(key, self.workers.len());
        let addr = &self.workers[index].addr;
        let line = Json::obj(vec![
            ("cmd", "eval-candidate".into()),
            ("ir", ir.into()),
            ("platform_json", platform_json.clone()),
            ("objective_json", objective_json.clone()),
            ("point_label", point.label.as_str().into()),
            ("point_pipeline", point.pipeline.as_str().into()),
            ("key", key.to_hex().into()),
        ])
        .to_string();
        let resp = self.call(index, &line).map_err(|e| format!("worker {addr}: {e}"))?;
        if resp.get("ok") != &Json::Bool(true) {
            return Err(format!(
                "worker {addr} rejected eval [{}]: {}",
                resp.get("error").get("code").as_str().unwrap_or("?"),
                resp.get("error").get("message").as_str().unwrap_or("?")
            ));
        }
        let outcome = outcome_from_json(resp.get("result"))
            .ok_or_else(|| format!("worker {addr} returned an undecodable outcome"))?;
        let cached = resp.get("cached") == &Json::Bool(true);
        if cached {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.evals.fetch_add(1, Ordering::Relaxed);
        }
        Ok((outcome, !cached))
    }
}

/// The distributed [`Evaluator`]: full-fidelity evaluations route through
/// the coordinator's candidate memo to the key's shard owner (local
/// failover on any remote failure); screens stay in-process. Slots under
/// every `SearchDriver` unchanged — see the module docs.
pub struct RemoteEvaluator<'a> {
    pool: Arc<WorkerPool>,
    /// Serves the analytic screens and the failover path; carries no cache
    /// and no counter — both live in this wrapper.
    local: ObjectiveEvaluator<'a>,
    cache: Option<Arc<CandidateCache>>,
    module_fp: String,
    plat_fp: String,
    obj_desc: String,
    ir_text: String,
    platform_json: Json,
    objective_json: Json,
    threads: usize,
    full_evals: AtomicUsize,
}

impl<'a> RemoteEvaluator<'a> {
    pub fn new(
        pool: Arc<WorkerPool>,
        input: &'a Module,
        plat: &'a PlatformSpec,
        objective: &'a DseObjective,
        threads: usize,
        cache: Option<Arc<CandidateCache>>,
    ) -> RemoteEvaluator<'a> {
        RemoteEvaluator {
            local: ObjectiveEvaluator::new(input, plat, objective, threads, None),
            module_fp: module_fingerprint(input),
            plat_fp: plat.fingerprint(),
            obj_desc: format!("{objective:?}"),
            ir_text: print_module(input),
            platform_json: plat.to_json(),
            objective_json: objective_to_json(objective),
            pool,
            cache,
            threads,
            full_evals: AtomicUsize::new(0),
        }
    }

    /// One point's outcome, answered through the coordinator-side memo
    /// (single-flight) and then the owning worker.
    fn outcome_for(&self, point: &CandidatePoint) -> CandidateOutcome {
        let key =
            candidate_cache_key(&self.module_fp, &self.plat_fp, &point.pipeline, &self.obj_desc);
        let compute = || self.remote_or_local(key, point);
        match &self.cache {
            Some(cache) => {
                let started = std::time::Instant::now();
                let (outcome, cached) = cache.get_or_compute(key, compute);
                if cached {
                    crate::obs::metrics().eval_cache_hit.record_duration(started.elapsed());
                }
                outcome
            }
            None => compute(),
        }
    }

    fn remote_or_local(&self, key: ContentHash, point: &CandidatePoint) -> CandidateOutcome {
        let started = std::time::Instant::now();
        let sent = self.pool.eval_candidate(
            key,
            &self.ir_text,
            &self.platform_json,
            &self.objective_json,
            point,
        );
        match sent {
            Ok((outcome, computed)) => {
                crate::obs::metrics().eval_remote.record_duration(started.elapsed());
                if computed {
                    self.full_evals.fetch_add(1, Ordering::Relaxed);
                }
                outcome
            }
            Err(msg) => {
                // the answer must not depend on fleet health: evaluate
                // locally — deterministic, so bit-identical to what the
                // worker would have said
                self.pool.note_failover();
                crate::obs::warn(
                    "remote-failover",
                    &[
                        ("candidate", point.label.as_str().into()),
                        ("error", msg.as_str().into()),
                    ],
                );
                self.full_evals.fetch_add(1, Ordering::Relaxed);
                let local_start = std::time::Instant::now();
                let outcome = self.local.compute_outcome(point);
                crate::obs::metrics().eval_local.record_duration(local_start.elapsed());
                outcome
            }
        }
    }
}

impl Evaluator for RemoteEvaluator<'_> {
    fn evaluate(&self, points: &[CandidatePoint]) -> Vec<Option<(DseCandidate, Module)>> {
        let n = points.len();
        if n == 0 {
            return Vec::new();
        }
        let threads = if self.threads == 0 {
            std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
        } else {
            self.threads
        }
        .clamp(1, n);
        let slots: Mutex<Vec<Option<(DseCandidate, Module)>>> =
            Mutex::new((0..n).map(|_| None).collect());
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    if let CandidateOutcome::Evaluated { mut cand, module } =
                        self.outcome_for(&points[i])
                    {
                        // a worker journal (or the coordinator memo) may
                        // hold this outcome under the label it was first
                        // computed with — the label is outside the key, so
                        // restore this point's own label for bit-identical
                        // reports across cache temperatures
                        cand.strategy = points[i].label.clone();
                        slots.lock().unwrap()[i] = Some((cand, module));
                    }
                });
            }
        });
        slots.into_inner().unwrap()
    }

    fn screen(&self, points: &[CandidatePoint]) -> Vec<Option<(DseCandidate, Module)>> {
        self.local.screen(points)
    }

    fn screen_from(&self, base: &Module, pipeline: &str) -> Option<(DseCandidate, Module)> {
        self.local.screen_from(base, pipeline)
    }

    fn full_evals(&self) -> usize {
        self.full_evals.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(s: &str) -> ContentHash {
        ContentHash::of_parts(&[s])
    }

    #[test]
    fn shard_of_is_deterministic_and_in_range() {
        for n in 1..=5usize {
            for i in 0..200u32 {
                let k = key(&format!("k{i}"));
                let s = shard_of(k, n);
                assert!(s < n);
                assert_eq!(s, shard_of(k, n), "same inputs, same shard");
            }
        }
    }

    #[test]
    fn shard_of_spreads_keys_across_workers() {
        let n = 3;
        let mut counts = vec![0usize; n];
        for i in 0..600u32 {
            counts[shard_of(key(&format!("k{i}")), n)] += 1;
        }
        for (shard, c) in counts.iter().enumerate() {
            // a uniform spread gives 200 each; any real imbalance under
            // rendezvous hashing stays far from these bounds
            assert!(*c > 100 && *c < 300, "shard {shard} owns {c} of 600 keys");
        }
    }

    #[test]
    fn removing_the_last_shard_only_remaps_its_keys() {
        // the rendezvous property CI failover relies on: keys owned by a
        // surviving worker keep their owner when the fleet shrinks
        for i in 0..400u32 {
            let k = key(&format!("k{i}"));
            let with3 = shard_of(k, 3);
            if with3 < 2 {
                assert_eq!(shard_of(k, 2), with3, "surviving owner must not change");
            }
        }
    }
}
