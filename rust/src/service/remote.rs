//! The coordinator side of the distributed cache fabric:
//! `olympus serve --workers`.
//!
//! The content-addressed keys (candidate keys via [`candidate_cache_key`],
//! response keys via `Flow::response_key`) are process-independent and —
//! with `--cache-dir` — survive process death, so any `olympus worker` can
//! own a slice of the key space and serve every journal record it holds.
//! This module supplies the pieces that turn that property into a
//! horizontally scaled service:
//!
//! * **[`WorkerPool`]** — one persistent connection per remote worker,
//!   handshaken with the protocol version, a capability list and the
//!   worker's shard of the key space under an **epoch-versioned shard
//!   map** ([`PROTO_VERSION`], `shard_map`). Work routes to the worker
//!   owning its key under **rendezvous (highest-random-weight) hashing**
//!   ([`shard_of`]): adding or removing a worker only remaps the keys it
//!   owned, so warm worker journals keep their value as the fleet changes.
//!   Membership is **elastic**: [`WorkerPool::join`] / [`WorkerPool::leave`]
//!   re-rendezvous the map at runtime (epoch bump + fleet-wide
//!   re-handshake, no restart); the key handoff itself rides on journal
//!   gossip ([`super::gossip`]).
//! * **Response routing** ([`WorkerPool::eval_response_line`]) — whole
//!   requests forwarded to their response key's shard owner as an
//!   `eval-response` line. The owner answers with the byte-exact response
//!   a direct submission would get, and the coordinator passes the raw
//!   line through unparsed — the coordinator is a thin router, and warm
//!   response hits scale with the fleet.
//! * **[`RemoteEvaluator`]** — a [`Evaluator`] that slots under every
//!   `SearchDriver` unchanged. Full-fidelity evaluations go through the
//!   coordinator's own candidate memo first (single-flight, exactly like
//!   the in-process path), then to the owning worker; cheap analytic
//!   screens and the iterative loop's incremental moves stay local
//!   (microseconds each — a network hop would cost more than it saves).
//!
//! **Failover**: a transport failure retries once on a fresh connection,
//! then the work runs locally — a dead worker degrades throughput, never
//! availability and never the answer. **Determinism**: outcomes travel in
//! the same bit-exact codec the disk journals use ([`outcome_from_json`]:
//! floats as raw bit patterns, modules as printed IR), routed responses
//! travel as raw bytes, and the worker cross-checks every routed key
//! against the one it derives itself, so a served result is bit-identical
//! to a single-process run no matter which process computed it.
//! `cache-stats` exposes `remote_hits` / `remote_evals` /
//! `remote_failovers` (candidate level) and `resp_shard_hits` /
//! `resp_shard_evals` / `resp_shard_failovers` (whole-request level).

use std::fmt;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::ir::{module_fingerprint, print_module, Module};
use crate::passes::dse::{
    candidate_cache_key, objective_to_json, outcome_from_json, CandidateCache, CandidateOutcome,
    DseCandidate, DseObjective,
};
use crate::platform::PlatformSpec;
use crate::search::{CandidatePoint, Evaluator, ObjectiveEvaluator};
use crate::util::{fnv1a_64, ContentHash, Json};

use super::proto::{CAPABILITIES, PROTO_VERSION};

/// Establishing a TCP connection to a worker.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);
/// Waiting for a handshake reply (cheap: parse + validate + echo).
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);
/// Waiting for an evaluation reply. A des-score candidate is a full
/// discrete-event simulation (milliseconds to seconds), so this is tens of
/// times the worst expected evaluation — but deliberately finite: each
/// worker serves its shard over ONE connection guarded by a mutex, so a
/// wedged-but-listening worker head-of-line blocks every evaluation routed
/// to its shard until this deadline fails them over to local compute.
const EVAL_TIMEOUT: Duration = Duration::from_secs(120);
/// Writing a request line.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Coordinator-side counters surfaced through `cache-stats`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RemoteStats {
    /// Candidate evaluations a worker answered from its warm cache.
    pub remote_hits: u64,
    /// Candidate evaluations a worker computed fresh.
    pub remote_evals: u64,
    /// Candidate evaluations that fell back to local compute (worker
    /// unreachable or answering garbage, after the one retry).
    pub remote_failovers: u64,
    /// Routed whole requests the shard owner answered from its warm cache.
    pub resp_shard_hits: u64,
    /// Routed whole requests the shard owner computed fresh.
    pub resp_shard_evals: u64,
    /// Routed whole requests that fell back to local execution.
    pub resp_shard_failovers: u64,
}

/// Rendezvous (highest-random-weight) owner of `key` among `n` shards:
/// every process ranks the `(key, shard)` pairs with the same stable hash
/// and picks the top one, so the mapping needs no coordination, and
/// removing a shard only remaps the keys that shard owned. Stable across
/// processes and releases — worker journals are addressed by it.
pub fn shard_of(key: ContentHash, n: usize) -> usize {
    let hex = key.to_hex();
    (0..n).max_by_key(|i| fnv1a_64(format!("{hex}#{i}").as_bytes())).unwrap_or(0)
}

/// [`shard_of`] for callers holding a key in its 32-hex-digit wire form
/// (tests, CI tooling computing which worker to kill). `None` when the
/// string is not a well-formed key.
pub fn shard_of_hex(hex: &str, n: usize) -> Option<usize> {
    ContentHash::from_hex(hex).map(|k| shard_of(k, n))
}

/// How a remote call failed.
enum RemoteError {
    /// Socket-level failure (resolve/connect/send/recv): retried, then
    /// failed over.
    Transport(String),
    /// The worker answered but refuses us (handshake rejection, protocol-
    /// version mismatch): failed over per call, and a hard error at
    /// startup — a misconfigured fleet should not boot quietly.
    Protocol(String),
}

impl fmt::Display for RemoteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RemoteError::Transport(m) | RemoteError::Protocol(m) => f.write_str(m),
        }
    }
}

/// One worker connection: reader/writer halves of a handshaken stream.
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// One request line -> one raw response line.
fn roundtrip(conn: &mut Conn, line: &str) -> Result<String, String> {
    conn.writer.write_all(line.as_bytes()).map_err(|e| format!("send: {e}"))?;
    conn.writer.write_all(b"\n").map_err(|e| format!("send: {e}"))?;
    conn.writer.flush().map_err(|e| format!("send: {e}"))?;
    let mut resp = String::new();
    match conn.reader.read_line(&mut resp) {
        Ok(0) => Err("connection closed by worker".to_string()),
        Ok(_) => Ok(resp.trim_end().to_string()),
        Err(e) => Err(format!("recv: {e}")),
    }
}

struct RemoteWorker {
    addr: String,
    conn: Mutex<Option<Conn>>,
}

/// An immutable snapshot of the fleet at one epoch. Calls route against a
/// snapshot, so a concurrent `join`/`leave` never shifts indices under an
/// in-flight request.
type Members = Arc<Vec<Arc<RemoteWorker>>>;

/// The coordinator's set of remote evaluation workers (`serve --workers`).
/// See the module docs for routing, handshake, membership and failover
/// semantics.
pub struct WorkerPool {
    members: Mutex<Members>,
    /// Bumped by every membership change; announced in each handshake so
    /// workers can tell a re-rendezvous from a reconnect.
    epoch: AtomicU64,
    /// Serializes `join`/`leave` so concurrent membership changes cannot
    /// interleave their handshake/commit phases.
    admin: Mutex<()>,
    hits: AtomicU64,
    evals: AtomicU64,
    failovers: AtomicU64,
    resp_hits: AtomicU64,
    resp_evals: AtomicU64,
    resp_failovers: AtomicU64,
}

impl fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkerPool").field("workers", &self.addrs()).finish()
    }
}

impl WorkerPool {
    /// Build the pool and eagerly handshake every worker. An unreachable
    /// worker is a warning (it is retried per evaluation and failed over
    /// locally meanwhile); a protocol-version mismatch or handshake
    /// rejection is a configuration error and fails the startup.
    pub fn connect(addrs: &[String]) -> Result<WorkerPool> {
        if addrs.is_empty() {
            bail!("--workers names no worker addresses");
        }
        let members: Members = Arc::new(
            addrs
                .iter()
                .map(|a| Arc::new(RemoteWorker { addr: a.clone(), conn: Mutex::new(None) }))
                .collect(),
        );
        let pool = WorkerPool {
            members: Mutex::new(members.clone()),
            epoch: AtomicU64::new(1),
            admin: Mutex::new(()),
            hits: AtomicU64::new(0),
            evals: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            resp_hits: AtomicU64::new(0),
            resp_evals: AtomicU64::new(0),
            resp_failovers: AtomicU64::new(0),
        };
        for (index, worker) in members.iter().enumerate() {
            match pool.establish(&members, index, 1) {
                Ok(conn) => *worker.conn.lock().unwrap() = Some(conn),
                Err(RemoteError::Protocol(msg)) => bail!("worker {}: {msg}", worker.addr),
                Err(RemoteError::Transport(msg)) => crate::obs::warn(
                    "remote-worker-unreachable",
                    &[("worker", worker.addr.as_str().into()), ("error", msg.as_str().into())],
                ),
            }
        }
        Ok(pool)
    }

    fn snapshot(&self) -> Members {
        self.members.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.snapshot().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The current worker addresses, in shard-index order.
    pub fn addrs(&self) -> Vec<String> {
        self.snapshot().iter().map(|w| w.addr.clone()).collect()
    }

    /// The shard-map version. Starts at 1; every `join`/`leave` bumps it.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> RemoteStats {
        RemoteStats {
            remote_hits: self.hits.load(Ordering::Relaxed),
            remote_evals: self.evals.load(Ordering::Relaxed),
            remote_failovers: self.failovers.load(Ordering::Relaxed),
            resp_shard_hits: self.resp_hits.load(Ordering::Relaxed),
            resp_shard_evals: self.resp_evals.load(Ordering::Relaxed),
            resp_shard_failovers: self.resp_failovers.load(Ordering::Relaxed),
        }
    }

    /// Count one local candidate failover (the evaluator performs the
    /// local compute).
    fn note_failover(&self) {
        self.failovers.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one local whole-request failover (the caller executes the
    /// request itself).
    pub fn note_response_failover(&self) {
        self.resp_failovers.fetch_add(1, Ordering::Relaxed);
    }

    /// Admit `addr` into the fleet at the next shard index. The new worker
    /// is handshaken with the proposed map *before* anything commits — a
    /// dead or incompatible address changes nothing. On success the epoch
    /// bumps and every incumbent is re-handshaken with the new map.
    pub fn join(&self, addr: &str) -> Result<(), String> {
        let _admin = self.admin.lock().unwrap();
        let current = self.snapshot();
        if current.iter().any(|w| w.addr == addr) {
            return Err(format!("worker '{addr}' is already a member"));
        }
        let mut next: Vec<Arc<RemoteWorker>> = current.as_ref().clone();
        next.push(Arc::new(RemoteWorker { addr: addr.to_string(), conn: Mutex::new(None) }));
        let next: Members = Arc::new(next);
        let epoch = self.epoch() + 1;
        let index = next.len() - 1;
        match self.establish(&next, index, epoch) {
            Ok(conn) => *next[index].conn.lock().unwrap() = Some(conn),
            Err(e) => return Err(format!("worker {addr}: {e}")),
        }
        *self.members.lock().unwrap() = next.clone();
        self.epoch.store(epoch, Ordering::Relaxed);
        self.rehandshake(&next, epoch, Some(index));
        crate::obs::info(
            "fleet-join",
            &[("worker", addr.into()), ("epoch", epoch.into()), ("total", next.len().into())],
        );
        Ok(())
    }

    /// Remove `addr` from the fleet (dead or retiring — no connection is
    /// needed). The epoch bumps and every survivor is re-handshaken with
    /// the shrunk map; keys the leaver owned re-rendezvous onto survivors,
    /// whose journals gossip has already warmed.
    pub fn leave(&self, addr: &str) -> Result<(), String> {
        let _admin = self.admin.lock().unwrap();
        let current = self.snapshot();
        let Some(pos) = current.iter().position(|w| w.addr == addr) else {
            return Err(format!("worker '{addr}' is not a member"));
        };
        let mut next: Vec<Arc<RemoteWorker>> = current.as_ref().clone();
        next.remove(pos);
        let next: Members = Arc::new(next);
        let epoch = self.epoch() + 1;
        *self.members.lock().unwrap() = next.clone();
        self.epoch.store(epoch, Ordering::Relaxed);
        self.rehandshake(&next, epoch, None);
        crate::obs::info(
            "fleet-leave",
            &[("worker", addr.into()), ("epoch", epoch.into()), ("total", next.len().into())],
        );
        Ok(())
    }

    /// Push a new shard map to every member (except `skip`, which already
    /// has it). Best-effort: an unreachable member keeps a stale map until
    /// its next per-call reconnect, which re-handshakes anyway.
    fn rehandshake(&self, members: &Members, epoch: u64, skip: Option<usize>) {
        for (index, worker) in members.iter().enumerate() {
            if Some(index) == skip {
                continue;
            }
            match self.establish(members, index, epoch) {
                Ok(conn) => *worker.conn.lock().unwrap() = Some(conn),
                Err(e) => {
                    *worker.conn.lock().unwrap() = None;
                    crate::obs::warn(
                        "fleet-rehandshake-failed",
                        &[
                            ("worker", worker.addr.as_str().into()),
                            ("error", e.to_string().into()),
                        ],
                    );
                }
            }
        }
    }

    /// The handshake line announcing worker `index`'s shard assignment
    /// under `epoch`, plus this coordinator's capabilities.
    fn handshake_line(members: &Members, index: usize, epoch: u64) -> String {
        let workers: Vec<Json> = members.iter().map(|w| w.addr.as_str().into()).collect();
        let caps: Vec<Json> = CAPABILITIES.iter().map(|&c| c.into()).collect();
        Json::obj(vec![
            ("cmd", "handshake".into()),
            ("proto_version", PROTO_VERSION.into()),
            ("capabilities", Json::Arr(caps)),
            (
                "shard_map",
                Json::obj(vec![
                    ("index", index.into()),
                    ("total", members.len().into()),
                    ("epoch", epoch.into()),
                    ("workers", Json::Arr(workers)),
                ]),
            ),
        ])
        .to_string()
    }

    /// Open + handshake a fresh connection to `members[index]`.
    fn establish(&self, members: &Members, index: usize, epoch: u64) -> Result<Conn, RemoteError> {
        let addr = &members[index].addr;
        let transport = |m: String| RemoteError::Transport(m);
        let sock = addr
            .to_socket_addrs()
            .map_err(|e| transport(format!("resolve {addr}: {e}")))?
            .next()
            .ok_or_else(|| transport(format!("resolve {addr}: no address")))?;
        let writer = TcpStream::connect_timeout(&sock, CONNECT_TIMEOUT)
            .map_err(|e| transport(format!("connect {addr}: {e}")))?;
        let _ = writer.set_nodelay(true);
        let _ = writer.set_write_timeout(Some(WRITE_TIMEOUT));
        let _ = writer.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
        let reader = writer.try_clone().map_err(|e| transport(format!("clone {addr}: {e}")))?;
        let mut conn = Conn { reader: BufReader::new(reader), writer };
        let line = Self::handshake_line(members, index, epoch);
        let raw = roundtrip(&mut conn, &line)
            .map_err(|e| transport(format!("handshake {addr}: {e}")))?;
        let resp = Json::parse(&raw)
            .map_err(|e| transport(format!("handshake {addr}: malformed response: {e}")))?;
        if resp.get("ok") != &Json::Bool(true) {
            return Err(RemoteError::Protocol(format!(
                "handshake rejected [{}]: {}",
                resp.get("error").get("code").as_str().unwrap_or("?"),
                resp.get("error").get("message").as_str().unwrap_or("?")
            )));
        }
        let spoken = resp.get("result").get("proto_version").as_u64();
        if spoken != Some(PROTO_VERSION) {
            return Err(RemoteError::Protocol(format!(
                "protocol version mismatch: worker speaks {spoken:?}, coordinator {PROTO_VERSION}"
            )));
        }
        // handshake done: widen the read timeout to evaluation scale
        let _ = conn.writer.set_read_timeout(Some(EVAL_TIMEOUT));
        Ok(conn)
    }

    /// One request/response against `members[index]`, (re)establishing the
    /// connection as needed. A transport failure (including an unparsable
    /// reply) drops the connection and retries exactly once on a fresh one
    /// before giving up. Returns the raw response line plus its parse.
    fn call(
        &self,
        members: &Members,
        index: usize,
        line: &str,
    ) -> Result<(String, Json), RemoteError> {
        let mut guard = members[index].conn.lock().unwrap();
        let mut last = String::from("unreachable");
        for _attempt in 0..2 {
            if guard.is_none() {
                match self.establish(members, index, self.epoch()) {
                    Ok(conn) => *guard = Some(conn),
                    Err(RemoteError::Protocol(msg)) => return Err(RemoteError::Protocol(msg)),
                    Err(RemoteError::Transport(msg)) => {
                        last = msg;
                        continue;
                    }
                }
            }
            let started = std::time::Instant::now();
            match roundtrip(guard.as_mut().expect("connection just ensured"), line) {
                Ok(raw) => match Json::parse(&raw) {
                    Ok(v) => {
                        crate::obs::metrics().remote_rtt.record_duration(started.elapsed());
                        return Ok((raw, v));
                    }
                    Err(e) => {
                        *guard = None; // mid-line garbage: never reuse
                        last = format!("malformed response: {e}");
                    }
                },
                Err(msg) => {
                    *guard = None; // poisoned half-stream: never reuse
                    last = msg;
                }
            }
        }
        Err(RemoteError::Transport(last))
    }

    /// Evaluate one candidate on the worker owning `key`'s shard. Returns
    /// the decoded outcome plus whether the worker *computed* it (`false`
    /// = answered from its warm cache). Every failure mode comes back as a
    /// message; the caller fails over to local evaluation.
    pub fn eval_candidate(
        &self,
        key: ContentHash,
        ir: &str,
        platform_json: &Json,
        objective_json: &Json,
        point: &CandidatePoint,
    ) -> Result<(CandidateOutcome, bool), String> {
        let members = self.snapshot();
        if members.is_empty() {
            return Err("the fleet has no members (all workers left)".to_string());
        }
        let index = shard_of(key, members.len());
        let addr = members[index].addr.clone();
        let line = Json::obj(vec![
            ("cmd", "eval-candidate".into()),
            ("ir", ir.into()),
            ("platform_json", platform_json.clone()),
            ("objective_json", objective_json.clone()),
            ("point_label", point.label.as_str().into()),
            ("point_pipeline", point.pipeline.as_str().into()),
            ("key", key.to_hex().into()),
        ])
        .to_string();
        let (_, resp) =
            self.call(&members, index, &line).map_err(|e| format!("worker {addr}: {e}"))?;
        if resp.get("ok") != &Json::Bool(true) {
            return Err(format!(
                "worker {addr} rejected eval [{}]: {}",
                resp.get("error").get("code").as_str().unwrap_or("?"),
                resp.get("error").get("message").as_str().unwrap_or("?")
            ));
        }
        let outcome = outcome_from_json(resp.get("result"))
            .ok_or_else(|| format!("worker {addr} returned an undecodable outcome"))?;
        let cached = resp.get("cached") == &Json::Bool(true);
        if cached {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.evals.fetch_add(1, Ordering::Relaxed);
        }
        Ok((outcome, !cached))
    }

    /// Route a whole request to the worker owning its response key and
    /// return the worker's response line **verbatim** — the owner renders
    /// the byte-exact response a direct submission would get, so passing
    /// the raw bytes through preserves bit-identity without a re-serialize.
    /// Every failure mode (transport, rejection, skew) comes back as a
    /// message; the caller executes the request locally instead.
    pub fn eval_response_line(&self, key: ContentHash, line: &str) -> Result<String, String> {
        let members = self.snapshot();
        if members.is_empty() {
            return Err("the fleet has no members (all workers left)".to_string());
        }
        let index = shard_of(key, members.len());
        let addr = members[index].addr.clone();
        let (raw, resp) =
            self.call(&members, index, line).map_err(|e| format!("worker {addr}: {e}"))?;
        if resp.get("ok") != &Json::Bool(true) {
            // The request already validated locally (its response key
            // exists), so a rejection here means version skew or a
            // disputed key — recompute locally for availability; the
            // answer is deterministic either way.
            return Err(format!(
                "worker {addr} rejected routed request [{}]: {}",
                resp.get("error").get("code").as_str().unwrap_or("?"),
                resp.get("error").get("message").as_str().unwrap_or("?")
            ));
        }
        if resp.get("cached") == &Json::Bool(true) {
            self.resp_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.resp_evals.fetch_add(1, Ordering::Relaxed);
        }
        Ok(raw)
    }
}

/// The distributed [`Evaluator`]: full-fidelity evaluations route through
/// the coordinator's candidate memo to the key's shard owner (local
/// failover on any remote failure); screens stay in-process. Slots under
/// every `SearchDriver` unchanged — see the module docs.
pub struct RemoteEvaluator<'a> {
    pool: Arc<WorkerPool>,
    /// Serves the analytic screens and the failover path; carries no cache
    /// and no counter — both live in this wrapper.
    local: ObjectiveEvaluator<'a>,
    cache: Option<Arc<CandidateCache>>,
    module_fp: String,
    plat_fp: String,
    obj_desc: String,
    ir_text: String,
    platform_json: Json,
    objective_json: Json,
    threads: usize,
    full_evals: AtomicUsize,
}

impl<'a> RemoteEvaluator<'a> {
    pub fn new(
        pool: Arc<WorkerPool>,
        input: &'a Module,
        plat: &'a PlatformSpec,
        objective: &'a DseObjective,
        threads: usize,
        cache: Option<Arc<CandidateCache>>,
    ) -> RemoteEvaluator<'a> {
        RemoteEvaluator {
            local: ObjectiveEvaluator::new(input, plat, objective, threads, None),
            module_fp: module_fingerprint(input),
            plat_fp: plat.fingerprint(),
            obj_desc: format!("{objective:?}"),
            ir_text: print_module(input),
            platform_json: plat.to_json(),
            objective_json: objective_to_json(objective),
            pool,
            cache,
            threads,
            full_evals: AtomicUsize::new(0),
        }
    }

    /// One point's outcome, answered through the coordinator-side memo
    /// (single-flight) and then the owning worker.
    fn outcome_for(&self, point: &CandidatePoint) -> CandidateOutcome {
        let key =
            candidate_cache_key(&self.module_fp, &self.plat_fp, &point.pipeline, &self.obj_desc);
        let compute = || self.remote_or_local(key, point);
        match &self.cache {
            Some(cache) => {
                let started = std::time::Instant::now();
                let (outcome, cached) = cache.get_or_compute(key, compute);
                if cached {
                    crate::obs::metrics().eval_cache_hit.record_duration(started.elapsed());
                }
                outcome
            }
            None => compute(),
        }
    }

    fn remote_or_local(&self, key: ContentHash, point: &CandidatePoint) -> CandidateOutcome {
        let started = std::time::Instant::now();
        let sent = self.pool.eval_candidate(
            key,
            &self.ir_text,
            &self.platform_json,
            &self.objective_json,
            point,
        );
        match sent {
            Ok((outcome, computed)) => {
                crate::obs::metrics().eval_remote.record_duration(started.elapsed());
                if computed {
                    self.full_evals.fetch_add(1, Ordering::Relaxed);
                }
                outcome
            }
            Err(msg) => {
                // the answer must not depend on fleet health: evaluate
                // locally — deterministic, so bit-identical to what the
                // worker would have said
                self.pool.note_failover();
                crate::obs::warn(
                    "remote-failover",
                    &[
                        ("candidate", point.label.as_str().into()),
                        ("error", msg.as_str().into()),
                    ],
                );
                self.full_evals.fetch_add(1, Ordering::Relaxed);
                let local_start = std::time::Instant::now();
                let outcome = self.local.compute_outcome(point);
                crate::obs::metrics().eval_local.record_duration(local_start.elapsed());
                outcome
            }
        }
    }
}

impl Evaluator for RemoteEvaluator<'_> {
    fn evaluate(&self, points: &[CandidatePoint]) -> Vec<Option<(DseCandidate, Module)>> {
        let n = points.len();
        if n == 0 {
            return Vec::new();
        }
        let threads = if self.threads == 0 {
            std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
        } else {
            self.threads
        }
        .clamp(1, n);
        let slots: Mutex<Vec<Option<(DseCandidate, Module)>>> =
            Mutex::new((0..n).map(|_| None).collect());
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    if let CandidateOutcome::Evaluated { mut cand, module } =
                        self.outcome_for(&points[i])
                    {
                        // a worker journal (or the coordinator memo) may
                        // hold this outcome under the label it was first
                        // computed with — the label is outside the key, so
                        // restore this point's own label for bit-identical
                        // reports across cache temperatures
                        cand.strategy = points[i].label.clone();
                        slots.lock().unwrap()[i] = Some((cand, module));
                    }
                });
            }
        });
        slots.into_inner().unwrap()
    }

    fn screen(&self, points: &[CandidatePoint]) -> Vec<Option<(DseCandidate, Module)>> {
        self.local.screen(points)
    }

    fn screen_from(&self, base: &Module, pipeline: &str) -> Option<(DseCandidate, Module)> {
        self.local.screen_from(base, pipeline)
    }

    fn full_evals(&self) -> usize {
        self.full_evals.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(s: &str) -> ContentHash {
        ContentHash::of_parts(&[s])
    }

    #[test]
    fn shard_of_is_deterministic_and_in_range() {
        for n in 1..=5usize {
            for i in 0..200u32 {
                let k = key(&format!("k{i}"));
                let s = shard_of(k, n);
                assert!(s < n);
                assert_eq!(s, shard_of(k, n), "same inputs, same shard");
            }
        }
    }

    #[test]
    fn shard_of_spreads_keys_across_workers() {
        let n = 3;
        let mut counts = vec![0usize; n];
        for i in 0..600u32 {
            counts[shard_of(key(&format!("k{i}")), n)] += 1;
        }
        for (shard, c) in counts.iter().enumerate() {
            // a uniform spread gives 200 each; any real imbalance under
            // rendezvous hashing stays far from these bounds
            assert!(*c > 100 && *c < 300, "shard {shard} owns {c} of 600 keys");
        }
    }

    #[test]
    fn removing_the_last_shard_only_remaps_its_keys() {
        // the rendezvous property CI failover relies on: keys owned by a
        // surviving worker keep their owner when the fleet shrinks
        for i in 0..400u32 {
            let k = key(&format!("k{i}"));
            let with3 = shard_of(k, 3);
            if with3 < 2 {
                assert_eq!(shard_of(k, 2), with3, "surviving owner must not change");
            }
        }
    }

    #[test]
    fn shard_of_hex_matches_shard_of() {
        for i in 0..50u32 {
            let k = key(&format!("k{i}"));
            assert_eq!(shard_of_hex(&k.to_hex(), 3), Some(shard_of(k, 3)));
        }
        assert_eq!(shard_of_hex("not a key", 3), None);
    }

    #[test]
    fn leave_shrinks_the_fleet_and_bumps_the_epoch() {
        // ports 1/2 refuse instantly, so connect() warns and proceeds —
        // membership bookkeeping is testable without live workers
        let addrs = vec!["127.0.0.1:1".to_string(), "127.0.0.1:2".to_string()];
        let pool = WorkerPool::connect(&addrs).unwrap();
        assert_eq!((pool.len(), pool.epoch()), (2, 1));
        assert!(pool.leave("127.0.0.1:9").is_err(), "unknown member must be rejected");
        pool.leave("127.0.0.1:1").unwrap();
        assert_eq!((pool.len(), pool.epoch()), (1, 2));
        assert_eq!(pool.addrs(), vec!["127.0.0.1:2".to_string()]);
        assert!(pool.leave("127.0.0.1:1").is_err(), "cannot leave twice");
        assert_eq!(pool.stats().resp_shard_failovers, 0);
    }

    #[test]
    fn join_of_an_unreachable_worker_changes_nothing() {
        let addrs = vec!["127.0.0.1:1".to_string()];
        let pool = WorkerPool::connect(&addrs).unwrap();
        assert!(pool.join("127.0.0.1:1").is_err(), "duplicate member must be rejected");
        // handshake-first: a dead joiner must not commit a new epoch
        assert!(pool.join("127.0.0.1:2").is_err());
        assert_eq!((pool.len(), pool.epoch()), (1, 1));
    }
}
