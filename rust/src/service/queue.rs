//! Blocking MPMC job queue for the worker pool (condvar over a `VecDeque`;
//! no external crates, no lock-free cleverness — the queue holds whole DSE
//! jobs, so it is never the hot path).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct State<T> {
    jobs: VecDeque<T>,
    closed: bool,
}

/// See module docs.
pub struct JobQueue<T> {
    state: Mutex<State<T>>,
    available: Condvar,
}

impl<T> Default for JobQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> JobQueue<T> {
    pub fn new() -> Self {
        JobQueue {
            state: Mutex::new(State { jobs: VecDeque::new(), closed: false }),
            available: Condvar::new(),
        }
    }

    /// Enqueue a job. Returns `false` (dropping the job) after [`close`].
    ///
    /// [`close`]: JobQueue::close
    pub fn push(&self, job: T) -> bool {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return false;
        }
        s.jobs.push_back(job);
        drop(s);
        self.available.notify_one();
        true
    }

    /// Dequeue, blocking while the queue is open and empty. Returns `None`
    /// once the queue is closed *and* drained — the worker-exit signal.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(job) = s.jobs.pop_front() {
                return Some(job);
            }
            if s.closed {
                return None;
            }
            s = self.available.wait(s).unwrap();
        }
    }

    /// Stop accepting jobs and wake every blocked worker. Queued jobs still
    /// drain (graceful shutdown).
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.available.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_single_thread() {
        let q = JobQueue::new();
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let q = JobQueue::new();
        q.push("a");
        q.close();
        assert!(!q.push("b"), "closed queue rejects jobs");
        assert_eq!(q.pop(), Some("a"), "queued jobs still drain");
        assert_eq!(q.pop(), None, "then workers see the exit signal");
    }

    #[test]
    fn blocked_workers_wake_on_close() {
        let q = Arc::new(JobQueue::<u32>::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || q.pop()));
        }
        // give the workers a moment to block, then close
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        for h in handles {
            assert_eq!(h.join().unwrap(), None);
        }
    }

    #[test]
    fn many_producers_many_consumers_conserve_jobs() {
        let q = Arc::new(JobQueue::<u64>::new());
        let mut producers = Vec::new();
        for p in 0..4u64 {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..100 {
                    assert!(q.push(p * 100 + i));
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..4 {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut sum = 0u64;
                let mut count = 0u64;
                while let Some(j) = q.pop() {
                    sum += j;
                    count += 1;
                }
                (sum, count)
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let (mut sum, mut count) = (0, 0);
        for c in consumers {
            let (s, n) = c.join().unwrap();
            sum += s;
            count += n;
        }
        assert_eq!(count, 400);
        assert_eq!(sum, (0..400u64).sum::<u64>());
    }
}
