//! Blocking MPMC job queue for the worker pool (condvar over a `VecDeque`;
//! no external crates, no lock-free cleverness — the queue holds whole DSE
//! jobs, so it is never the hot path).
//!
//! Jobs carry a scheduling priority: [`push_prio`] inserts ahead of every
//! strictly-lower-priority job already queued, while jobs of equal priority
//! stay FIFO. Plain [`push`] is priority 0, so a queue that never sees an
//! elevated priority behaves exactly like the original FIFO.
//!
//! [`push`]: JobQueue::push
//! [`push_prio`]: JobQueue::push_prio

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct State<T> {
    /// Kept sorted by (priority desc, arrival order asc).
    jobs: VecDeque<(u32, T)>,
    closed: bool,
}

/// See module docs.
pub struct JobQueue<T> {
    state: Mutex<State<T>>,
    available: Condvar,
}

impl<T> Default for JobQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> JobQueue<T> {
    pub fn new() -> Self {
        JobQueue {
            state: Mutex::new(State { jobs: VecDeque::new(), closed: false }),
            available: Condvar::new(),
        }
    }

    /// Enqueue a job at priority 0. Returns `false` (dropping the job)
    /// after [`close`].
    ///
    /// [`close`]: JobQueue::close
    pub fn push(&self, job: T) -> bool {
        self.push_prio(job, 0)
    }

    /// Enqueue a job ahead of every strictly-lower-priority job already
    /// queued; equal-priority jobs stay FIFO. Returns `false` (dropping the
    /// job) after [`close`].
    ///
    /// [`close`]: JobQueue::close
    pub fn push_prio(&self, job: T, prio: u32) -> bool {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return false;
        }
        // Insertion point: just past the last entry at `>=` this priority.
        // With uniform priorities that is always the back, so the common
        // case stays O(1) push_back.
        let at = match s.jobs.back() {
            Some((p, _)) if *p >= prio => s.jobs.len(),
            _ => s.jobs.iter().rposition(|(p, _)| *p >= prio).map_or(0, |i| i + 1),
        };
        s.jobs.insert(at, (prio, job));
        drop(s);
        self.available.notify_one();
        true
    }

    /// Dequeue, blocking while the queue is open and empty. Returns `None`
    /// once the queue is closed *and* drained — the worker-exit signal.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some((_, job)) = s.jobs.pop_front() {
                return Some(job);
            }
            if s.closed {
                return None;
            }
            s = self.available.wait(s).unwrap();
        }
    }

    /// Stop accepting jobs and wake every blocked worker. Queued jobs still
    /// drain (graceful shutdown).
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.available.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_single_thread() {
        let q = JobQueue::new();
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn priority_jumps_queue_but_equal_priorities_stay_fifo() {
        let q = JobQueue::new();
        assert!(q.push(1)); // prio 0
        assert!(q.push(2)); // prio 0
        assert!(q.push_prio(10, 5));
        assert!(q.push_prio(11, 5)); // same prio: behind 10
        assert!(q.push_prio(20, 9)); // highest: front of everything
        assert!(q.push(3)); // prio 0: back of the line
        for want in [20, 10, 11, 1, 2, 3] {
            assert_eq!(q.pop(), Some(want));
        }
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let q = JobQueue::new();
        q.push("a");
        q.close();
        assert!(!q.push("b"), "closed queue rejects jobs");
        assert_eq!(q.pop(), Some("a"), "queued jobs still drain");
        assert_eq!(q.pop(), None, "then workers see the exit signal");
    }

    #[test]
    fn blocked_workers_wake_on_close() {
        let q = Arc::new(JobQueue::<u32>::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || q.pop()));
        }
        // give the workers a moment to block, then close
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        for h in handles {
            assert_eq!(h.join().unwrap(), None);
        }
    }

    #[test]
    fn many_producers_many_consumers_conserve_jobs() {
        let q = Arc::new(JobQueue::<u64>::new());
        let mut producers = Vec::new();
        for p in 0..4u64 {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..100 {
                    assert!(q.push(p * 100 + i));
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..4 {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut sum = 0u64;
                let mut count = 0u64;
                while let Some(j) = q.pop() {
                    sum += j;
                    count += 1;
                }
                (sum, count)
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let (mut sum, mut count) = (0, 0);
        for c in consumers {
            let (s, n) = c.join().unwrap();
            sum += s;
            count += n;
        }
        assert_eq!(count, 400);
        assert_eq!(sum, (0..400u64).sum::<u64>());
    }
}
