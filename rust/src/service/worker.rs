//! Job execution: turn a parsed [`Request`] into a response line, answering
//! through the two-level content-addressed cache.
//!
//! * **Response cache** — keyed on [`Flow::cache_key`] (module IR, platform,
//!   pipeline/objective, scenario, seed). A warm repeat of an identical
//!   request skips *everything* and replays the stored payload, which is
//!   bit-identical to a fresh run because every evaluation is deterministic.
//! * **Candidate cache** — shared across jobs via
//!   [`DseOptions::cache`](crate::passes::DseOptions): overlapping requests
//!   (same module on another platform, a grown factor sweep, a different
//!   scenario on the same candidates) reuse individual candidate
//!   evaluations even when the response key differs.
//!
//! Workers are plain std threads popping a [`JobQueue`]; results travel
//! back to the connection thread over the job's `mpsc` channel.

use std::path::Path;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::{flow_report_json, render_dse_table, Flow};
use crate::des::{DesConfig, WorkloadScenario};
use crate::ir::{module_fingerprint, parse_module, Module};
use crate::passes::{
    candidate_cache_key, objective_from_json, outcome_to_json, CandidateCache, DseObjective,
};
use crate::platform::{builtin, builtin_names, PlatformSpec};
use crate::search::{CandidatePoint, ObjectiveEvaluator};
use crate::traffic::{AutoscalePolicy, SloSpec};
use crate::util::Json;

use super::cache::{CacheStats, EvalCache};
use super::persist::{decode_served, encode_served, open_candidate_cache, open_persistent_cache};
use super::proto::{error_response, ok_response, Command, ProtoError, Request, PROTO_VERSION};
use super::queue::JobQueue;
use super::remote::WorkerPool;

/// One unit of work: a request plus the channel its response line goes back
/// through (the connection thread blocks on the receiver).
pub struct Job {
    pub req: Request,
    pub reply: mpsc::Sender<String>,
    /// When the connection thread enqueued it (queue-wait metric).
    pub enqueued: std::time::Instant,
}

/// The outcome of evaluating a job request: the `result` payload, or a
/// deterministic failure. Both are cached — recomputing a failure costs as
/// much as recomputing a success.
#[derive(Debug, Clone)]
pub enum Served {
    Ok(Json),
    Failed(String),
}

/// Shared service state: the caches and per-job evaluation knobs.
pub struct ServiceState {
    /// Whole-response memo (single-flight).
    pub responses: EvalCache<Served>,
    /// Candidate-evaluation memo shared with the DSE.
    pub candidates: Arc<CandidateCache>,
    /// DSE candidate-evaluation threads *per job* (the pool already
    /// parallelizes across jobs; keep this at 1 unless the pool is small).
    pub dse_threads: usize,
    /// Remote evaluation pool (`olympus serve --workers`); `None`
    /// evaluates every candidate in-process.
    pub remote: Option<Arc<WorkerPool>>,
    /// Shard assignment announced by a coordinator's `handshake` (worker
    /// daemons only); echoed by `cache-stats`.
    pub shard: Mutex<Option<(u64, u64)>>,
    /// Daemon start time (`uptime_ms` in `cache-stats`/`metrics`).
    pub started: std::time::Instant,
}

impl ServiceState {
    pub fn new(response_capacity: usize, dse_threads: usize) -> ServiceState {
        // Candidate entries hold cloned Modules, so a bounded response cache
        // implies a bounded candidate cache too (~a dozen candidates per
        // response); 0 keeps both unbounded.
        let candidate_capacity = response_capacity.saturating_mul(16);
        // Touch the registry so the process uptime epoch is pinned at
        // daemon construction, not at the first request.
        let _ = crate::obs::metrics();
        ServiceState {
            responses: EvalCache::with_capacity(response_capacity),
            candidates: Arc::new(CandidateCache::with_capacity(candidate_capacity)),
            dse_threads: dse_threads.max(1),
            remote: None,
            shard: Mutex::new(None),
            started: std::time::Instant::now(),
        }
    }

    /// Like [`ServiceState::new`], plus an optional on-disk persistence
    /// dir (`olympus serve --cache-dir`): both cache tiers load every
    /// decodable journal record at startup and write through on miss, so a
    /// restarted daemon answers repeated requests from disk — bit-identical
    /// and with zero evaluations (see [`crate::service::persist`]).
    pub fn with_cache_dir(
        response_capacity: usize,
        dse_threads: usize,
        cache_dir: Option<&Path>,
    ) -> Result<ServiceState> {
        let Some(dir) = cache_dir else {
            return Ok(ServiceState::new(response_capacity, dse_threads));
        };
        let candidate_capacity = response_capacity.saturating_mul(16);
        // responses fsync per append (a served answer must survive a crash
        // once the client saw it); candidates are OS-buffered + fsync at
        // drop — losing one to a power cut only re-pays one evaluation
        let (responses, _rstore) = open_persistent_cache(
            &dir.join(super::persist::RESPONSES_JOURNAL),
            response_capacity,
            true,
            encode_served,
            decode_served,
        )?;
        let (candidates, _cstore) = open_candidate_cache(dir, candidate_capacity)?;
        Ok(ServiceState {
            responses,
            candidates,
            dse_threads: dse_threads.max(1),
            remote: None,
            shard: Mutex::new(None),
            started: std::time::Instant::now(),
        })
    }

    /// Counters for `cache-stats`.
    pub fn stats(&self) -> (CacheStats, CacheStats) {
        (self.responses.stats(), self.candidates.stats())
    }
}

/// Worker thread body: drain the queue until it closes. Queue wait is
/// recorded overall and per scheduling class (`p{prio}`); a job whose
/// `deadline_ms` expired while it sat queued is shed with a structured
/// `deadline-expired` error instead of burning an evaluation on an answer
/// the client no longer wants.
pub fn worker_loop(queue: Arc<JobQueue<Job>>, state: Arc<ServiceState>) {
    while let Some(job) = queue.pop() {
        let m = crate::obs::metrics();
        let waited = job.enqueued.elapsed();
        m.queue_wait.record_duration(waited);
        m.class_queue_wait(&format!("p{}", job.req.priority.unwrap_or(0)))
            .record_duration(waited);
        if let Some(limit) = job.req.deadline_ms {
            if waited.as_millis() > u128::from(limit) {
                let mut e = ProtoError::new(
                    "deadline-expired",
                    format!(
                        "job queued {} ms, past its {limit} ms deadline",
                        waited.as_millis()
                    ),
                );
                e.id = job.req.id.clone();
                let _ = job.reply.send(error_response(&e));
                continue;
            }
        }
        let resp = execute_request(&state, &job.req);
        // a dropped receiver just means the client went away mid-job
        let _ = job.reply.send(resp);
    }
}

fn stats_json(s: &CacheStats) -> Json {
    Json::obj(vec![
        ("entries", s.entries.into()),
        ("hits", s.hits.into()),
        ("misses", s.misses.into()),
        ("coalesced", s.coalesced.into()),
        ("evicted", s.evicted.into()),
        ("disk_loaded", s.disk_loaded.into()),
        ("disk_persisted", s.disk_persisted.into()),
        ("disk_corrupt_skipped", s.disk_corrupt_skipped.into()),
    ])
}

/// Evaluate one request to a full response line. Pure up to cache effects:
/// identical requests produce byte-identical `result` payloads regardless
/// of worker count or cache temperature. Observability (the span log + the
/// verb counter + the latency histogram) is recorded around the dispatch
/// and never touches the payload.
pub fn execute_request(state: &ServiceState, req: &Request) -> String {
    let metrics = crate::obs::metrics();
    metrics.count_request(req.cmd.as_str());
    let span = crate::obs::next_span();
    crate::obs::debug("request", &[("span", span.into()), ("cmd", req.cmd.as_str().into())]);
    let t0 = std::time::Instant::now();
    let resp = execute_request_inner(state, req);
    let dt = t0.elapsed();
    metrics.request_latency.record_duration(dt);
    crate::obs::debug(
        "request-done",
        &[
            ("span", span.into()),
            ("cmd", req.cmd.as_str().into()),
            ("ms", (dt.as_secs_f64() * 1e3).into()),
        ],
    );
    resp
}

fn execute_request_inner(state: &ServiceState, req: &Request) -> String {
    match req.cmd {
        Command::Ping => ok_response(&req.id, req.cmd, false, None, Json::obj(vec![])),
        Command::Shutdown => {
            // the connection thread performs the actual shutdown; this arm
            // only exists so a queued shutdown still gets a well-formed reply
            ok_response(&req.id, req.cmd, false, None, Json::obj(vec![]))
        }
        Command::CacheStats => {
            let (resp, cand) = state.stats();
            let remote = state.remote.as_ref().map(|p| p.stats()).unwrap_or_default();
            let workers = state.remote.as_ref().map(|p| p.len()).unwrap_or(0);
            let mut fields = vec![
                ("responses", stats_json(&resp)),
                ("candidates", stats_json(&cand)),
                (
                    "remote",
                    Json::obj(vec![
                        ("workers", workers.into()),
                        ("remote_hits", remote.remote_hits.into()),
                        ("remote_evals", remote.remote_evals.into()),
                        ("remote_failovers", remote.remote_failovers.into()),
                    ]),
                ),
            ];
            fields.push(("uptime_ms", uptime_ms(state).into()));
            fields.push(("requests", crate::obs::metrics().requests_json()));
            if let Some((index, total)) = *state.shard.lock().unwrap() {
                let shard = Json::obj(vec![("index", index.into()), ("total", total.into())]);
                fields.push(("shard", shard));
            }
            ok_response(&req.id, req.cmd, false, None, Json::obj(fields))
        }
        Command::Metrics => execute_metrics(state, req),
        Command::Handshake => execute_handshake(state, req),
        Command::EvalCandidate => match execute_eval_candidate(state, req) {
            Ok(resp) => resp,
            Err(mut e) => {
                e.id = req.id.clone();
                error_response(&e)
            }
        },
        Command::Dse | Command::Des | Command::Flow => match execute_job(state, req) {
            Ok((key, payload, cached)) => match payload {
                Served::Ok(result) => ok_response(&req.id, req.cmd, cached, Some(&key), result),
                Served::Failed(msg) => {
                    let mut e = ProtoError::new("eval-failed", msg);
                    e.id = req.id.clone();
                    error_response(&e)
                }
            },
            Err(mut e) => {
                e.id = req.id.clone();
                error_response(&e)
            }
        },
    }
}

fn uptime_ms(state: &ServiceState) -> u64 {
    state.started.elapsed().as_millis().min(u64::MAX as u128) as u64
}

/// The `metrics` verb: the process-wide registry as one JSON object —
/// per-verb request counters, latency histogram summaries, DES throughput —
/// plus (on a coordinator) the remote counters and worker addresses
/// `olympus stats` fans out to, and (on a worker) the shard assignment.
fn execute_metrics(state: &ServiceState, req: &Request) -> String {
    let m = crate::obs::metrics();
    let mut fields = vec![
        ("uptime_ms", uptime_ms(state).into()),
        ("requests", m.requests_json()),
        ("histograms", m.histograms_json()),
        ("des", m.des_json()),
    ];
    if let Some(pool) = &state.remote {
        let rs = pool.stats();
        let workers: Vec<Json> = pool.addrs().iter().map(|a| a.as_str().into()).collect();
        fields.push((
            "remote",
            Json::obj(vec![
                ("workers", Json::Arr(workers)),
                ("remote_hits", rs.remote_hits.into()),
                ("remote_evals", rs.remote_evals.into()),
                ("remote_failovers", rs.remote_failovers.into()),
            ]),
        ));
    }
    if let Some((index, total)) = *state.shard.lock().unwrap() {
        fields.push(("shard", Json::obj(vec![("index", index.into()), ("total", total.into())])));
    }
    ok_response(&req.id, req.cmd, false, None, Json::obj(fields))
}

/// Validate a coordinator's `handshake`: exact protocol version, then a
/// well-formed shard map. Every failure mode — malformed registration,
/// version skew, truncated shard map — is a structured error on a live
/// connection, never a drop or a panic.
fn execute_handshake(state: &ServiceState, req: &Request) -> String {
    let fail = |code: &'static str, msg: String| {
        let mut e = ProtoError::new(code, msg);
        e.id = req.id.clone();
        error_response(&e)
    };
    let Some(version) = req.proto_version else {
        return fail("bad-request", "handshake requires integer field 'proto_version'".into());
    };
    if version != PROTO_VERSION {
        return fail(
            "proto-mismatch",
            format!("coordinator speaks protocol {version}, this worker speaks {PROTO_VERSION}"),
        );
    }
    let Some(map) = &req.shard_map else {
        return fail("bad-request", "handshake requires object field 'shard_map'".into());
    };
    match parse_shard_map(map) {
        Err(msg) => fail("bad-request", msg),
        Ok((index, total)) => {
            *state.shard.lock().unwrap() = Some((index, total));
            ok_response(
                &req.id,
                req.cmd,
                false,
                None,
                Json::obj(vec![
                    ("proto_version", PROTO_VERSION.into()),
                    ("shard", Json::obj(vec![("index", index.into()), ("total", total.into())])),
                ]),
            )
        }
    }
}

/// Well-formedness of a handshake `shard_map`: an object with
/// `index < total`, `total >= 1` and — when present — exactly `total`
/// string entries in `workers`. Error messages name the offending field so
/// a truncated map is diagnosable from the coordinator side.
fn parse_shard_map(map: &Json) -> Result<(u64, u64), String> {
    if map.as_obj().is_none() {
        return Err("'shard_map' must be an object".to_string());
    }
    let total = map
        .get("total")
        .as_u64()
        .ok_or_else(|| "'shard_map.total' must be an integer >= 1".to_string())?;
    if total == 0 {
        return Err("'shard_map.total' must be >= 1".to_string());
    }
    let index = map
        .get("index")
        .as_u64()
        .ok_or_else(|| "'shard_map.index' must be a non-negative integer".to_string())?;
    if index >= total {
        return Err(format!("'shard_map.index' {index} out of range for total {total}"));
    }
    if map.get("workers") != &Json::Null {
        let arr = map
            .get("workers")
            .as_arr()
            .ok_or_else(|| "'shard_map.workers' must be an array of addresses".to_string())?;
        if arr.len() as u64 != total {
            return Err(format!(
                "'shard_map.workers' names {} workers but total is {total} (truncated map?)",
                arr.len()
            ));
        }
        if arr.iter().any(|w| w.as_str().is_none()) {
            return Err("'shard_map.workers' entries must be strings".to_string());
        }
    }
    Ok((index, total))
}

/// Evaluate one DSE candidate for a coordinator (`eval-candidate`),
/// answered through this process's candidate cache — memory tier plus the
/// optional `--cache-dir` journal, written through on miss. The outcome
/// travels in the bit-exact journal codec ([`outcome_to_json`]), so the
/// coordinator reconstructs exactly what a local evaluation would have
/// produced; the derived key is cross-checked against the routed one so
/// codec skew fails structured instead of caching under a wrong address.
fn execute_eval_candidate(state: &ServiceState, req: &Request) -> Result<String, ProtoError> {
    let module = load_module(req)?;
    let platform = load_platform(req)?;
    let objective = match &req.objective_json {
        Some(j) => objective_from_json(j).ok_or_else(|| {
            ProtoError::new("bad-request", "undecodable 'objective_json' (version skew?)")
        })?,
        None => DseObjective::Analytic,
    };
    let pipeline = req.point_pipeline.as_deref().ok_or_else(|| {
        ProtoError::new("bad-request", "'eval-candidate' requires string field 'point_pipeline'")
    })?;
    let point = CandidatePoint::new(req.point_label.as_deref().unwrap_or("remote"), pipeline);
    let key = candidate_cache_key(
        &module_fingerprint(&module),
        &platform.fingerprint(),
        &point.pipeline,
        &format!("{objective:?}"),
    );
    if let Some(expected) = &req.key {
        if *expected != key.to_hex() {
            return Err(ProtoError::new(
                "key-mismatch",
                format!(
                    "coordinator routed key {expected} but this worker derives {}; \
                     refusing to answer under a disputed address (version skew?)",
                    key.to_hex()
                ),
            ));
        }
    }
    let evaluator = ObjectiveEvaluator::new(&module, &platform, &objective, 1, None);
    let t0 = std::time::Instant::now();
    let (outcome, cached) =
        state.candidates.get_or_compute(key, || evaluator.compute_outcome(&point));
    let m = crate::obs::metrics();
    if cached {
        m.eval_cache_hit.record_duration(t0.elapsed());
    } else {
        m.eval_local.record_duration(t0.elapsed());
    }
    Ok(ok_response(&req.id, req.cmd, cached, Some(&key.to_hex()), outcome_to_json(&outcome)))
}

/// Resolve + evaluate a job command through the response cache. Returns the
/// content-address (hex), the served payload and whether it came from cache.
fn execute_job(
    state: &ServiceState,
    req: &Request,
) -> Result<(String, Served, bool), ProtoError> {
    let module = load_module(req)?;
    let axis = load_platform_axis(req)?;
    let platform = match &axis {
        Some(specs) => specs[0].clone(),
        None => load_platform(req)?,
    };
    let mut flow = build_flow(state, req, platform)?;
    if let Some(specs) = axis {
        flow = flow.with_platforms(specs);
    }
    let cmd = req.cmd;
    // `dse` and `flow` can share a Flow::cache_key but render different
    // payloads, so the command is part of the response address
    let key = crate::util::ContentHash::of_parts(&[
        "olympus-serve-v1",
        cmd.as_str(),
        &flow.cache_key(&module).to_hex(),
    ]);
    let (served, cached) = state.responses.get_or_compute(key, || {
        match flow.run(module.clone(), "app") {
            Ok(r) => Served::Ok(render_result(cmd, &r)),
            Err(e) => Served::Failed(format!("{e:#}")),
        }
    });
    Ok((key.to_hex(), served, cached))
}

fn load_module(req: &Request) -> Result<Module, ProtoError> {
    let text = req.ir.as_deref().ok_or_else(|| ProtoError::new("bad-request", "missing 'ir'"))?;
    let m = parse_module(text).map_err(|e| ProtoError::new("bad-ir", e.to_string()))?;
    let errs = crate::ir::verify_module(&m);
    if !errs.is_empty() {
        return Err(ProtoError::new("bad-ir", format!("structural verification failed: {errs:?}")));
    }
    let derrs = crate::dialect::verify_dialect(&m, false);
    if !derrs.is_empty() {
        return Err(ProtoError::new("bad-ir", format!("dialect verification failed: {derrs:?}")));
    }
    Ok(m)
}

/// Resolve the `platforms` search axis when present: builtin names only
/// (the wire carries names, not full specs), mutually exclusive with
/// `platform`/`platform_json`. The first entry doubles as the primary
/// platform, mirroring the CLI's `--platforms`.
fn load_platform_axis(req: &Request) -> Result<Option<Vec<PlatformSpec>>, ProtoError> {
    let Some(names) = &req.platforms else { return Ok(None) };
    if req.platform.is_some() || req.platform_json.is_some() {
        return Err(ProtoError::new(
            "bad-request",
            "'platforms' is mutually exclusive with 'platform'/'platform_json'; the axis \
             searches the listed platforms and lowers onto the winner",
        ));
    }
    let mut specs = Vec::with_capacity(names.len());
    for name in names {
        let spec = builtin(name).ok_or_else(|| {
            ProtoError::new(
                "bad-platform",
                format!(
                    "unknown builtin platform '{name}' in 'platforms' (have {:?}); the axis \
                     carries builtin names only — submit 'platform_json' for a single \
                     custom board",
                    builtin_names()
                ),
            )
        })?;
        specs.push(spec);
    }
    Ok(Some(specs))
}

fn load_platform(req: &Request) -> Result<PlatformSpec, ProtoError> {
    if let Some(j) = &req.platform_json {
        return PlatformSpec::from_json(j)
            .map_err(|e| ProtoError::new("bad-platform", format!("{e:#}")));
    }
    let name = req.platform.as_deref().unwrap_or("u280");
    builtin(name).ok_or_else(|| {
        ProtoError::new(
            "bad-platform",
            format!(
                "unknown builtin platform '{name}' (have {:?}); pass platform_json for \
                 custom boards",
                builtin_names()
            ),
        )
    })
}

/// Mirror the CLI's `dse`/`des`/`lower` flow construction so served results
/// are bit-identical to single-shot runs.
fn build_flow(
    state: &ServiceState,
    req: &Request,
    platform: PlatformSpec,
) -> Result<Flow, ProtoError> {
    // a pre-resolved `scenario_json` (how the CLI ships trace files, so the
    // daemon never needs the client's filesystem) wins over the spec string;
    // the string form still resolves `trace:` against the daemon's own disk
    let scenario = match (&req.scenario_json, req.scenario.as_deref()) {
        (Some(j), _) => Some(WorkloadScenario::from_json(j).ok_or_else(|| {
            ProtoError::new("bad-request", "undecodable 'scenario_json' (version skew?)")
        })?),
        (None, Some(spec)) => Some(
            crate::traffic::scenario_from_spec(spec)
                .map_err(|e| ProtoError::new("bad-request", e))?,
        ),
        (None, None) => None,
    };
    let mut cfg = DesConfig::default();
    if let Some(seed) = req.seed {
        cfg.seed = seed;
    }
    if let Some(spec) = req.autoscale.as_deref() {
        cfg.autoscale =
            Some(AutoscalePolicy::parse(spec).map_err(|e| ProtoError::new("bad-request", e))?);
    }
    let slo = match req.slo.as_deref() {
        Some(spec) => Some(SloSpec::parse(spec).map_err(|e| ProtoError::new("bad-request", e))?),
        None => None,
    };
    // an SLO only scores under the slo-score objective; alongside an
    // explicit analytic/des-score objective it would be silently dead
    if slo.is_some() && matches!(req.objective.as_deref(), Some("analytic") | Some("des-score")) {
        return Err(ProtoError::new(
            "bad-request",
            "'slo' only scores under objective 'slo-score'; drop it or switch objective",
        ));
    }
    // an explicit pipeline skips the DSE entirely, so search fields on the
    // same request would be silently dead — reject, mirroring the CLI
    if req.pipeline.is_some()
        && (req.driver.is_some()
            || req.budget.is_some()
            || req.search_seed.is_some()
            || req.factors.is_some()
            || req.platforms.is_some())
    {
        return Err(ProtoError::new(
            "bad-request",
            "'driver'/'budget'/'search_seed'/'factors'/'platforms' configure the \
             design-space search; drop 'pipeline' to search, or drop the search fields",
        ));
    }
    let mut flow = Flow::new(platform)
        .with_jobs(state.dse_threads)
        .with_cache(state.candidates.clone());
    if let Some(pool) = &state.remote {
        // full-fidelity candidate evaluations route to the shard owners;
        // the response stays bit-identical, so the pool is deliberately
        // NOT part of any cache key
        flow = flow.with_remote(pool.clone());
    }
    flow.dse_factors = req.factors.clone().unwrap_or_default();
    flow.des_config = cfg.clone();
    // driver + budget round-trip into the flow (and thus the cache key)
    let driver = crate::search::DriverKind::from_flags(
        req.driver.as_deref().unwrap_or("exhaustive"),
        req.budget.map(|b| b as usize),
        req.search_seed,
    )
    .map_err(|e| ProtoError::new("bad-request", e))?;
    flow = flow.with_driver(driver);
    match (req.objective.as_deref(), &slo) {
        (None, None) | (Some("analytic"), _) => {}
        // a bare `slo` implies the slo-score objective
        (None, Some(sl)) | (Some("slo-score"), Some(sl)) => {
            let sc = scenario.clone().unwrap_or_else(|| WorkloadScenario::closed_loop(4));
            flow = flow.with_objective(DseObjective::slo_score_with(sc, cfg.clone(), sl.clone()));
        }
        (Some("slo-score"), None) => {
            return Err(ProtoError::new(
                "bad-request",
                "objective 'slo-score' requires string field 'slo' (CLASS=p99<MS[,...])",
            ));
        }
        (Some("des-score"), _) => {
            let sc = scenario.clone().unwrap_or_else(|| WorkloadScenario::closed_loop(4));
            flow = flow.with_objective(DseObjective::des_score_with(sc, cfg.clone()));
        }
        (Some(other), _) => {
            return Err(ProtoError::new(
                "bad-request",
                format!("unknown objective '{other}' (want analytic | des-score | slo-score)"),
            ));
        }
    }
    match req.cmd {
        Command::Dse => {
            if let Some(p) = &req.pipeline {
                return Err(ProtoError::new(
                    "bad-request",
                    format!("'dse' explores strategies itself; drop pipeline '{p}' or use cmd 'flow'"),
                ));
            }
        }
        Command::Des => {
            let sc = scenario.clone().unwrap_or_else(|| WorkloadScenario::closed_loop(4));
            flow = flow.with_scenario(sc.clone());
            match &req.pipeline {
                Some(p) => flow = flow.with_pipeline(p),
                // no explicit pipeline: DSE picks the design, scored by the
                // DES too (mirrors `olympus des`) — unless an slo-score
                // objective is already in charge
                None => {
                    if slo.is_none() && req.objective.as_deref() != Some("slo-score") {
                        flow = flow.with_objective(DseObjective::des_score_with(sc, cfg));
                    }
                }
            }
        }
        Command::Flow => {
            if let Some(p) = &req.pipeline {
                flow = flow.with_pipeline(p);
            }
            if let Some(sc) = scenario {
                flow = flow.with_scenario(sc);
            }
        }
        _ => {}
    }
    Ok(flow)
}

fn render_result(cmd: Command, r: &crate::coordinator::FlowResult) -> Json {
    let mut fields: Vec<(&str, Json)> = Vec::new();
    if let Some(dse) = &r.dse {
        fields.push(("best_strategy", dse.best_strategy.as_str().into()));
        fields.push(("driver", dse.driver.as_str().into()));
        fields.push(("full_evals", dse.full_evals.into()));
        fields.push(("table", render_dse_table(dse).into()));
        let cands: Vec<Json> = dse
            .candidates
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("strategy", c.strategy.as_str().into()),
                    ("pipeline", c.pipeline.as_str().into()),
                    // infinite = infeasible under the objective; null in JSON
                    ("score", if c.score.is_finite() { c.score.into() } else { Json::Null }),
                    ("makespan_s", c.makespan_s.into()),
                    (
                        "des_makespan_s",
                        c.des_makespan_s.map(Json::Num).unwrap_or(Json::Null),
                    ),
                    ("fits", c.fits.into()),
                ])
            })
            .collect();
        fields.push(("candidates", Json::Arr(cands)));
    }
    match cmd {
        Command::Dse => {
            fields.push(("best_ir", crate::ir::print_module(&r.module).into()));
        }
        Command::Des => {
            if let Some(des) = &r.des {
                fields.push(("des_report", des.to_string().into()));
                fields.push(("makespan_s", des.makespan_s.into()));
                fields.push(("p99_job_latency_s", des.p99_job_latency_s.into()));
                fields.push(("jobs_completed", des.jobs_completed.into()));
            }
        }
        Command::Flow => {
            fields.push(("report", flow_report_json(r)));
        }
        _ => {}
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::ir::print_module;
    use crate::service::proto::parse_request;

    fn request(extra: &str) -> Request {
        let ir = print_module(&fig4a_module());
        let line = Json::obj(vec![("cmd", "dse".into()), ("ir", ir.into())]).to_string();
        // splice extra fields in via reparse to keep escaping correct
        let mut v = Json::parse(&line).unwrap();
        if !extra.is_empty() {
            let add = Json::parse(extra).unwrap();
            if let (Json::Obj(dst), Json::Obj(src)) = (&mut v, add) {
                dst.extend(src);
            }
        }
        parse_request(&v.to_string()).unwrap()
    }

    #[test]
    fn dse_request_serves_table_and_caches_repeat() {
        let state = ServiceState::new(0, 1);
        let req = request(r#"{"factors": [2], "id": 1}"#);
        let cold = execute_request(&state, &req);
        let v = Json::parse(&cold).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(true));
        assert_eq!(v.get("cached"), &Json::Bool(false));
        assert!(v.get("result").get("table").as_str().unwrap().contains("best: "));
        assert_eq!(v.get("key").as_str().unwrap().len(), 32);

        let warm = execute_request(&state, &req);
        let w = Json::parse(&warm).unwrap();
        assert_eq!(w.get("cached"), &Json::Bool(true));
        // identical payload + key, only the `cached` flag differs
        assert_eq!(w.get("result"), v.get("result"));
        assert_eq!(w.get("key"), v.get("key"));
        assert_eq!(state.responses.stats().misses, 1);
    }

    #[test]
    fn bad_platform_and_bad_ir_fail_structured() {
        let state = ServiceState::new(0, 1);
        let req = request(r#"{"platform": "nonesuch"}"#);
        let v = Json::parse(&execute_request(&state, &req)).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(false));
        assert_eq!(v.get("error").get("code").as_str(), Some("bad-platform"));

        let req = parse_request(r#"{"cmd": "flow", "ir": "%0 = garbage"}"#).unwrap();
        let v = Json::parse(&execute_request(&state, &req)).unwrap();
        assert_eq!(v.get("error").get("code").as_str(), Some("bad-ir"));
    }

    #[test]
    fn des_request_reports_scenario_replay() {
        let state = ServiceState::new(0, 1);
        let mut req = request(r#"{"scenario": "closed:2", "seed": 7}"#);
        req.cmd = Command::Des;
        req.pipeline = Some("sanitize, iris, channel-reassign".into());
        let v = Json::parse(&execute_request(&state, &req)).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(true), "{v}");
        assert_eq!(v.get("result").get("jobs_completed").as_usize(), Some(2));
        assert!(v.get("result").get("des_report").as_str().unwrap().contains("des report"));
    }

    #[test]
    fn driver_and_budget_requests_serve_and_key_separately() {
        let state = ServiceState::new(0, 1);
        let exhaustive = request(r#"{"factors": [2]}"#);
        let sh = request(r#"{"factors": [2], "driver": "successive-halving", "budget": 2}"#);
        let e = Json::parse(&execute_request(&state, &exhaustive)).unwrap();
        let s = Json::parse(&execute_request(&state, &sh)).unwrap();
        assert_eq!(e.get("ok"), &Json::Bool(true), "{e}");
        assert_eq!(s.get("ok"), &Json::Bool(true), "{s}");
        assert_ne!(e.get("key"), s.get("key"), "driver+budget round-trip into the key");
        assert_eq!(e.get("result").get("driver").as_str(), Some("exhaustive"));
        assert_eq!(s.get("result").get("driver").as_str(), Some("successive-halving"));
        // the shared candidate cache answers the promoted evaluations the
        // exhaustive request already paid for: at most 2 fresh computes
        assert!(s.get("result").get("full_evals").as_usize().unwrap() <= 2, "{s}");
        // budgeted search can never beat the exhaustive best strategy set
        assert!(e.get("result").get("table").as_str().unwrap().contains("best: "));
        assert!(s.get("result").get("table").as_str().unwrap().contains("best: "));
        // a bad driver/budget combination is a structured error
        let bad = request(r#"{"driver": "random"}"#);
        let b = Json::parse(&execute_request(&state, &bad)).unwrap();
        assert_eq!(b.get("ok"), &Json::Bool(false));
        assert_eq!(b.get("error").get("code").as_str(), Some("bad-request"));
        // search fields alongside an explicit pipeline are dead, so the
        // protocol rejects the combination just like the CLI does
        let mut dead = request(r#"{"driver": "successive-halving", "budget": 2}"#);
        dead.cmd = Command::Des;
        dead.pipeline = Some("sanitize".into());
        let d = Json::parse(&execute_request(&state, &dead)).unwrap();
        assert_eq!(d.get("ok"), &Json::Bool(false));
        assert_eq!(d.get("error").get("code").as_str(), Some("bad-request"));
    }

    #[test]
    fn slo_objective_serves_and_keys_apart_from_des_score() {
        let state = ServiceState::new(0, 1);
        // slo-score without the slo field is a structured error
        let missing = request(r#"{"objective": "slo-score", "factors": [2]}"#);
        let v = Json::parse(&execute_request(&state, &missing)).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(false));
        assert_eq!(v.get("error").get("code").as_str(), Some("bad-request"));
        assert!(v.get("error").get("message").as_str().unwrap().contains("'slo'"), "{v}");
        // an slo that can never score (wrong objective) is dead: rejected
        let dead = request(r#"{"objective": "des-score", "slo": "*=p99<5", "factors": [2]}"#);
        let d = Json::parse(&execute_request(&state, &dead)).unwrap();
        assert_eq!(d.get("error").get("code").as_str(), Some("bad-request"));
        // slo-score serves, and its response key differs from des-score on
        // the otherwise-identical request (the objective rides the key)
        let base = r#""factors": [2], "scenario": "closed:2", "seed": 3"#;
        let slo = request(&format!(
            r#"{{"objective": "slo-score", "slo": "*=p99<0.0001", {base}}}"#
        ));
        let des = request(&format!(r#"{{"objective": "des-score", {base}}}"#));
        let s = Json::parse(&execute_request(&state, &slo)).unwrap();
        let e = Json::parse(&execute_request(&state, &des)).unwrap();
        assert_eq!(s.get("ok"), &Json::Bool(true), "{s}");
        assert_eq!(e.get("ok"), &Json::Bool(true), "{e}");
        assert_ne!(s.get("key"), e.get("key"), "slo must ride the response key");
        assert!(s.get("result").get("table").as_str().unwrap().contains("best: "));
    }

    #[test]
    fn autoscale_and_scenario_json_ride_the_response_key() {
        let state = ServiceState::new(0, 1);
        let mk = |extra: &str| {
            let mut r = request(extra);
            r.cmd = Command::Des;
            r.pipeline = Some("sanitize".into());
            r
        };
        let plain = mk(r#"{"scenario": "closed:2", "seed": 7}"#);
        let scaled = mk(r#"{"scenario": "closed:2", "seed": 7, "autoscale": "0.001:4:0:1:4"}"#);
        let p = Json::parse(&execute_request(&state, &plain)).unwrap();
        let s = Json::parse(&execute_request(&state, &scaled)).unwrap();
        assert_eq!(p.get("ok"), &Json::Bool(true), "{p}");
        assert_eq!(s.get("ok"), &Json::Bool(true), "{s}");
        assert_ne!(p.get("key"), s.get("key"), "autoscale policy must ride the key");
        // a scenario shipped pre-resolved as JSON keys identically to the
        // same scenario named by spec string
        let sc = WorkloadScenario::closed_loop(2);
        let mut by_json = mk(r#"{"seed": 7}"#);
        by_json.scenario = None;
        by_json.scenario_json = Some(sc.to_json());
        let j = Json::parse(&execute_request(&state, &by_json)).unwrap();
        assert_eq!(j.get("ok"), &Json::Bool(true), "{j}");
        assert_eq!(j.get("key"), p.get("key"), "resolved scenario keys like its spec");
        assert_eq!(j.get("cached"), &Json::Bool(true), "and replays the cached payload");
        // a malformed autoscale spec fails structured
        let bad = mk(r#"{"scenario": "closed:2", "autoscale": "nope"}"#);
        let b = Json::parse(&execute_request(&state, &bad)).unwrap();
        assert_eq!(b.get("error").get("code").as_str(), Some("bad-request"));
    }

    #[test]
    fn expired_deadline_sheds_job_from_the_queue() {
        let state = Arc::new(ServiceState::new(0, 1));
        let queue = Arc::new(JobQueue::new());
        let (tx, rx) = mpsc::channel();
        let mut req = request("{}");
        req.deadline_ms = Some(0);
        // enqueued in the past, so any deadline has expired by pickup
        let enqueued = std::time::Instant::now() - std::time::Duration::from_millis(50);
        queue.push(Job { req, reply: tx, enqueued });
        queue.close();
        worker_loop(queue, state);
        let resp = Json::parse(&rx.recv().unwrap()).unwrap();
        assert_eq!(resp.get("ok"), &Json::Bool(false));
        assert_eq!(resp.get("error").get("code").as_str(), Some("deadline-expired"));
    }

    #[test]
    fn platform_axis_serves_cross_platform_table_and_keys_apart() {
        let state = ServiceState::new(0, 1);
        let single = request(r#"{"factors": [2]}"#);
        let multi = request(r#"{"factors": [2], "platforms": ["u280", "generic-ddr"]}"#);
        let s = Json::parse(&execute_request(&state, &single)).unwrap();
        let m = Json::parse(&execute_request(&state, &multi)).unwrap();
        assert_eq!(s.get("ok"), &Json::Bool(true), "{s}");
        assert_eq!(m.get("ok"), &Json::Bool(true), "{m}");
        assert_ne!(s.get("key"), m.get("key"), "the platform axis rides the response key");
        let table = m.get("result").get("table").as_str().unwrap();
        assert!(table.contains("best[u280]: u280/"), "{table}");
        assert!(table.contains("best[generic-ddr]: generic-ddr/"), "{table}");
        assert!(m.get("result").get("best_strategy").as_str().unwrap().contains('/'), "{m}");
        // the shared candidate cache answers the u280 half of the product
        // space from the single-platform run: a warm repeat computes nothing
        let warm = Json::parse(&execute_request(&state, &multi)).unwrap();
        assert_eq!(warm.get("cached"), &Json::Bool(true));
        assert_eq!(warm.get("result"), m.get("result"));
    }

    #[test]
    fn platform_axis_conflicts_fail_structured() {
        let state = ServiceState::new(0, 1);
        // unknown builtin in the axis
        let bad = request(r#"{"platforms": ["u280", "nonesuch"]}"#);
        let v = Json::parse(&execute_request(&state, &bad)).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(false));
        assert_eq!(v.get("error").get("code").as_str(), Some("bad-platform"));
        assert!(v.get("error").get("message").as_str().unwrap().contains("u50"), "{v}");
        // axis alongside a single-platform field
        let both = request(r#"{"platforms": ["u280", "generic-ddr"], "platform": "u280"}"#);
        let v = Json::parse(&execute_request(&state, &both)).unwrap();
        assert_eq!(v.get("error").get("code").as_str(), Some("bad-request"));
        // axis alongside an explicit pipeline (the axis would be dead)
        let mut dead = request(r#"{"platforms": ["u280", "generic-ddr"]}"#);
        dead.cmd = Command::Des;
        dead.pipeline = Some("sanitize".into());
        let v = Json::parse(&execute_request(&state, &dead)).unwrap();
        assert_eq!(v.get("error").get("code").as_str(), Some("bad-request"));
        assert!(v.get("error").get("message").as_str().unwrap().contains("platforms"), "{v}");
    }

    #[test]
    fn candidate_cache_spans_distinct_requests() {
        let state = ServiceState::new(0, 1);
        let a = request(r#"{"factors": [2]}"#);
        execute_request(&state, &a);
        let cand_misses = state.candidates.stats().misses;
        assert!(cand_misses > 0);
        // a *grown* sweep shares every already-evaluated candidate
        let b = request(r#"{"factors": [2, 4]}"#);
        let v = Json::parse(&execute_request(&state, &b)).unwrap();
        assert_eq!(v.get("cached"), &Json::Bool(false), "different response key");
        let after = state.candidates.stats();
        assert!(
            after.hits >= cand_misses - 2,
            "overlapping candidates served from cache: {after:?}"
        );
        // only the two new replicate/full x4 variants (plus nothing else) evaluate
        assert_eq!(after.misses, cand_misses + 2, "{after:?}");
    }
}
