//! Job execution: turn a parsed [`Request`] into a response line, answering
//! through the two-level content-addressed cache.
//!
//! * **Response cache** — keyed on [`Flow::response_key`] (command + module
//!   IR, platform, pipeline/objective, scenario, seed). A warm repeat of an
//!   identical request skips *everything* and replays the stored payload,
//!   which is bit-identical to a fresh run because every evaluation is
//!   deterministic.
//! * **Candidate cache** — shared across jobs via
//!   [`DseOptions::cache`](crate::passes::DseOptions): overlapping requests
//!   (same module on another platform, a grown factor sweep, a different
//!   scenario on the same candidates) reuse individual candidate
//!   evaluations even when the response key differs.
//!
//! With a worker fleet attached (`--workers`), a whole client-facing job is
//! additionally *routed*: the coordinator derives the response key, peeks
//! its own cache (old journals stay warm), and otherwise forwards the
//! request as an `eval-response` to the rendezvous owner of the key's
//! shard. Any routing failure falls back to local compute — bit-identical
//! by determinism, surfaced in `resp_shard_failovers`. Computed responses
//! also feed the [`GossipLog`] peers replicate over `journal-pull` (see
//! [`crate::service::gossip`]).
//!
//! Workers are plain std threads popping a [`JobQueue`]; results travel
//! back to the connection thread over the job's `mpsc` channel.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, Weak};

use anyhow::Result;

use crate::coordinator::{flow_report_json, render_dse_table, Flow};
use crate::des::{DesConfig, WorkloadScenario};
use crate::ir::{module_fingerprint, parse_module, Module};
use crate::passes::{
    candidate_cache_key, objective_from_json, outcome_to_json, CandidateCache, DseObjective,
};
use crate::platform::{builtin, builtin_names, PlatformSpec};
use crate::search::{CandidatePoint, ObjectiveEvaluator};
use crate::traffic::{AutoscalePolicy, SloSpec};
use crate::util::{ContentHash, Json};

use super::cache::{CacheStats, EvalCache};
use super::gossip::{GossipLog, GOSSIP_PAGE_LIMIT};
use super::persist::{
    decode_served, encode_served, open_candidate_cache, open_persistent_cache, DiskStore,
};
use super::proto::{
    encode_request, error_response, ok_response, Command, EvalResponsePayload, JobPayload,
    ProtoError, Request, VerbPayload, CAPABILITIES, PROTO_VERSION,
};
use super::queue::JobQueue;
use super::remote::WorkerPool;

/// One unit of work: a request plus the channel its response line goes back
/// through (the connection thread blocks on the receiver).
pub struct Job {
    pub req: Request,
    pub reply: mpsc::Sender<String>,
    /// When the connection thread enqueued it (queue-wait metric).
    pub enqueued: std::time::Instant,
}

/// The outcome of evaluating a job request: the `result` payload, or a
/// deterministic failure. Both are cached — recomputing a failure costs as
/// much as recomputing a success.
#[derive(Debug, Clone)]
pub enum Served {
    Ok(Json),
    Failed(String),
}

/// A coordinator's `handshake` shard assignment: this worker's slot in the
/// rendezvous map, the membership epoch the map was computed under, and the
/// full worker address list (gossip peers = everyone but ourselves).
#[derive(Debug, Clone, Default)]
pub struct ShardInfo {
    pub index: u64,
    pub total: u64,
    pub epoch: u64,
    pub workers: Vec<String>,
}

/// Shared service state: the caches and per-job evaluation knobs.
pub struct ServiceState {
    /// Whole-response memo (single-flight).
    pub responses: EvalCache<Served>,
    /// Candidate-evaluation memo shared with the DSE.
    pub candidates: Arc<CandidateCache>,
    /// DSE candidate-evaluation threads *per job* (the pool already
    /// parallelizes across jobs; keep this at 1 unless the pool is small).
    pub dse_threads: usize,
    /// Remote evaluation pool (`olympus serve --workers`); `None`
    /// evaluates every candidate in-process.
    pub remote: Option<Arc<WorkerPool>>,
    /// Response journal writer (with `--cache-dir`): absorbed gossip
    /// records are appended too, so a warmed shard survives a restart.
    resp_store: Option<Arc<DiskStore>>,
    /// Journal mirror peers page over `journal-pull`.
    pub gossip: GossipLog,
    /// Shard assignment announced by a coordinator's `handshake` (worker
    /// daemons only); echoed by `cache-stats`.
    pub shard: Mutex<Option<ShardInfo>>,
    /// Set at shutdown so background threads (gossip) exit promptly.
    stop: AtomicBool,
    /// Weak handle to the owning `Arc` (set by `bind`); what the lazily
    /// started gossip thread holds so it never outlives the server.
    self_ref: Mutex<Weak<ServiceState>>,
    gossip_started: AtomicBool,
    /// Daemon start time (`uptime_ms` in `cache-stats`/`metrics`).
    pub started: std::time::Instant,
}

impl ServiceState {
    fn assemble(
        responses: EvalCache<Served>,
        candidates: Arc<CandidateCache>,
        dse_threads: usize,
        resp_store: Option<Arc<DiskStore>>,
    ) -> ServiceState {
        // Touch the registry so the process uptime epoch is pinned at
        // daemon construction, not at the first request.
        let _ = crate::obs::metrics();
        ServiceState {
            responses,
            candidates,
            dse_threads: dse_threads.max(1),
            remote: None,
            resp_store,
            gossip: GossipLog::new(),
            shard: Mutex::new(None),
            stop: AtomicBool::new(false),
            self_ref: Mutex::new(Weak::new()),
            gossip_started: AtomicBool::new(false),
            started: std::time::Instant::now(),
        }
    }

    pub fn new(response_capacity: usize, dse_threads: usize) -> ServiceState {
        // Candidate entries hold cloned Modules, so a bounded response cache
        // implies a bounded candidate cache too (~a dozen candidates per
        // response); 0 keeps both unbounded.
        let candidate_capacity = response_capacity.saturating_mul(16);
        Self::assemble(
            EvalCache::with_capacity(response_capacity),
            Arc::new(CandidateCache::with_capacity(candidate_capacity)),
            dse_threads,
            None,
        )
    }

    /// Like [`ServiceState::new`], plus an optional on-disk persistence
    /// dir (`olympus serve --cache-dir`): both cache tiers load every
    /// decodable journal record at startup and write through on miss, so a
    /// restarted daemon answers repeated requests from disk — bit-identical
    /// and with zero evaluations (see [`crate::service::persist`]).
    pub fn with_cache_dir(
        response_capacity: usize,
        dse_threads: usize,
        cache_dir: Option<&Path>,
    ) -> Result<ServiceState> {
        let Some(dir) = cache_dir else {
            return Ok(ServiceState::new(response_capacity, dse_threads));
        };
        let candidate_capacity = response_capacity.saturating_mul(16);
        // responses fsync per append (a served answer must survive a crash
        // once the client saw it); candidates are OS-buffered + fsync at
        // drop — losing one to a power cut only re-pays one evaluation
        let (responses, rstore, replayed) = open_persistent_cache(
            &dir.join(super::persist::RESPONSES_JOURNAL),
            response_capacity,
            true,
            encode_served,
            decode_served,
        )?;
        let (candidates, _cstore) = open_candidate_cache(dir, candidate_capacity)?;
        let state = Self::assemble(responses, candidates, dse_threads, Some(rstore));
        // replayed journal records seed the gossip log, so a restarted
        // worker warms its *peers* (not just itself) from disk
        for (key, bytes) in replayed {
            state.gossip.offer(key, bytes);
        }
        Ok(state)
    }

    /// Counters for `cache-stats`.
    pub fn stats(&self) -> (CacheStats, CacheStats) {
        (self.responses.stats(), self.candidates.stats())
    }

    /// Register the owning `Arc` (done by `bind`) so lazily started
    /// background threads can hold a `Weak` reference to this state.
    pub fn set_self(self: &Arc<Self>) {
        *self.self_ref.lock().unwrap() = Arc::downgrade(self);
    }

    /// Ask background threads (gossip) to exit; called at shutdown so the
    /// response journal's writer lock is released promptly.
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Addresses this worker gossips with: every fleet member except the
    /// slot the coordinator assigned to us. Empty until a handshake
    /// supplies a shard map with a worker list.
    pub fn gossip_peers(&self) -> Vec<String> {
        let shard = self.shard.lock().unwrap();
        let Some(info) = shard.as_ref() else { return Vec::new() };
        info.workers
            .iter()
            .enumerate()
            .filter(|(i, _)| *i as u64 != info.index)
            .map(|(_, w)| w.clone())
            .collect()
    }

    /// Absorb one gossiped journal record: decode, warm the response cache
    /// (first writer wins — an already-present key is a no-op), append to
    /// our own journal and re-offer to our own log so warmth spreads
    /// transitively. Returns whether the record was new here.
    pub fn absorb_gossip_record(&self, key: ContentHash, bytes: &[u8]) -> bool {
        let Some(served) = decode_served(bytes) else { return false };
        if !self.responses.warm_insert(key, served) {
            return false;
        }
        if let Some(store) = &self.resp_store {
            store.append(key, bytes);
        }
        self.gossip.offer(key, bytes.to_vec());
        self.gossip.note_received(1);
        true
    }

    /// Start the gossip pull loop once we know our peers (first handshake
    /// carrying a worker list). A no-op for states never wrapped in an
    /// `Arc` (plain test states) — gossip is a daemon-only concern.
    pub fn maybe_spawn_gossip(&self) {
        if self.gossip_peers().is_empty() {
            return;
        }
        let weak = self.self_ref.lock().unwrap().clone();
        if weak.upgrade().is_none() {
            return;
        }
        if !self.gossip_started.swap(true, Ordering::SeqCst) {
            let _ = super::gossip::spawn_gossip_thread(weak);
        }
    }
}

/// Worker thread body: drain the queue until it closes. Queue wait is
/// recorded overall and per scheduling class (`p{prio}`); a job whose
/// `deadline_ms` expired while it sat queued is shed with a structured
/// `deadline-expired` error instead of burning an evaluation on an answer
/// the client no longer wants.
pub fn worker_loop(queue: Arc<JobQueue<Job>>, state: Arc<ServiceState>) {
    while let Some(job) = queue.pop() {
        let m = crate::obs::metrics();
        let waited = job.enqueued.elapsed();
        m.queue_wait.record_duration(waited);
        m.class_queue_wait(&format!("p{}", job.req.common.priority.unwrap_or(0)))
            .record_duration(waited);
        if let Some(limit) = job.req.common.deadline_ms {
            if waited.as_millis() > u128::from(limit) {
                let mut e = ProtoError::new(
                    "deadline-expired",
                    format!(
                        "job queued {} ms, past its {limit} ms deadline",
                        waited.as_millis()
                    ),
                );
                e.id = job.req.id.clone();
                let _ = job.reply.send(error_response(&e));
                continue;
            }
        }
        let resp = execute_request(&state, &job.req);
        // a dropped receiver just means the client went away mid-job
        let _ = job.reply.send(resp);
    }
}

fn stats_json(s: &CacheStats) -> Json {
    Json::obj(vec![
        ("entries", s.entries.into()),
        ("hits", s.hits.into()),
        ("misses", s.misses.into()),
        ("coalesced", s.coalesced.into()),
        ("evicted", s.evicted.into()),
        ("disk_loaded", s.disk_loaded.into()),
        ("disk_persisted", s.disk_persisted.into()),
        ("disk_corrupt_skipped", s.disk_corrupt_skipped.into()),
    ])
}

/// The `remote` stats object of `cache-stats`/`metrics`. Canonical counter
/// names are bare snake_case (`hits`, `resp_shard_hits`, ...); the
/// `remote_*` aliases of the candidate counters are kept for one release
/// (see PROTOCOL.md). `workers` is a count in `cache-stats` and the
/// address list in `metrics` (pinned shapes).
fn remote_stats_json(state: &ServiceState, workers_as_addrs: bool) -> Json {
    let (rs, count, epoch, addrs) = match &state.remote {
        Some(p) => (p.stats(), p.len(), p.epoch(), p.addrs()),
        None => (Default::default(), 0, 0, Vec::new()),
    };
    let workers = if workers_as_addrs {
        Json::Arr(addrs.iter().map(|a| a.as_str().into()).collect())
    } else {
        count.into()
    };
    Json::obj(vec![
        ("workers", workers),
        ("epoch", epoch.into()),
        ("hits", rs.remote_hits.into()),
        ("evals", rs.remote_evals.into()),
        ("failovers", rs.remote_failovers.into()),
        ("resp_shard_hits", rs.resp_shard_hits.into()),
        ("resp_shard_evals", rs.resp_shard_evals.into()),
        ("resp_shard_failovers", rs.resp_shard_failovers.into()),
        ("remote_hits", rs.remote_hits.into()),
        ("remote_evals", rs.remote_evals.into()),
        ("remote_failovers", rs.remote_failovers.into()),
    ])
}

fn shard_json(state: &ServiceState) -> Option<Json> {
    let shard = state.shard.lock().unwrap();
    shard.as_ref().map(|s| {
        Json::obj(vec![
            ("index", s.index.into()),
            ("total", s.total.into()),
            ("epoch", s.epoch.into()),
        ])
    })
}

/// Evaluate one request to a full response line. Pure up to cache effects:
/// identical requests produce byte-identical `result` payloads regardless
/// of worker count or cache temperature. Observability (the span log + the
/// verb counter + the latency histogram) is recorded around the dispatch
/// and never touches the payload.
pub fn execute_request(state: &ServiceState, req: &Request) -> String {
    let metrics = crate::obs::metrics();
    metrics.count_request(req.cmd.as_str());
    let span = crate::obs::next_span();
    crate::obs::debug("request", &[("span", span.into()), ("cmd", req.cmd.as_str().into())]);
    let t0 = std::time::Instant::now();
    let resp = execute_request_inner(state, req);
    let dt = t0.elapsed();
    metrics.request_latency.record_duration(dt);
    crate::obs::debug(
        "request-done",
        &[
            ("span", span.into()),
            ("cmd", req.cmd.as_str().into()),
            ("ms", (dt.as_secs_f64() * 1e3).into()),
        ],
    );
    resp
}

fn execute_request_inner(state: &ServiceState, req: &Request) -> String {
    match req.cmd {
        Command::Ping => ok_response(&req.id, req.cmd, false, None, Json::obj(vec![])),
        Command::Shutdown => {
            // the connection thread performs the actual shutdown; this arm
            // only exists so a queued shutdown still gets a well-formed reply
            ok_response(&req.id, req.cmd, false, None, Json::obj(vec![]))
        }
        Command::CacheStats => {
            let (resp, cand) = state.stats();
            let mut fields = vec![
                ("responses", stats_json(&resp)),
                ("candidates", stats_json(&cand)),
                ("remote", remote_stats_json(state, false)),
                ("gossip_records_sent", state.gossip.records_sent().into()),
                ("gossip_records_received", state.gossip.records_received().into()),
                ("gossip_log_entries", state.gossip.len().into()),
                ("uptime_ms", uptime_ms(state).into()),
                ("requests", crate::obs::metrics().requests_json()),
            ];
            if let Some(shard) = shard_json(state) {
                fields.push(("shard", shard));
            }
            ok_response(&req.id, req.cmd, false, None, Json::obj(fields))
        }
        Command::Metrics => execute_metrics(state, req),
        Command::Handshake => execute_handshake(state, req),
        Command::JournalPull => execute_journal_pull(state, req),
        Command::Join | Command::Leave => execute_membership(state, req),
        Command::EvalCandidate => match execute_eval_candidate(state, req) {
            Ok(resp) => resp,
            Err(mut e) => {
                e.id = req.id.clone();
                error_response(&e)
            }
        },
        Command::EvalResponse => {
            let VerbPayload::EvalResponse(p) = &req.verb else {
                return mismatched_payload(req);
            };
            serve_job(state, req, p.job_cmd, &p.job, p.key.as_deref())
        }
        Command::Dse | Command::Des | Command::Flow => {
            let VerbPayload::Job(job) = &req.verb else {
                return mismatched_payload(req);
            };
            serve_job(state, req, req.cmd, job, None)
        }
    }
}

/// A request whose payload variant does not match its command can only be
/// built by a bug (the parser always pairs them); answer a structured
/// `internal` error instead of panicking a worker thread.
fn mismatched_payload(req: &Request) -> String {
    let mut e = ProtoError::new("internal", "request payload does not match its cmd");
    e.id = req.id.clone();
    error_response(&e)
}

fn uptime_ms(state: &ServiceState) -> u64 {
    state.started.elapsed().as_millis().min(u64::MAX as u128) as u64
}

/// The `metrics` verb: the process-wide registry as one JSON object —
/// per-verb request counters, latency histogram summaries, DES throughput —
/// plus (on a coordinator) the remote counters and worker addresses
/// `olympus stats` fans out to, the gossip counters, and (on a worker) the
/// shard assignment.
fn execute_metrics(state: &ServiceState, req: &Request) -> String {
    let m = crate::obs::metrics();
    let mut fields = vec![
        ("uptime_ms", uptime_ms(state).into()),
        ("requests", m.requests_json()),
        ("histograms", m.histograms_json()),
        ("des", m.des_json()),
        (
            "gossip",
            Json::obj(vec![
                ("records_sent", state.gossip.records_sent().into()),
                ("records_received", state.gossip.records_received().into()),
                ("log_entries", state.gossip.len().into()),
            ]),
        ),
    ];
    if state.remote.is_some() {
        fields.push(("remote", remote_stats_json(state, true)));
    }
    if let Some(shard) = shard_json(state) {
        fields.push(("shard", shard));
    }
    ok_response(&req.id, req.cmd, false, None, Json::obj(fields))
}

/// Validate a coordinator's `handshake`: exact protocol version, then a
/// well-formed shard map. Every failure mode — malformed registration,
/// version skew, truncated shard map — is a structured error on a live
/// connection, never a drop or a panic. Success stores the shard
/// assignment, answers with this build's capability list, and (once peers
/// are known) starts the gossip pull loop.
fn execute_handshake(state: &ServiceState, req: &Request) -> String {
    let VerbPayload::Handshake(h) = &req.verb else {
        return mismatched_payload(req);
    };
    let fail = |code: &'static str, msg: String| {
        let mut e = ProtoError::new(code, msg);
        e.id = req.id.clone();
        error_response(&e)
    };
    let Some(version) = h.proto_version else {
        return fail("bad-request", "handshake requires integer field 'proto_version'".into());
    };
    if version != PROTO_VERSION {
        return fail(
            "proto-mismatch",
            format!("coordinator speaks protocol {version}, this worker speaks {PROTO_VERSION}"),
        );
    }
    let Some(map) = &h.shard_map else {
        return fail("bad-request", "handshake requires object field 'shard_map'".into());
    };
    match parse_shard_map(map) {
        Err(msg) => fail("bad-request", msg),
        Ok(info) => {
            let shard = Json::obj(vec![
                ("index", info.index.into()),
                ("total", info.total.into()),
                ("epoch", info.epoch.into()),
            ]);
            *state.shard.lock().unwrap() = Some(info);
            state.maybe_spawn_gossip();
            let caps: Vec<Json> = CAPABILITIES.iter().map(|c| (*c).into()).collect();
            ok_response(
                &req.id,
                req.cmd,
                false,
                None,
                Json::obj(vec![
                    ("proto_version", PROTO_VERSION.into()),
                    ("capabilities", Json::Arr(caps)),
                    ("shard", shard),
                ]),
            )
        }
    }
}

/// Well-formedness of a handshake `shard_map`: an object with
/// `index < total`, `total >= 1`, an optional non-negative `epoch` and —
/// when present — exactly `total` string entries in `workers`. Error
/// messages name the offending field so a truncated map is diagnosable
/// from the coordinator side.
fn parse_shard_map(map: &Json) -> Result<ShardInfo, String> {
    if map.as_obj().is_none() {
        return Err("'shard_map' must be an object".to_string());
    }
    let total = map
        .get("total")
        .as_u64()
        .ok_or_else(|| "'shard_map.total' must be an integer >= 1".to_string())?;
    if total == 0 {
        return Err("'shard_map.total' must be >= 1".to_string());
    }
    let index = map
        .get("index")
        .as_u64()
        .ok_or_else(|| "'shard_map.index' must be a non-negative integer".to_string())?;
    if index >= total {
        return Err(format!("'shard_map.index' {index} out of range for total {total}"));
    }
    let epoch = match map.get("epoch") {
        Json::Null => 0,
        j => j
            .as_u64()
            .ok_or_else(|| "'shard_map.epoch' must be a non-negative integer".to_string())?,
    };
    let mut workers = Vec::new();
    if map.get("workers") != &Json::Null {
        let arr = map
            .get("workers")
            .as_arr()
            .ok_or_else(|| "'shard_map.workers' must be an array of addresses".to_string())?;
        if arr.len() as u64 != total {
            return Err(format!(
                "'shard_map.workers' names {} workers but total is {total} (truncated map?)",
                arr.len()
            ));
        }
        for w in arr {
            let addr = w
                .as_str()
                .ok_or_else(|| "'shard_map.workers' entries must be strings".to_string())?;
            workers.push(addr.to_string());
        }
    }
    Ok(ShardInfo { index, total, epoch, workers })
}

/// The `journal-pull` verb: one page of this worker's gossip log, records
/// rendered as `{key: <32-hex>, value: <journal bytes as text>}`. The page
/// size is clamped so a hostile `limit` cannot make the response line
/// unbounded.
fn execute_journal_pull(state: &ServiceState, req: &Request) -> String {
    let VerbPayload::JournalPull(p) = &req.verb else {
        return mismatched_payload(req);
    };
    let limit = p.limit.unwrap_or(GOSSIP_PAGE_LIMIT).clamp(1, 1024);
    let page = state.gossip.page(p.cursor, limit, p.shard);
    let records: Vec<Json> = page
        .records
        .iter()
        .map(|(key, value)| {
            Json::obj(vec![
                ("key", key.to_hex().into()),
                ("value", String::from_utf8_lossy(value).into_owned().into()),
            ])
        })
        .collect();
    let result = Json::obj(vec![
        ("records", Json::Arr(records)),
        ("next", page.next.into()),
        ("total", page.total.into()),
    ]);
    ok_response(&req.id, req.cmd, false, None, result)
}

/// The `join`/`leave` membership verbs: edit the worker fleet at runtime
/// and answer with the re-rendezvoused map (bumped epoch + address list).
/// Only a coordinator has a fleet to edit; rejected edits (duplicate join,
/// unknown leave, unreachable joiner) are structured errors and change
/// nothing.
fn execute_membership(state: &ServiceState, req: &Request) -> String {
    let VerbPayload::Membership(m) = &req.verb else {
        return mismatched_payload(req);
    };
    let fail = |code: &'static str, msg: String| {
        let mut e = ProtoError::new(code, msg);
        e.id = req.id.clone();
        error_response(&e)
    };
    let Some(pool) = &state.remote else {
        return fail("no-fleet", "this server has no worker fleet (start with --workers)".into());
    };
    let edit = match req.cmd {
        Command::Join => pool.join(&m.worker),
        _ => pool.leave(&m.worker),
    };
    if let Err(msg) = edit {
        return fail("membership-rejected", msg);
    }
    let workers: Vec<Json> = pool.addrs().iter().map(|a| a.as_str().into()).collect();
    let result = Json::obj(vec![
        ("epoch", pool.epoch().into()),
        ("total", pool.len().into()),
        ("workers", Json::Arr(workers)),
    ]);
    ok_response(&req.id, req.cmd, false, None, result)
}

/// Evaluate one DSE candidate for a coordinator (`eval-candidate`),
/// answered through this process's candidate cache — memory tier plus the
/// optional `--cache-dir` journal, written through on miss. The outcome
/// travels in the bit-exact journal codec ([`outcome_to_json`]), so the
/// coordinator reconstructs exactly what a local evaluation would have
/// produced; the derived key is cross-checked against the routed one so
/// codec skew fails structured instead of caching under a wrong address.
fn execute_eval_candidate(state: &ServiceState, req: &Request) -> Result<String, ProtoError> {
    let VerbPayload::EvalCandidate(p) = &req.verb else {
        return Err(ProtoError::new("internal", "request payload does not match its cmd"));
    };
    let module = load_module(&p.ir)?;
    let platform = load_platform(p.platform.as_deref(), p.platform_json.as_ref())?;
    let objective = match &p.objective_json {
        Some(j) => objective_from_json(j).ok_or_else(|| {
            ProtoError::new("bad-request", "undecodable 'objective_json' (version skew?)")
        })?,
        None => DseObjective::Analytic,
    };
    let label = p.point_label.as_deref().unwrap_or("remote");
    let point = CandidatePoint::new(label, &p.point_pipeline);
    let key = candidate_cache_key(
        &module_fingerprint(&module),
        &platform.fingerprint(),
        &point.pipeline,
        &format!("{objective:?}"),
    );
    if let Some(expected) = &p.key {
        if *expected != key.to_hex() {
            return Err(ProtoError::new(
                "key-mismatch",
                format!(
                    "coordinator routed key {expected} but this worker derives {}; \
                     refusing to answer under a disputed address (version skew?)",
                    key.to_hex()
                ),
            ));
        }
    }
    let evaluator = ObjectiveEvaluator::new(&module, &platform, &objective, 1, None);
    let t0 = std::time::Instant::now();
    let (outcome, cached) =
        state.candidates.get_or_compute(key, || evaluator.compute_outcome(&point));
    let m = crate::obs::metrics();
    if cached {
        m.eval_cache_hit.record_duration(t0.elapsed());
    } else {
        m.eval_local.record_duration(t0.elapsed());
    }
    Ok(ok_response(&req.id, req.cmd, cached, Some(&key.to_hex()), outcome_to_json(&outcome)))
}

/// Serve one whole job — a client-facing `dse`/`des`/`flow`, or the inner
/// job of a routed `eval-response` — through the response cache. The
/// response key is derived here (never trusted from the wire); a routed key
/// that disagrees is a structured `key-mismatch`. Client-facing jobs on a
/// coordinator first try the shard route ([`try_route_response`]); fresh
/// local computes feed the gossip log.
fn serve_job(
    state: &ServiceState,
    req: &Request,
    cmd: Command,
    job: &JobPayload,
    routed_key: Option<&str>,
) -> String {
    let (module, flow) = match prepare_job(state, cmd, job) {
        Ok(mf) => mf,
        Err(mut e) => {
            e.id = req.id.clone();
            return error_response(&e);
        }
    };
    let key = flow.response_key(cmd.as_str(), &module);
    if let Some(expected) = routed_key {
        if expected != key.to_hex() {
            let mut e = ProtoError::new(
                "key-mismatch",
                format!(
                    "coordinator routed response key {expected} but this worker derives {}; \
                     refusing to answer under a disputed address (version skew?)",
                    key.to_hex()
                ),
            );
            e.id = req.id.clone();
            return error_response(&e);
        }
    }
    if routed_key.is_none() {
        if let Some(line) = try_route_response(state, req, cmd, job, key) {
            return line;
        }
    }
    let (served, cached) = state.responses.get_or_compute(key, || {
        match flow.run(module.clone(), "app") {
            Ok(r) => Served::Ok(render_result(cmd, &r)),
            Err(e) => Served::Failed(format!("{e:#}")),
        }
    });
    if !cached {
        if let Some(bytes) = encode_served(&served) {
            state.gossip.offer(key, bytes);
        }
    }
    match served {
        Served::Ok(result) => ok_response(&req.id, cmd, cached, Some(&key.to_hex()), result),
        Served::Failed(msg) => {
            let mut e = ProtoError::new("eval-failed", msg);
            e.id = req.id.clone();
            error_response(&e)
        }
    }
}

/// Route a client-facing job to the response-key shard owner (coordinator
/// only). `None` means "answer locally": no fleet, or the owner failed
/// (local failover recomputes the same bytes by determinism, surfaced in
/// `resp_shard_failovers`). A local cache hit short-circuits the route so
/// journals written before the fabric existed stay warm. The owner's raw
/// response line passes through *verbatim* — it answered under the
/// client-facing `cmd` and the same `id`, so the bytes are exactly what a
/// direct submission to that worker would have produced.
fn try_route_response(
    state: &ServiceState,
    req: &Request,
    cmd: Command,
    job: &JobPayload,
    key: ContentHash,
) -> Option<String> {
    let pool = state.remote.as_ref()?;
    if pool.is_empty() {
        return None;
    }
    if let Some(served) = state.responses.get(key) {
        return Some(match served {
            Served::Ok(result) => ok_response(&req.id, cmd, true, Some(&key.to_hex()), result),
            Served::Failed(msg) => {
                let mut e = ProtoError::new("eval-failed", msg);
                e.id = req.id.clone();
                error_response(&e)
            }
        });
    }
    let fwd = Request {
        cmd: Command::EvalResponse,
        id: req.id.clone(),
        common: req.common.clone(),
        verb: VerbPayload::EvalResponse(EvalResponsePayload {
            job_cmd: cmd,
            key: Some(key.to_hex()),
            job: job.clone(),
        }),
    };
    let line = encode_request(&fwd).to_string();
    match pool.eval_response_line(key, &line) {
        Ok(raw) => Some(raw),
        Err(msg) => {
            pool.note_response_failover();
            crate::obs::warn(
                "response-failover",
                &[("key", key.to_hex().into()), ("error", msg.into())],
            );
            None
        }
    }
}

/// Resolve a job payload into its module + fully configured flow. Shared by
/// direct jobs and routed `eval-response` jobs so both sides derive the
/// same response key from the same inputs.
fn prepare_job(
    state: &ServiceState,
    cmd: Command,
    job: &JobPayload,
) -> Result<(Module, Flow), ProtoError> {
    let module = load_module(&job.ir)?;
    let axis = load_platform_axis(job)?;
    let platform = match &axis {
        Some(specs) => specs[0].clone(),
        None => load_platform(job.platform.as_deref(), job.platform_json.as_ref())?,
    };
    let mut flow = build_flow(state, cmd, job, platform)?;
    if let Some(specs) = axis {
        flow = flow.with_platforms(specs);
    }
    Ok((module, flow))
}

fn load_module(text: &str) -> Result<Module, ProtoError> {
    let m = parse_module(text).map_err(|e| ProtoError::new("bad-ir", e.to_string()))?;
    let errs = crate::ir::verify_module(&m);
    if !errs.is_empty() {
        return Err(ProtoError::new("bad-ir", format!("structural verification failed: {errs:?}")));
    }
    let derrs = crate::dialect::verify_dialect(&m, false);
    if !derrs.is_empty() {
        return Err(ProtoError::new("bad-ir", format!("dialect verification failed: {derrs:?}")));
    }
    Ok(m)
}

/// Resolve the `platforms` search axis when present: builtin names only
/// (the wire carries names, not full specs), mutually exclusive with
/// `platform`/`platform_json`. The first entry doubles as the primary
/// platform, mirroring the CLI's `--platforms`.
fn load_platform_axis(job: &JobPayload) -> Result<Option<Vec<PlatformSpec>>, ProtoError> {
    let Some(names) = &job.platforms else { return Ok(None) };
    if job.platform.is_some() || job.platform_json.is_some() {
        return Err(ProtoError::new(
            "bad-request",
            "'platforms' is mutually exclusive with 'platform'/'platform_json'; the axis \
             searches the listed platforms and lowers onto the winner",
        ));
    }
    let mut specs = Vec::with_capacity(names.len());
    for name in names {
        let spec = builtin(name).ok_or_else(|| {
            ProtoError::new(
                "bad-platform",
                format!(
                    "unknown builtin platform '{name}' in 'platforms' (have {:?}); the axis \
                     carries builtin names only — submit 'platform_json' for a single \
                     custom board",
                    builtin_names()
                ),
            )
        })?;
        specs.push(spec);
    }
    Ok(Some(specs))
}

fn load_platform(name: Option<&str>, json: Option<&Json>) -> Result<PlatformSpec, ProtoError> {
    if let Some(j) = json {
        return PlatformSpec::from_json(j)
            .map_err(|e| ProtoError::new("bad-platform", format!("{e:#}")));
    }
    let name = name.unwrap_or("u280");
    builtin(name).ok_or_else(|| {
        ProtoError::new(
            "bad-platform",
            format!(
                "unknown builtin platform '{name}' (have {:?}); pass platform_json for \
                 custom boards",
                builtin_names()
            ),
        )
    })
}

/// Mirror the CLI's `dse`/`des`/`lower` flow construction so served results
/// are bit-identical to single-shot runs.
fn build_flow(
    state: &ServiceState,
    cmd: Command,
    job: &JobPayload,
    platform: PlatformSpec,
) -> Result<Flow, ProtoError> {
    // a pre-resolved `scenario_json` (how the CLI ships trace files, so the
    // daemon never needs the client's filesystem) wins over the spec string;
    // the string form still resolves `trace:` against the daemon's own disk
    let scenario = match (&job.scenario_json, job.scenario.as_deref()) {
        (Some(j), _) => Some(WorkloadScenario::from_json(j).ok_or_else(|| {
            ProtoError::new("bad-request", "undecodable 'scenario_json' (version skew?)")
        })?),
        (None, Some(spec)) => Some(
            crate::traffic::scenario_from_spec(spec)
                .map_err(|e| ProtoError::new("bad-request", e))?,
        ),
        (None, None) => None,
    };
    let mut cfg = DesConfig::default();
    if let Some(seed) = job.seed {
        cfg.seed = seed;
    }
    if let Some(spec) = job.autoscale.as_deref() {
        cfg.autoscale =
            Some(AutoscalePolicy::parse(spec).map_err(|e| ProtoError::new("bad-request", e))?);
    }
    let slo = match job.slo.as_deref() {
        Some(spec) => Some(SloSpec::parse(spec).map_err(|e| ProtoError::new("bad-request", e))?),
        None => None,
    };
    // an SLO only scores under the slo-score objective; alongside an
    // explicit analytic/des-score objective it would be silently dead
    if slo.is_some() && matches!(job.objective.as_deref(), Some("analytic") | Some("des-score")) {
        return Err(ProtoError::new(
            "bad-request",
            "'slo' only scores under objective 'slo-score'; drop it or switch objective",
        ));
    }
    // an explicit pipeline skips the DSE entirely, so search fields on the
    // same request would be silently dead — reject, mirroring the CLI
    if job.pipeline.is_some()
        && (job.driver.is_some()
            || job.budget.is_some()
            || job.search_seed.is_some()
            || job.factors.is_some()
            || job.platforms.is_some())
    {
        return Err(ProtoError::new(
            "bad-request",
            "'driver'/'budget'/'search_seed'/'factors'/'platforms' configure the \
             design-space search; drop 'pipeline' to search, or drop the search fields",
        ));
    }
    let mut flow = Flow::new(platform)
        .with_jobs(state.dse_threads)
        .with_cache(state.candidates.clone());
    if let Some(pool) = &state.remote {
        // full-fidelity candidate evaluations route to the shard owners;
        // the response stays bit-identical, so the pool is deliberately
        // NOT part of any cache key
        flow = flow.with_remote(pool.clone());
    }
    flow.dse_factors = job.factors.clone().unwrap_or_default();
    flow.des_config = cfg.clone();
    // driver + budget round-trip into the flow (and thus the cache key)
    let driver = crate::search::DriverKind::from_flags(
        job.driver.as_deref().unwrap_or("exhaustive"),
        job.budget.map(|b| b as usize),
        job.search_seed,
    )
    .map_err(|e| ProtoError::new("bad-request", e))?;
    flow = flow.with_driver(driver);
    match (job.objective.as_deref(), &slo) {
        (None, None) | (Some("analytic"), _) => {}
        // a bare `slo` implies the slo-score objective
        (None, Some(sl)) | (Some("slo-score"), Some(sl)) => {
            let sc = scenario.clone().unwrap_or_else(|| WorkloadScenario::closed_loop(4));
            flow = flow.with_objective(DseObjective::slo_score_with(sc, cfg.clone(), sl.clone()));
        }
        (Some("slo-score"), None) => {
            return Err(ProtoError::new(
                "bad-request",
                "objective 'slo-score' requires string field 'slo' (CLASS=p99<MS[,...])",
            ));
        }
        (Some("des-score"), _) => {
            let sc = scenario.clone().unwrap_or_else(|| WorkloadScenario::closed_loop(4));
            flow = flow.with_objective(DseObjective::des_score_with(sc, cfg.clone()));
        }
        (Some(other), _) => {
            return Err(ProtoError::new(
                "bad-request",
                format!("unknown objective '{other}' (want analytic | des-score | slo-score)"),
            ));
        }
    }
    match cmd {
        Command::Dse => {
            if let Some(p) = &job.pipeline {
                return Err(ProtoError::new(
                    "bad-request",
                    format!("'dse' explores strategies itself; drop pipeline '{p}' or use cmd 'flow'"),
                ));
            }
        }
        Command::Des => {
            let sc = scenario.clone().unwrap_or_else(|| WorkloadScenario::closed_loop(4));
            flow = flow.with_scenario(sc.clone());
            match &job.pipeline {
                Some(p) => flow = flow.with_pipeline(p),
                // no explicit pipeline: DSE picks the design, scored by the
                // DES too (mirrors `olympus des`) — unless an slo-score
                // objective is already in charge
                None => {
                    if slo.is_none() && job.objective.as_deref() != Some("slo-score") {
                        flow = flow.with_objective(DseObjective::des_score_with(sc, cfg));
                    }
                }
            }
        }
        Command::Flow => {
            if let Some(p) = &job.pipeline {
                flow = flow.with_pipeline(p);
            }
            if let Some(sc) = scenario {
                flow = flow.with_scenario(sc);
            }
        }
        _ => {}
    }
    Ok(flow)
}

fn render_result(cmd: Command, r: &crate::coordinator::FlowResult) -> Json {
    let mut fields: Vec<(&str, Json)> = Vec::new();
    if let Some(dse) = &r.dse {
        fields.push(("best_strategy", dse.best_strategy.as_str().into()));
        fields.push(("driver", dse.driver.as_str().into()));
        fields.push(("full_evals", dse.full_evals.into()));
        fields.push(("table", render_dse_table(dse).into()));
        let cands: Vec<Json> = dse
            .candidates
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("strategy", c.strategy.as_str().into()),
                    ("pipeline", c.pipeline.as_str().into()),
                    // infinite = infeasible under the objective; null in JSON
                    ("score", if c.score.is_finite() { c.score.into() } else { Json::Null }),
                    ("makespan_s", c.makespan_s.into()),
                    (
                        "des_makespan_s",
                        c.des_makespan_s.map(Json::Num).unwrap_or(Json::Null),
                    ),
                    ("fits", c.fits.into()),
                ])
            })
            .collect();
        fields.push(("candidates", Json::Arr(cands)));
    }
    match cmd {
        Command::Dse => {
            fields.push(("best_ir", crate::ir::print_module(&r.module).into()));
        }
        Command::Des => {
            if let Some(des) = &r.des {
                fields.push(("des_report", des.to_string().into()));
                fields.push(("makespan_s", des.makespan_s.into()));
                fields.push(("p99_job_latency_s", des.p99_job_latency_s.into()));
                fields.push(("jobs_completed", des.jobs_completed.into()));
            }
        }
        Command::Flow => {
            fields.push(("report", flow_report_json(r)));
        }
        _ => {}
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::ir::print_module;
    use crate::service::proto::parse_request;

    fn request_with(cmd: &str, extra: &str) -> Request {
        let ir = print_module(&fig4a_module());
        let line = Json::obj(vec![("cmd", cmd.into()), ("ir", ir.into())]).to_string();
        // splice extra fields in via reparse to keep escaping correct
        let mut v = Json::parse(&line).unwrap();
        if !extra.is_empty() {
            let add = Json::parse(extra).unwrap();
            if let (Json::Obj(dst), Json::Obj(src)) = (&mut v, add) {
                dst.extend(src);
            }
        }
        parse_request(&v.to_string()).unwrap()
    }

    fn request(extra: &str) -> Request {
        request_with("dse", extra)
    }

    #[test]
    fn dse_request_serves_table_and_caches_repeat() {
        let state = ServiceState::new(0, 1);
        let req = request(r#"{"factors": [2], "id": 1}"#);
        let cold = execute_request(&state, &req);
        let v = Json::parse(&cold).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(true));
        assert_eq!(v.get("cached"), &Json::Bool(false));
        assert!(v.get("result").get("table").as_str().unwrap().contains("best: "));
        assert_eq!(v.get("key").as_str().unwrap().len(), 32);

        let warm = execute_request(&state, &req);
        let w = Json::parse(&warm).unwrap();
        assert_eq!(w.get("cached"), &Json::Bool(true));
        // identical payload + key, only the `cached` flag differs
        assert_eq!(w.get("result"), v.get("result"));
        assert_eq!(w.get("key"), v.get("key"));
        assert_eq!(state.responses.stats().misses, 1);
    }

    #[test]
    fn bad_platform_and_bad_ir_fail_structured() {
        let state = ServiceState::new(0, 1);
        let req = request(r#"{"platform": "nonesuch"}"#);
        let v = Json::parse(&execute_request(&state, &req)).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(false));
        assert_eq!(v.get("error").get("code").as_str(), Some("bad-platform"));

        let req = parse_request(r#"{"cmd": "flow", "ir": "%0 = garbage"}"#).unwrap();
        let v = Json::parse(&execute_request(&state, &req)).unwrap();
        assert_eq!(v.get("error").get("code").as_str(), Some("bad-ir"));
    }

    #[test]
    fn des_request_reports_scenario_replay() {
        let state = ServiceState::new(0, 1);
        let req = request_with(
            "des",
            r#"{"scenario": "closed:2", "seed": 7,
                "pipeline": "sanitize, iris, channel-reassign"}"#,
        );
        let v = Json::parse(&execute_request(&state, &req)).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(true), "{v}");
        assert_eq!(v.get("result").get("jobs_completed").as_usize(), Some(2));
        assert!(v.get("result").get("des_report").as_str().unwrap().contains("des report"));
    }

    #[test]
    fn driver_and_budget_requests_serve_and_key_separately() {
        let state = ServiceState::new(0, 1);
        let exhaustive = request(r#"{"factors": [2]}"#);
        let sh = request(r#"{"factors": [2], "driver": "successive-halving", "budget": 2}"#);
        let e = Json::parse(&execute_request(&state, &exhaustive)).unwrap();
        let s = Json::parse(&execute_request(&state, &sh)).unwrap();
        assert_eq!(e.get("ok"), &Json::Bool(true), "{e}");
        assert_eq!(s.get("ok"), &Json::Bool(true), "{s}");
        assert_ne!(e.get("key"), s.get("key"), "driver+budget round-trip into the key");
        assert_eq!(e.get("result").get("driver").as_str(), Some("exhaustive"));
        assert_eq!(s.get("result").get("driver").as_str(), Some("successive-halving"));
        // the shared candidate cache answers the promoted evaluations the
        // exhaustive request already paid for: at most 2 fresh computes
        assert!(s.get("result").get("full_evals").as_usize().unwrap() <= 2, "{s}");
        // budgeted search can never beat the exhaustive best strategy set
        assert!(e.get("result").get("table").as_str().unwrap().contains("best: "));
        assert!(s.get("result").get("table").as_str().unwrap().contains("best: "));
        // a bad driver/budget combination is a structured error
        let bad = request(r#"{"driver": "random"}"#);
        let b = Json::parse(&execute_request(&state, &bad)).unwrap();
        assert_eq!(b.get("ok"), &Json::Bool(false));
        assert_eq!(b.get("error").get("code").as_str(), Some("bad-request"));
        // search fields alongside an explicit pipeline are dead, so the
        // protocol rejects the combination just like the CLI does
        let dead = request_with(
            "des",
            r#"{"driver": "successive-halving", "budget": 2, "pipeline": "sanitize"}"#,
        );
        let d = Json::parse(&execute_request(&state, &dead)).unwrap();
        assert_eq!(d.get("ok"), &Json::Bool(false));
        assert_eq!(d.get("error").get("code").as_str(), Some("bad-request"));
    }

    #[test]
    fn slo_objective_serves_and_keys_apart_from_des_score() {
        let state = ServiceState::new(0, 1);
        // slo-score without the slo field is a structured error
        let missing = request(r#"{"objective": "slo-score", "factors": [2]}"#);
        let v = Json::parse(&execute_request(&state, &missing)).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(false));
        assert_eq!(v.get("error").get("code").as_str(), Some("bad-request"));
        assert!(v.get("error").get("message").as_str().unwrap().contains("'slo'"), "{v}");
        // an slo that can never score (wrong objective) is dead: rejected
        let dead = request(r#"{"objective": "des-score", "slo": "*=p99<5", "factors": [2]}"#);
        let d = Json::parse(&execute_request(&state, &dead)).unwrap();
        assert_eq!(d.get("error").get("code").as_str(), Some("bad-request"));
        // slo-score serves, and its response key differs from des-score on
        // the otherwise-identical request (the objective rides the key)
        let base = r#""factors": [2], "scenario": "closed:2", "seed": 3"#;
        let slo = request(&format!(
            r#"{{"objective": "slo-score", "slo": "*=p99<0.0001", {base}}}"#
        ));
        let des = request(&format!(r#"{{"objective": "des-score", {base}}}"#));
        let s = Json::parse(&execute_request(&state, &slo)).unwrap();
        let e = Json::parse(&execute_request(&state, &des)).unwrap();
        assert_eq!(s.get("ok"), &Json::Bool(true), "{s}");
        assert_eq!(e.get("ok"), &Json::Bool(true), "{e}");
        assert_ne!(s.get("key"), e.get("key"), "slo must ride the response key");
        assert!(s.get("result").get("table").as_str().unwrap().contains("best: "));
    }

    #[test]
    fn autoscale_and_scenario_json_ride_the_response_key() {
        let state = ServiceState::new(0, 1);
        let mk = |extra: &str| request_with("des", extra);
        let plain = mk(r#"{"scenario": "closed:2", "seed": 7, "pipeline": "sanitize"}"#);
        let scaled = mk(
            r#"{"scenario": "closed:2", "seed": 7, "pipeline": "sanitize",
                "autoscale": "0.001:4:0:1:4"}"#,
        );
        let p = Json::parse(&execute_request(&state, &plain)).unwrap();
        let s = Json::parse(&execute_request(&state, &scaled)).unwrap();
        assert_eq!(p.get("ok"), &Json::Bool(true), "{p}");
        assert_eq!(s.get("ok"), &Json::Bool(true), "{s}");
        assert_ne!(p.get("key"), s.get("key"), "autoscale policy must ride the key");
        // a scenario shipped pre-resolved as JSON keys identically to the
        // same scenario named by spec string
        let sc = WorkloadScenario::closed_loop(2);
        let mut by_json = mk(r#"{"seed": 7, "pipeline": "sanitize"}"#);
        let VerbPayload::Job(job) = &mut by_json.verb else { panic!("job payload") };
        job.scenario = None;
        job.scenario_json = Some(sc.to_json());
        let j = Json::parse(&execute_request(&state, &by_json)).unwrap();
        assert_eq!(j.get("ok"), &Json::Bool(true), "{j}");
        assert_eq!(j.get("key"), p.get("key"), "resolved scenario keys like its spec");
        assert_eq!(j.get("cached"), &Json::Bool(true), "and replays the cached payload");
        // a malformed autoscale spec fails structured
        let bad = mk(r#"{"scenario": "closed:2", "pipeline": "sanitize", "autoscale": "nope"}"#);
        let b = Json::parse(&execute_request(&state, &bad)).unwrap();
        assert_eq!(b.get("error").get("code").as_str(), Some("bad-request"));
    }

    #[test]
    fn expired_deadline_sheds_job_from_the_queue() {
        let state = Arc::new(ServiceState::new(0, 1));
        let queue = Arc::new(JobQueue::new());
        let (tx, rx) = mpsc::channel();
        let mut req = request("{}");
        req.common.deadline_ms = Some(0);
        // enqueued in the past, so any deadline has expired by pickup
        let enqueued = std::time::Instant::now() - std::time::Duration::from_millis(50);
        queue.push(Job { req, reply: tx, enqueued });
        queue.close();
        worker_loop(queue, state);
        let resp = Json::parse(&rx.recv().unwrap()).unwrap();
        assert_eq!(resp.get("ok"), &Json::Bool(false));
        assert_eq!(resp.get("error").get("code").as_str(), Some("deadline-expired"));
    }

    #[test]
    fn platform_axis_serves_cross_platform_table_and_keys_apart() {
        let state = ServiceState::new(0, 1);
        let single = request(r#"{"factors": [2]}"#);
        let multi = request(r#"{"factors": [2], "platforms": ["u280", "generic-ddr"]}"#);
        let s = Json::parse(&execute_request(&state, &single)).unwrap();
        let m = Json::parse(&execute_request(&state, &multi)).unwrap();
        assert_eq!(s.get("ok"), &Json::Bool(true), "{s}");
        assert_eq!(m.get("ok"), &Json::Bool(true), "{m}");
        assert_ne!(s.get("key"), m.get("key"), "the platform axis rides the response key");
        let table = m.get("result").get("table").as_str().unwrap();
        assert!(table.contains("best[u280]: u280/"), "{table}");
        assert!(table.contains("best[generic-ddr]: generic-ddr/"), "{table}");
        assert!(m.get("result").get("best_strategy").as_str().unwrap().contains('/'), "{m}");
        // the shared candidate cache answers the u280 half of the product
        // space from the single-platform run: a warm repeat computes nothing
        let warm = Json::parse(&execute_request(&state, &multi)).unwrap();
        assert_eq!(warm.get("cached"), &Json::Bool(true));
        assert_eq!(warm.get("result"), m.get("result"));
    }

    #[test]
    fn platform_axis_conflicts_fail_structured() {
        let state = ServiceState::new(0, 1);
        // unknown builtin in the axis
        let bad = request(r#"{"platforms": ["u280", "nonesuch"]}"#);
        let v = Json::parse(&execute_request(&state, &bad)).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(false));
        assert_eq!(v.get("error").get("code").as_str(), Some("bad-platform"));
        assert!(v.get("error").get("message").as_str().unwrap().contains("u50"), "{v}");
        // axis alongside a single-platform field
        let both = request(r#"{"platforms": ["u280", "generic-ddr"], "platform": "u280"}"#);
        let v = Json::parse(&execute_request(&state, &both)).unwrap();
        assert_eq!(v.get("error").get("code").as_str(), Some("bad-request"));
        // axis alongside an explicit pipeline (the axis would be dead)
        let dead = request_with(
            "des",
            r#"{"platforms": ["u280", "generic-ddr"], "pipeline": "sanitize"}"#,
        );
        let v = Json::parse(&execute_request(&state, &dead)).unwrap();
        assert_eq!(v.get("error").get("code").as_str(), Some("bad-request"));
        assert!(v.get("error").get("message").as_str().unwrap().contains("platforms"), "{v}");
    }

    #[test]
    fn candidate_cache_spans_distinct_requests() {
        let state = ServiceState::new(0, 1);
        let a = request(r#"{"factors": [2]}"#);
        execute_request(&state, &a);
        let cand_misses = state.candidates.stats().misses;
        assert!(cand_misses > 0);
        // a *grown* sweep shares every already-evaluated candidate
        let b = request(r#"{"factors": [2, 4]}"#);
        let v = Json::parse(&execute_request(&state, &b)).unwrap();
        assert_eq!(v.get("cached"), &Json::Bool(false), "different response key");
        let after = state.candidates.stats();
        assert!(
            after.hits >= cand_misses - 2,
            "overlapping candidates served from cache: {after:?}"
        );
        // only the two new replicate/full x4 variants (plus nothing else) evaluate
        assert_eq!(after.misses, cand_misses + 2, "{after:?}");
    }

    #[test]
    fn handshake_announces_capabilities_and_epoch() {
        let state = ServiceState::new(0, 1);
        let line = format!(
            r#"{{"cmd": "handshake", "proto_version": {PROTO_VERSION},
                "capabilities": ["response-shard"],
                "shard_map": {{"index": 0, "total": 2, "epoch": 5,
                               "workers": ["a:1", "b:2"]}}}}"#
        );
        let req = parse_request(&line).unwrap();
        let v = Json::parse(&execute_request(&state, &req)).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(true), "{v}");
        assert_eq!(v.get("result").get("proto_version").as_u64(), Some(PROTO_VERSION));
        let caps = v.get("result").get("capabilities").as_arr().unwrap();
        assert!(caps.iter().any(|c| c.as_str() == Some("journal-gossip")), "{v}");
        assert_eq!(v.get("result").get("shard").get("epoch").as_u64(), Some(5));
        // the stored shard info yields the peer list (everyone but us)
        assert_eq!(state.gossip_peers(), vec!["b:2".to_string()]);
        // ...and rides cache-stats / metrics
        let stats = parse_request(r#"{"cmd": "cache-stats"}"#).unwrap();
        let s = Json::parse(&execute_request(&state, &stats)).unwrap();
        assert_eq!(s.get("result").get("shard").get("epoch").as_u64(), Some(5));
        // a malformed epoch is a structured error
        let bad = parse_request(&format!(
            r#"{{"cmd": "handshake", "proto_version": {PROTO_VERSION},
                "shard_map": {{"index": 0, "total": 1, "epoch": "x"}}}}"#
        ))
        .unwrap();
        let b = Json::parse(&execute_request(&state, &bad)).unwrap();
        assert_eq!(b.get("error").get("code").as_str(), Some("bad-request"));
        assert!(b.get("error").get("message").as_str().unwrap().contains("epoch"), "{b}");
    }

    #[test]
    fn v1_handshake_gets_structured_proto_mismatch() {
        let state = ServiceState::new(0, 1);
        let req = parse_request(
            r#"{"cmd": "handshake", "proto_version": 1, "shard_map": {"index": 0, "total": 1}}"#,
        )
        .unwrap();
        let v = Json::parse(&execute_request(&state, &req)).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(false));
        assert_eq!(v.get("error").get("code").as_str(), Some("proto-mismatch"));
        let msg = v.get("error").get("message").as_str().unwrap();
        assert!(msg.contains("speaks protocol 1"), "{msg}");
    }

    #[test]
    fn journal_pull_pages_the_gossip_log() {
        let state = ServiceState::new(0, 1);
        let job = request(r#"{"factors": [2]}"#);
        let served = Json::parse(&execute_request(&state, &job)).unwrap();
        let key = served.get("key").as_str().unwrap().to_string();
        assert_eq!(state.gossip.len(), 1, "a fresh compute feeds the gossip log");
        let pull = parse_request(r#"{"cmd": "journal-pull", "cursor": 0}"#).unwrap();
        let v = Json::parse(&execute_request(&state, &pull)).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(true), "{v}");
        let result = v.get("result");
        assert_eq!(result.get("next").as_u64(), Some(1));
        assert_eq!(result.get("total").as_u64(), Some(1));
        let records = result.get("records").as_arr().unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].get("key").as_str(), Some(key.as_str()));
        // the value is the exact journal encoding of the served result
        let value = records[0].get("value").as_str().unwrap();
        assert_eq!(Json::parse(value).unwrap().get("ok"), served.get("result"));
    }

    #[test]
    fn absorbed_gossip_records_serve_bit_identical_repeats() {
        let a = ServiceState::new(0, 1);
        let req = request(r#"{"factors": [2], "id": 1}"#);
        let direct = execute_request(&a, &req);
        let fresh = Json::parse(&direct).unwrap();
        let key = ContentHash::from_hex(fresh.get("key").as_str().unwrap()).unwrap();
        let page = a.gossip.page(0, 10, None);
        assert_eq!(page.records.len(), 1);
        let (gossip_key, bytes) = &page.records[0];
        assert_eq!(*gossip_key, key);

        let b = ServiceState::new(0, 1);
        assert!(b.absorb_gossip_record(key, bytes), "first absorb is new");
        assert!(!b.absorb_gossip_record(key, bytes), "repeat absorb is a no-op");
        assert_eq!(b.gossip.records_received(), 1);
        assert_eq!(b.gossip.len(), 1, "absorbed records re-offer to our own log");
        // the warmed cache answers the repeat without evaluating anything
        let warmed = Json::parse(&execute_request(&b, &req)).unwrap();
        assert_eq!(warmed.get("cached"), &Json::Bool(true), "{warmed}");
        assert_eq!(warmed.get("result"), fresh.get("result"), "bit-identical payload");
        assert_eq!(warmed.get("key"), fresh.get("key"));
        assert_eq!(b.responses.stats().misses, 0, "zero evaluations after gossip warmup");
    }

    #[test]
    fn eval_response_serves_bit_identical_to_direct() {
        let a = ServiceState::new(0, 1);
        let direct_req = request(r#"{"factors": [2], "id": "j1"}"#);
        let direct = execute_request(&a, &direct_req);

        let b = ServiceState::new(0, 1);
        let VerbPayload::Job(job) = &direct_req.verb else { panic!("job payload") };
        let routed_req = Request {
            cmd: Command::EvalResponse,
            id: direct_req.id.clone(),
            common: direct_req.common.clone(),
            verb: VerbPayload::EvalResponse(EvalResponsePayload {
                job_cmd: Command::Dse,
                key: None,
                job: job.clone(),
            }),
        };
        let routed = execute_request(&b, &routed_req);
        assert_eq!(routed, direct, "routed answer must be byte-identical to direct");
        // ...and the encode/parse round trip preserves that
        let reparsed = parse_request(&encode_request(&routed_req).to_string()).unwrap();
        assert_eq!(reparsed, routed_req);
        // a disputed key is refused before any evaluation happens
        let disputed = Request {
            verb: VerbPayload::EvalResponse(EvalResponsePayload {
                job_cmd: Command::Dse,
                key: Some("0".repeat(32)),
                job: job.clone(),
            }),
            ..routed_req
        };
        let v = Json::parse(&execute_request(&b, &disputed)).unwrap();
        assert_eq!(v.get("error").get("code").as_str(), Some("key-mismatch"));
    }

    #[test]
    fn membership_without_a_fleet_fails_structured() {
        let state = ServiceState::new(0, 1);
        let join = parse_request(r#"{"cmd": "join", "worker": "h:1", "id": 4}"#).unwrap();
        let v = Json::parse(&execute_request(&state, &join)).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(false));
        assert_eq!(v.get("error").get("code").as_str(), Some("no-fleet"));
        assert_eq!(v.get("id").as_u64(), Some(4));
        let leave = parse_request(r#"{"cmd": "leave", "worker": "h:1"}"#).unwrap();
        let v = Json::parse(&execute_request(&state, &leave)).unwrap();
        assert_eq!(v.get("error").get("code").as_str(), Some("no-fleet"));
    }
}
