//! Peer-to-peer journal gossip: how a rebuilt or newly joined worker warms
//! its response shard from neighbors instead of recomputing it.
//!
//! Every persisted response journal record (`responses.jrnl`) is also
//! appended to an in-memory [`GossipLog`] — an append-only, deduplicated
//! sequence of `(key, encoded-bytes)` pairs in journal order. Peers page
//! through each other's logs with the `journal-pull` verb: a high-water
//! `cursor` (index into the log) plus an optional `shard` filter for
//! callers that only want the keys one shard owns under the current
//! rendezvous map. The built-in pull loop deliberately does *not* filter —
//! it mirrors the full log, so every worker converges on the union of the
//! fleet's journals and any surviving neighbor can warm a replacement
//! worker for *any* shard (a filter-to-own-shard loop would never move a
//! record across shards, and a dead worker's keyspace would die with it).
//! Because cursors are per-peer and monotone, a pull round is idempotent
//! and cheap once caught up (one empty page per peer).
//!
//! Records travel as the *exact bytes* the disk journal stores
//! ([`encode_served`](super::persist::encode_served) output), so a gossiped
//! entry is bit-identical to one computed locally — the determinism
//! contract ("same answer no matter which process computed it") survives
//! replication. Received records are absorbed through the same
//! `warm_insert` + journal-append path as disk replay, and re-offered to
//! this worker's own log, so warmth spreads transitively through fleets
//! that are not fully connected.
//!
//! The pull loop runs on one background thread per server
//! ([`spawn_gossip_thread`]), started lazily when a handshake supplies a
//! shard map with peer addresses. It holds only a [`Weak`] reference to the
//! server state — upgraded per round, dropped before sleeping — so it never
//! keeps a shut-down server (or its journal writer lock) alive.
//! `cache-stats` surfaces progress as `gossip_records_sent` /
//! `gossip_records_received`; round wall time lands in the
//! `journal_gossip` histogram.

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, Weak};
use std::time::{Duration, Instant};

use crate::util::{ContentHash, Json};

use super::remote::shard_of;
use super::worker::ServiceState;

/// Sleep between pull rounds. Short enough that a joining worker warms in
/// well under a second on a LAN; long enough to stay invisible in profiles.
pub const GOSSIP_ROUND_MS: u64 = 200;
/// Records per `journal-pull` page. Bounds response lines well under the
/// service's request cap even with large rendered reports in the values.
pub const GOSSIP_PAGE_LIMIT: u64 = 64;

const CONNECT_TIMEOUT: Duration = Duration::from_millis(500);
const IO_TIMEOUT: Duration = Duration::from_secs(2);

/// One page of a peer's log, as returned by [`GossipLog::page`].
pub struct GossipPage {
    /// Records in log order, filtered to the requested shard.
    pub records: Vec<(ContentHash, Vec<u8>)>,
    /// Cursor to resume from (records *scanned*, not returned — a filtered
    /// page still advances past what it inspected).
    pub next: u64,
    /// Total log length, so pullers know when they are caught up.
    pub total: u64,
}

#[derive(Default)]
struct LogInner {
    records: Vec<(ContentHash, Vec<u8>)>,
    seen: HashSet<ContentHash>,
}

/// Append-only, deduplicated journal mirror served to peers.
///
/// Entries are `(response key, encoded Served bytes)` in the order this
/// process first saw them (disk replay first, then live computes and
/// absorbed gossip). Indices are stable forever — the log never compacts —
/// which is what makes a plain integer cursor a correct high-water mark.
#[derive(Default)]
pub struct GossipLog {
    inner: Mutex<LogInner>,
    sent: AtomicU64,
    received: AtomicU64,
}

impl GossipLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a record unless its key is already present. Returns whether
    /// the record was new.
    pub fn offer(&self, key: ContentHash, value: Vec<u8>) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if !inner.seen.insert(key) {
            return false;
        }
        inner.records.push((key, value));
        true
    }

    pub fn len(&self) -> u64 {
        self.inner.lock().unwrap().records.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serve one page starting at `cursor`: scan up to `limit` records,
    /// keep those owned by `shard` (all of them when `None`), and report
    /// where the scan stopped so the caller can resume. Served records
    /// count toward `gossip_records_sent`.
    pub fn page(&self, cursor: u64, limit: u64, shard: Option<(u64, u64)>) -> GossipPage {
        let inner = self.inner.lock().unwrap();
        let total = inner.records.len() as u64;
        let from = cursor.min(total) as usize;
        let to = cursor.saturating_add(limit.max(1)).min(total) as usize;
        let records: Vec<(ContentHash, Vec<u8>)> = inner.records[from..to]
            .iter()
            .filter(|(key, _)| match shard {
                Some((index, total)) => shard_of(*key, total as usize) as u64 == index,
                None => true,
            })
            .cloned()
            .collect();
        drop(inner);
        self.sent.fetch_add(records.len() as u64, Ordering::Relaxed);
        GossipPage { records, next: to as u64, total }
    }

    /// Count records absorbed from peers (called by the pull loop).
    pub fn note_received(&self, n: u64) {
        self.received.fetch_add(n, Ordering::Relaxed);
    }

    pub fn records_sent(&self) -> u64 {
        self.sent.load(Ordering::Relaxed)
    }

    pub fn records_received(&self) -> u64 {
        self.received.load(Ordering::Relaxed)
    }
}

/// Start the background pull loop. Returns immediately; the thread exits on
/// its own once `state` is dropped or the server begins shutdown.
pub fn spawn_gossip_thread(state: Weak<ServiceState>) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("olympus-gossip".into())
        .spawn(move || pull_loop(state))
        .expect("spawn gossip thread")
}

fn pull_loop(state: Weak<ServiceState>) {
    // High-water cursor per peer address. A peer that restarts with an
    // empty log answers `total < cursor`; the cursor resets on that signal.
    let mut cursors: HashMap<String, u64> = HashMap::new();
    loop {
        {
            let Some(st) = state.upgrade() else { break };
            if st.stopping() {
                break;
            }
            for peer in st.gossip_peers() {
                pull_from_peer(&st, &peer, &mut cursors);
            }
        }
        std::thread::sleep(Duration::from_millis(GOSSIP_ROUND_MS));
    }
}

/// Page through one peer's log until caught up. Any transport or decode
/// problem abandons this peer until the next round — gossip is best-effort
/// by design; correctness never depends on it (a miss just recomputes).
fn pull_from_peer(st: &ServiceState, peer: &str, cursors: &mut HashMap<String, u64>) {
    let start = Instant::now();
    let Some(mut conn) = connect(peer) else { return };
    let mut absorbed = 0u64;
    loop {
        let cursor = cursors.get(peer).copied().unwrap_or(0);
        let req = Json::obj(vec![
            ("cmd", "journal-pull".into()),
            ("cursor", cursor.into()),
            ("limit", GOSSIP_PAGE_LIMIT.into()),
        ]);
        let Some(resp) = roundtrip(&mut conn, &req.to_string()) else { break };
        if resp.get("ok").as_bool() != Some(true) {
            break;
        }
        let result = resp.get("result");
        let (Some(next), Some(total)) = (result.get("next").as_u64(), result.get("total").as_u64())
        else {
            break;
        };
        if let Some(records) = result.get("records").as_arr() {
            for rec in records {
                let Some(key) = rec.get("key").as_str().and_then(ContentHash::from_hex) else {
                    continue;
                };
                let Some(value) = rec.get("value").as_str() else { continue };
                if st.absorb_gossip_record(key, value.as_bytes()) {
                    absorbed += 1;
                }
            }
        }
        // A shrunken log means the peer restarted: start over next round.
        cursors.insert(peer.to_string(), if total < cursor { 0 } else { next });
        if next >= total {
            break;
        }
    }
    if absorbed > 0 {
        crate::obs::info(
            "gossip-warmed",
            &[("peer", peer.into()), ("records", absorbed.into())],
        );
    }
    crate::obs::metrics().journal_gossip.record_duration(start.elapsed());
}

struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

fn connect(addr: &str) -> Option<Conn> {
    let sock = addr.to_socket_addrs().ok()?.next()?;
    let stream = TcpStream::connect_timeout(&sock, CONNECT_TIMEOUT).ok()?;
    stream.set_nodelay(true).ok()?;
    stream.set_read_timeout(Some(IO_TIMEOUT)).ok()?;
    stream.set_write_timeout(Some(IO_TIMEOUT)).ok()?;
    let writer = stream.try_clone().ok()?;
    Some(Conn { reader: BufReader::new(stream), writer })
}

fn roundtrip(conn: &mut Conn, line: &str) -> Option<Json> {
    conn.writer.write_all(line.as_bytes()).ok()?;
    conn.writer.write_all(b"\n").ok()?;
    conn.writer.flush().ok()?;
    let mut reply = String::new();
    let n = conn.reader.read_line(&mut reply).ok()?;
    if n == 0 {
        return None;
    }
    Json::parse(reply.trim_end()).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(n: u64) -> ContentHash {
        ContentHash::of_parts(&["gossip-test", &n.to_string()])
    }

    #[test]
    fn offer_dedupes_by_key_and_preserves_order() {
        let log = GossipLog::new();
        assert!(log.offer(key(1), b"a".to_vec()));
        assert!(log.offer(key(2), b"b".to_vec()));
        assert!(!log.offer(key(1), b"other".to_vec()), "duplicate key must be rejected");
        assert_eq!(log.len(), 2);
        let page = log.page(0, 10, None);
        assert_eq!(page.records[0], (key(1), b"a".to_vec()));
        assert_eq!(page.records[1], (key(2), b"b".to_vec()));
        assert_eq!((page.next, page.total), (2, 2));
    }

    #[test]
    fn page_cursor_windows_the_log() {
        let log = GossipLog::new();
        for n in 0..5 {
            log.offer(key(n), vec![n as u8]);
        }
        let first = log.page(0, 2, None);
        assert_eq!(first.records.len(), 2);
        assert_eq!((first.next, first.total), (2, 5));
        let second = log.page(first.next, 2, None);
        assert_eq!(second.records.len(), 2);
        assert_eq!(second.next, 4);
        let last = log.page(second.next, 2, None);
        assert_eq!(last.records.len(), 1);
        assert_eq!((last.next, last.total), (5, 5));
        // Caught up: an empty page that does not advance.
        let done = log.page(last.next, 2, None);
        assert!(done.records.is_empty());
        assert_eq!(done.next, 5);
    }

    #[test]
    fn shard_filter_partitions_without_loss() {
        let log = GossipLog::new();
        for n in 0..32 {
            log.offer(key(n), vec![n as u8]);
        }
        let a = log.page(0, 100, Some((0, 2)));
        let b = log.page(0, 100, Some((1, 2)));
        assert_eq!(a.records.len() + b.records.len(), 32, "shards must partition the log");
        assert!(!a.records.is_empty() && !b.records.is_empty(), "32 keys should hit both shards");
        // The filtered page still advances the cursor past everything scanned.
        assert_eq!(a.next, 32);
        for (k, _) in &a.records {
            assert_eq!(shard_of(*k, 2), 0);
        }
    }

    #[test]
    fn sent_counter_tracks_served_records() {
        let log = GossipLog::new();
        for n in 0..4 {
            log.offer(key(n), vec![]);
        }
        assert_eq!(log.records_sent(), 0);
        let page = log.page(0, 10, None);
        assert_eq!(log.records_sent(), page.records.len() as u64);
        log.note_received(3);
        assert_eq!(log.records_received(), 3);
    }
}
