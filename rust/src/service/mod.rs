//! `service` — the concurrent DSE job service (`olympus serve`).
//!
//! The CLI is single-shot: every `olympus dse` re-evaluates every candidate
//! from scratch. This subsystem turns the same flow machinery into a
//! long-running daemon for the workloads the ROADMAP cares about — platform
//! sweeps, replication-factor sweeps, CI re-runs — where requests repeat
//! and overlap heavily:
//!
//! * **[`proto`]** — newline-delimited JSON over TCP; malformed input gets
//!   structured errors, never a dropped connection;
//! * **[`queue`]** — blocking MPMC queue feeding a std-thread worker pool
//!   (`--jobs N`); priority-aware, so a request carrying `priority` jumps
//!   queued lower-priority work, and one carrying `deadline_ms` is shed
//!   with a `deadline-expired` error instead of executing late;
//! * **[`cache`]** — content-addressed, single-flight evaluation cache.
//!   Keys hash *what is being evaluated* (module IR, platform spec,
//!   pipeline/strategy, objective, scenario, seed), so cache placement can
//!   never change a result — only skip recomputing it;
//! * **[`persist`]** — optional on-disk tier (`--cache-dir`): an
//!   append-only, checksummed journal both cache levels load at startup and
//!   write through on miss, so a killed-and-restarted daemon serves warm
//!   answers without re-evaluating;
//! * **[`worker`]** — request execution through a two-level memo (whole
//!   responses + individual DSE candidates);
//! * **[`remote`]** — horizontal scale-out: `olympus worker` daemons each
//!   own a rendezvous-hash shard of *both* content-addressed key spaces.
//!   A coordinator started with `--workers host:port,...` routes every
//!   candidate evaluation — and every whole client-facing job, by response
//!   key — to its shard owner (warm journals answer without recomputing),
//!   failing over to local evaluation when a worker dies. The fleet is
//!   elastic: `join`/`leave` re-rendezvous the shard map at runtime under a
//!   bumped membership epoch, no restart;
//! * **[`gossip`]** — peer-to-peer journal replication: workers page each
//!   other's persisted response records over `journal-pull`, so a rebuilt
//!   or newly joined worker warms its shard from neighbors instead of
//!   recomputing it.
//!
//! Determinism contract: a served result is bit-identical to the single-shot
//! CLI output for the same inputs, whether it was computed cold, served
//! warm, raced by N workers, or evaluated on remote shards — and a worker
//! dying mid-request cannot change the answer, only where it is computed.
//! (Like the single-process warm start, the report's `full_evals` counter
//! reflects genuine computations, so it credits warm caches wherever they
//! live.) `rust/tests/service.rs` pins this.

pub mod cache;
pub mod gossip;
pub mod persist;
pub mod proto;
pub mod queue;
pub mod remote;
pub mod worker;

pub use cache::{CacheStats, EvalCache};
pub use gossip::GossipLog;
pub use persist::{DiskStats, DiskStore};
pub use proto::{
    encode_request, error_response, ok_response, parse_request, Command, ProtoError, Request,
    CAPABILITIES, PROTO_VERSION,
};
pub use queue::JobQueue;
pub use remote::{shard_of, shard_of_hex, RemoteEvaluator, RemoteStats, WorkerPool};
pub use worker::{execute_request, Job, Served, ServiceState, ShardInfo};

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

/// Upper bound on one request line. Big enough for any real module IR
/// (the largest builtin designs serialize to a few hundred KB), small
/// enough that a hostile or broken client cannot balloon daemon memory by
/// streaming a newline-less body.
pub const MAX_REQUEST_BYTES: u64 = 16 * 1024 * 1024;

/// Daemon configuration.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Worker threads evaluating jobs (0 = all available cores).
    pub workers: usize,
    /// Response-cache capacity in entries (0 = unbounded).
    pub cache_capacity: usize,
    /// DSE candidate-evaluation threads per job. The pool parallelizes
    /// across jobs, so 1 avoids oversubscription; results are identical for
    /// any value.
    pub dse_threads: usize,
    /// Persist both cache tiers to this directory (`--cache-dir`); `None`
    /// keeps the caches memory-only.
    pub cache_dir: Option<PathBuf>,
    /// Remote evaluation workers (`--workers host:port,...`): DSE candidate
    /// evaluations route to the `olympus worker` owning each key's
    /// consistent-hash shard, with local failover. Empty = single-process.
    pub remote_workers: Vec<String>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: 0,
            cache_capacity: 0,
            dse_threads: 1,
            cache_dir: None,
            remote_workers: Vec::new(),
        }
    }
}

/// A running service: accept loop + worker pool + shared caches.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    queue: Arc<JobQueue<Job>>,
    state: Arc<ServiceState>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:7878`; port 0 picks a free port) and
    /// start accepting. Returns once the listener is live — [`Server::addr`]
    /// is immediately connectable.
    pub fn bind(addr: &str, opts: ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr().context("local_addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(JobQueue::new());
        let mut state = ServiceState::with_cache_dir(
            opts.cache_capacity,
            opts.dse_threads,
            opts.cache_dir.as_deref(),
        )?;
        if !opts.remote_workers.is_empty() {
            // eager handshakes: a version-skewed fleet fails the bind; a
            // merely unreachable worker is retried per evaluation
            state.remote = Some(Arc::new(remote::WorkerPool::connect(&opts.remote_workers)?));
        }
        let state = Arc::new(state);
        // background threads (gossip) hold a Weak reference to the state,
        // registered here so they can never outlive the server
        state.set_self();
        crate::obs::info(
            "service-start",
            &[
                ("addr", local.to_string().into()),
                ("remote_workers", opts.remote_workers.len().into()),
            ],
        );

        let n_workers = if opts.workers == 0 {
            std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
        } else {
            opts.workers
        };
        let workers = (0..n_workers)
            .map(|_| {
                let q = queue.clone();
                let s = state.clone();
                std::thread::spawn(move || worker::worker_loop(q, s))
            })
            .collect();

        let accept = {
            let stop = stop.clone();
            let queue = queue.clone();
            let state = state.clone();
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let stop = stop.clone();
                    let queue = queue.clone();
                    let state = state.clone();
                    // connection threads are detached: they exit when the
                    // client hangs up (read_line -> 0) or on shutdown
                    std::thread::spawn(move || {
                        handle_conn(stream, queue, state, stop, local);
                    });
                }
            })
        };

        Ok(Server { addr: local, stop, queue, state, accept: Some(accept), workers })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared state (tests inspect cache stats without a socket roundtrip).
    pub fn state(&self) -> &ServiceState {
        &self.state
    }

    /// Block until a `shutdown` request stops the service, then join the
    /// pool (the `olympus serve` foreground mode).
    pub fn wait(mut self) {
        self.join();
    }

    /// Stop from the owning thread: unblock the accept loop, drain queued
    /// jobs, join everything.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.state.request_stop();
        self.queue.close();
        let _ = TcpStream::connect(self.addr); // unblock accept()
        self.join();
    }

    fn join(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // belt-and-braces for tests that panic before shutdown()
        self.stop.store(true, Ordering::SeqCst);
        self.state.request_stop();
        self.queue.close();
        let _ = TcpStream::connect(self.addr);
        self.join();
    }
}

/// Per-connection loop: read request lines, answer each on its own line.
/// The connection survives malformed requests — including oversized ones,
/// whose bodies are drained without buffering after a `too-large` error —
/// only EOF, socket errors or `shutdown` end it.
fn handle_conn(
    stream: TcpStream,
    queue: Arc<JobQueue<Job>>,
    state: Arc<ServiceState>,
    stop: Arc<AtomicBool>,
    server_addr: SocketAddr,
) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let mut line = Vec::new();
    loop {
        line.clear();
        // bound each line read: a client that streams a newline-less body
        // must not grow `line` without limit. The +1 distinguishes "exactly
        // at the cap" from "over it". Bytes, not read_line: the cap must
        // not depend on where a multi-byte character happens to fall.
        match (&mut reader).take(MAX_REQUEST_BYTES + 1).read_until(b'\n', &mut line) {
            Ok(0) | Err(_) => break, // client hung up
            Ok(_) => {}
        }
        if !line.ends_with(b"\n") && line.len() as u64 > MAX_REQUEST_BYTES {
            line.clear(); // drop the oversized prefix immediately
            let resp = error_response(&ProtoError::new(
                "too-large",
                format!("request exceeds {MAX_REQUEST_BYTES} bytes; split the work or shrink the IR"),
            ));
            if writer.write_all(resp.as_bytes()).is_err()
                || writer.write_all(b"\n").is_err()
                || writer.flush().is_err()
            {
                break;
            }
            // discard the rest of the line chunk-by-chunk — never buffered —
            // so the connection stays usable for the next request
            let mut hangup = false;
            loop {
                let (consumed, at_line_end) = match reader.fill_buf() {
                    Ok([]) | Err(_) => {
                        hangup = true;
                        (0, true)
                    }
                    Ok(buf) => match buf.iter().position(|&b| b == b'\n') {
                        Some(pos) => (pos + 1, true),
                        None => (buf.len(), false),
                    },
                };
                reader.consume(consumed);
                if at_line_end {
                    break;
                }
            }
            if hangup {
                break;
            }
            continue;
        }
        // within bounds: now require UTF-8 (a structured error, not a
        // dropped connection)
        let text = match std::str::from_utf8(&line) {
            Ok(t) => t,
            Err(_) => {
                let resp = error_response(&ProtoError::new(
                    "bad-json",
                    "request is not valid UTF-8",
                ));
                if writer.write_all(resp.as_bytes()).is_err()
                    || writer.write_all(b"\n").is_err()
                    || writer.flush().is_err()
                {
                    break;
                }
                continue;
            }
        };
        let trimmed = text.trim();
        if trimmed.is_empty() {
            continue;
        }
        let mut shutdown_after_reply = false;
        let resp = match parse_request(trimmed) {
            Err(e) => error_response(&e),
            Ok(req) if req.cmd == Command::Shutdown => {
                shutdown_after_reply = true;
                execute_request(&state, &req)
            }
            Ok(req) if req.cmd.is_job() => {
                let (tx, rx) = mpsc::channel();
                // requests carrying `priority` jump ahead of lower-priority
                // queued jobs; absent = 0, the back of the line
                let prio = req.common.priority.unwrap_or(0).min(u32::MAX as u64) as u32;
                let job = Job { req, reply: tx, enqueued: std::time::Instant::now() };
                if queue.push_prio(job, prio) {
                    match rx.recv() {
                        Ok(r) => r,
                        Err(_) => error_response(&ProtoError::new(
                            "internal",
                            "worker pool shut down mid-job",
                        )),
                    }
                } else {
                    error_response(&ProtoError::new("shutting-down", "service is draining"))
                }
            }
            // ping / cache-stats answer inline, bypassing the queue
            Ok(req) => execute_request(&state, &req),
        };
        if writer.write_all(resp.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
            || writer.flush().is_err()
        {
            break;
        }
        if shutdown_after_reply {
            stop.store(true, Ordering::SeqCst);
            state.request_stop();
            queue.close();
            let _ = TcpStream::connect(server_addr); // unblock accept()
            break;
        }
    }
}
