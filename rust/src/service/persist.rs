//! On-disk persistence for the evaluation caches: the third tier under the
//! in-memory response/candidate memos.
//!
//! The store is an **append-only journal** of `(ContentHash, value bytes)`
//! records. Cache keys are already process-independent (stable fingerprints
//! hashed with [`ContentHash`]), so any process that opens the same
//! `--cache-dir` computes the same addresses and can reuse every record —
//! a killed-and-restarted daemon answers a repeated request from disk
//! without re-evaluating anything. The same property is what lets an
//! `olympus worker` serve any journal it holds to a coordinator: a
//! candidate journal is one warm shard of the distributed candidate store
//! ([`crate::service::remote`]), addressed by the identical keys every
//! process derives.
//!
//! Format, designed so that *no* on-disk state can panic a reader:
//!
//! * a 16-byte **versioned header** (`b"olympus-jrnl"` + `u32` version).
//!   A file with a different version or foreign magic is moved aside to
//!   `*.incompatible` and a fresh journal is started — incompatible formats
//!   are skipped, never misread;
//! * each record is `u32` payload length + `u64` FNV-1a checksum + payload
//!   (16-byte little-endian key, then the value bytes). A record that fails
//!   its checksum but frames correctly (bit rot) is skipped alone; a tail
//!   whose framing is broken (daemon killed mid-append) ends the replay.
//!   Both are counted, never a panic or a wrong hit;
//! * a key already journaled is never appended twice — an
//!   evicted-then-recomputed entry has, by determinism, the same value;
//! * **one writer at a time**, enforced with an advisory `*.lock` file
//!   stamped with the owner's PID (a lock whose process is dead is stolen,
//!   so a SIGKILLed daemon never wedges its cache dir). Non-owners open
//!   **read-only**: they warm-load every valid record but never append and
//!   never repair, so sharing a daemon's live dir with single-shot runs is
//!   safe;
//! * when damage is found at open, the **owner compacts**: valid records
//!   are rewritten through a temp file and an atomic rename. Only the lock
//!   owner does this, so no other writer's append handle can be orphaned.
//!
//! Startup replays the whole journal into memory before seeding the cache;
//! journal size is bounded by deleting the dir (see README), not by the
//! in-memory capacity bound.

use std::collections::HashSet;
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::passes::{outcome_from_json, outcome_to_json, CandidateCache};
use crate::util::{fnv1a_64, ContentHash, Json};

use super::cache::EvalCache;
use super::worker::Served;

/// Journal magic; a file that does not start with this is not ours.
const MAGIC: &[u8; 12] = b"olympus-jrnl";
/// Bump whenever the record payload encoding changes; readers skip (move
/// aside) journals written by another version instead of misreading them.
const VERSION: u32 = 1;
const HEADER_LEN: usize = 16;
/// Length prefix + checksum preceding every payload.
const RECORD_PREFIX: usize = 12;
/// A payload is at least its 16-byte key.
const MIN_PAYLOAD: u32 = 16;
/// A response or candidate is at most a few MB of IR + JSON; a length
/// beyond this is corruption, not data. [`DiskStore::append`] refuses (and
/// counts) values the replay path would reject, so a writer can never
/// poison its own journal.
const MAX_PAYLOAD: u32 = 256 * 1024 * 1024;

/// The whole-response journal inside a `--cache-dir`.
pub const RESPONSES_JOURNAL: &str = "responses.jrnl";
/// The per-candidate journal inside a `--cache-dir`.
pub const CANDIDATES_JOURNAL: &str = "candidates.jrnl";

/// Disk-tier counters surfaced through `cache-stats`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DiskStats {
    /// Records decoded from the journal into the in-memory tier at open.
    pub loaded: u64,
    /// Records appended (durably) by this process.
    pub persisted: u64,
    /// Records dropped: torn tails, failed checksums, undecodable values,
    /// and values too large for the record bound.
    pub corrupt_skipped: u64,
}

/// One open journal: replay-at-open, append afterwards (lock owner only).
pub struct DiskStore {
    path: PathBuf,
    file: Mutex<File>,
    /// `Some(lock file)` when this store owns the advisory writer lock;
    /// `None` = read-only (another live process is the writer).
    lock: Option<PathBuf>,
    /// fsync every append. The response journal wants this (a served answer
    /// must survive a machine crash once the client saw it); the candidate
    /// journal uses OS-buffered appends + fsync at drop instead — page
    /// cache survives a SIGKILL, so only power loss can cost records, and
    /// a lost candidate record only means one re-evaluation.
    sync_every_append: bool,
    /// Keys already present in the journal: appends dedupe against this so
    /// an evicted-then-recomputed entry cannot grow the file unboundedly.
    journaled: Mutex<HashSet<ContentHash>>,
    loaded: AtomicU64,
    persisted: AtomicU64,
    corrupt: AtomicU64,
}

impl DiskStore {
    /// Open with per-append fsync (see [`DiskStore::open_with`]).
    pub fn open(path: &Path) -> Result<(DiskStore, Vec<(ContentHash, Vec<u8>)>)> {
        Self::open_with(path, true)
    }

    /// Open (or create) the journal at `path` and replay every valid
    /// record. Returns the store plus the raw `(key, value bytes)` entries;
    /// the caller decodes values and seeds its in-memory cache. Corrupt
    /// records are counted, dropped and (for the lock owner) compacted
    /// away; an incompatible header moves the old file aside — neither is
    /// an error. If another live process holds the writer lock, the store
    /// opens read-only: it loads but never appends or repairs.
    pub fn open_with(
        path: &Path,
        sync_every_append: bool,
    ) -> Result<(DiskStore, Vec<(ContentHash, Vec<u8>)>)> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("create cache dir {}", parent.display()))?;
            }
        }
        let lock = acquire_writer_lock(path);
        if lock.is_none() {
            crate::obs::warn(
                "cache-read-only",
                &[
                    ("journal", path.display().to_string().into()),
                    ("reason", "another process holds the writer lock".into()),
                ],
            );
        }
        let replay_start = std::time::Instant::now();
        let open_rw = || {
            OpenOptions::new()
                .read(true)
                .append(true)
                .create(true)
                .open(path)
                .with_context(|| format!("open journal {}", path.display()))
        };
        let mut file = open_rw()?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)
            .with_context(|| format!("read journal {}", path.display()))?;
        let mut entries = Vec::new();
        let mut corrupt = 0u64;
        if bytes.is_empty() {
            if lock.is_some() {
                // fresh journal: the header goes through the same append
                // handle (no rename, nothing to orphan)
                file.write_all(&header_bytes()).context("write journal header")?;
                file.sync_all().context("fsync journal header")?;
            }
        } else if !header_ok(&bytes) {
            if lock.is_some() {
                // foreign or future-format file: move it aside untouched so
                // a downgrade never destroys data, then start fresh
                let aside = path.with_extension("incompatible");
                drop(file);
                std::fs::rename(path, &aside)
                    .with_context(|| format!("move incompatible journal {}", path.display()))?;
                crate::obs::warn(
                    "cache-journal-incompatible",
                    &[
                        ("journal", path.display().to_string().into()),
                        ("moved_to", aside.display().to_string().into()),
                    ],
                );
                file = open_rw()?;
                file.write_all(&header_bytes()).context("write journal header")?;
                file.sync_all().context("fsync journal header")?;
            } else {
                crate::obs::warn(
                    "cache-journal-incompatible",
                    &[
                        ("journal", path.display().to_string().into()),
                        ("moved_to", Json::Null),
                    ],
                );
            }
        } else {
            let (recs, bad) = replay(&bytes[HEADER_LEN..]);
            entries = recs;
            corrupt = bad;
            if corrupt > 0 {
                crate::obs::warn(
                    "cache-journal-corrupt",
                    &[
                        ("journal", path.display().to_string().into()),
                        ("dropped", corrupt.into()),
                        ("kept", entries.len().into()),
                    ],
                );
                if lock.is_some() {
                    // compact: rewrite the valid records through a temp file
                    // + atomic rename, then reopen our handle on the new
                    // inode. Safe: the lock guarantees no other writer whose
                    // append handle a rename could orphan.
                    write_compacted(path, &entries)?;
                    file = open_rw()?;
                }
            }
        }
        let replay_elapsed = replay_start.elapsed();
        crate::obs::metrics().journal_replay.record_duration(replay_elapsed);
        crate::obs::debug(
            "cache-journal-replayed",
            &[
                ("journal", path.display().to_string().into()),
                ("records", entries.len().into()),
                ("dropped", corrupt.into()),
                ("ms", Json::Num(replay_elapsed.as_secs_f64() * 1e3)),
            ],
        );
        let journaled = entries.iter().map(|(k, _)| *k).collect();
        Ok((
            DiskStore {
                path: path.to_path_buf(),
                file: Mutex::new(file),
                lock,
                sync_every_append,
                journaled: Mutex::new(journaled),
                loaded: AtomicU64::new(0),
                persisted: AtomicU64::new(0),
                corrupt: AtomicU64::new(corrupt),
            },
            entries,
        ))
    }

    /// Append one record (lock owner only; read-only stores skip). A key
    /// already journaled is skipped (same key means same value — every
    /// evaluation is deterministic), as is a value the replay path could
    /// not accept. IO failures are logged, not fatal: the in-memory tier
    /// keeps serving; only warm restarts lose the entry.
    pub fn append(&self, key: ContentHash, value: &[u8]) {
        if self.lock.is_none() {
            return; // read-only: another process owns the journal
        }
        if 16 + value.len() > MAX_PAYLOAD as usize {
            crate::obs::warn(
                "cache-value-too-large",
                &[
                    ("key", format!("{key}").into()),
                    ("bytes", (16 + value.len()).into()),
                    ("bound", (MAX_PAYLOAD as usize).into()),
                ],
            );
            self.corrupt.fetch_add(1, Ordering::Relaxed);
            return;
        }
        if !self.journaled.lock().unwrap().insert(key) {
            return; // already on disk (e.g. evicted from memory, recomputed)
        }
        let rec = encode_record(key, value);
        let mut f = self.file.lock().unwrap();
        let written = if self.sync_every_append {
            f.write_all(&rec).and_then(|_| f.sync_data())
        } else {
            f.write_all(&rec)
        };
        match written {
            Ok(()) => {
                self.persisted.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                // un-mark the key so a later recompute can retry persisting
                self.journaled.lock().unwrap().remove(&key);
                crate::obs::error(
                    "cache-append-failed",
                    &[
                        ("journal", self.path.display().to_string().into()),
                        ("error", e.to_string().into()),
                    ],
                );
            }
        }
    }

    /// Count one record decoded into the in-memory tier.
    pub fn note_loaded(&self) {
        self.loaded.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one record whose *value* this build could not decode (the
    /// framing was valid but e.g. the stored IR no longer parses).
    pub fn note_corrupt(&self) {
        self.corrupt.fetch_add(1, Ordering::Relaxed);
    }

    /// Does this store own the writer lock (false = read-only)?
    pub fn is_writer(&self) -> bool {
        self.lock.is_some()
    }

    pub fn stats(&self) -> DiskStats {
        DiskStats {
            loaded: self.loaded.load(Ordering::Relaxed),
            persisted: self.persisted.load(Ordering::Relaxed),
            corrupt_skipped: self.corrupt.load(Ordering::Relaxed),
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for DiskStore {
    fn drop(&mut self) {
        if let Some(lock) = &self.lock {
            if !self.sync_every_append {
                if let Ok(f) = self.file.lock() {
                    let _ = f.sync_data(); // flush OS-buffered appends
                }
            }
            let _ = std::fs::remove_file(lock);
        }
    }
}

/// Try to become the journal's writer: create `<journal>.lock` stamped with
/// our PID. A lock whose process is no longer alive is stolen (a SIGKILLed
/// daemon must not wedge its cache dir). Stealing is capture-and-inspect:
/// the suspect lock is atomically renamed aside first, and only deleted
/// after its *captured* contents confirm a dead holder — if a fresh owner
/// raced in, their lock is restored with a no-replace `hard_link`. Two
/// processes can therefore never both steal one stale lock; the locking
/// stays advisory (best-effort) only against 3-way sub-millisecond races.
/// Returns the lock path when owned.
fn acquire_writer_lock(path: &Path) -> Option<PathBuf> {
    let lock = path.with_extension("lock");
    for _ in 0..2 {
        match OpenOptions::new().write(true).create_new(true).open(&lock) {
            Ok(mut f) => {
                let _ = f.write_all(std::process::id().to_string().as_bytes());
                let _ = f.sync_all();
                return Some(lock);
            }
            Err(_) => {
                let holder = std::fs::read_to_string(&lock)
                    .ok()
                    .and_then(|s| s.trim().parse::<u32>().ok());
                if let Some(pid) = holder {
                    if pid_alive(pid) {
                        return None;
                    }
                }
                // dead or unreadable holder: capture the lock aside (atomic
                // rename — only one stealer can win it) and re-inspect
                let stale = lock.with_extension(format!("stale-{}", std::process::id()));
                if std::fs::rename(&lock, &stale).is_err() {
                    continue; // someone else captured it first; retry create_new
                }
                let captured = std::fs::read_to_string(&stale)
                    .ok()
                    .and_then(|s| s.trim().parse::<u32>().ok());
                match captured {
                    Some(pid) if pid_alive(pid) => {
                        // a fresh owner re-locked between our read and the
                        // rename: give their lock back (no-replace, in case
                        // yet another process locked meanwhile) and yield
                        let _ = std::fs::hard_link(&stale, &lock);
                        let _ = std::fs::remove_file(&stale);
                        return None;
                    }
                    _ => {
                        let _ = std::fs::remove_file(&stale);
                        // confirmed stale and captured by us alone: retry
                        // create_new for the now-absent lock
                    }
                }
            }
        }
    }
    None
}

/// Best-effort liveness check. On Linux `/proc/<pid>` exists for live
/// processes; elsewhere the check conservatively reports "dead", degrading
/// the lock to last-opener-wins (the pre-lock behavior).
fn pid_alive(pid: u32) -> bool {
    if pid == std::process::id() {
        return true; // our own (e.g. a lingering handle in this process)
    }
    Path::new(&format!("/proc/{pid}")).exists()
}

fn header_bytes() -> Vec<u8> {
    let mut header = Vec::with_capacity(HEADER_LEN);
    header.extend_from_slice(MAGIC);
    header.extend_from_slice(&VERSION.to_le_bytes());
    header
}

fn header_ok(bytes: &[u8]) -> bool {
    bytes.len() >= HEADER_LEN
        && &bytes[..MAGIC.len()] == MAGIC
        && u32::from_le_bytes(bytes[MAGIC.len()..HEADER_LEN].try_into().unwrap()) == VERSION
}

/// Walk the record stream. A record that frames correctly but fails its
/// checksum (bit rot) is skipped alone — the length prefix still gives the
/// next boundary. A record whose framing is implausible (length out of
/// bounds, or extending past end-of-file: a torn tail) ends the replay,
/// since no later boundary can be trusted. Returns the valid records and
/// the number of records dropped.
fn replay(b: &[u8]) -> (Vec<(ContentHash, Vec<u8>)>, u64) {
    let mut out = Vec::new();
    let mut corrupt = 0u64;
    let mut pos = 0usize;
    while pos < b.len() {
        let rest = &b[pos..];
        if rest.len() < RECORD_PREFIX {
            return (out, corrupt + 1);
        }
        let len = u32::from_le_bytes(rest[0..4].try_into().unwrap());
        let sum = u64::from_le_bytes(rest[4..12].try_into().unwrap());
        if !(MIN_PAYLOAD..=MAX_PAYLOAD).contains(&len)
            || rest.len() - RECORD_PREFIX < len as usize
        {
            return (out, corrupt + 1);
        }
        let payload = &rest[RECORD_PREFIX..RECORD_PREFIX + len as usize];
        if fnv1a_64(payload) == sum {
            let key = ContentHash(u128::from_le_bytes(payload[..16].try_into().unwrap()));
            out.push((key, payload[16..].to_vec()));
        } else {
            corrupt += 1; // framed but rotten: skip just this record
        }
        pos += RECORD_PREFIX + len as usize;
    }
    (out, corrupt)
}

fn encode_record(key: ContentHash, value: &[u8]) -> Vec<u8> {
    let mut rec = Vec::with_capacity(RECORD_PREFIX + 16 + value.len());
    rec.extend_from_slice(&((16 + value.len()) as u32).to_le_bytes());
    let mut payload = Vec::with_capacity(16 + value.len());
    payload.extend_from_slice(&key.0.to_le_bytes());
    payload.extend_from_slice(value);
    rec.extend_from_slice(&fnv1a_64(&payload).to_le_bytes());
    rec.extend_from_slice(&payload);
    rec
}

/// Atomically replace the journal with header + `entries`: write a temp
/// file, fsync it, rename over, fsync the directory. Caller must own the
/// writer lock — a rename orphans any other open append handle.
fn write_compacted(path: &Path, entries: &[(ContentHash, Vec<u8>)]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    let mut buf = header_bytes();
    for (key, value) in entries {
        buf.extend_from_slice(&encode_record(*key, value));
    }
    let mut f = File::create(&tmp)
        .with_context(|| format!("create compacted journal {}", tmp.display()))?;
    f.write_all(&buf).context("write compacted journal")?;
    f.sync_all().context("fsync compacted journal")?;
    drop(f);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publish compacted journal {}", path.display()))?;
    if let Some(parent) = path.parent() {
        if let Ok(d) = File::open(parent) {
            let _ = d.sync_all(); // make the rename itself durable
        }
    }
    Ok(())
}

/// Open the journal at `path` and build a persistent cache over it: every
/// decodable record seeds the in-memory tier, every fresh computation
/// writes through. `encode` may decline (`None`) values that must not
/// outlive the process; `decode` failures are counted as corrupt-skipped.
/// Also returns the accepted raw records, in journal order, so a caller
/// can seed a secondary index over the same bytes — the journal gossip
/// log ([`crate::service::gossip`]) serves exactly these records to peers.
pub fn open_persistent_cache<V, E, D>(
    path: &Path,
    capacity: usize,
    sync_every_append: bool,
    encode: E,
    decode: D,
) -> Result<(EvalCache<V>, Arc<DiskStore>, Vec<(ContentHash, Vec<u8>)>)>
where
    V: Clone,
    E: Fn(&V) -> Option<Vec<u8>> + Send + Sync + 'static,
    D: Fn(&[u8]) -> Option<V>,
{
    let (store, entries) = DiskStore::open_with(path, sync_every_append)?;
    let store = Arc::new(store);
    let mut cache = EvalCache::with_capacity(capacity);
    cache.persist_to(store.clone(), encode);
    let mut accepted = Vec::with_capacity(entries.len());
    for (key, bytes) in entries {
        match decode(&bytes) {
            Some(v) => {
                cache.warm_insert(key, v);
                store.note_loaded();
                accepted.push((key, bytes));
            }
            None => store.note_corrupt(),
        }
    }
    Ok((cache, store, accepted))
}

/// Serialize a [`Served`] response for the disk tier. The stored `Json` is
/// re-serialized verbatim on a warm restart, so the encoding must (and
/// does) round-trip bit-identically: object keys are ordered (`BTreeMap`)
/// and finite numbers print in Rust's shortest round-trip form.
/// [`Served::Failed`] is deliberately *not* persisted — a failure may be
/// environment-dependent (resource pressure, thread limits), and a journal
/// must never make one permanent across restarts.
pub fn encode_served(v: &Served) -> Option<Vec<u8>> {
    match v {
        Served::Ok(result) => {
            Some(Json::obj(vec![("ok", result.clone())]).to_string().into_bytes())
        }
        Served::Failed(_) => None,
    }
}

/// Inverse of [`encode_served`]; `None` marks an undecodable record
/// (counted as corrupt-skipped by the caller, never an error).
pub fn decode_served(bytes: &[u8]) -> Option<Served> {
    let text = std::str::from_utf8(bytes).ok()?;
    let v = Json::parse(text).ok()?;
    match v.get("ok") {
        Json::Null => None,
        j => Some(Served::Ok(j.clone())),
    }
}

/// Open a persistent candidate cache rooted at `dir` — the layout both
/// `olympus serve --cache-dir` and the single-shot `olympus dse/des
/// --cache-dir` warm starts share. Candidate appends are OS-buffered
/// (fsync at drop): losing one to a power cut only re-pays one evaluation.
/// The returned store is also captured by the cache's write-through hook,
/// so dropping the `Arc` only loses access to the counters, not
/// persistence.
pub fn open_candidate_cache(
    dir: &Path,
    capacity: usize,
) -> Result<(Arc<CandidateCache>, Arc<DiskStore>)> {
    let (cache, store, _) = open_persistent_cache(
        &dir.join(CANDIDATES_JOURNAL),
        capacity,
        false,
        |outcome| Some(outcome_to_json(outcome).to_string().into_bytes()),
        |bytes| {
            std::str::from_utf8(bytes)
                .ok()
                .and_then(|t| Json::parse(t).ok())
                .and_then(|j| outcome_from_json(&j))
        },
    )?;
    Ok((Arc::new(cache), store))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "olympus_persist_{tag}_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn key(n: u128) -> ContentHash {
        ContentHash(n)
    }

    #[test]
    fn round_trips_records_across_reopen() {
        let dir = tmpdir("roundtrip");
        let path = dir.join("t.jrnl");
        let (store, entries) = DiskStore::open(&path).unwrap();
        assert!(entries.is_empty());
        assert!(store.is_writer());
        store.append(key(1), b"alpha");
        store.append(key(2), b"beta");
        assert_eq!(store.stats().persisted, 2);
        drop(store);
        let (store, entries) = DiskStore::open(&path).unwrap();
        assert_eq!(store.stats().corrupt_skipped, 0);
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0], (key(1), b"alpha".to_vec()));
        assert_eq!(entries[1], (key(2), b"beta".to_vec()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duplicate_keys_are_appended_once() {
        let dir = tmpdir("dedupe");
        let path = dir.join("t.jrnl");
        let (store, _) = DiskStore::open(&path).unwrap();
        store.append(key(1), b"alpha");
        store.append(key(1), b"alpha");
        assert_eq!(store.stats().persisted, 1, "second append deduped");
        drop(store);
        let (store, entries) = DiskStore::open(&path).unwrap();
        assert_eq!(entries.len(), 1);
        // the dedupe set survives the reopen: still no second record
        store.append(key(1), b"alpha");
        assert_eq!(store.stats().persisted, 0);
        drop(store);
        let (_, entries) = DiskStore::open(&path).unwrap();
        assert_eq!(entries.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Satellite: a journal truncated at *every* byte offset of its last
    /// record (daemon killed mid-append) loses exactly that record —
    /// counted, compacted, never a panic or a wrong entry.
    #[test]
    fn truncated_tail_is_skipped_at_every_byte_offset() {
        let dir = tmpdir("truncate");
        let path = dir.join("t.jrnl");
        let (store, _) = DiskStore::open(&path).unwrap();
        store.append(key(10), b"alpha");
        store.append(key(11), b"beta");
        store.append(key(12), b"gamma");
        drop(store);
        let full = std::fs::read(&path).unwrap();
        // the last record is prefix + 16-byte key + "gamma"
        let rec3_len = RECORD_PREFIX + 16 + "gamma".len();
        let rec3_start = full.len() - rec3_len;
        for cut in rec3_start..full.len() {
            let p = dir.join(format!("cut_{cut}.jrnl"));
            std::fs::write(&p, &full[..cut]).unwrap();
            let (s, entries) = DiskStore::open(&p).unwrap();
            assert_eq!(entries.len(), 2, "cut at {cut}");
            assert_eq!(entries[1], (key(11), b"beta".to_vec()), "cut at {cut}");
            if cut == rec3_start {
                assert_eq!(s.stats().corrupt_skipped, 0, "clean boundary at {cut}");
            } else {
                assert_eq!(s.stats().corrupt_skipped, 1, "torn record at {cut}");
            }
            // open compacted the torn bytes away: appending then reopening
            // yields a clean 3-record journal
            s.append(key(12), b"gamma");
            drop(s);
            let (s2, entries2) = DiskStore::open(&p).unwrap();
            assert_eq!(entries2.len(), 3, "cut at {cut}");
            assert_eq!(s2.stats().corrupt_skipped, 0, "cut at {cut}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rotten_record_mid_file_is_skipped_alone() {
        let dir = tmpdir("bitrot");
        let path = dir.join("t.jrnl");
        let (store, _) = DiskStore::open(&path).unwrap();
        store.append(key(1), b"alpha");
        store.append(key(2), b"beta");
        store.append(key(3), b"gamma");
        drop(store);
        let mut bytes = std::fs::read(&path).unwrap();
        // flip one payload byte inside the *middle* record ("beta")
        let rec1_len = RECORD_PREFIX + 16 + "alpha".len();
        let rec2_last = HEADER_LEN + rec1_len + RECORD_PREFIX + 16 + "beta".len() - 1;
        bytes[rec2_last] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let (s, entries) = DiskStore::open(&path).unwrap();
        // only the rotten record is lost; the one after it survives
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].0, key(1));
        assert_eq!(entries[1].0, key(3));
        assert_eq!(s.stats().corrupt_skipped, 1);
        // the compacted journal is clean on reopen
        drop(s);
        let (s2, entries2) = DiskStore::open(&path).unwrap();
        assert_eq!(entries2.len(), 2);
        assert_eq!(s2.stats().corrupt_skipped, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn incompatible_header_is_moved_aside_not_crashed() {
        let dir = tmpdir("version");
        let path = dir.join("t.jrnl");
        // future version
        let mut future = Vec::new();
        future.extend_from_slice(MAGIC);
        future.extend_from_slice(&(VERSION + 1).to_le_bytes());
        future.extend_from_slice(b"opaque future records");
        std::fs::write(&path, &future).unwrap();
        let (store, entries) = DiskStore::open(&path).unwrap();
        assert!(entries.is_empty());
        let aside = path.with_extension("incompatible");
        assert_eq!(std::fs::read(&aside).unwrap(), future, "old data preserved");
        store.append(key(5), b"fresh");
        drop(store);
        let (_, entries) = DiskStore::open(&path).unwrap();
        assert_eq!(entries.len(), 1);
        // foreign magic too
        let path2 = dir.join("t2.jrnl");
        std::fs::write(&path2, b"not a journal at all").unwrap();
        let (_, entries) = DiskStore::open(&path2).unwrap();
        assert!(entries.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn second_opener_is_read_only_while_lock_is_held() {
        let dir = tmpdir("lock");
        let path = dir.join("t.jrnl");
        let (a, _) = DiskStore::open(&path).unwrap();
        assert!(a.is_writer());
        a.append(key(1), b"alpha");
        // same pid holds the lock: the second open degrades to read-only
        let (b, entries) = DiskStore::open(&path).unwrap();
        assert!(!b.is_writer());
        assert_eq!(entries.len(), 1, "read-only opens still warm-load");
        b.append(key(2), b"beta");
        assert_eq!(b.stats().persisted, 0, "read-only stores never append");
        drop(b); // must not release a's lock
        a.append(key(2), b"beta");
        assert_eq!(a.stats().persisted, 2);
        drop(a);
        let (c, entries) = DiskStore::open(&path).unwrap();
        assert!(c.is_writer(), "lock released at drop");
        assert_eq!(entries.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_writer_lock_is_stolen() {
        let dir = tmpdir("stale");
        let path = dir.join("t.jrnl");
        // a SIGKILLed daemon leaves its lock behind; the pid is dead (or
        // unreadable), so the next opener steals it
        std::fs::write(path.with_extension("lock"), b"4294967294").unwrap();
        let (store, _) = DiskStore::open(&path).unwrap();
        assert!(store.is_writer(), "dead holder must not wedge the dir");
        store.append(key(1), b"alpha");
        assert_eq!(store.stats().persisted, 1);
        drop(store);
        std::fs::write(path.with_extension("lock"), b"not a pid").unwrap();
        let (store, entries) = DiskStore::open(&path).unwrap();
        assert!(store.is_writer());
        assert_eq!(entries.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unsynced_store_flushes_at_drop() {
        let dir = tmpdir("unsynced");
        let path = dir.join("t.jrnl");
        let (store, _) = DiskStore::open_with(&path, false).unwrap();
        store.append(key(1), b"alpha");
        assert_eq!(store.stats().persisted, 1);
        drop(store);
        let (_, entries) = DiskStore::open(&path).unwrap();
        assert_eq!(entries.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn served_codec_round_trips_ok_and_never_persists_failures() {
        let payload = Json::obj(vec![
            ("table", "best: full_x4".into()),
            ("score", 0.12345678901234567.into()),
            ("n", 42u64.into()),
            ("nothing", Json::Null),
        ]);
        let ok = Served::Ok(payload.clone());
        let decoded = decode_served(&encode_served(&ok).unwrap()).unwrap();
        match decoded {
            Served::Ok(j) => {
                assert_eq!(j, payload);
                assert_eq!(j.to_string(), payload.to_string(), "byte-identical reserialization");
            }
            other => panic!("wrong variant: {other:?}"),
        }
        // failures may be environment-dependent: never written to disk
        assert!(encode_served(&Served::Failed("verifier rejected".into())).is_none());
        assert!(decode_served(b"garbage").is_none());
        assert!(decode_served(b"{}").is_none());
    }

    #[test]
    fn persistent_cache_skips_declined_values_on_write_through() {
        let dir = tmpdir("declined");
        let path = dir.join("t.jrnl");
        let open = || {
            open_persistent_cache(
                &path,
                0,
                true,
                |v: &i64| if *v >= 0 { Some(v.to_le_bytes().to_vec()) } else { None },
                |b| b.try_into().ok().map(i64::from_le_bytes),
            )
            .unwrap()
        };
        let (cache, store, entries) = open();
        assert!(entries.is_empty());
        cache.get_or_compute(key(1), || 7);
        cache.get_or_compute(key(2), || -1); // declined by the encoder
        assert_eq!(store.stats().persisted, 1);
        drop((cache, store));
        let (cache, store, entries) = open();
        assert_eq!(store.stats().loaded, 1);
        // the accepted raw records come back for secondary indexes
        assert_eq!(entries, vec![(key(1), 7i64.to_le_bytes().to_vec())]);
        let (v, cached) = cache.get_or_compute(key(1), || panic!("warm"));
        assert_eq!((v, cached), (7, true));
        // the declined key recomputes after a restart, as intended
        let (v, cached) = cache.get_or_compute(key(2), || -1);
        assert_eq!((v, cached), (-1, false));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
