//! The `olympus serve` wire protocol: newline-delimited JSON over TCP.
//!
//! One request per line, one response line per request, in order. A
//! malformed line gets a structured `{"ok": false, "error": {...}}` response
//! and the connection stays open — clients never have to guess why a socket
//! died. A request line longer than
//! [`MAX_REQUEST_BYTES`](crate::service::MAX_REQUEST_BYTES) is answered
//! with a `too-large` error; the server drains (never buffers) the rest of
//! the oversized line and the connection stays open.
//!
//! Every request decodes through one typed envelope:
//! [`Request`]`{ cmd, id, common, verb }`. `cmd` selects the verb, `id`
//! (any JSON value) is echoed back, [`CommonOpts`] carries the fields every
//! verb accepts (`priority`, `deadline_ms`), and [`VerbPayload`] holds the
//! verb-specific fields. A known verb with an *unknown* field is a
//! structured `bad-request` naming the field (`error.detail.field`) — never
//! silently ignored. The full field tables, defaults and error codes live
//! in `PROTOCOL.md` at the repo root.
//!
//! Requests:
//!
//! ```json
//! {"cmd": "dse",  "ir": "<mlir>", "platform": "u280", "objective": "des-score",
//!  "scenario": "closed:4", "seed": 42, "factors": [2, 4],
//!  "driver": "successive-halving", "budget": 3, "id": 1}
//! {"cmd": "des",  "ir": "<mlir>", "pipeline": "sanitize, iris, channel-reassign",
//!  "scenario": "poisson:1000:20", "seed": 7}
//! {"cmd": "flow", "ir": "<mlir>", "platform": "u280"}
//! {"cmd": "handshake", "proto_version": 3, "capabilities": ["journal-gossip"],
//!  "shard_map": {"index": 0, "total": 2, "epoch": 4,
//!                "workers": ["h1:7900", "h2:7900"]}}
//! {"cmd": "eval-candidate", "ir": "<mlir>", "platform_json": {...},
//!  "objective_json": {"kind": "analytic"}, "point_label": "full(x4)",
//!  "point_pipeline": "sanitize, ...", "key": "<32-hex>"}
//! {"cmd": "eval-response", "job_cmd": "dse", "ir": "<mlir>", "seed": 42,
//!  "key": "<32-hex>"}
//! {"cmd": "journal-pull", "cursor": 0, "limit": 64}
//! {"cmd": "join",  "worker": "h3:7900"}
//! {"cmd": "leave", "worker": "h2:7900"}
//! {"cmd": "cache-stats"}
//! {"cmd": "ping"}
//! {"cmd": "shutdown"}
//! ```
//!
//! The distributed verbs (see [`crate::service::remote`] and
//! [`crate::service::gossip`]):
//!
//! * `handshake` — a coordinator announces the protocol version, its
//!   capability list and the worker's shard of the rendezvous-hash key
//!   space (with the membership `epoch` so stale maps are recognizable). A
//!   version mismatch is a structured `proto-mismatch` error; a malformed
//!   shard map is a structured `bad-request` — never a dropped connection.
//! * `eval-candidate` — evaluate one DSE candidate, answered through the
//!   worker's candidate cache. Carries full inline platform/objective specs
//!   (not names) so the worker recomputes the same content-addressed key
//!   and cross-checks it against `key` (`key-mismatch` on skew).
//! * `eval-response` — evaluate one *whole* job (`job_cmd` = dse|des|flow,
//!   plus the job's own fields) on the worker owning the response key's
//!   shard, answered through the worker's response cache. The worker
//!   re-derives the response key and cross-checks it against `key`.
//! * `journal-pull` — page persisted journal records out of a peer worker
//!   (`cursor` high-water mark, `limit` page size, optional `shard` filter)
//!   so a rebuilt or newly joined worker warms its shard from neighbors
//!   instead of recomputing.
//! * `join` / `leave` — coordinator-side membership edits: add or remove a
//!   worker at runtime and re-rendezvous the shard map under a bumped
//!   epoch, no restart.
//!
//! Responses: `{"ok": true, "id": ..., "cached": bool, "key": "<32-hex>",
//! "result": {...}}` — `key` is the content-address of the evaluation
//! (stable across servers), `cached` whether this answer skipped
//! evaluation. Every failure, on every path, is
//! `{"ok": false, "id": ..., "error": {"code", "message", "id"?,
//! "detail"?}}` — one shape for parse errors, executor errors, version
//! skew, oversize lines and drain-time teardowns alike.

use crate::util::Json;

/// Version of the distributed-evaluation protocol. A coordinator announces
/// it in every `handshake`; a worker built from a different version answers
/// `proto-mismatch` instead of silently computing keys the coordinator
/// would disagree with. Bump whenever the handshake, the `eval-*` fields,
/// or any wire codec they carry changes shape.
///
/// v2: traffic fields (`scenario_json`, `slo`, `autoscale`, `priority`,
/// `deadline_ms`), the `slo-score` objective and the trace/diurnal
/// scenario codecs.
///
/// v3: the typed request envelope (unknown fields rejected), the unified
/// error shape, capability + epoch handshake, and the `eval-response` /
/// `journal-pull` / `join` / `leave` verbs.
pub const PROTO_VERSION: u64 = 3;

/// What this build of the service can do, exchanged in `handshake` so
/// mixed-version fleets can see at a glance which peers support which
/// distributed features.
pub const CAPABILITIES: &[&str] = &["response-shard", "journal-gossip", "elastic-membership"];

/// What a request asks the service to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Command {
    /// Full DSE over the strategy table; returns the decision table + best.
    Dse,
    /// Flow + discrete-event replay of a scenario.
    Des,
    /// Full flow report (analyses + architecture + emission summary).
    Flow,
    /// Coordinator -> worker: version/capability check + shard assignment.
    Handshake,
    /// Coordinator -> worker: evaluate one DSE candidate, answered through
    /// the worker's candidate cache (memory + `--cache-dir` journal).
    EvalCandidate,
    /// Coordinator -> worker: evaluate one whole job on its response-key
    /// shard owner, answered through the worker's response cache.
    EvalResponse,
    /// Worker -> worker: page journal records out of a peer (gossip).
    JournalPull,
    /// Add a worker to the fleet at runtime (coordinator only).
    Join,
    /// Remove a worker from the fleet at runtime (coordinator only).
    Leave,
    /// Evaluation-cache counters.
    CacheStats,
    /// Observability snapshot: per-verb request counters, latency
    /// histograms, DES throughput (`olympus stats` fans this out).
    Metrics,
    /// Liveness probe.
    Ping,
    /// Stop accepting connections and drain.
    Shutdown,
}

impl Command {
    pub fn parse(s: &str) -> Option<Command> {
        match s {
            "dse" => Some(Command::Dse),
            "des" => Some(Command::Des),
            "flow" => Some(Command::Flow),
            "handshake" => Some(Command::Handshake),
            "eval-candidate" => Some(Command::EvalCandidate),
            "eval-response" => Some(Command::EvalResponse),
            "journal-pull" => Some(Command::JournalPull),
            "join" => Some(Command::Join),
            "leave" => Some(Command::Leave),
            "cache-stats" => Some(Command::CacheStats),
            "metrics" => Some(Command::Metrics),
            "ping" => Some(Command::Ping),
            "shutdown" => Some(Command::Shutdown),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Command::Dse => "dse",
            Command::Des => "des",
            Command::Flow => "flow",
            Command::Handshake => "handshake",
            Command::EvalCandidate => "eval-candidate",
            Command::EvalResponse => "eval-response",
            Command::JournalPull => "journal-pull",
            Command::Join => "join",
            Command::Leave => "leave",
            Command::CacheStats => "cache-stats",
            Command::Metrics => "metrics",
            Command::Ping => "ping",
            Command::Shutdown => "shutdown",
        }
    }

    /// Does this command evaluate a design (and therefore go through the
    /// job queue + cache)?
    pub fn is_job(self) -> bool {
        matches!(
            self,
            Command::Dse
                | Command::Des
                | Command::Flow
                | Command::EvalCandidate
                | Command::EvalResponse
        )
    }
}

/// Fields every verb accepts (the queue knobs; no-ops for inline verbs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CommonOpts {
    /// Serve-queue priority of this request (default 0; higher jumps
    /// ahead of lower-priority queued jobs).
    pub priority: Option<u64>,
    /// Queue deadline, ms: a job still waiting when it lapses is answered
    /// with a `deadline-expired` error instead of evaluated.
    pub deadline_ms: Option<u64>,
}

/// The fields of a whole evaluation job (`dse` / `des` / `flow`, and the
/// job carried inside an `eval-response`).
#[derive(Debug, Clone, PartialEq)]
pub struct JobPayload {
    /// Olympus MLIR text (required).
    pub ir: String,
    /// Builtin platform name (default "u280").
    pub platform: Option<String>,
    /// Full inline platform spec (overrides `platform`).
    pub platform_json: Option<Json>,
    /// Cross-platform search axis: two or more *builtin* platform names
    /// (the wire carries names, not specs). The DSE scores every strategy
    /// on every listed platform and the flow lowers onto the winner.
    /// Mutually exclusive with `platform`/`platform_json` (executor-
    /// checked); duplicates and non-string entries are parse errors.
    pub platforms: Option<Vec<String>>,
    /// Explicit pass pipeline (skips DSE for `des`/`flow`).
    pub pipeline: Option<String>,
    /// "analytic" (default), "des-score" or "slo-score".
    pub objective: Option<String>,
    /// Workload scenario spec (`closed:N` | `poisson:HZ:N` |
    /// `bursty:HZ:ON:OFF:N` | `diurnal:HZ:AMPL:PERIOD:N`).
    pub scenario: Option<String>,
    /// Full inline scenario ([`crate::des::WorkloadScenario::to_json`]);
    /// overrides `scenario`. How `submit` ships a local `trace:<file>` to a
    /// daemon without a shared filesystem.
    pub scenario_json: Option<Json>,
    /// SLO spec (`CLASS=p99<MS[,...]`) for the `slo-score` objective.
    pub slo: Option<String>,
    /// Autoscale policy spec (`INTERVAL_S:UP:DOWN:MIN:MAX`) enabling
    /// elastic replicas inside the DES.
    pub autoscale: Option<String>,
    /// DES seed (engine default when absent).
    pub seed: Option<u64>,
    /// Replication factors for DSE (absent = defaults). Normalized (sorted,
    /// deduplicated); an explicitly empty array is rejected.
    pub factors: Option<Vec<u64>>,
    /// Search driver name (absent = "exhaustive").
    pub driver: Option<String>,
    /// Candidate budget for budgeted drivers.
    pub budget: Option<u64>,
    /// Sampling seed for the `random` driver.
    pub search_seed: Option<u64>,
}

/// Fields of an `eval-candidate` request.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalCandidatePayload {
    /// Olympus MLIR text (required).
    pub ir: String,
    /// Builtin platform name (default "u280").
    pub platform: Option<String>,
    /// Full inline platform spec (overrides `platform`).
    pub platform_json: Option<Json>,
    /// Full objective spec ([`crate::passes::objective_to_json`]).
    pub objective_json: Option<Json>,
    /// Expected candidate key (32 hex digits); the worker cross-checks it
    /// against the key it derives itself (`key-mismatch` on skew).
    pub key: Option<String>,
    /// Decision-table label of the point.
    pub point_label: Option<String>,
    /// Pass pipeline (or iterative tag) of the point (required).
    pub point_pipeline: String,
}

/// Fields of an `eval-response` request: one whole job routed to the
/// response-key shard owner.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalResponsePayload {
    /// The verb this job answers (`dse` | `des` | `flow`); enters the
    /// response cache key exactly as the client-facing verb would.
    pub job_cmd: Command,
    /// Expected response key (32 hex digits); the worker cross-checks it
    /// against the key it derives itself (`key-mismatch` on skew).
    pub key: Option<String>,
    /// The job itself (same fields as a direct `dse`/`des`/`flow`).
    pub job: JobPayload,
}

/// Fields of a `handshake` request.
#[derive(Debug, Clone, PartialEq)]
pub struct HandshakePayload {
    /// Distributed-protocol version announced by the coordinator
    /// (executor-required so the mismatch answer can be structured).
    pub proto_version: Option<u64>,
    /// Raw shard-map object (validated by the executor so malformed maps
    /// answer structured errors, not parse panics).
    pub shard_map: Option<Json>,
    /// Capability list of the announcing peer (see [`CAPABILITIES`]).
    pub capabilities: Option<Vec<String>>,
}

/// Fields of a `journal-pull` request.
#[derive(Debug, Clone, PartialEq)]
pub struct JournalPullPayload {
    /// High-water mark: first journal record index not yet seen (default 0).
    pub cursor: u64,
    /// Max records scanned per page (default 64, clamped by the server).
    pub limit: Option<u64>,
    /// Optional `(index, total)` rendezvous filter: only records whose key
    /// hashes to this shard are returned (full replication omits it).
    pub shard: Option<(u64, u64)>,
}

/// Fields of a `join` / `leave` membership edit.
#[derive(Debug, Clone, PartialEq)]
pub struct MembershipPayload {
    /// `host:port` of the worker to add or remove.
    pub worker: String,
}

/// The verb-specific half of a [`Request`].
#[derive(Debug, Clone, PartialEq)]
pub enum VerbPayload {
    /// `dse` / `des` / `flow`.
    Job(JobPayload),
    /// `eval-candidate`.
    EvalCandidate(EvalCandidatePayload),
    /// `eval-response`.
    EvalResponse(EvalResponsePayload),
    /// `handshake`.
    Handshake(HandshakePayload),
    /// `journal-pull`.
    JournalPull(JournalPullPayload),
    /// `join` / `leave`.
    Membership(MembershipPayload),
    /// `cache-stats` / `metrics` / `ping` / `shutdown` (no payload).
    Control,
}

/// A parsed request: one envelope for every verb.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub cmd: Command,
    /// Echoed verbatim in the response (`Json::Null` when absent).
    pub id: Json,
    /// Fields accepted on every verb.
    pub common: CommonOpts,
    /// The verb-specific payload.
    pub verb: VerbPayload,
}

impl Request {
    /// The job carried by this request: a direct `dse`/`des`/`flow`, or
    /// the inner job of an `eval-response`.
    pub fn job(&self) -> Option<&JobPayload> {
        match &self.verb {
            VerbPayload::Job(j) => Some(j),
            VerbPayload::EvalResponse(r) => Some(&r.job),
            _ => None,
        }
    }
}

/// A protocol-level failure: structured error code + message, with the
/// request id when one was recoverable from the line and optional
/// machine-readable detail (e.g. the offending field name).
#[derive(Debug, Clone)]
pub struct ProtoError {
    pub id: Json,
    pub code: &'static str,
    pub message: String,
    pub detail: Option<Json>,
}

impl ProtoError {
    pub fn new(code: &'static str, message: impl Into<String>) -> ProtoError {
        ProtoError { id: Json::Null, code, message: message.into(), detail: None }
    }

    pub fn with_id(mut self, id: Json) -> ProtoError {
        self.id = id;
        self
    }

    pub fn with_detail(mut self, detail: Json) -> ProtoError {
        self.detail = Some(detail);
        self
    }
}

/// Fields accepted on *every* verb (the [`CommonOpts`] knobs + framing).
const COMMON_FIELDS: &[&str] = &["cmd", "id", "priority", "deadline_ms"];
/// Fields of a whole evaluation job ([`JobPayload`]).
const JOB_FIELDS: &[&str] = &[
    "ir",
    "platform",
    "platform_json",
    "platforms",
    "pipeline",
    "objective",
    "scenario",
    "scenario_json",
    "slo",
    "autoscale",
    "seed",
    "factors",
    "driver",
    "budget",
    "search_seed",
];
const EVAL_RESPONSE_FIELDS: &[&str] = &["job_cmd", "key"];
const EVAL_CANDIDATE_FIELDS: &[&str] =
    &["ir", "platform", "platform_json", "objective_json", "key", "point_label", "point_pipeline"];
const HANDSHAKE_FIELDS: &[&str] = &["proto_version", "shard_map", "capabilities"];
const JOURNAL_PULL_FIELDS: &[&str] = &["cursor", "limit", "shard"];
const MEMBERSHIP_FIELDS: &[&str] = &["worker"];

/// The verb-specific fields `cmd` accepts (on top of [`COMMON_FIELDS`]).
/// `eval-response` additionally accepts every job field.
fn verb_fields(cmd: Command) -> &'static [&'static str] {
    match cmd {
        Command::Dse | Command::Des | Command::Flow => JOB_FIELDS,
        Command::EvalCandidate => EVAL_CANDIDATE_FIELDS,
        Command::EvalResponse => EVAL_RESPONSE_FIELDS,
        Command::Handshake => HANDSHAKE_FIELDS,
        Command::JournalPull => JOURNAL_PULL_FIELDS,
        Command::Join | Command::Leave => MEMBERSHIP_FIELDS,
        Command::CacheStats | Command::Metrics | Command::Ping | Command::Shutdown => &[],
    }
}

fn uint_field(v: &Json, k: &'static str, id: &Json) -> Result<Option<u64>, ProtoError> {
    match v.get(k) {
        Json::Null => Ok(None),
        j => j
            .as_f64()
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .map(|n| Some(n as u64))
            .ok_or_else(|| {
                ProtoError::new("bad-request", format!("'{k}' must be a non-negative integer"))
                    .with_id(id.clone())
            }),
    }
}

fn str_field(v: &Json, k: &'static str, id: &Json) -> Result<Option<String>, ProtoError> {
    match v.get(k) {
        Json::Null => Ok(None),
        Json::Str(s) => Ok(Some(s.clone())),
        _ => Err(ProtoError::new("bad-request", format!("'{k}' must be a string"))
            .with_id(id.clone())),
    }
}

fn json_field(v: &Json, k: &str) -> Option<Json> {
    match v.get(k) {
        Json::Null => None,
        j => Some(j.clone()),
    }
}

fn required_ir(v: &Json, cmd_str: &str, id: &Json) -> Result<String, ProtoError> {
    str_field(v, "ir", id)?.ok_or_else(|| {
        ProtoError::new("bad-request", format!("cmd '{cmd_str}' requires string field 'ir'"))
            .with_id(id.clone())
    })
}

fn parse_job_payload(v: &Json, cmd_str: &str, id: &Json) -> Result<JobPayload, ProtoError> {
    let ir = required_ir(v, cmd_str, id)?;
    let factors = match v.get("factors") {
        Json::Null => None,
        j => {
            let arr = j.as_arr().ok_or_else(|| {
                ProtoError::new("bad-request", "'factors' must be an array of integers")
                    .with_id(id.clone())
            })?;
            if arr.is_empty() {
                return Err(ProtoError::new(
                    "bad-request",
                    "'factors' must not be empty (omit the field for the default sweep)",
                )
                .with_id(id.clone()));
            }
            let mut out = Vec::with_capacity(arr.len());
            for f in arr {
                let n = f.as_f64().filter(|n| *n >= 1.0 && n.fract() == 0.0).ok_or_else(|| {
                    ProtoError::new("bad-request", "'factors' entries must be integers >= 1")
                        .with_id(id.clone())
                })?;
                out.push(n as u64);
            }
            // dedupe/sort so [4, 2, 2] and [2, 4] share a cache address
            let normalized = crate::search::normalize_factors(&out)
                .map_err(|e| ProtoError::new("bad-request", e).with_id(id.clone()))?;
            Some(normalized)
        }
    };
    let platforms = match v.get("platforms") {
        Json::Null => None,
        j => {
            let arr = j.as_arr().ok_or_else(|| {
                ProtoError::new("bad-request", "'platforms' must be an array of platform names")
                    .with_id(id.clone())
            })?;
            if arr.is_empty() {
                return Err(ProtoError::new(
                    "bad-request",
                    "'platforms' must not be empty (omit the field for a single platform)",
                )
                .with_id(id.clone()));
            }
            let mut names = Vec::with_capacity(arr.len());
            let mut seen = std::collections::BTreeSet::new();
            for n in arr {
                let name = n.as_str().ok_or_else(|| {
                    ProtoError::new("bad-request", "'platforms' entries must be strings")
                        .with_id(id.clone())
                })?;
                if !seen.insert(name.to_string()) {
                    return Err(ProtoError::new(
                        "bad-request",
                        format!("'platforms' lists platform '{name}' more than once"),
                    )
                    .with_id(id.clone()));
                }
                names.push(name.to_string());
            }
            Some(names)
        }
    };
    Ok(JobPayload {
        ir,
        platform: str_field(v, "platform", id)?,
        platform_json: json_field(v, "platform_json"),
        platforms,
        pipeline: str_field(v, "pipeline", id)?,
        objective: str_field(v, "objective", id)?,
        scenario: str_field(v, "scenario", id)?,
        scenario_json: json_field(v, "scenario_json"),
        slo: str_field(v, "slo", id)?,
        autoscale: str_field(v, "autoscale", id)?,
        seed: uint_field(v, "seed", id)?,
        factors,
        driver: str_field(v, "driver", id)?,
        budget: uint_field(v, "budget", id)?,
        search_seed: uint_field(v, "search_seed", id)?,
    })
}

/// Parse one request line into the typed envelope. Never panics on hostile
/// input; every failure mode maps to a [`ProtoError`] the caller turns
/// into an error response.
pub fn parse_request(line: &str) -> Result<Request, ProtoError> {
    let v = Json::parse(line)
        .map_err(|e| ProtoError::new("bad-json", format!("request is not valid JSON: {e}")))?;
    let Some(obj) = v.as_obj() else {
        return Err(ProtoError::new("bad-request", "request must be a JSON object"));
    };
    let id = v.get("id").clone();
    let cmd_str = v.get("cmd").as_str().ok_or_else(|| {
        ProtoError::new("bad-request", "missing string field 'cmd'").with_id(id.clone())
    })?;
    let cmd = Command::parse(cmd_str).ok_or_else(|| {
        ProtoError::new(
            "bad-request",
            format!(
                "unknown cmd '{cmd_str}' (want dse|des|flow|handshake|eval-candidate|\
                 eval-response|journal-pull|join|leave|cache-stats|metrics|ping|shutdown)"
            ),
        )
        .with_id(id.clone())
    })?;
    // a known verb with an unknown field is an error naming the field —
    // a typo must never silently change what gets evaluated
    let verb_allowed = verb_fields(cmd);
    let job_extras = cmd == Command::EvalResponse;
    for k in obj.keys() {
        let known = COMMON_FIELDS.contains(&k.as_str())
            || verb_allowed.contains(&k.as_str())
            || (job_extras && JOB_FIELDS.contains(&k.as_str()));
        if !known {
            return Err(ProtoError::new(
                "bad-request",
                format!("unknown field '{k}' for cmd '{cmd_str}' (see PROTOCOL.md)"),
            )
            .with_id(id)
            .with_detail(Json::obj(vec![("field", k.as_str().into())])));
        }
    }
    let common = CommonOpts {
        priority: uint_field(&v, "priority", &id)?,
        deadline_ms: uint_field(&v, "deadline_ms", &id)?,
    };
    let verb = match cmd {
        Command::Dse | Command::Des | Command::Flow => {
            VerbPayload::Job(parse_job_payload(&v, cmd_str, &id)?)
        }
        Command::EvalCandidate => {
            let ir = required_ir(&v, cmd_str, &id)?;
            let point_pipeline = str_field(&v, "point_pipeline", &id)?.ok_or_else(|| {
                ProtoError::new(
                    "bad-request",
                    "'eval-candidate' requires string field 'point_pipeline'",
                )
                .with_id(id.clone())
            })?;
            VerbPayload::EvalCandidate(EvalCandidatePayload {
                ir,
                platform: str_field(&v, "platform", &id)?,
                platform_json: json_field(&v, "platform_json"),
                objective_json: json_field(&v, "objective_json"),
                key: str_field(&v, "key", &id)?,
                point_label: str_field(&v, "point_label", &id)?,
                point_pipeline,
            })
        }
        Command::EvalResponse => {
            let job_cmd_str = str_field(&v, "job_cmd", &id)?.ok_or_else(|| {
                ProtoError::new("bad-request", "'eval-response' requires string field 'job_cmd'")
                    .with_id(id.clone())
            })?;
            let job_cmd = match Command::parse(&job_cmd_str) {
                Some(c @ (Command::Dse | Command::Des | Command::Flow)) => c,
                _ => {
                    return Err(ProtoError::new(
                        "bad-request",
                        format!("'job_cmd' must be dse|des|flow, got '{job_cmd_str}'"),
                    )
                    .with_id(id));
                }
            };
            VerbPayload::EvalResponse(EvalResponsePayload {
                job_cmd,
                key: str_field(&v, "key", &id)?,
                job: parse_job_payload(&v, cmd_str, &id)?,
            })
        }
        Command::Handshake => {
            let capabilities = match v.get("capabilities") {
                Json::Null => None,
                j => {
                    let arr = j.as_arr().ok_or_else(|| {
                        ProtoError::new("bad-request", "'capabilities' must be a string array")
                            .with_id(id.clone())
                    })?;
                    let mut caps = Vec::with_capacity(arr.len());
                    for c in arr {
                        let s = c.as_str().ok_or_else(|| {
                            ProtoError::new(
                                "bad-request",
                                "'capabilities' entries must be strings",
                            )
                            .with_id(id.clone())
                        })?;
                        caps.push(s.to_string());
                    }
                    Some(caps)
                }
            };
            VerbPayload::Handshake(HandshakePayload {
                proto_version: uint_field(&v, "proto_version", &id)?,
                shard_map: json_field(&v, "shard_map"),
                capabilities,
            })
        }
        Command::JournalPull => {
            let shard = match v.get("shard") {
                Json::Null => None,
                j => {
                    let index = j.get("index").as_u64();
                    let total = j.get("total").as_u64();
                    match (index, total) {
                        (Some(i), Some(t)) if t >= 1 && i < t => Some((i, t)),
                        _ => {
                            return Err(ProtoError::new(
                                "bad-request",
                                "'shard' must be {\"index\": I, \"total\": T} with I < T",
                            )
                            .with_id(id));
                        }
                    }
                }
            };
            VerbPayload::JournalPull(JournalPullPayload {
                cursor: uint_field(&v, "cursor", &id)?.unwrap_or(0),
                limit: uint_field(&v, "limit", &id)?,
                shard,
            })
        }
        Command::Join | Command::Leave => {
            let worker = str_field(&v, "worker", &id)?.ok_or_else(|| {
                ProtoError::new(
                    "bad-request",
                    format!("cmd '{cmd_str}' requires string field 'worker'"),
                )
                .with_id(id.clone())
            })?;
            VerbPayload::Membership(MembershipPayload { worker })
        }
        Command::CacheStats | Command::Metrics | Command::Ping | Command::Shutdown => {
            VerbPayload::Control
        }
    };
    Ok(Request { cmd, id, common, verb })
}

fn push_opt_str(out: &mut Vec<(&'static str, Json)>, k: &'static str, v: &Option<String>) {
    if let Some(s) = v {
        out.push((k, s.as_str().into()));
    }
}

fn push_opt_json(out: &mut Vec<(&'static str, Json)>, k: &'static str, v: &Option<Json>) {
    if let Some(j) = v {
        out.push((k, j.clone()));
    }
}

fn push_opt_uint(out: &mut Vec<(&'static str, Json)>, k: &'static str, v: &Option<u64>) {
    if let Some(n) = v {
        out.push((k, (*n).into()));
    }
}

fn push_job_fields(out: &mut Vec<(&'static str, Json)>, j: &JobPayload) {
    out.push(("ir", j.ir.as_str().into()));
    push_opt_str(out, "platform", &j.platform);
    push_opt_json(out, "platform_json", &j.platform_json);
    if let Some(ps) = &j.platforms {
        out.push(("platforms", ps.clone().into()));
    }
    push_opt_str(out, "pipeline", &j.pipeline);
    push_opt_str(out, "objective", &j.objective);
    push_opt_str(out, "scenario", &j.scenario);
    push_opt_json(out, "scenario_json", &j.scenario_json);
    push_opt_str(out, "slo", &j.slo);
    push_opt_str(out, "autoscale", &j.autoscale);
    push_opt_uint(out, "seed", &j.seed);
    if let Some(fs) = &j.factors {
        out.push(("factors", fs.clone().into()));
    }
    push_opt_str(out, "driver", &j.driver);
    push_opt_uint(out, "budget", &j.budget);
    push_opt_uint(out, "search_seed", &j.search_seed);
}

/// Inverse of [`parse_request`]: encode a request back to its wire object.
/// Every documented field survives the round trip (`parse(encode(r)) == r`
/// up to already-applied normalization) — this is what the coordinator uses
/// to forward a job to its response-shard owner verbatim.
pub fn encode_request(req: &Request) -> Json {
    let mut out: Vec<(&'static str, Json)> = vec![("cmd", req.cmd.as_str().into())];
    if req.id != Json::Null {
        out.push(("id", req.id.clone()));
    }
    push_opt_uint(&mut out, "priority", &req.common.priority);
    push_opt_uint(&mut out, "deadline_ms", &req.common.deadline_ms);
    match &req.verb {
        VerbPayload::Job(j) => push_job_fields(&mut out, j),
        VerbPayload::EvalCandidate(c) => {
            out.push(("ir", c.ir.as_str().into()));
            push_opt_str(&mut out, "platform", &c.platform);
            push_opt_json(&mut out, "platform_json", &c.platform_json);
            push_opt_json(&mut out, "objective_json", &c.objective_json);
            push_opt_str(&mut out, "key", &c.key);
            push_opt_str(&mut out, "point_label", &c.point_label);
            out.push(("point_pipeline", c.point_pipeline.as_str().into()));
        }
        VerbPayload::EvalResponse(r) => {
            out.push(("job_cmd", r.job_cmd.as_str().into()));
            push_opt_str(&mut out, "key", &r.key);
            push_job_fields(&mut out, &r.job);
        }
        VerbPayload::Handshake(h) => {
            push_opt_uint(&mut out, "proto_version", &h.proto_version);
            push_opt_json(&mut out, "shard_map", &h.shard_map);
            if let Some(caps) = &h.capabilities {
                out.push(("capabilities", caps.clone().into()));
            }
        }
        VerbPayload::JournalPull(p) => {
            out.push(("cursor", p.cursor.into()));
            push_opt_uint(&mut out, "limit", &p.limit);
            if let Some((index, total)) = p.shard {
                out.push((
                    "shard",
                    Json::obj(vec![("index", index.into()), ("total", total.into())]),
                ));
            }
        }
        VerbPayload::Membership(m) => out.push(("worker", m.worker.as_str().into())),
        VerbPayload::Control => {}
    }
    Json::obj(out)
}

/// Serialize a success response.
pub fn ok_response(
    id: &Json,
    cmd: Command,
    cached: bool,
    key: Option<&str>,
    result: Json,
) -> String {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("id", id.clone()),
        ("cmd", cmd.as_str().into()),
        ("cached", cached.into()),
        ("result", result),
    ];
    if let Some(k) = key {
        fields.push(("key", k.into()));
    }
    Json::obj(fields).to_string()
}

/// Serialize an error response: the one shape every failure path answers
/// with — `{"ok": false, "id": ..., "error": {"code", "message", "id"?,
/// "detail"?}}` (`id` repeated inside `error` when present, so error
/// objects stay self-describing when extracted from a log).
pub fn error_response(err: &ProtoError) -> String {
    let mut e =
        vec![("code", err.code.into()), ("message", Json::Str(err.message.clone()))];
    if err.id != Json::Null {
        e.push(("id", err.id.clone()));
    }
    if let Some(d) = &err.detail {
        e.push(("detail", d.clone()));
    }
    Json::obj(vec![("ok", Json::Bool(false)), ("id", err.id.clone()), ("error", Json::obj(e))])
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_dse_request() {
        let r = parse_request(r#"{"cmd": "dse", "ir": "x", "id": 3}"#).unwrap();
        assert_eq!(r.cmd, Command::Dse);
        assert_eq!(r.id, Json::Num(3.0));
        let j = r.job().expect("dse carries a job payload");
        assert_eq!(j.ir, "x");
        assert_eq!(j.factors, None);
        assert_eq!(j.seed, None);
        assert_eq!(j.driver, None);
        assert_eq!(j.budget, None);
        assert_eq!(j.search_seed, None);
    }

    #[test]
    fn driver_and_budget_fields_round_trip() {
        let r = parse_request(
            r#"{"cmd": "dse", "ir": "x", "driver": "successive-halving", "budget": 3,
                "search_seed": 9, "factors": [4, 2, 2]}"#,
        )
        .unwrap();
        let j = r.job().unwrap();
        assert_eq!(j.driver.as_deref(), Some("successive-halving"));
        assert_eq!(j.budget, Some(3));
        assert_eq!(j.search_seed, Some(9));
        // factors arrive normalized: sorted, deduplicated
        assert_eq!(j.factors, Some(vec![2, 4]));
        // bad budget types are structured errors
        let e = parse_request(r#"{"cmd": "dse", "ir": "x", "budget": -1}"#).unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert!(e.message.contains("budget"), "{}", e.message);
    }

    #[test]
    fn empty_factor_list_is_rejected() {
        let e = parse_request(r#"{"cmd": "dse", "ir": "x", "factors": [], "id": 5}"#)
            .unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert!(e.message.contains("factors"), "{}", e.message);
        assert_eq!(e.id, Json::Num(5.0), "id survives into the error");
        // zero factors are rejected too
        assert!(parse_request(r#"{"cmd": "dse", "ir": "x", "factors": [0]}"#).is_err());
    }

    #[test]
    fn platform_axis_parses_and_validates() {
        let r = parse_request(
            r#"{"cmd": "dse", "ir": "x", "platforms": ["u280", "generic-ddr"]}"#,
        )
        .unwrap();
        let j = r.job().unwrap();
        assert_eq!(j.platforms, Some(vec!["u280".to_string(), "generic-ddr".to_string()]));
        // empty lists, duplicates and non-string entries are structured errors
        let e = parse_request(r#"{"cmd": "dse", "ir": "x", "platforms": [], "id": 7}"#)
            .unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert!(e.message.contains("platforms"), "{}", e.message);
        assert_eq!(e.id, Json::Num(7.0));
        let e = parse_request(r#"{"cmd": "dse", "ir": "x", "platforms": ["u280", "u280"]}"#)
            .unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert!(e.message.contains("more than once"), "{}", e.message);
        assert!(parse_request(r#"{"cmd": "dse", "ir": "x", "platforms": [1]}"#).is_err());
        assert!(parse_request(r#"{"cmd": "dse", "ir": "x", "platforms": "u280"}"#).is_err());
    }

    #[test]
    fn rejects_garbage_with_codes() {
        assert_eq!(parse_request("not json").unwrap_err().code, "bad-json");
        assert_eq!(parse_request("[1, 2]").unwrap_err().code, "bad-request");
        assert_eq!(parse_request(r#"{"cmd": "frobnicate"}"#).unwrap_err().code, "bad-request");
        // job commands require IR; the id still makes it into the error
        let e = parse_request(r#"{"cmd": "dse", "id": "j1"}"#).unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert_eq!(e.id, Json::Str("j1".into()));
        // bad field types
        assert!(parse_request(r#"{"cmd": "dse", "ir": "x", "seed": -1}"#).is_err());
        assert!(parse_request(r#"{"cmd": "dse", "ir": "x", "factors": "two"}"#).is_err());
        assert!(parse_request(r#"{"cmd": "dse", "ir": "x", "pipeline": 5}"#).is_err());
    }

    #[test]
    fn unknown_fields_on_known_verbs_are_named() {
        let e = parse_request(r#"{"cmd": "dse", "ir": "x", "factrs": [2], "id": 9}"#)
            .unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert!(e.message.contains("'factrs'"), "{}", e.message);
        assert!(e.message.contains("PROTOCOL.md"), "{}", e.message);
        assert_eq!(e.id, Json::Num(9.0));
        assert_eq!(e.detail.as_ref().unwrap().get("field").as_str(), Some("factrs"));
        // verb fields do not leak across verbs: 'worker' is join/leave-only
        let e = parse_request(r#"{"cmd": "ping", "worker": "h:1"}"#).unwrap_err();
        assert_eq!(e.detail.as_ref().unwrap().get("field").as_str(), Some("worker"));
        // ...and job fields are not valid on handshake
        assert!(parse_request(r#"{"cmd": "handshake", "proto_version": 3, "seed": 1}"#).is_err());
        // common knobs are accepted everywhere
        assert!(parse_request(r#"{"cmd": "cache-stats", "priority": 1}"#).is_ok());
    }

    #[test]
    fn responses_round_trip_as_json() {
        let ok = ok_response(&Json::Num(1.0), Command::Ping, false, Some("abc"), Json::Null);
        let v = Json::parse(&ok).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(true));
        assert_eq!(v.get("key").as_str(), Some("abc"));
        let err = error_response(&ProtoError::new("bad-json", "nope"));
        let v = Json::parse(&err).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(false));
        assert_eq!(v.get("error").get("code").as_str(), Some("bad-json"));
        assert_eq!(v.get("error").get("id"), &Json::Null, "no id when none was recoverable");
        // with id + detail, the error object is self-describing
        let err = error_response(
            &ProtoError::new("bad-request", "unknown field")
                .with_id(Json::Num(7.0))
                .with_detail(Json::obj(vec![("field", "x".into())])),
        );
        let v = Json::parse(&err).unwrap();
        assert_eq!(v.get("id").as_u64(), Some(7));
        assert_eq!(v.get("error").get("id").as_u64(), Some(7));
        assert_eq!(v.get("error").get("detail").get("field").as_str(), Some("x"));
        // single line (newline-delimited framing)
        assert!(!ok.contains('\n') && !err.contains('\n'));
    }

    #[test]
    fn traffic_fields_parse_and_validate() {
        let r = parse_request(
            r#"{"cmd": "dse", "ir": "x", "objective": "slo-score",
                "slo": "interactive=p99<5", "autoscale": "0.001:256:16:1:4",
                "priority": 3, "deadline_ms": 5000,
                "scenario_json": {"name": "t", "arrivals": {"kind": "closed", "jobs": "4"}}}"#,
        )
        .unwrap();
        assert_eq!(r.common.priority, Some(3));
        assert_eq!(r.common.deadline_ms, Some(5000));
        let j = r.job().unwrap();
        assert_eq!(j.slo.as_deref(), Some("interactive=p99<5"));
        assert_eq!(j.autoscale.as_deref(), Some("0.001:256:16:1:4"));
        let sj = j.scenario_json.as_ref().expect("scenario_json parsed");
        assert_eq!(sj.get("arrivals").get("kind").as_str(), Some("closed"));
        // absent fields default to None; bad types are structured errors
        let r = parse_request(r#"{"cmd": "ping"}"#).unwrap();
        assert_eq!((r.common.priority, r.common.deadline_ms), (None, None));
        assert_eq!(r.verb, VerbPayload::Control);
        let e = parse_request(r#"{"cmd": "dse", "ir": "x", "priority": -2}"#).unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert!(e.message.contains("priority"), "{}", e.message);
        assert!(parse_request(r#"{"cmd": "dse", "ir": "x", "deadline_ms": 0.5}"#).is_err());
    }

    #[test]
    fn non_job_commands_need_no_ir() {
        for cmd in ["cache-stats", "metrics", "ping", "shutdown", "handshake", "journal-pull"] {
            let r = parse_request(&format!(r#"{{"cmd": "{cmd}"}}"#)).unwrap();
            assert!(!r.cmd.is_job());
        }
    }

    #[test]
    fn handshake_and_eval_candidate_fields_parse() {
        let r = parse_request(
            r#"{"cmd": "handshake", "proto_version": 1,
                "shard_map": {"index": 0, "total": 2}}"#,
        )
        .unwrap();
        assert_eq!(r.cmd, Command::Handshake);
        let VerbPayload::Handshake(h) = &r.verb else { panic!("handshake payload") };
        assert_eq!(h.proto_version, Some(1));
        assert!(h.shard_map.is_some());
        assert_eq!(h.capabilities, None);

        let r = parse_request(
            r#"{"cmd": "handshake", "proto_version": 3, "capabilities": ["journal-gossip"]}"#,
        )
        .unwrap();
        let VerbPayload::Handshake(h) = &r.verb else { panic!("handshake payload") };
        assert_eq!(h.capabilities.as_deref(), Some(&["journal-gossip".to_string()][..]));
        assert!(parse_request(r#"{"cmd": "handshake", "capabilities": [1]}"#).is_err());

        let r = parse_request(
            r#"{"cmd": "eval-candidate", "ir": "x", "point_label": "full(x2)",
                "point_pipeline": "sanitize", "key": "00ff",
                "objective_json": {"kind": "analytic"}}"#,
        )
        .unwrap();
        assert!(r.cmd.is_job(), "eval-candidate goes through the job queue");
        let VerbPayload::EvalCandidate(c) = &r.verb else { panic!("eval-candidate payload") };
        assert_eq!(c.point_label.as_deref(), Some("full(x2)"));
        assert_eq!(c.point_pipeline, "sanitize");
        assert_eq!(c.key.as_deref(), Some("00ff"));
        let obj = c.objective_json.as_ref().expect("objective_json parsed");
        assert_eq!(obj.get("kind").as_str(), Some("analytic"));

        // a missing point_pipeline is a structured parse error, id intact
        let e = parse_request(r#"{"cmd": "eval-candidate", "ir": "x", "id": 4}"#).unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert_eq!(e.id, Json::Num(4.0));
        // ...and so is a missing ir (eval-candidate is a job command)
        let e = parse_request(r#"{"cmd": "eval-candidate", "point_pipeline": "x"}"#).unwrap_err();
        assert_eq!(e.code, "bad-request");
    }

    #[test]
    fn fabric_verbs_parse_and_validate() {
        // eval-response wraps a whole job plus routing metadata
        let r = parse_request(
            r#"{"cmd": "eval-response", "job_cmd": "dse", "ir": "x", "seed": 9,
                "key": "00ff", "id": 2}"#,
        )
        .unwrap();
        assert!(r.cmd.is_job(), "eval-response goes through the job queue");
        let VerbPayload::EvalResponse(er) = &r.verb else { panic!("eval-response payload") };
        assert_eq!(er.job_cmd, Command::Dse);
        assert_eq!(er.key.as_deref(), Some("00ff"));
        assert_eq!(er.job.seed, Some(9));
        assert_eq!(r.job().unwrap().ir, "x", "job() reaches the inner payload");
        let e = parse_request(r#"{"cmd": "eval-response", "ir": "x"}"#).unwrap_err();
        assert!(e.message.contains("job_cmd"), "{}", e.message);
        let e = parse_request(r#"{"cmd": "eval-response", "job_cmd": "ping", "ir": "x"}"#)
            .unwrap_err();
        assert!(e.message.contains("dse|des|flow"), "{}", e.message);

        // journal-pull defaults + shard filter validation
        let r = parse_request(r#"{"cmd": "journal-pull"}"#).unwrap();
        let VerbPayload::JournalPull(p) = &r.verb else { panic!("journal-pull payload") };
        assert_eq!((p.cursor, p.limit, p.shard), (0, None, None));
        let r = parse_request(
            r#"{"cmd": "journal-pull", "cursor": 7, "limit": 64,
                "shard": {"index": 1, "total": 2}}"#,
        )
        .unwrap();
        let VerbPayload::JournalPull(p) = &r.verb else { panic!("journal-pull payload") };
        assert_eq!((p.cursor, p.limit, p.shard), (7, Some(64), Some((1, 2))));
        let oob = r#"{"cmd": "journal-pull", "shard": {"index": 2, "total": 2}}"#;
        assert!(parse_request(oob).is_err(), "shard index must be < total");
        assert!(parse_request(r#"{"cmd": "journal-pull", "cursor": -1}"#).is_err());

        // join/leave need a worker address
        let r = parse_request(r#"{"cmd": "join", "worker": "h3:7900"}"#).unwrap();
        let VerbPayload::Membership(m) = &r.verb else { panic!("membership payload") };
        assert_eq!(m.worker, "h3:7900");
        let e = parse_request(r#"{"cmd": "leave"}"#).unwrap_err();
        assert!(e.message.contains("worker"), "{}", e.message);
    }

    #[test]
    fn every_documented_field_survives_encode_then_parse() {
        // one representative line per verb, every field populated
        let lines = [
            r#"{"cmd": "dse", "id": 1, "priority": 2, "deadline_ms": 100, "ir": "x",
                "platform": "u280", "pipeline": "sanitize", "objective": "des-score",
                "scenario": "closed:4", "slo": "i=p99<5", "autoscale": "1:2:1:1:4",
                "seed": 42, "factors": [2, 4], "driver": "random", "budget": 3,
                "search_seed": 9}"#,
            r#"{"cmd": "des", "ir": "x", "platforms": ["u280", "generic-ddr"],
                "scenario_json": {"name": "t"}}"#,
            r#"{"cmd": "flow", "ir": "x", "platform_json": {"name": "p"}}"#,
            r#"{"cmd": "eval-candidate", "ir": "x", "platform": "u280",
                "platform_json": {"name": "p"}, "objective_json": {"kind": "analytic"},
                "key": "00ff", "point_label": "full(x2)", "point_pipeline": "sanitize"}"#,
            r#"{"cmd": "eval-response", "id": "r1", "job_cmd": "des", "key": "00ff",
                "ir": "x", "scenario": "closed:4", "seed": 7}"#,
            r#"{"cmd": "handshake", "proto_version": 3, "capabilities": ["journal-gossip"],
                "shard_map": {"index": 0, "total": 2, "epoch": 1, "workers": ["a:1", "b:2"]}}"#,
            r#"{"cmd": "journal-pull", "cursor": 5, "limit": 16,
                "shard": {"index": 0, "total": 2}}"#,
            r#"{"cmd": "join", "worker": "h3:7900"}"#,
            r#"{"cmd": "leave", "worker": "h2:7900"}"#,
            r#"{"cmd": "cache-stats"}"#,
            r#"{"cmd": "metrics"}"#,
            r#"{"cmd": "ping", "id": 9}"#,
            r#"{"cmd": "shutdown"}"#,
        ];
        for line in lines {
            let parsed = parse_request(line).unwrap_or_else(|e| panic!("{line}: {}", e.message));
            let encoded = encode_request(&parsed).to_string();
            let reparsed = parse_request(&encoded)
                .unwrap_or_else(|e| panic!("re-parse {encoded}: {}", e.message));
            assert_eq!(reparsed, parsed, "round trip changed the request: {encoded}");
        }
    }
}
