//! The `olympus serve` wire protocol: newline-delimited JSON over TCP.
//!
//! One request per line, one response line per request, in order. A
//! malformed line gets a structured `{"ok": false, "error": {...}}` response
//! and the connection stays open — clients never have to guess why a socket
//! died. A request line longer than
//! [`MAX_REQUEST_BYTES`](crate::service::MAX_REQUEST_BYTES) is answered
//! with a `too-large` error; the server drains (never buffers) the rest of
//! the oversized line and the connection stays open.
//!
//! Requests:
//!
//! ```json
//! {"cmd": "dse",  "ir": "<mlir>", "platform": "u280", "objective": "des-score",
//!  "scenario": "closed:4", "seed": 42, "factors": [2, 4],
//!  "driver": "successive-halving", "budget": 3, "id": 1}
//! {"cmd": "dse",  "ir": "<mlir>", "objective": "slo-score",
//!  "slo": "interactive=p99<5", "autoscale": "0.001:256:16:1:4",
//!  "scenario_json": {"name": "trace-3job-...", "arrivals": {...}},
//!  "priority": 2, "deadline_ms": 5000}
//! {"cmd": "dse",  "ir": "<mlir>", "platforms": ["u280", "generic-ddr"], "factors": [2]}
//! {"cmd": "des",  "ir": "<mlir>", "pipeline": "sanitize, iris, channel-reassign",
//!  "scenario": "poisson:1000:20", "seed": 7}
//! {"cmd": "flow", "ir": "<mlir>", "platform": "u280"}
//! {"cmd": "handshake", "proto_version": 2,
//!  "shard_map": {"index": 0, "total": 2, "workers": ["h1:7900", "h2:7900"]}}
//! {"cmd": "eval-candidate", "ir": "<mlir>", "platform_json": {...},
//!  "objective_json": {"kind": "analytic"}, "point_label": "full(x4)",
//!  "point_pipeline": "sanitize, ...", "key": "<32-hex>"}
//! {"cmd": "cache-stats"}
//! {"cmd": "ping"}
//! {"cmd": "shutdown"}
//! ```
//!
//! `handshake` and `eval-candidate` are the distributed-evaluation verbs
//! (see [`crate::service::remote`]): a coordinator handshakes each
//! `olympus worker` with the protocol version and the worker's shard of
//! the consistent-hash key space, then routes individual candidate
//! evaluations to the shard owner. A version mismatch is a structured
//! `proto-mismatch` error; a malformed or truncated shard map is a
//! structured `bad-request` — never a dropped connection. `eval-candidate`
//! carries the full inline platform/objective specs (not names), so the
//! worker recomputes the same content-addressed candidate key and
//! cross-checks it against `key` (`key-mismatch` on skew).
//!
//! `platform` is a builtin name; `platform_json` may carry a full inline
//! platform spec object instead. `platforms` (an array of two or more
//! builtin names, e.g. `["u280", "generic-ddr"]`) makes the platform a
//! search axis for `dse`/`des`: every strategy is scored on every listed
//! platform and the flow lowers onto the winner; it is mutually exclusive
//! with `platform`/`platform_json` and with an explicit `pipeline`, and
//! entries must be builtin names (custom boards submit a single
//! `platform_json`). `id` (any JSON value) is echoed back.
//! `driver` selects the search policy (`exhaustive` default | `random` |
//! `successive-halving` | `iterative`) with `budget` / `search_seed` as its
//! knobs; driver and budget are part of the response cache key, so a
//! budgeted search never shares an address with an exhaustive one.
//! `factors` must be a non-empty array of integers >= 1 when present; it is
//! normalized (sorted, deduplicated) before evaluation and cache keying.
//!
//! Traffic fields: `scenario_json` carries a full inline scenario
//! ([`crate::des::WorkloadScenario::to_json`]) — the way `submit` ships a
//! local `trace:<file>` to a daemon that cannot see the file; it overrides
//! `scenario`. `slo` (an SLO spec, job commands) selects the `slo-score`
//! objective's targets; `autoscale` (a policy spec) turns on elastic
//! replicas inside the DES. `priority` (integer, default 0) orders the
//! request in the serve queue ahead of lower-priority jobs; `deadline_ms`
//! sheds it with a `deadline-expired` error if it is still queued when the
//! deadline lapses. Per-priority queue-wait histograms land in the
//! `metrics` verb (`olympus stats --raw`).
//!
//! Responses: `{"ok": true, "id": ..., "cached": bool, "key": "<32-hex>",
//! "result": {...}}` — `key` is the content-address of the evaluation
//! (stable across servers), `cached` whether this answer skipped
//! evaluation (including answers replayed from a `--cache-dir` journal by
//! a restarted daemon). `cache-stats` reports, per cache tier, the memory
//! counters (`entries`/`hits`/`misses`/`coalesced`/`evicted`) plus the
//! disk-tier counters `disk_loaded` (journal records decoded at startup),
//! `disk_persisted` (records written through by this process) and
//! `disk_corrupt_skipped` (torn or undecodable records dropped).

use crate::util::Json;

/// Version of the distributed-evaluation protocol. A coordinator announces
/// it in every `handshake`; a worker built from a different version answers
/// `proto-mismatch` instead of silently computing keys the coordinator
/// would disagree with. Bump whenever the handshake, the `eval-candidate`
/// fields, or any wire codec they carry changes shape.
///
/// v2: traffic fields (`scenario_json`, `slo`, `autoscale`, `priority`,
/// `deadline_ms`), the `slo-score` objective and the trace/diurnal
/// scenario codecs.
pub const PROTO_VERSION: u64 = 2;

/// What a request asks the service to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Command {
    /// Full DSE over the strategy table; returns the decision table + best.
    Dse,
    /// Flow + discrete-event replay of a scenario.
    Des,
    /// Full flow report (analyses + architecture + emission summary).
    Flow,
    /// Coordinator -> worker: version check + shard assignment.
    Handshake,
    /// Coordinator -> worker: evaluate one DSE candidate, answered through
    /// the worker's candidate cache (memory + `--cache-dir` journal).
    EvalCandidate,
    /// Evaluation-cache counters.
    CacheStats,
    /// Observability snapshot: per-verb request counters, latency
    /// histograms, DES throughput (`olympus stats` fans this out).
    Metrics,
    /// Liveness probe.
    Ping,
    /// Stop accepting connections and drain.
    Shutdown,
}

impl Command {
    pub fn parse(s: &str) -> Option<Command> {
        match s {
            "dse" => Some(Command::Dse),
            "des" => Some(Command::Des),
            "flow" => Some(Command::Flow),
            "handshake" => Some(Command::Handshake),
            "eval-candidate" => Some(Command::EvalCandidate),
            "cache-stats" => Some(Command::CacheStats),
            "metrics" => Some(Command::Metrics),
            "ping" => Some(Command::Ping),
            "shutdown" => Some(Command::Shutdown),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Command::Dse => "dse",
            Command::Des => "des",
            Command::Flow => "flow",
            Command::Handshake => "handshake",
            Command::EvalCandidate => "eval-candidate",
            Command::CacheStats => "cache-stats",
            Command::Metrics => "metrics",
            Command::Ping => "ping",
            Command::Shutdown => "shutdown",
        }
    }

    /// Does this command evaluate a design (and therefore go through the
    /// job queue + cache)?
    pub fn is_job(self) -> bool {
        matches!(self, Command::Dse | Command::Des | Command::Flow | Command::EvalCandidate)
    }
}

/// A parsed request.
#[derive(Debug, Clone)]
pub struct Request {
    pub cmd: Command,
    /// Echoed verbatim in the response (`Json::Null` when absent).
    pub id: Json,
    /// Olympus MLIR text (required for job commands).
    pub ir: Option<String>,
    /// Builtin platform name (default "u280").
    pub platform: Option<String>,
    /// Full inline platform spec (overrides `platform`).
    pub platform_json: Option<Json>,
    /// Cross-platform search axis: two or more *builtin* platform names
    /// (the wire carries names, not specs). The DSE scores every strategy
    /// on every listed platform and the flow lowers onto the winner.
    /// Mutually exclusive with `platform`/`platform_json` (executor-
    /// checked); duplicates and non-string entries are parse errors.
    pub platforms: Option<Vec<String>>,
    /// Explicit pass pipeline (skips DSE for `des`/`flow`).
    pub pipeline: Option<String>,
    /// "analytic" (default), "des-score" or "slo-score".
    pub objective: Option<String>,
    /// Workload scenario spec (`closed:N` | `poisson:HZ:N` |
    /// `bursty:HZ:ON:OFF:N` | `diurnal:HZ:AMPL:PERIOD:N`).
    pub scenario: Option<String>,
    /// Full inline scenario ([`crate::des::WorkloadScenario::to_json`]);
    /// overrides `scenario`. How `submit` ships a local `trace:<file>` to a
    /// daemon without a shared filesystem.
    pub scenario_json: Option<Json>,
    /// SLO spec (`CLASS=p99<MS[,...]`) for the `slo-score` objective.
    pub slo: Option<String>,
    /// Autoscale policy spec (`INTERVAL_S:UP:DOWN:MIN:MAX`) enabling
    /// elastic replicas inside the DES.
    pub autoscale: Option<String>,
    /// Serve-queue priority of this request (default 0; higher jumps
    /// ahead of lower-priority queued jobs).
    pub priority: Option<u64>,
    /// Queue deadline, ms: a job still waiting when it lapses is answered
    /// with a `deadline-expired` error instead of evaluated.
    pub deadline_ms: Option<u64>,
    /// DES seed (engine default when absent).
    pub seed: Option<u64>,
    /// Replication factors for DSE (absent = defaults). Normalized (sorted,
    /// deduplicated); an explicitly empty array is rejected.
    pub factors: Option<Vec<u64>>,
    /// Search driver name (absent = "exhaustive").
    pub driver: Option<String>,
    /// Candidate budget for budgeted drivers.
    pub budget: Option<u64>,
    /// Sampling seed for the `random` driver.
    pub search_seed: Option<u64>,
    /// Distributed-protocol version announced by a `handshake`.
    pub proto_version: Option<u64>,
    /// Raw shard-map object of a `handshake` (validated by the executor so
    /// malformed maps answer structured errors, not parse panics).
    pub shard_map: Option<Json>,
    /// Expected candidate key (32 hex digits) of an `eval-candidate`; the
    /// worker cross-checks it against the key it derives itself.
    pub key: Option<String>,
    /// Decision-table label of an `eval-candidate` point.
    pub point_label: Option<String>,
    /// Pass pipeline (or iterative tag) of an `eval-candidate` point.
    pub point_pipeline: Option<String>,
    /// Full objective spec of an `eval-candidate`
    /// ([`crate::passes::objective_to_json`]).
    pub objective_json: Option<Json>,
}

/// A protocol-level failure: structured error code + message, with the
/// request id when one was recoverable from the line.
#[derive(Debug, Clone)]
pub struct ProtoError {
    pub id: Json,
    pub code: &'static str,
    pub message: String,
}

impl ProtoError {
    pub fn new(code: &'static str, message: impl Into<String>) -> ProtoError {
        ProtoError { id: Json::Null, code, message: message.into() }
    }

    fn with_id(mut self, id: Json) -> ProtoError {
        self.id = id;
        self
    }
}

/// Parse one request line. Never panics on hostile input; every failure
/// mode maps to a [`ProtoError`] the caller turns into an error response.
pub fn parse_request(line: &str) -> Result<Request, ProtoError> {
    let v = Json::parse(line)
        .map_err(|e| ProtoError::new("bad-json", format!("request is not valid JSON: {e}")))?;
    if v.as_obj().is_none() {
        return Err(ProtoError::new("bad-request", "request must be a JSON object"));
    }
    let id = v.get("id").clone();
    let cmd_str = v
        .get("cmd")
        .as_str()
        .ok_or_else(|| {
            ProtoError::new("bad-request", "missing string field 'cmd'").with_id(id.clone())
        })?;
    let cmd = Command::parse(cmd_str).ok_or_else(|| {
        ProtoError::new(
            "bad-request",
            format!(
                "unknown cmd '{cmd_str}' (want dse|des|flow|handshake|eval-candidate|\
                 cache-stats|metrics|ping|shutdown)"
            ),
        )
        .with_id(id.clone())
    })?;
    let opt_str = |k: &str| v.get(k).as_str().map(|s| s.to_string());
    let ir = opt_str("ir");
    if cmd.is_job() && ir.is_none() {
        return Err(ProtoError::new(
            "bad-request",
            format!("cmd '{cmd_str}' requires string field 'ir'"),
        )
        .with_id(id));
    }
    // non-negative integer fields share one parser ('seed', 'budget', ...)
    let uint_field = |k: &'static str| -> Result<Option<u64>, ProtoError> {
        match v.get(k) {
            Json::Null => Ok(None),
            j => j
                .as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .map(|n| Some(n as u64))
                .ok_or_else(|| {
                    ProtoError::new("bad-request", format!("'{k}' must be a non-negative integer"))
                        .with_id(id.clone())
                }),
        }
    };
    let seed = uint_field("seed")?;
    let budget = uint_field("budget")?;
    let search_seed = uint_field("search_seed")?;
    let proto_version = uint_field("proto_version")?;
    let priority = uint_field("priority")?;
    let deadline_ms = uint_field("deadline_ms")?;
    if cmd == Command::EvalCandidate && v.get("point_pipeline").as_str().is_none() {
        return Err(ProtoError::new(
            "bad-request",
            "'eval-candidate' requires string field 'point_pipeline'",
        )
        .with_id(id));
    }
    let shard_map = match v.get("shard_map") {
        Json::Null => None,
        j => Some(j.clone()),
    };
    let objective_json = match v.get("objective_json") {
        Json::Null => None,
        j => Some(j.clone()),
    };
    let factors = match v.get("factors") {
        Json::Null => None,
        j => {
            let arr = j.as_arr().ok_or_else(|| {
                ProtoError::new("bad-request", "'factors' must be an array of integers")
                    .with_id(id.clone())
            })?;
            if arr.is_empty() {
                return Err(ProtoError::new(
                    "bad-request",
                    "'factors' must not be empty (omit the field for the default sweep)",
                )
                .with_id(id));
            }
            let mut out = Vec::with_capacity(arr.len());
            for f in arr {
                let n = f.as_f64().filter(|n| *n >= 1.0 && n.fract() == 0.0).ok_or_else(|| {
                    ProtoError::new("bad-request", "'factors' entries must be integers >= 1")
                        .with_id(id.clone())
                })?;
                out.push(n as u64);
            }
            // dedupe/sort so [4, 2, 2] and [2, 4] share a cache address
            let normalized = crate::search::normalize_factors(&out)
                .map_err(|e| ProtoError::new("bad-request", e).with_id(id.clone()))?;
            Some(normalized)
        }
    };
    let platform_json = match v.get("platform_json") {
        Json::Null => None,
        j => Some(j.clone()),
    };
    let platforms = match v.get("platforms") {
        Json::Null => None,
        j => {
            let arr = j.as_arr().ok_or_else(|| {
                ProtoError::new("bad-request", "'platforms' must be an array of platform names")
                    .with_id(id.clone())
            })?;
            if arr.is_empty() {
                return Err(ProtoError::new(
                    "bad-request",
                    "'platforms' must not be empty (omit the field for a single platform)",
                )
                .with_id(id));
            }
            let mut names = Vec::with_capacity(arr.len());
            let mut seen = std::collections::BTreeSet::new();
            for n in arr {
                let name = n.as_str().ok_or_else(|| {
                    ProtoError::new("bad-request", "'platforms' entries must be strings")
                        .with_id(id.clone())
                })?;
                if !seen.insert(name.to_string()) {
                    return Err(ProtoError::new(
                        "bad-request",
                        format!("'platforms' lists platform '{name}' more than once"),
                    )
                    .with_id(id));
                }
                names.push(name.to_string());
            }
            Some(names)
        }
    };
    let scenario_json = match v.get("scenario_json") {
        Json::Null => None,
        j => Some(j.clone()),
    };
    Ok(Request {
        cmd,
        id,
        ir,
        platform: opt_str("platform"),
        platform_json,
        platforms,
        pipeline: opt_str("pipeline"),
        objective: opt_str("objective"),
        scenario: opt_str("scenario"),
        scenario_json,
        slo: opt_str("slo"),
        autoscale: opt_str("autoscale"),
        priority,
        deadline_ms,
        seed,
        factors,
        driver: opt_str("driver"),
        budget,
        search_seed,
        proto_version,
        shard_map,
        key: opt_str("key"),
        point_label: opt_str("point_label"),
        point_pipeline: opt_str("point_pipeline"),
        objective_json,
    })
}

/// Serialize a success response.
pub fn ok_response(
    id: &Json,
    cmd: Command,
    cached: bool,
    key: Option<&str>,
    result: Json,
) -> String {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("id", id.clone()),
        ("cmd", cmd.as_str().into()),
        ("cached", cached.into()),
        ("result", result),
    ];
    if let Some(k) = key {
        fields.push(("key", k.into()));
    }
    Json::obj(fields).to_string()
}

/// Serialize an error response.
pub fn error_response(err: &ProtoError) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("id", err.id.clone()),
        (
            "error",
            Json::obj(vec![("code", err.code.into()), ("message", err.message.as_str().into())]),
        ),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_dse_request() {
        let r = parse_request(r#"{"cmd": "dse", "ir": "x", "id": 3}"#).unwrap();
        assert_eq!(r.cmd, Command::Dse);
        assert_eq!(r.ir.as_deref(), Some("x"));
        assert_eq!(r.id, Json::Num(3.0));
        assert_eq!(r.factors, None);
        assert_eq!(r.seed, None);
        assert_eq!(r.driver, None);
        assert_eq!(r.budget, None);
        assert_eq!(r.search_seed, None);
    }

    #[test]
    fn driver_and_budget_fields_round_trip() {
        let r = parse_request(
            r#"{"cmd": "dse", "ir": "x", "driver": "successive-halving", "budget": 3,
                "search_seed": 9, "factors": [4, 2, 2]}"#,
        )
        .unwrap();
        assert_eq!(r.driver.as_deref(), Some("successive-halving"));
        assert_eq!(r.budget, Some(3));
        assert_eq!(r.search_seed, Some(9));
        // factors arrive normalized: sorted, deduplicated
        assert_eq!(r.factors, Some(vec![2, 4]));
        // bad budget types are structured errors
        let e = parse_request(r#"{"cmd": "dse", "ir": "x", "budget": -1}"#).unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert!(e.message.contains("budget"), "{}", e.message);
    }

    #[test]
    fn empty_factor_list_is_rejected() {
        let e = parse_request(r#"{"cmd": "dse", "ir": "x", "factors": [], "id": 5}"#)
            .unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert!(e.message.contains("factors"), "{}", e.message);
        assert_eq!(e.id, Json::Num(5.0), "id survives into the error");
        // zero factors are rejected too
        assert!(parse_request(r#"{"cmd": "dse", "ir": "x", "factors": [0]}"#).is_err());
    }

    #[test]
    fn platform_axis_parses_and_validates() {
        let r = parse_request(
            r#"{"cmd": "dse", "ir": "x", "platforms": ["u280", "generic-ddr"]}"#,
        )
        .unwrap();
        assert_eq!(r.platforms, Some(vec!["u280".to_string(), "generic-ddr".to_string()]));
        // empty lists, duplicates and non-string entries are structured errors
        let e = parse_request(r#"{"cmd": "dse", "ir": "x", "platforms": [], "id": 7}"#)
            .unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert!(e.message.contains("platforms"), "{}", e.message);
        assert_eq!(e.id, Json::Num(7.0));
        let e = parse_request(r#"{"cmd": "dse", "ir": "x", "platforms": ["u280", "u280"]}"#)
            .unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert!(e.message.contains("more than once"), "{}", e.message);
        assert!(parse_request(r#"{"cmd": "dse", "ir": "x", "platforms": [1]}"#).is_err());
        assert!(parse_request(r#"{"cmd": "dse", "ir": "x", "platforms": "u280"}"#).is_err());
    }

    #[test]
    fn rejects_garbage_with_codes() {
        assert_eq!(parse_request("not json").unwrap_err().code, "bad-json");
        assert_eq!(parse_request("[1, 2]").unwrap_err().code, "bad-request");
        assert_eq!(parse_request(r#"{"cmd": "frobnicate"}"#).unwrap_err().code, "bad-request");
        // job commands require IR; the id still makes it into the error
        let e = parse_request(r#"{"cmd": "dse", "id": "j1"}"#).unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert_eq!(e.id, Json::Str("j1".into()));
        // bad field types
        assert!(parse_request(r#"{"cmd": "dse", "ir": "x", "seed": -1}"#).is_err());
        assert!(parse_request(r#"{"cmd": "dse", "ir": "x", "factors": "two"}"#).is_err());
    }

    #[test]
    fn responses_round_trip_as_json() {
        let ok = ok_response(&Json::Num(1.0), Command::Ping, false, Some("abc"), Json::Null);
        let v = Json::parse(&ok).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(true));
        assert_eq!(v.get("key").as_str(), Some("abc"));
        let err = error_response(&ProtoError::new("bad-json", "nope"));
        let v = Json::parse(&err).unwrap();
        assert_eq!(v.get("ok"), &Json::Bool(false));
        assert_eq!(v.get("error").get("code").as_str(), Some("bad-json"));
        // single line (newline-delimited framing)
        assert!(!ok.contains('\n') && !err.contains('\n'));
    }

    #[test]
    fn traffic_fields_parse_and_validate() {
        let r = parse_request(
            r#"{"cmd": "dse", "ir": "x", "objective": "slo-score",
                "slo": "interactive=p99<5", "autoscale": "0.001:256:16:1:4",
                "priority": 3, "deadline_ms": 5000,
                "scenario_json": {"name": "t", "arrivals": {"kind": "closed", "jobs": "4"}}}"#,
        )
        .unwrap();
        assert_eq!(r.slo.as_deref(), Some("interactive=p99<5"));
        assert_eq!(r.autoscale.as_deref(), Some("0.001:256:16:1:4"));
        assert_eq!(r.priority, Some(3));
        assert_eq!(r.deadline_ms, Some(5000));
        let sj = r.scenario_json.as_ref().expect("scenario_json parsed");
        assert_eq!(sj.get("arrivals").get("kind").as_str(), Some("closed"));
        // absent fields default to None; bad types are structured errors
        let r = parse_request(r#"{"cmd": "ping"}"#).unwrap();
        assert_eq!((r.priority, r.deadline_ms), (None, None));
        assert!(r.slo.is_none() && r.autoscale.is_none() && r.scenario_json.is_none());
        let e = parse_request(r#"{"cmd": "dse", "ir": "x", "priority": -2}"#).unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert!(e.message.contains("priority"), "{}", e.message);
        assert!(parse_request(r#"{"cmd": "dse", "ir": "x", "deadline_ms": 0.5}"#).is_err());
    }

    #[test]
    fn non_job_commands_need_no_ir() {
        for cmd in ["cache-stats", "metrics", "ping", "shutdown", "handshake"] {
            let r = parse_request(&format!(r#"{{"cmd": "{cmd}"}}"#)).unwrap();
            assert!(!r.cmd.is_job());
        }
    }

    #[test]
    fn handshake_and_eval_candidate_fields_parse() {
        let r = parse_request(
            r#"{"cmd": "handshake", "proto_version": 1,
                "shard_map": {"index": 0, "total": 2}}"#,
        )
        .unwrap();
        assert_eq!(r.cmd, Command::Handshake);
        assert_eq!(r.proto_version, Some(1));
        assert!(r.shard_map.is_some());

        let r = parse_request(
            r#"{"cmd": "eval-candidate", "ir": "x", "point_label": "full(x2)",
                "point_pipeline": "sanitize", "key": "00ff",
                "objective_json": {"kind": "analytic"}}"#,
        )
        .unwrap();
        assert!(r.cmd.is_job(), "eval-candidate goes through the job queue");
        assert_eq!(r.point_label.as_deref(), Some("full(x2)"));
        assert_eq!(r.point_pipeline.as_deref(), Some("sanitize"));
        assert_eq!(r.key.as_deref(), Some("00ff"));
        let obj = r.objective_json.as_ref().expect("objective_json parsed");
        assert_eq!(obj.get("kind").as_str(), Some("analytic"));

        // a missing point_pipeline is a structured parse error, id intact
        let e = parse_request(r#"{"cmd": "eval-candidate", "ir": "x", "id": 4}"#).unwrap_err();
        assert_eq!(e.code, "bad-request");
        assert_eq!(e.id, Json::Num(4.0));
        // ...and so is a missing ir (eval-candidate is a job command)
        let e = parse_request(r#"{"cmd": "eval-candidate", "point_pipeline": "x"}"#).unwrap_err();
        assert_eq!(e.code, "bad-request");
    }
}
