//! Content-addressed, single-flight evaluation cache.
//!
//! The cache maps a [`ContentHash`] key — a stable hash of *everything the
//! result depends on* (module IR, platform spec, pipeline/strategy,
//! objective, scenario, seed) — to the evaluated value. Because every
//! evaluation in Olympus is a pure function of those inputs, a cache entry
//! is bit-identical to a fresh computation, and serving warm answers cannot
//! change results, only latency.
//!
//! **Single-flight**: when several workers ask for the same key
//! concurrently, exactly one computes; the rest block on a condvar and
//! reuse the result. This is what makes 8 identical DSE submissions cost
//! one evaluation instead of eight.
//!
//! **LRU eviction**: a bounded cache drops the least-recently-*used* entry,
//! not the oldest-inserted one — an entry that keeps getting hit (the hot
//! platform, the CI regression module) survives arbitrarily many inserts.
//! Recency is tracked with a lazily-compacted access log: each touch
//! appends a `(key, seq)` record; eviction pops stale records until it
//! finds one whose sequence is still current.
//!
//! **Disk tier (optional)**: [`EvalCache::persist_to`] attaches an
//! append-only journal ([`crate::service::persist`]); computed values write
//! through on miss and [`EvalCache::warm_insert`] seeds entries back at
//! startup, so a restarted process answers repeated keys without
//! recomputing. Eviction only trims the in-memory tier — the journal keeps
//! every record until its directory is deleted.
//!
//! Because keys are process-independent, the same cache also serves as one
//! shard of a *distributed* candidate store: an `olympus worker` answers
//! `eval-candidate` requests straight out of this structure (memory, then
//! journal), and a coordinator routes each key to the worker owning its
//! consistent-hash shard ([`crate::service::remote`]).

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use fxhash::FxHashMap;

use crate::util::ContentHash;

use super::persist::DiskStore;

/// Counters exposed by `cache-stats`. The `disk_*` fields mirror the
/// attached [`DiskStore`] tier and stay zero for memory-only caches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    /// Ready entries currently stored.
    pub entries: u64,
    /// Lookups answered from a stored entry.
    pub hits: u64,
    /// Lookups that computed (each is one real evaluation).
    pub misses: u64,
    /// Lookups that waited on a concurrent identical computation
    /// (single-flight followers).
    pub coalesced: u64,
    /// Entries dropped by the capacity bound.
    pub evicted: u64,
    /// Records loaded from the disk journal at startup.
    pub disk_loaded: u64,
    /// Records written through to the disk journal by this process.
    pub disk_persisted: u64,
    /// Disk records dropped as corrupt/undecodable (torn tails, failed
    /// checksums, values this build cannot parse).
    pub disk_corrupt_skipped: u64,
}

enum Slot<V> {
    /// A computation for this key is running on some worker.
    InFlight,
    Ready(V),
}

struct Inner<V> {
    /// Keyed by already-uniform content hashes, so the keyless [`fxhash`]
    /// hasher is safe and keeps the per-candidate probe cheap.
    map: FxHashMap<ContentHash, Slot<V>>,
    /// Access log: `(key, seq)` per touch; a record is current only while
    /// `last_used[key] == seq`. Oldest-first pops find the LRU entry.
    order: VecDeque<(ContentHash, u64)>,
    /// Latest access sequence per Ready key.
    last_used: FxHashMap<ContentHash, u64>,
    /// Monotonic access counter.
    counter: u64,
    /// Number of Ready entries (InFlight markers excluded).
    ready: usize,
}

impl<V> Inner<V> {
    /// Record an access to a Ready `key` (bounded caches only). The log is
    /// compacted in place once stale records dominate, so repeated hits on
    /// a hot key cannot grow it without bound.
    fn touch(&mut self, key: ContentHash) {
        self.counter += 1;
        self.last_used.insert(key, self.counter);
        self.order.push_back((key, self.counter));
        if self.order.len() > 2 * self.last_used.len() + 16 {
            let last_used = &self.last_used;
            self.order.retain(|(k, s)| last_used.get(k) == Some(s));
        }
    }
}

/// See module docs. `V` is cloned out on every hit, so keep values
/// cheaply-cloneable (or wrap them in `Arc`).
pub struct EvalCache<V> {
    inner: Mutex<Inner<V>>,
    ready: Condvar,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
    evicted: AtomicU64,
    /// Max Ready entries (0 = unbounded). Least-recently-used evicts first.
    capacity: usize,
    /// Optional disk tier: every computed value the encoder accepts is
    /// appended to the journal (write-through on miss; see
    /// [`crate::service::persist`]).
    disk: Option<(Arc<DiskStore>, Box<dyn Fn(&V) -> Option<Vec<u8>> + Send + Sync>)>,
}

impl<V: Clone> Default for EvalCache<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V: Clone> EvalCache<V> {
    /// Unbounded cache.
    pub fn new() -> Self {
        Self::with_capacity(0)
    }

    /// Cache holding at most `capacity` ready entries (0 = unbounded).
    pub fn with_capacity(capacity: usize) -> Self {
        EvalCache {
            inner: Mutex::new(Inner {
                map: FxHashMap::default(),
                order: VecDeque::new(),
                last_used: FxHashMap::default(),
                counter: 0,
                ready: 0,
            }),
            ready: Condvar::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            capacity,
            disk: None,
        }
    }

    /// Attach a disk tier: every value computed through
    /// [`EvalCache::get_or_compute`] is encoded with `encode` and appended
    /// to `store` (durability per the store's sync policy). `encode` may
    /// decline (`None`) values that must not outlive the process (e.g.
    /// possibly-transient failures). Warm-loaded and coalesced values are
    /// never re-appended. Must be called before the cache is shared (takes
    /// `&mut self`).
    pub fn persist_to<F>(&mut self, store: Arc<DiskStore>, encode: F)
    where
        F: Fn(&V) -> Option<Vec<u8>> + Send + Sync + 'static,
    {
        self.disk = Some((store, Box::new(encode)));
    }

    /// Seed `key` from the disk tier at startup. Counts neither as a hit
    /// nor a miss and never writes back through to disk. Returns `false`
    /// (leaving the stored entry alone) when the key is already present.
    pub fn warm_insert(&self, key: ContentHash, value: V) -> bool {
        let mut guard = self.inner.lock().unwrap();
        if guard.map.contains_key(&key) {
            return false;
        }
        guard.map.insert(key, Slot::Ready(value));
        guard.ready += 1;
        if self.capacity > 0 {
            guard.touch(key);
            self.evict_to_capacity(&mut guard);
        }
        drop(guard);
        self.ready.notify_all();
        true
    }

    /// Drop least-recently-used Ready entries until the capacity bound
    /// holds again (bounded caches only; the lock is already held).
    fn evict_to_capacity(&self, guard: &mut Inner<V>) {
        while guard.ready > self.capacity {
            // pop access records oldest-first; stale ones (a newer touch
            // exists) are skipped, the first current one is the LRU entry
            let Some((old, seq)) = guard.order.pop_front() else { break };
            if guard.last_used.get(&old) != Some(&seq) {
                continue;
            }
            if matches!(guard.map.get(&old), Some(Slot::Ready(_))) {
                guard.map.remove(&old);
                guard.last_used.remove(&old);
                guard.ready -= 1;
                self.evicted.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Look `key` up; on a miss, run `compute` (outside the lock) and store
    /// the result. Concurrent callers with the same key wait for the one
    /// in-flight computation instead of duplicating it. Returns the value
    /// and whether it was served from cache (`true` for hits and coalesced
    /// waiters, `false` for the caller that computed). Hits refresh the
    /// entry's recency.
    pub fn get_or_compute<F>(&self, key: ContentHash, compute: F) -> (V, bool)
    where
        F: FnOnce() -> V,
    {
        enum Peek<V> {
            Hit(V),
            Wait,
            Miss,
        }
        let mut waited = false;
        let mut guard = self.inner.lock().unwrap();
        loop {
            let peek = match guard.map.get(&key) {
                Some(Slot::Ready(v)) => Peek::Hit(v.clone()),
                Some(Slot::InFlight) => Peek::Wait,
                None => Peek::Miss,
            };
            match peek {
                Peek::Hit(v) => {
                    if self.capacity > 0 {
                        guard.touch(key);
                    }
                    if waited {
                        self.coalesced.fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                    }
                    return (v, true);
                }
                Peek::Wait => {
                    waited = true;
                    guard = self.ready.wait(guard).unwrap();
                }
                Peek::Miss => {
                    guard.map.insert(key, Slot::InFlight);
                    break;
                }
            }
        }
        drop(guard);
        self.misses.fetch_add(1, Ordering::Relaxed);
        // If `compute` unwinds, clear the InFlight marker and wake waiters
        // (they retry and one becomes the new computer) instead of leaving
        // them blocked forever.
        let mut flight = FlightGuard { cache: self, key, armed: true };
        let value = compute();
        flight.armed = false;
        let mut guard = self.inner.lock().unwrap();
        let prev = guard.map.insert(key, Slot::Ready(value.clone()));
        if !matches!(prev, Some(Slot::Ready(_))) {
            guard.ready += 1;
        }
        if self.capacity > 0 {
            guard.touch(key);
            self.evict_to_capacity(&mut guard);
        }
        drop(guard);
        self.ready.notify_all();
        // write-through to the disk tier, outside the lock: fsync latency
        // must not serialize unrelated keys
        if let Some((store, encode)) = &self.disk {
            if let Some(bytes) = encode(&value) {
                store.append(key, &bytes);
            }
        }
        (value, false)
    }

    /// Peek without computing (refreshes recency on a hit).
    pub fn get(&self, key: ContentHash) -> Option<V> {
        let mut guard = self.inner.lock().unwrap();
        let value = match guard.map.get(&key) {
            Some(Slot::Ready(v)) => Some(v.clone()),
            _ => None,
        };
        if value.is_some() {
            if self.capacity > 0 {
                guard.touch(key);
            }
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        value
    }

    pub fn stats(&self) -> CacheStats {
        let guard = self.inner.lock().unwrap();
        let disk = self.disk.as_ref().map(|(s, _)| s.stats()).unwrap_or_default();
        CacheStats {
            entries: guard.ready as u64,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
            disk_loaded: disk.loaded,
            disk_persisted: disk.persisted,
            disk_corrupt_skipped: disk.corrupt_skipped,
        }
    }
}

struct FlightGuard<'a, V> {
    cache: &'a EvalCache<V>,
    key: ContentHash,
    armed: bool,
}

impl<V> Drop for FlightGuard<'_, V> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        if let Ok(mut guard) = self.cache.inner.lock() {
            if matches!(guard.map.get(&self.key), Some(Slot::InFlight)) {
                guard.map.remove(&self.key);
            }
        }
        self.cache.ready.notify_all();
    }
}

impl<V> fmt::Debug for EvalCache<V> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("EvalCache")
            .field("hits", &self.hits.load(Ordering::Relaxed))
            .field("misses", &self.misses.load(Ordering::Relaxed))
            .field("coalesced", &self.coalesced.load(Ordering::Relaxed))
            .field("capacity", &self.capacity)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    fn key(s: &str) -> ContentHash {
        ContentHash::of_parts(&[s])
    }

    #[test]
    fn second_lookup_hits() {
        let c = EvalCache::new();
        let (v, cached) = c.get_or_compute(key("a"), || 41);
        assert_eq!((v, cached), (41, false));
        let (v, cached) = c.get_or_compute(key("a"), || panic!("must not recompute"));
        assert_eq!((v, cached), (41, true));
        let s = c.stats();
        assert_eq!((s.entries, s.hits, s.misses), (1, 1, 1));
    }

    #[test]
    fn concurrent_identical_keys_compute_once() {
        let c = Arc::new(EvalCache::new());
        let computations = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = c.clone();
            let n = computations.clone();
            handles.push(std::thread::spawn(move || {
                c.get_or_compute(key("shared"), || {
                    n.fetch_add(1, Ordering::SeqCst);
                    // widen the race window so followers really coalesce
                    std::thread::sleep(std::time::Duration::from_millis(50));
                    7
                })
                .0
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 7);
        }
        assert_eq!(computations.load(Ordering::SeqCst), 1, "single-flight");
        let s = c.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits + s.coalesced, 7);
    }

    #[test]
    fn capacity_evicts_least_recently_used() {
        let c = EvalCache::with_capacity(2);
        c.get_or_compute(key("a"), || 1);
        c.get_or_compute(key("b"), || 2);
        c.get_or_compute(key("c"), || 3);
        let s = c.stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.evicted, 1);
        assert_eq!(c.get(key("a")), None, "untouched oldest entry evicted");
        assert_eq!(c.get(key("c")), Some(3));
    }

    #[test]
    fn rehit_entry_survives_eviction() {
        let c = EvalCache::with_capacity(2);
        c.get_or_compute(key("a"), || 1);
        c.get_or_compute(key("b"), || 2);
        // touch `a`: it becomes the most recently used entry...
        assert_eq!(c.get_or_compute(key("a"), || panic!("cached")).0, 1);
        // ...so inserting `c` evicts `b`, not `a` (FIFO would drop `a`)
        c.get_or_compute(key("c"), || 3);
        assert_eq!(c.get(key("b")), None, "LRU entry evicted");
        assert_eq!(c.get(key("a")), Some(1), "re-hit entry survives");
        assert_eq!(c.get(key("c")), Some(3));
        assert_eq!(c.stats().evicted, 1);
    }

    #[test]
    fn peek_refreshes_recency_too() {
        let c = EvalCache::with_capacity(2);
        c.get_or_compute(key("a"), || 1);
        c.get_or_compute(key("b"), || 2);
        assert_eq!(c.get(key("a")), Some(1));
        c.get_or_compute(key("c"), || 3);
        assert_eq!(c.get(key("a")), Some(1), "peeked entry survives");
        assert_eq!(c.get(key("b")), None);
    }

    #[test]
    fn hot_key_hammering_keeps_the_access_log_bounded() {
        let c = EvalCache::with_capacity(2);
        c.get_or_compute(key("a"), || 1);
        c.get_or_compute(key("b"), || 2);
        for _ in 0..10_000 {
            c.get(key("a"));
        }
        let guard = c.inner.lock().unwrap();
        assert!(
            guard.order.len() <= 2 * guard.last_used.len() + 17,
            "access log must compact: {} records",
            guard.order.len()
        );
    }

    #[test]
    fn warm_insert_serves_without_miss_and_respects_capacity() {
        let c = EvalCache::with_capacity(2);
        assert!(c.warm_insert(key("a"), 1));
        assert!(!c.warm_insert(key("a"), 99), "first load wins");
        let (v, cached) = c.get_or_compute(key("a"), || panic!("warm entry must hit"));
        assert_eq!((v, cached), (1, true));
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 0), "warm entries are hits, not misses");
        assert_eq!((s.disk_loaded, s.disk_persisted), (0, 0), "no disk tier attached");
        // warm inserts participate in the LRU bound like any other entry
        assert!(c.warm_insert(key("b"), 2));
        assert!(c.warm_insert(key("c"), 3));
        assert_eq!(c.stats().entries, 2);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let c = EvalCache::new();
        c.get_or_compute(key("x"), || 1);
        let (v, cached) = c.get_or_compute(key("y"), || 2);
        assert_eq!((v, cached), (2, false));
    }
}
