//! Platform specification types + JSON (de)serialization.

use anyhow::{bail, Context, Result};

use crate::dialect::ResourceVec;
use crate::util::Json;

/// Kind of off-chip memory behind a channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemKind {
    /// HBM pseudo-channel.
    Hbm,
    /// DDR4 channel.
    Ddr,
}

impl MemKind {
    pub fn as_str(self) -> &'static str {
        match self {
            MemKind::Hbm => "hbm",
            MemKind::Ddr => "ddr",
        }
    }

    pub fn parse(s: &str) -> Option<MemKind> {
        match s {
            "hbm" => Some(MemKind::Hbm),
            "ddr" => Some(MemKind::Ddr),
            _ => None,
        }
    }
}

/// One physical memory channel (HBM pseudo-channel or DDR channel).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PcSpec {
    pub kind: MemKind,
    /// Data width in bits.
    pub width_bits: u32,
    /// Effective transfer rate in MT/s (per-pin data rate × 1; for HBM PCs
    /// the paper quotes the 450 MHz @ 256-bit figure directly).
    pub freq_mhz: f64,
    /// Capacity in bytes.
    pub capacity_bytes: u64,
    /// Fraction of the peak beat rate sustainable when several engines
    /// contend for the channel concurrently (arXiv 2010.08916 measures HBM
    /// pseudo-channels well below peak under multi-master access patterns).
    /// `1.0` = contention costs nothing beyond the fair bandwidth split.
    /// Only the discrete-event simulator ([`crate::des`]) consumes this; the
    /// static analytic model intentionally ignores it.
    pub sustained_frac: f64,
    /// Concurrently interleavable banks (HBM pseudo-channel bank count, or
    /// DDR banks × bank groups). More masters than banks on one channel
    /// cannot all hide their row-activate latency behind interleaving, so
    /// the DES derates the channel (see [`PcSpec::bank_conflict_derate`]).
    pub banks: u32,
    /// Multiplier applied to `sustained_frac` when more movers land on this
    /// channel than it has `banks` (bank-conflict regime). arXiv 2010.08916
    /// measures DDR4 losing ~40% under conflicting multi-master streams;
    /// HBM pseudo-channels are single-master behind the switch, so `1.0`
    /// there. Must be in `(0, 1]`; `1.0` = conflicts cost nothing extra.
    pub bank_conflict_derate: f64,
}

impl PcSpec {
    /// Peak bandwidth in bytes/second.
    pub fn bandwidth_bps(&self) -> f64 {
        self.width_bits as f64 / 8.0 * self.freq_mhz * 1e6
    }

    /// Peak bandwidth in GB/s (decimal GB, as the paper quotes).
    pub fn bandwidth_gbs(&self) -> f64 {
        self.bandwidth_bps() / 1e9
    }

    /// Beat rate (beats/second) sustainable with `concurrent` engines
    /// sharing the channel: peak when alone, derated fair share otherwise.
    pub fn shared_beat_rate(&self, concurrent: usize) -> f64 {
        let peak = self.freq_mhz * 1e6;
        if concurrent <= 1 {
            peak
        } else {
            peak * self.sustained_frac.clamp(0.0, 1.0)
        }
    }
}

/// A full platform description.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformSpec {
    pub name: String,
    /// Physical memory channels, index == `olympus.pc` id.
    pub pcs: Vec<PcSpec>,
    /// Total FPGA fabric resources.
    pub resources: ResourceVec,
    /// Default resource utilization limit (paper §V-B: default 80%).
    pub util_limit: f64,
    /// Kernel clock in MHz (the fabric clock kernels are compiled at).
    pub kernel_mhz: f64,
    /// AXI master port budget: how many memory-mapped AXI masters the shell
    /// + memory subsystem accepts (U280: one per HBM switch port plus the
    /// DDR controllers). The mapping phase of
    /// [`crate::lower::build_architecture`] shares ports when a design
    /// needs more, and rejects designs spread over more distinct channels
    /// than there are ports.
    pub axi_ports: usize,
}

impl PlatformSpec {
    /// Aggregate peak bandwidth over all memory channels, GB/s.
    pub fn total_bandwidth_gbs(&self) -> f64 {
        self.pcs.iter().map(|p| p.bandwidth_gbs()).sum()
    }

    /// Ids of channels of `kind`.
    pub fn pc_ids(&self, kind: MemKind) -> Vec<u32> {
        self.pcs
            .iter()
            .enumerate()
            .filter(|(_, p)| p.kind == kind)
            .map(|(i, _)| i as u32)
            .collect()
    }

    /// Number of memory channels.
    pub fn num_pcs(&self) -> usize {
        self.pcs.len()
    }

    /// Stable content fingerprint: the canonical JSON form (BTreeMap-backed,
    /// so key order is deterministic) under [`crate::util::ContentHash`].
    /// Two specs with equal fields fingerprint identically regardless of how
    /// they were loaded (builtin vs JSON file vs inline request object).
    pub fn fingerprint(&self) -> String {
        crate::util::ContentHash::of_parts(&["olympus-platform-v1", &self.to_json().to_string()])
            .to_hex()
    }

    // ---- JSON -----------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let pcs: Vec<Json> = self
            .pcs
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("kind", p.kind.as_str().into()),
                    ("width_bits", (p.width_bits as usize).into()),
                    ("freq_mhz", p.freq_mhz.into()),
                    ("capacity_bytes", (p.capacity_bytes as usize).into()),
                    ("sustained_frac", p.sustained_frac.into()),
                    ("banks", (p.banks as usize).into()),
                    ("bank_conflict_derate", p.bank_conflict_derate.into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("pcs", Json::Arr(pcs)),
            (
                "resources",
                Json::obj(vec![
                    ("ff", (self.resources.ff as usize).into()),
                    ("lut", (self.resources.lut as usize).into()),
                    ("bram", (self.resources.bram as usize).into()),
                    ("uram", (self.resources.uram as usize).into()),
                    ("dsp", (self.resources.dsp as usize).into()),
                ]),
            ),
            ("util_limit", self.util_limit.into()),
            ("kernel_mhz", self.kernel_mhz.into()),
            ("axi_ports", self.axi_ports.into()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<PlatformSpec> {
        let name = v.get("name").as_str().context("platform: missing name")?.to_string();
        let mut pcs = Vec::new();
        for (i, p) in v.get("pcs").as_arr().context("platform: missing pcs")?.iter().enumerate() {
            let kind = MemKind::parse(p.get("kind").as_str().unwrap_or(""))
                .with_context(|| format!("pc {i}: bad kind"))?;
            let width_bits = p.get("width_bits").as_usize().context("pc width_bits")? as u32;
            let freq_mhz = p.get("freq_mhz").as_f64().context("pc freq_mhz")?;
            let capacity_bytes = p.get("capacity_bytes").as_usize().unwrap_or(0) as u64;
            let sustained_frac = p.get("sustained_frac").as_f64().unwrap_or(1.0);
            // absent bank topology = one big bank that never conflicts
            let banks = p.get("banks").as_usize().unwrap_or(1) as u32;
            let bank_conflict_derate = p.get("bank_conflict_derate").as_f64().unwrap_or(1.0);
            if width_bits == 0 || freq_mhz <= 0.0 {
                bail!("pc {i}: non-positive width/frequency");
            }
            if !(0.0..=1.0).contains(&sustained_frac) {
                bail!("pc {i}: sustained_frac must be in [0, 1]");
            }
            if banks == 0 {
                bail!("pc {i}: banks must be >= 1");
            }
            if !(bank_conflict_derate > 0.0 && bank_conflict_derate <= 1.0) {
                bail!("pc {i}: bank_conflict_derate must be in (0, 1]");
            }
            pcs.push(PcSpec {
                kind,
                width_bits,
                freq_mhz,
                capacity_bytes,
                sustained_frac,
                banks,
                bank_conflict_derate,
            });
        }
        if pcs.is_empty() {
            bail!("platform '{name}' has no memory channels");
        }
        // absent port budget = one AXI master per channel (never constrains
        // a valid per-channel mapping), so pre-topology JSON files keep
        // lowering exactly as before
        let axi_ports = match v.get("axi_ports") {
            Json::Null => pcs.len(),
            j => j.as_usize().context("platform: axi_ports must be an integer")?,
        };
        if axi_ports == 0 {
            bail!("platform '{name}': axi_ports must be >= 1");
        }
        let r = v.get("resources");
        let g = |k: &str| r.get(k).as_usize().unwrap_or(0) as u64;
        Ok(PlatformSpec {
            name,
            pcs,
            resources: ResourceVec::new(g("ff"), g("lut"), g("bram"), g("uram"), g("dsp")),
            util_limit: v.get("util_limit").as_f64().unwrap_or(0.8),
            kernel_mhz: v.get("kernel_mhz").as_f64().unwrap_or(300.0),
            axi_ports,
        })
    }

    pub fn load(path: &std::path::Path) -> Result<PlatformSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read platform file {}", path.display()))?;
        let v = Json::parse(&text).context("platform file is not valid JSON")?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pc() -> PcSpec {
        PcSpec {
            kind: MemKind::Hbm,
            width_bits: 256,
            freq_mhz: 450.0,
            capacity_bytes: 256 << 20,
            sustained_frac: 0.85,
            banks: 16,
            bank_conflict_derate: 1.0,
        }
    }

    #[test]
    fn hbm_pc_bandwidth_matches_paper() {
        // paper §II-B: each 256-bit PC at 450 MHz = 14.4 GB/s
        assert!((pc().bandwidth_gbs() - 14.4).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let spec = PlatformSpec {
            name: "test".into(),
            pcs: vec![
                pc(),
                PcSpec {
                    kind: MemKind::Ddr,
                    width_bits: 64,
                    freq_mhz: 2400.0,
                    capacity_bytes: 16 << 30,
                    sustained_frac: 0.95,
                    banks: 16,
                    bank_conflict_derate: 0.6,
                },
            ],
            resources: ResourceVec::new(1, 2, 3, 4, 5),
            util_limit: 0.8,
            kernel_mhz: 300.0,
            axi_ports: 3,
        };
        let j = spec.to_json().to_string();
        let back = PlatformSpec::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn sustained_frac_defaults_and_derates() {
        // absent in JSON -> 1.0 (no derate)
        let j = Json::parse(
            r#"{"name": "x", "pcs": [{"kind": "hbm", "width_bits": 256, "freq_mhz": 450.0}]}"#,
        )
        .unwrap();
        let spec = PlatformSpec::from_json(&j).unwrap();
        assert_eq!(spec.pcs[0].sustained_frac, 1.0);
        assert_eq!(spec.pcs[0].shared_beat_rate(1), spec.pcs[0].shared_beat_rate(4));
        // absent topology fields: one never-conflicting bank, one AXI
        // master per channel — pre-topology JSON specs lower as before
        assert_eq!(spec.pcs[0].banks, 1);
        assert_eq!(spec.pcs[0].bank_conflict_derate, 1.0);
        assert_eq!(spec.axi_ports, spec.pcs.len());
        // explicit derate only kicks in under contention
        let p = pc();
        assert!((p.shared_beat_rate(1) - 450e6).abs() < 1e-3);
        assert!((p.shared_beat_rate(2) - 450e6 * 0.85).abs() < 1e-3);
        // out-of-range rejected
        let j = Json::parse(
            r#"{"name": "x", "pcs": [{"kind": "hbm", "width_bits": 256,
                "freq_mhz": 450.0, "sustained_frac": 1.5}]}"#,
        )
        .unwrap();
        assert!(PlatformSpec::from_json(&j).is_err());
    }

    #[test]
    fn fingerprint_tracks_content_not_provenance() {
        let spec = PlatformSpec {
            name: "test".into(),
            pcs: vec![pc()],
            resources: ResourceVec::new(1, 2, 3, 4, 5),
            util_limit: 0.8,
            kernel_mhz: 300.0,
            axi_ports: 1,
        };
        // a JSON round-trip preserves the fingerprint...
        let back =
            PlatformSpec::from_json(&Json::parse(&spec.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(spec.fingerprint(), back.fingerprint());
        // ...and any field change shifts it
        let mut other = spec.clone();
        other.kernel_mhz = 301.0;
        assert_ne!(spec.fingerprint(), other.fingerprint());
    }

    #[test]
    fn rejects_empty_pcs() {
        let j = Json::parse(r#"{"name": "x", "pcs": []}"#).unwrap();
        assert!(PlatformSpec::from_json(&j).is_err());
    }

    #[test]
    fn rejects_bad_port_bank_topology() {
        let mk = |extra: &str| {
            Json::parse(&format!(
                r#"{{"name": "x", "pcs": [{{"kind": "hbm", "width_bits": 256,
                    "freq_mhz": 450.0{extra}}}]}}"#
            ))
            .unwrap()
        };
        assert!(PlatformSpec::from_json(&mk(r#", "banks": 0"#)).is_err());
        assert!(PlatformSpec::from_json(&mk(r#", "bank_conflict_derate": 0.0"#)).is_err());
        assert!(PlatformSpec::from_json(&mk(r#", "bank_conflict_derate": 1.5"#)).is_err());
        let mut v = mk("");
        if let Json::Obj(o) = &mut v {
            o.insert("axi_ports".into(), Json::Num(0.0));
        }
        assert!(PlatformSpec::from_json(&v).is_err());
    }

    #[test]
    fn pc_ids_by_kind() {
        let spec = PlatformSpec {
            name: "t".into(),
            pcs: vec![
                pc(),
                PcSpec {
                    kind: MemKind::Ddr,
                    width_bits: 64,
                    freq_mhz: 2400.0,
                    capacity_bytes: 0,
                    sustained_frac: 1.0,
                    banks: 1,
                    bank_conflict_derate: 1.0,
                },
                pc(),
            ],
            resources: ResourceVec::ZERO,
            util_limit: 0.8,
            kernel_mhz: 300.0,
            axi_ports: 3,
        };
        assert_eq!(spec.pc_ids(MemKind::Hbm), vec![0, 2]);
        assert_eq!(spec.pc_ids(MemKind::Ddr), vec![1]);
    }
}
