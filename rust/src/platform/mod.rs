//! Platform models (paper §II-B, §V-B): memory channel specs + resource
//! capacities for the FPGA cards Olympus targets.
//!
//! The paper's running target is the Xilinx Alveo U280; we also model the
//! Alveo U50, the Intel Stratix 10 MX, and a DDR-only generic board to show
//! platform-awareness (the same DFG optimizes differently per platform).
//! Custom platforms load from JSON (the "FPGA platform details" input of
//! paper Fig 3).

mod registry;
mod spec;

pub use registry::{builtin, builtin_names};
pub use spec::{MemKind, PcSpec, PlatformSpec};
