//! Built-in platform models.
//!
//! Numbers are from public datasheets / the paper:
//! * **Alveo U280** (paper §II-B): 32 HBM2 PCs × 256 bit @ 450 MHz
//!   (14.4 GB/s each, 460.8 GB/s total), 2 × DDR4-2400 64-bit (19.2 GB/s
//!   each ≈ the paper's 38 GB/s), XCU280 fabric: 2607k FF, 1304k LUT,
//!   2016 BRAM36, 960 URAM, 9024 DSP.
//! * **Alveo U50**: 32 HBM2 PCs (8 GB), no DDR; 1743k FF, 872k LUT,
//!   1344 BRAM36, 640 URAM, 5952 DSP.
//! * **Stratix 10 MX** (approximated onto the same resource classes):
//!   32 HBM2 PCs × 256 bit @ 400 MHz (409.6 GB/s), ALM/M20K counts mapped
//!   to lut/bram equivalents.
//! * **generic-ddr**: a midrange board with 2 × DDR4-2400 only — the
//!   baseline platform where HBM-oriented optimizations can't help.

use crate::dialect::ResourceVec;

use super::spec::{MemKind, PcSpec, PlatformSpec};

fn hbm_pc(freq_mhz: f64, capacity_bytes: u64) -> PcSpec {
    // HBM pseudo-channels sustain well below peak once several AXI masters
    // contend (arXiv 2010.08916 reports ~80-90% under mixed access). Each
    // PC fronts 16 banks, and the switch serializes masters before bank
    // conflicts matter, so conflicts cost nothing beyond the shared rate.
    PcSpec {
        kind: MemKind::Hbm,
        width_bits: 256,
        freq_mhz,
        capacity_bytes,
        sustained_frac: 0.85,
        banks: 16,
        bank_conflict_derate: 1.0,
    }
}

fn ddr4_2400() -> PcSpec {
    // 4 bank groups x 4 banks; once more streams than banks interleave on
    // one channel, row thrashing costs ~40% (arXiv 2010.08916's DDR4
    // multi-master measurements).
    PcSpec {
        kind: MemKind::Ddr,
        width_bits: 64,
        freq_mhz: 2400.0,
        capacity_bytes: 16 << 30,
        sustained_frac: 0.95,
        banks: 16,
        bank_conflict_derate: 0.6,
    }
}

/// Alveo U280 (the paper's example target).
pub fn u280() -> PlatformSpec {
    let mut pcs = vec![hbm_pc(450.0, 256 << 20); 32];
    pcs.push(ddr4_2400());
    pcs.push(ddr4_2400());
    PlatformSpec {
        name: "u280".into(),
        pcs,
        resources: ResourceVec::new(2_607_000, 1_304_000, 2_016, 960, 9_024),
        util_limit: 0.8,
        kernel_mhz: 300.0,
        // 32 HBM switch ports + 2 DDR controller ports
        axi_ports: 34,
    }
}

/// Alveo U50.
pub fn u50() -> PlatformSpec {
    PlatformSpec {
        name: "u50".into(),
        pcs: vec![hbm_pc(450.0, 256 << 20); 32],
        resources: ResourceVec::new(1_743_000, 872_000, 1_344, 640, 5_952),
        util_limit: 0.8,
        kernel_mhz: 300.0,
        axi_ports: 32,
    }
}

/// Intel Stratix 10 MX (resource classes approximated).
pub fn stratix10mx() -> PlatformSpec {
    PlatformSpec {
        name: "stratix10mx".into(),
        pcs: vec![hbm_pc(400.0, 256 << 20); 32],
        resources: ResourceVec::new(2_808_000, 702_720, 6_847, 0, 3_960),
        util_limit: 0.8,
        kernel_mhz: 300.0,
        axi_ports: 32,
    }
}

/// DDR-only generic board (baseline).
pub fn generic_ddr() -> PlatformSpec {
    PlatformSpec {
        name: "generic-ddr".into(),
        pcs: vec![ddr4_2400(), ddr4_2400()],
        resources: ResourceVec::new(1_000_000, 500_000, 1_000, 0, 2_000),
        util_limit: 0.8,
        kernel_mhz: 300.0,
        // a midrange shell exposes far more masters than channels; replica
        // fan-out shares ports well before the interconnect runs out
        axi_ports: 16,
    }
}

/// Look up a built-in platform by name.
pub fn builtin(name: &str) -> Option<PlatformSpec> {
    match name {
        "u280" => Some(u280()),
        "u50" => Some(u50()),
        "stratix10mx" => Some(stratix10mx()),
        "generic-ddr" => Some(generic_ddr()),
        _ => None,
    }
}

/// Names of all built-in platforms.
pub fn builtin_names() -> &'static [&'static str] {
    &["u280", "u50", "stratix10mx", "generic-ddr"]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Json;

    #[test]
    fn builtin_port_bank_topology() {
        // one AXI master per HBM switch port + one per DDR controller
        assert_eq!(u280().axi_ports, 34);
        assert_eq!(u50().axi_ports, 32);
        assert_eq!(stratix10mx().axi_ports, 32);
        assert_eq!(generic_ddr().axi_ports, 16);
        for p in builtin_names().iter().map(|n| builtin(n).unwrap()) {
            for pc in &p.pcs {
                assert_eq!(pc.banks, 16, "{}: 16 banks per channel", p.name);
                let derate = pc.bank_conflict_derate;
                match pc.kind {
                    // single-master behind the switch: conflicts are free
                    MemKind::Hbm => assert_eq!(derate, 1.0, "{}", p.name),
                    // DDR4 row thrashing under multi-master streams
                    MemKind::Ddr => assert_eq!(derate, 0.6, "{}", p.name),
                }
            }
        }
    }

    #[test]
    fn builtin_canonical_json_is_pinned() {
        // The platform fingerprint hashes exactly this canonical text (plus
        // a constant version tag), and every persisted cache journal is
        // addressed by it — a silent change here orphans every journal.
        // Update the pinned text only alongside a deliberate format bump.
        let hbm450 = r#"{"bank_conflict_derate":1,"banks":16,"capacity_bytes":268435456,"freq_mhz":450,"kind":"hbm","sustained_frac":0.85,"width_bits":256}"#;
        let hbm400 = hbm450.replace(":450,", ":400,");
        let ddr = r#"{"bank_conflict_derate":0.6,"banks":16,"capacity_bytes":17179869184,"freq_mhz":2400,"kind":"ddr","sustained_frac":0.95,"width_bits":64}"#;
        let rep = |pc: &str, n: usize| vec![pc.to_string(); n].join(",");
        let expect = [
            (
                "u280",
                format!(
                    r#"{{"axi_ports":34,"kernel_mhz":300,"name":"u280","pcs":[{},{ddr},{ddr}],"resources":{{"bram":2016,"dsp":9024,"ff":2607000,"lut":1304000,"uram":960}},"util_limit":0.8}}"#,
                    rep(hbm450, 32)
                ),
            ),
            (
                "u50",
                format!(
                    r#"{{"axi_ports":32,"kernel_mhz":300,"name":"u50","pcs":[{}],"resources":{{"bram":1344,"dsp":5952,"ff":1743000,"lut":872000,"uram":640}},"util_limit":0.8}}"#,
                    rep(hbm450, 32)
                ),
            ),
            (
                "stratix10mx",
                format!(
                    r#"{{"axi_ports":32,"kernel_mhz":300,"name":"stratix10mx","pcs":[{}],"resources":{{"bram":6847,"dsp":3960,"ff":2808000,"lut":702720,"uram":0}},"util_limit":0.8}}"#,
                    rep(&hbm400, 32)
                ),
            ),
            (
                "generic-ddr",
                format!(
                    r#"{{"axi_ports":16,"kernel_mhz":300,"name":"generic-ddr","pcs":[{ddr},{ddr}],"resources":{{"bram":1000,"dsp":2000,"ff":1000000,"lut":500000,"uram":0}},"util_limit":0.8}}"#
                ),
            ),
        ];
        for (name, want) in expect {
            let spec = builtin(name).unwrap();
            let got = spec.to_json().to_string();
            assert_eq!(got, want, "canonical JSON for '{name}' changed");
            // a JSON round-trip (how file-loaded specs arrive) preserves
            // the spec and therefore its journal address
            let back = PlatformSpec::from_json(&Json::parse(&got).unwrap()).unwrap();
            assert_eq!(back, spec);
            assert_eq!(back.fingerprint(), spec.fingerprint());
        }
    }

    #[test]
    fn u280_matches_paper_claims() {
        let p = u280();
        let hbm: Vec<_> = p.pcs.iter().filter(|pc| pc.kind == MemKind::Hbm).collect();
        assert_eq!(hbm.len(), 32, "paper: 32 pseudo-channels");
        // per-PC 14.4 GB/s, total HBM 460.8 GB/s (paper §II-B)
        assert!((hbm[0].bandwidth_gbs() - 14.4).abs() < 1e-9);
        let hbm_total: f64 = hbm.iter().map(|pc| pc.bandwidth_gbs()).sum();
        assert!((hbm_total - 460.8).abs() < 1e-6);
        // 8 GB HBM total
        let hbm_cap: u64 = hbm.iter().map(|pc| pc.capacity_bytes).sum();
        assert_eq!(hbm_cap, 8 << 30);
        // DDR ~38 GB/s total
        let ddr_total: f64 = p
            .pcs
            .iter()
            .filter(|pc| pc.kind == MemKind::Ddr)
            .map(|pc| pc.bandwidth_gbs())
            .sum();
        assert!((ddr_total - 38.4).abs() < 0.5, "paper: ~38 GB/s, got {ddr_total}");
    }

    #[test]
    fn builtins_resolve() {
        for n in builtin_names() {
            let p = builtin(n).unwrap();
            assert_eq!(&p.name, n);
            assert!(!p.pcs.is_empty());
        }
        assert!(builtin("nope").is_none());
    }

    #[test]
    fn u50_has_no_ddr() {
        assert!(u50().pc_ids(MemKind::Ddr).is_empty());
        assert_eq!(u50().pc_ids(MemKind::Hbm).len(), 32);
    }
}
