//! Synthetic workload generation: random-but-realistic Olympus DFGs for
//! benches and property tests (the "many sources of input" of the paper's
//! abstract — stand-ins for DSL front-ends).

use crate::dialect::{DfgBuilder, KernelEst, ParamType, ResourceVec};
use crate::ir::Module;
use crate::util::Rng;

/// Workload shape knobs.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Number of kernel stages.
    pub kernels: usize,
    /// Elements per stream channel.
    pub depth: u64,
    /// Probability a kernel input comes from a previous kernel's output
    /// (pipeline edge) rather than fresh from memory.
    pub pipeline_p: f64,
    /// Probability a memory channel is `small` (PLM-bound) instead of stream.
    pub small_p: f64,
    /// Element widths to draw from.
    pub widths: Vec<u32>,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            kernels: 8,
            depth: 1024,
            pipeline_p: 0.35,
            small_p: 0.15,
            widths: vec![16, 32, 32, 32, 64],
        }
    }
}

/// Generate a random DFG. All kernels use the `vecadd_1024`-style estimate
/// scaled by a size factor, with callees drawn from the AOT manifest names
/// so generated designs stay simulatable.
pub fn random_dfg(rng: &mut Rng, spec: &WorkloadSpec) -> Module {
    let mut b = DfgBuilder::new();
    let mut open_outputs: Vec<crate::ir::ValueId> = Vec::new();
    for _ in 0..spec.kernels {
        let n_in = rng.range(1, 3);
        let mut ins = Vec::new();
        for _ in 0..n_in {
            if !open_outputs.is_empty() && rng.chance(spec.pipeline_p) {
                let i = rng.range(0, open_outputs.len());
                ins.push(open_outputs.swap_remove(i));
            } else {
                let pt =
                    if rng.chance(spec.small_p) { ParamType::Small } else { ParamType::Stream };
                let w = *rng.pick(&spec.widths);
                ins.push(b.channel(w, pt, spec.depth));
            }
        }
        let out = b.channel(32, ParamType::Stream, spec.depth);
        let scale = rng.range(1, 6) as u64;
        // match the AOT manifest signatures so generated designs simulate:
        // 1 data input -> scale_offset (plus its two scalar PLM params),
        // 2 data inputs -> vecadd.
        let callee = if n_in == 1 { "scale_offset_1024" } else { "vecadd_1024" };
        if n_in == 1 {
            ins.push(b.channel(32, ParamType::Small, 1)); // scale
            ins.push(b.channel(32, ParamType::Small, 1)); // offset
        }
        b.kernel(
            callee,
            &ins,
            &[out],
            KernelEst {
                latency: 1000 + rng.range(0, 2000) as u64,
                ii: rng.range(1, 4) as u64,
                res: ResourceVec::new(4000, 5000, 2, 0, 4) * scale,
            },
        );
        open_outputs.push(out);
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::verify_dialect;
    use crate::ir::verify_module;

    #[test]
    fn generated_dfgs_verify() {
        let mut rng = Rng::new(5);
        for k in [1usize, 4, 16, 64] {
            let m = random_dfg(&mut rng, &WorkloadSpec { kernels: k, ..Default::default() });
            assert!(verify_module(&m).is_empty());
            assert!(verify_dialect(&m, false).is_empty());
            assert!(m.num_ops() >= k);
        }
    }

    #[test]
    fn generated_dfgs_survive_full_pipeline() {
        use crate::passes::manager::{parse_pipeline, PassContext};
        use crate::platform::builtin;
        let mut rng = Rng::new(9);
        for seed in 0..5u64 {
            let _ = seed;
            let mut m = random_dfg(&mut rng, &Default::default());
            let mut ctx = PassContext::new(builtin("u280").unwrap());
            let pm = parse_pipeline(
                "sanitize, plm-share, iris, replicate{factor=2}, channel-reassign, canonicalize",
                &mut ctx,
            )
            .unwrap();
            pm.run(&mut m, &ctx).unwrap();
            assert!(verify_module(&m).is_empty());
        }
    }
}
