//! Host runtime (paper §V-C host API): the generated driver's verbs —
//! device init, buffer create/migrate, kernel execution — implemented over
//! the platform simulator. On a real Alveo these calls map 1:1 onto the
//! OpenCL/XRT methods the paper's generated library uses.

mod device;

pub use device::Device;
