//! The simulated device handle.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::lower::{Architecture, MoverDir};
use crate::runtime::KernelRegistry;
use crate::sim::{SimMetrics, Simulator};

/// A programmed device: architecture + kernel binaries, ready to run.
///
/// Mirrors the XRT flow: `program` ≈ `xclLoadXclbin`, `write_buffer` ≈
/// `clCreateBuffer` + `clEnqueueMigrateMemObjects`, `run` ≈
/// `clEnqueueTask`, `read_buffer` ≈ migrate-back.
pub struct Device {
    arch: Architecture,
    registry: KernelRegistry,
    buffers: HashMap<String, Vec<f32>>,
    outputs: HashMap<String, Vec<f32>>,
    last_metrics: Option<SimMetrics>,
    utilization: f64,
}

impl Device {
    /// "Load the bitstream": validate the architecture against the kernel
    /// manifest and return a device handle.
    pub fn program(arch: Architecture, registry: KernelRegistry) -> Result<Device> {
        let dev = Device {
            arch,
            registry,
            buffers: HashMap::new(),
            outputs: HashMap::new(),
            last_metrics: None,
            utilization: 0.0,
        };
        Simulator::new(&dev.arch, &dev.registry).validate()?;
        Ok(dev)
    }

    /// Record resource utilization (from `analyze_resources`) so the timing
    /// model can apply the congestion derate.
    pub fn set_utilization(&mut self, utilization: f64) {
        self.utilization = utilization;
    }

    /// Readable names of the device's memory-facing channels.
    pub fn channel_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.arch.memory_bindings.keys().map(|s| s.as_str()).collect();
        names.sort_unstable();
        names
    }

    /// Create + fill an on-device buffer bound to channel `name`.
    pub fn write_buffer(&mut self, name: &str, data: &[f32]) -> Result<()> {
        if !self.arch.memory_bindings.contains_key(name) {
            bail!(
                "channel '{name}' is not a memory-facing channel (have: {:?})",
                self.channel_names()
            );
        }
        self.buffers.insert(name.to_string(), data.to_vec());
        Ok(())
    }

    /// Execute one app iteration; returns the run's metrics.
    pub fn run(&mut self) -> Result<SimMetrics> {
        // read channels = read movers' base fields
        for mv in &self.arch.movers {
            if mv.dir != MoverDir::Read {
                continue;
            }
            for (field, _) in &mv.routes {
                let base = field.split('.').next().unwrap_or(field);
                if !self.buffers.contains_key(base) {
                    bail!("read channel '{base}' has no host buffer (call write_buffer first)");
                }
            }
        }
        let sim = Simulator {
            arch: &self.arch,
            registry: &self.registry,
            congestion_model: true,
            utilization: self.utilization,
        };
        let out = sim.run(&self.buffers)?;
        self.outputs = out.outputs;
        self.last_metrics = Some(out.metrics.clone());
        Ok(out.metrics)
    }

    /// Read back an output buffer produced by the last `run`.
    pub fn read_buffer(&self, name: &str) -> Result<Vec<f32>> {
        self.outputs
            .get(name)
            .cloned()
            .with_context(|| {
                format!(
                    "no output for channel '{name}' (outputs: {:?})",
                    self.outputs.keys().collect::<Vec<_>>()
                )
            })
    }

    /// Execute `n` app iterations back-to-back (the steady-state serving
    /// loop of the generated host API); returns aggregate metrics: summed
    /// makespan/bytes, per-iteration mean throughput.
    pub fn run_iterations(&mut self, n: usize) -> Result<SimMetrics> {
        if n == 0 {
            bail!("run_iterations(0)");
        }
        let mut agg: Option<SimMetrics> = None;
        for _ in 0..n {
            let m = self.run()?;
            match &mut agg {
                None => agg = Some(m),
                Some(a) => {
                    a.makespan_s += m.makespan_s;
                    a.mem_time_s += m.mem_time_s;
                    a.compute_time_s += m.compute_time_s;
                    a.total_bytes += m.total_bytes;
                    a.sim_wall_s += m.sim_wall_s;
                }
            }
        }
        let mut a = agg.unwrap();
        a.achieved_gbs = if a.makespan_s > 0.0 {
            a.total_bytes as f64 / a.makespan_s / 1e9
        } else {
            0.0
        };
        self.last_metrics = Some(a.clone());
        Ok(a)
    }

    /// Metrics of the last run.
    pub fn metrics(&self) -> Option<&SimMetrics> {
        self.last_metrics.as_ref()
    }

    pub fn architecture(&self) -> &Architecture {
        &self.arch
    }
}
