//! Mnemosyne-style PLM sharing (Pilato et al., TCAD'17 — paper reference
//! [15]): share physical BRAM between `small` buffers that are never alive
//! at the same time (temporal compatibility) or that can coexist in one
//! physical memory's ports (spatial compatibility).
//!
//! "This information can be detected by static compiler analysis and
//! supplied as additional information" (paper §V-B) — here it arrives as
//! channel attributes: `phase = <int>` (buffers of different phases are
//! never simultaneously live) and `share_group = "<tag>"` (explicitly
//! spatially compatible).

mod compat;

pub use compat::{plan_sharing, CompatInfo, SharingPlan, SharingGroup};
