//! Compatibility-graph construction + greedy clique partitioning.

/// Sharing-relevant facts about one buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct CompatInfo {
    /// Stable buffer name (the channel's `name` attribute).
    pub name: String,
    /// Storage demand in BRAM36 blocks.
    pub brams: u64,
    /// Execution phase; buffers in different phases are never live together
    /// (temporal compatibility).
    pub phase: Option<i64>,
    /// Explicit spatial-compatibility tag: same tag => may share a memory.
    pub share_group: Option<String>,
}

/// Two buffers may share one physical memory iff temporally or spatially
/// compatible.
pub fn compatible(a: &CompatInfo, b: &CompatInfo) -> bool {
    let temporal = match (a.phase, b.phase) {
        (Some(pa), Some(pb)) => pa != pb,
        _ => false,
    };
    let spatial = match (&a.share_group, &b.share_group) {
        (Some(ga), Some(gb)) => ga == gb,
        _ => false,
    };
    temporal || spatial
}

/// One shared physical memory.
#[derive(Debug, Clone)]
pub struct SharingGroup {
    /// Member buffer names.
    pub members: Vec<String>,
    /// BRAMs of the physical memory: max of members (temporal sharing keeps
    /// only one member's data live at a time).
    pub brams: u64,
    /// BRAMs saved vs. separate memories.
    pub saved: u64,
}

/// A full sharing plan.
#[derive(Debug, Clone, Default)]
pub struct SharingPlan {
    pub groups: Vec<SharingGroup>,
}

impl SharingPlan {
    pub fn total_saved(&self) -> u64 {
        self.groups.iter().map(|g| g.saved).sum()
    }
}

/// Greedy clique partition: biggest buffers first, each placed into the
/// first group whose *every* member is compatible (sharing requires mutual
/// compatibility), else a new group.
pub fn plan_sharing(buffers: &[CompatInfo]) -> SharingPlan {
    let mut order: Vec<usize> = (0..buffers.len()).collect();
    order.sort_by(|&a, &b| buffers[b].brams.cmp(&buffers[a].brams));

    let mut groups: Vec<Vec<usize>> = Vec::new();
    for i in order {
        let slot = groups
            .iter_mut()
            .find(|g| g.iter().all(|&j| compatible(&buffers[i], &buffers[j])));
        match slot {
            Some(g) => g.push(i),
            None => groups.push(vec![i]),
        }
    }

    SharingPlan {
        groups: groups
            .into_iter()
            .map(|g| {
                let total: u64 = g.iter().map(|&i| buffers[i].brams).sum();
                let brams = g.iter().map(|&i| buffers[i].brams).max().unwrap_or(0);
                SharingGroup {
                    members: g.iter().map(|&i| buffers[i].name.clone()).collect(),
                    brams,
                    saved: total - brams,
                }
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf(name: &str, brams: u64, phase: Option<i64>, group: Option<&str>) -> CompatInfo {
        CompatInfo { name: name.into(), brams, phase, share_group: group.map(|s| s.into()) }
    }

    #[test]
    fn different_phases_share() {
        let plan = plan_sharing(&[buf("a", 8, Some(0), None), buf("b", 6, Some(1), None)]);
        assert_eq!(plan.groups.len(), 1);
        assert_eq!(plan.groups[0].brams, 8);
        assert_eq!(plan.total_saved(), 6);
    }

    #[test]
    fn same_phase_does_not_share() {
        let plan = plan_sharing(&[buf("a", 8, Some(0), None), buf("b", 6, Some(0), None)]);
        assert_eq!(plan.groups.len(), 2);
        assert_eq!(plan.total_saved(), 0);
    }

    #[test]
    fn no_info_no_sharing() {
        let plan = plan_sharing(&[buf("a", 8, None, None), buf("b", 6, None, None)]);
        assert_eq!(plan.groups.len(), 2);
    }

    #[test]
    fn spatial_tag_shares() {
        let plan =
            plan_sharing(&[buf("a", 4, None, Some("g")), buf("b", 4, None, Some("g"))]);
        assert_eq!(plan.groups.len(), 1);
        assert_eq!(plan.total_saved(), 4);
    }

    #[test]
    fn mutual_compatibility_required() {
        // a(phase 0), b(phase 1), c(phase 1): c shares with a but NOT with b
        let plan = plan_sharing(&[
            buf("a", 10, Some(0), None),
            buf("b", 9, Some(1), None),
            buf("c", 8, Some(1), None),
        ]);
        // {a, b} share; c can't join (b and c same phase) -> own group
        assert_eq!(plan.groups.len(), 2);
        assert_eq!(plan.total_saved(), 9);
    }

    #[test]
    fn three_phase_pipeline_saves_two_thirds() {
        let bufs: Vec<CompatInfo> =
            (0..6).map(|i| buf(&format!("t{i}"), 10, Some(i % 3), None)).collect();
        let plan = plan_sharing(&bufs);
        // 6 buffers in 3 phases -> groups of 3 distinct phases, 2 groups
        assert_eq!(plan.groups.len(), 2);
        assert_eq!(plan.total_saved(), 40, "60 brams packed into 20");
    }

    #[test]
    fn sharing_plan_is_sound() {
        use crate::util::{prop, Rng};
        prop::check("mnemosyne-sound", 50, 20, |rng: &mut Rng, size| {
            let n = 1 + rng.range(0, size.max(1));
            let bufs: Vec<CompatInfo> = (0..n)
                .map(|i| {
                    buf(
                        &format!("b{i}"),
                        rng.range(1, 64) as u64,
                        rng.chance(0.7).then(|| rng.range(0, 4) as i64),
                        rng.chance(0.3).then(|| "s".to_string()).as_deref(),
                    )
                })
                .collect();
            let plan = plan_sharing(&bufs);
            // every buffer appears exactly once
            let mut seen = std::collections::HashSet::new();
            for g in &plan.groups {
                for m in &g.members {
                    if !seen.insert(m.clone()) {
                        return Err(format!("{m} in two groups"));
                    }
                }
                // pairwise compatibility within the group
                for x in &g.members {
                    for y in &g.members {
                        if x != y {
                            let bx = bufs.iter().find(|b| &b.name == x).unwrap();
                            let by = bufs.iter().find(|b| &b.name == y).unwrap();
                            if !compatible(bx, by) {
                                return Err(format!("{x} and {y} share but are incompatible"));
                            }
                        }
                    }
                }
                // group memory == max member
                let mx = g
                    .members
                    .iter()
                    .map(|m| bufs.iter().find(|b| &b.name == m).unwrap().brams)
                    .max()
                    .unwrap();
                if g.brams != mx {
                    return Err("group size != max member".into());
                }
            }
            if seen.len() != bufs.len() {
                return Err("buffer lost".into());
            }
            Ok(())
        });
    }
}
