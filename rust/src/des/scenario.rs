//! Workload scenarios: how app iterations ("jobs") arrive at the device.
//!
//! Three arrival processes cover the serving regimes the ROADMAP cares
//! about: closed-loop batch (throughput benchmarking), open-loop Poisson
//! (steady online traffic) and bursty on/off (diurnal / flash-crowd
//! traffic, where p99 latency diverges hard from the mean).

use crate::util::{
    f64_from_bits_json, f64_to_bits_json, u64_from_str_json, u64_to_str_json, Json, Rng,
};

use super::time::{TimePoint, TimeSpan};

/// How jobs enter the system.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// `jobs` iterations all admitted at t=0 (a batch drained back-to-back;
    /// the makespan is the batch completion time).
    ClosedLoopBatch { jobs: u64 },
    /// Open loop: exponential interarrivals at `rate_hz` jobs/second.
    Poisson { rate_hz: f64, jobs: u64 },
    /// On/off modulated Poisson: `rate_hz` arrivals during `on_s`-second
    /// windows, silence for `off_s` seconds between them. Same *offered
    /// load* as `Poisson` at `rate_hz * on/(on+off)`, very different tails.
    BurstyOnOff { rate_hz: f64, on_s: f64, off_s: f64, jobs: u64 },
}

/// A named scenario = an arrival process (plus room to grow: per-scenario
/// payload scaling, mixes, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadScenario {
    pub name: String,
    pub arrivals: ArrivalProcess,
}

impl WorkloadScenario {
    pub fn closed_loop(jobs: u64) -> Self {
        WorkloadScenario {
            name: format!("closed-loop-{jobs}"),
            arrivals: ArrivalProcess::ClosedLoopBatch { jobs: jobs.max(1) },
        }
    }

    pub fn poisson(rate_hz: f64, jobs: u64) -> Self {
        WorkloadScenario {
            name: format!("poisson-{rate_hz:.0}hz-{jobs}"),
            arrivals: ArrivalProcess::Poisson { rate_hz, jobs: jobs.max(1) },
        }
    }

    pub fn bursty(rate_hz: f64, on_s: f64, off_s: f64, jobs: u64) -> Self {
        WorkloadScenario {
            name: format!("bursty-{rate_hz:.0}hz-{jobs}"),
            arrivals: ArrivalProcess::BurstyOnOff { rate_hz, on_s, off_s, jobs: jobs.max(1) },
        }
    }

    /// Parse a CLI/protocol scenario spec: `closed:N` | `poisson:HZ:N` |
    /// `bursty:HZ:ON:OFF:N`.
    pub fn parse(spec: &str) -> Result<WorkloadScenario, String> {
        let parts: Vec<&str> = spec.split(':').collect();
        let num = |s: &str| -> Result<f64, String> {
            s.parse::<f64>().map_err(|_| format!("bad number '{s}' in scenario '{spec}'"))
        };
        match parts.as_slice() {
            ["closed", n] => Ok(WorkloadScenario::closed_loop(num(n)? as u64)),
            ["poisson", hz, n] => Ok(WorkloadScenario::poisson(num(hz)?, num(n)? as u64)),
            ["bursty", hz, on, off, n] => {
                Ok(WorkloadScenario::bursty(num(hz)?, num(on)?, num(off)?, num(n)? as u64))
            }
            _ => Err(format!(
                "bad scenario '{spec}' (want closed:N | poisson:HZ:N | bursty:HZ:ON:OFF:N)"
            )),
        }
    }

    /// Wire codec for remote candidate evaluation (`olympus worker`): the
    /// scenario travels as JSON with floats as raw bit patterns, so the
    /// value a worker reconstructs — and therefore the objective's
    /// `Debug` rendering inside every candidate cache key — is
    /// byte-identical to the coordinator's.
    pub fn to_json(&self) -> Json {
        let arrivals = match &self.arrivals {
            ArrivalProcess::ClosedLoopBatch { jobs } => {
                Json::obj(vec![("kind", "closed".into()), ("jobs", u64_to_str_json(*jobs))])
            }
            ArrivalProcess::Poisson { rate_hz, jobs } => Json::obj(vec![
                ("kind", "poisson".into()),
                ("rate_hz", f64_to_bits_json(*rate_hz)),
                ("jobs", u64_to_str_json(*jobs)),
            ]),
            ArrivalProcess::BurstyOnOff { rate_hz, on_s, off_s, jobs } => Json::obj(vec![
                ("kind", "bursty".into()),
                ("rate_hz", f64_to_bits_json(*rate_hz)),
                ("on_s", f64_to_bits_json(*on_s)),
                ("off_s", f64_to_bits_json(*off_s)),
                ("jobs", u64_to_str_json(*jobs)),
            ]),
        };
        Json::obj(vec![("name", self.name.as_str().into()), ("arrivals", arrivals)])
    }

    /// Inverse of [`WorkloadScenario::to_json`]; `None` marks a value this
    /// build cannot decode (callers fail structured, never panic).
    pub fn from_json(j: &Json) -> Option<WorkloadScenario> {
        let name = j.get("name").as_str()?.to_string();
        let a = j.get("arrivals");
        let arrivals = match a.get("kind").as_str()? {
            "closed" => {
                ArrivalProcess::ClosedLoopBatch { jobs: u64_from_str_json(a.get("jobs"))? }
            }
            "poisson" => ArrivalProcess::Poisson {
                rate_hz: f64_from_bits_json(a.get("rate_hz"))?,
                jobs: u64_from_str_json(a.get("jobs"))?,
            },
            "bursty" => ArrivalProcess::BurstyOnOff {
                rate_hz: f64_from_bits_json(a.get("rate_hz"))?,
                on_s: f64_from_bits_json(a.get("on_s"))?,
                off_s: f64_from_bits_json(a.get("off_s"))?,
                jobs: u64_from_str_json(a.get("jobs"))?,
            },
            _ => return None,
        };
        Some(WorkloadScenario { name, arrivals })
    }

    pub fn jobs(&self) -> u64 {
        match self.arrivals {
            ArrivalProcess::ClosedLoopBatch { jobs } => jobs,
            ArrivalProcess::Poisson { jobs, .. } => jobs,
            ArrivalProcess::BurstyOnOff { jobs, .. } => jobs,
        }
    }

    /// Materialize the arrival instants (sorted, deterministic in `rng`).
    pub fn arrival_times(&self, rng: &mut Rng) -> Vec<TimePoint> {
        match self.arrivals {
            ArrivalProcess::ClosedLoopBatch { jobs } => {
                vec![TimePoint::ZERO; jobs as usize]
            }
            ArrivalProcess::Poisson { rate_hz, jobs } => {
                let mut t = TimePoint::ZERO;
                (0..jobs)
                    .map(|_| {
                        t += exp_span(rng, rate_hz);
                        t
                    })
                    .collect()
            }
            ArrivalProcess::BurstyOnOff { rate_hz, on_s, off_s, jobs } => {
                // Draw the process in "active time" (a plain Poisson stream),
                // then stretch it onto the wall clock by inserting the off
                // windows: active time a lands at wall time
                //   floor(a/on) * (on + off) + a mod on.
                let on = on_s.max(1e-9);
                let off = off_s.max(0.0);
                let mut active = 0.0f64;
                (0..jobs)
                    .map(|_| {
                        active += exp_secs(rng, rate_hz);
                        let periods = (active / on).floor();
                        let wall = periods * (on + off) + (active - periods * on);
                        TimePoint::ZERO + TimeSpan::from_secs_f64(wall)
                    })
                    .collect()
            }
        }
    }
}

/// One exponential interarrival sample, in seconds.
fn exp_secs(rng: &mut Rng, rate_hz: f64) -> f64 {
    let rate = rate_hz.max(1e-9);
    let u = rng.f64(); // [0, 1)
    -(1.0 - u).ln() / rate
}

fn exp_span(rng: &mut Rng, rate_hz: f64) -> TimeSpan {
    TimeSpan::from_secs_f64(exp_secs(rng, rate_hz))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_loop_is_all_at_zero() {
        let s = WorkloadScenario::closed_loop(5);
        let mut rng = Rng::new(1);
        let a = s.arrival_times(&mut rng);
        assert_eq!(a.len(), 5);
        assert!(a.iter().all(|t| *t == TimePoint::ZERO));
    }

    #[test]
    fn poisson_mean_interarrival_matches_rate() {
        let s = WorkloadScenario::poisson(1000.0, 4000);
        let mut rng = Rng::new(7);
        let a = s.arrival_times(&mut rng);
        assert_eq!(a.len(), 4000);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "sorted");
        let span = a.last().unwrap().as_secs_f64();
        let mean = span / 4000.0;
        // 1/rate = 1 ms; law of large numbers within 10%
        assert!((mean - 1e-3).abs() < 1e-4, "mean interarrival {mean}");
    }

    #[test]
    fn bursty_avoids_off_windows() {
        let s = WorkloadScenario::bursty(10_000.0, 0.001, 0.009, 500);
        let mut rng = Rng::new(3);
        let a = s.arrival_times(&mut rng);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "sorted");
        // every arrival must land inside an on-window of the 10 ms period
        for t in &a {
            let phase = t.as_secs_f64() % 0.010;
            assert!(phase <= 0.001 + 1e-9, "arrival in off window at phase {phase}");
        }
    }

    #[test]
    fn parse_specs_round_trip() {
        assert_eq!(WorkloadScenario::parse("closed:4").unwrap(), WorkloadScenario::closed_loop(4));
        assert_eq!(
            WorkloadScenario::parse("poisson:1000:20").unwrap(),
            WorkloadScenario::poisson(1000.0, 20)
        );
        assert_eq!(
            WorkloadScenario::parse("bursty:50000:0.0002:0.0008:20").unwrap(),
            WorkloadScenario::bursty(50_000.0, 0.0002, 0.0008, 20)
        );
        assert!(WorkloadScenario::parse("closed").is_err());
        assert!(WorkloadScenario::parse("poisson:x:20").is_err());
        assert!(WorkloadScenario::parse("weird:1").is_err());
    }

    #[test]
    fn json_codec_round_trips_debug_identically() {
        for s in [
            WorkloadScenario::closed_loop(4),
            WorkloadScenario::poisson(1000.0, 20),
            WorkloadScenario::bursty(50_000.0, 0.0002, 0.0008, 20),
        ] {
            let back =
                WorkloadScenario::from_json(&Json::parse(&s.to_json().to_string()).unwrap())
                    .expect("decodes");
            assert_eq!(back, s);
            // the Debug rendering is the cache-key slice: must match exactly
            assert_eq!(format!("{back:?}"), format!("{s:?}"));
        }
        assert!(WorkloadScenario::from_json(&Json::parse("{}").unwrap()).is_none());
        assert!(WorkloadScenario::from_json(&Json::parse(r#"{"name": "x"}"#).unwrap()).is_none());
    }

    #[test]
    fn same_seed_same_arrivals() {
        let s = WorkloadScenario::bursty(500.0, 0.01, 0.02, 100);
        let a = s.arrival_times(&mut Rng::new(9));
        let b = s.arrival_times(&mut Rng::new(9));
        assert_eq!(a, b);
    }
}
