//! Workload scenarios: how app iterations ("jobs") arrive at the device.
//!
//! Five arrival processes cover the serving regimes the ROADMAP cares
//! about: closed-loop batch (throughput benchmarking), open-loop Poisson
//! (steady online traffic), bursty on/off (flash-crowd traffic, where p99
//! latency diverges hard from the mean), a sinusoidally modulated diurnal
//! curve, and verbatim trace replay ([`crate::traffic::trace`]) carrying
//! per-job classes, deadlines and priorities.

use crate::traffic::TraceJob;
use crate::util::{
    f64_from_bits_json, f64_to_bits_json, u64_from_str_json, u64_to_str_json, Json, Rng,
};

use super::time::{TimePoint, TimeSpan};

/// How jobs enter the system.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// `jobs` iterations all admitted at t=0 (a batch drained back-to-back;
    /// the makespan is the batch completion time).
    ClosedLoopBatch { jobs: u64 },
    /// Open loop: exponential interarrivals at `rate_hz` jobs/second.
    Poisson { rate_hz: f64, jobs: u64 },
    /// On/off modulated Poisson: `rate_hz` arrivals during `on_s`-second
    /// windows, silence for `off_s` seconds between them. Same *offered
    /// load* as `Poisson` at `rate_hz * on/(on+off)`, very different tails.
    BurstyOnOff { rate_hz: f64, on_s: f64, off_s: f64, jobs: u64 },
    /// Diurnal curve: non-homogeneous Poisson with rate
    /// `base_hz * (1 + amplitude * sin(2*pi*t / period_s))` — the slow
    /// load swell/ebb of day-night serving traffic, compressed to
    /// simulated-friendly periods. `amplitude` in [0, 1].
    Diurnal { base_hz: f64, amplitude: f64, period_s: f64, jobs: u64 },
    /// Verbatim replay of a recorded trace: every arrival instant, class,
    /// deadline and priority is given, nothing is drawn from the RNG.
    Trace { jobs: Vec<TraceJob> },
}

/// A named scenario = an arrival process (plus room to grow: per-scenario
/// payload scaling, mixes, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadScenario {
    pub name: String,
    pub arrivals: ArrivalProcess,
}

impl WorkloadScenario {
    pub fn closed_loop(jobs: u64) -> Self {
        WorkloadScenario {
            name: format!("closed-loop-{jobs}"),
            arrivals: ArrivalProcess::ClosedLoopBatch { jobs: jobs.max(1) },
        }
    }

    pub fn poisson(rate_hz: f64, jobs: u64) -> Self {
        WorkloadScenario {
            name: format!("poisson-{rate_hz:.0}hz-{jobs}"),
            arrivals: ArrivalProcess::Poisson { rate_hz, jobs: jobs.max(1) },
        }
    }

    pub fn bursty(rate_hz: f64, on_s: f64, off_s: f64, jobs: u64) -> Self {
        WorkloadScenario {
            name: format!("bursty-{rate_hz:.0}hz-{jobs}"),
            arrivals: ArrivalProcess::BurstyOnOff { rate_hz, on_s, off_s, jobs: jobs.max(1) },
        }
    }

    pub fn diurnal(base_hz: f64, amplitude: f64, period_s: f64, jobs: u64) -> Self {
        WorkloadScenario {
            name: format!("diurnal-{base_hz:.0}hz-{jobs}"),
            arrivals: ArrivalProcess::Diurnal { base_hz, amplitude, period_s, jobs: jobs.max(1) },
        }
    }

    /// Parse a CLI/protocol scenario spec: `closed:N` | `poisson:HZ:N` |
    /// `bursty:HZ:ON:OFF:N` | `diurnal:HZ:AMPL:PERIOD:N`. Every rate and
    /// duration is validated (finite, positive where required) — bad floats
    /// fail here with the accepted forms, never inside the simulator. This
    /// parser is pure; the `trace:<file>` spec form reads the filesystem
    /// and therefore lives in [`crate::traffic::scenario_from_spec`].
    pub fn parse(spec: &str) -> Result<WorkloadScenario, String> {
        let forms = "closed:N | poisson:HZ:N | bursty:HZ:ON:OFF:N | \
                     diurnal:HZ:AMPL:PERIOD:N | trace:<file>";
        let bad = |why: String| format!("bad scenario '{spec}': {why} (want {forms})");
        let parts: Vec<&str> = spec.split(':').collect();
        let pos = |s: &str, what: &str| -> Result<f64, String> {
            let x: f64 =
                s.parse().map_err(|_| bad(format!("{what} '{s}' is not a number")))?;
            if !x.is_finite() || x <= 0.0 {
                return Err(bad(format!("{what} must be finite and > 0, got '{s}'")));
            }
            Ok(x)
        };
        let jobs = |s: &str| -> Result<u64, String> {
            let n: u64 = s
                .parse()
                .map_err(|_| bad(format!("job count '{s}' is not a positive integer")))?;
            if n == 0 {
                return Err(bad("job count must be >= 1".to_string()));
            }
            Ok(n)
        };
        match parts.as_slice() {
            ["closed", n] => Ok(WorkloadScenario::closed_loop(jobs(n)?)),
            ["poisson", hz, n] => Ok(WorkloadScenario::poisson(pos(hz, "rate")?, jobs(n)?)),
            ["bursty", hz, on, off, n] => {
                let off_s: f64 =
                    off.parse().map_err(|_| bad(format!("off window '{off}' is not a number")))?;
                if !off_s.is_finite() || off_s < 0.0 {
                    return Err(bad(format!("off window must be finite and >= 0, got '{off}'")));
                }
                Ok(WorkloadScenario::bursty(
                    pos(hz, "rate")?,
                    pos(on, "on window")?,
                    off_s,
                    jobs(n)?,
                ))
            }
            ["diurnal", hz, ampl, period, n] => {
                let amplitude: f64 = ampl
                    .parse()
                    .map_err(|_| bad(format!("amplitude '{ampl}' is not a number")))?;
                if !(0.0..=1.0).contains(&amplitude) {
                    return Err(bad(format!("amplitude must be in [0, 1], got '{ampl}'")));
                }
                Ok(WorkloadScenario::diurnal(
                    pos(hz, "base rate")?,
                    amplitude,
                    pos(period, "period")?,
                    jobs(n)?,
                ))
            }
            ["trace", ..] => Err(bad(
                "trace scenarios read a file; resolve the spec through \
                 traffic::scenario_from_spec (the CLI and submit do)"
                    .to_string(),
            )),
            _ => Err(bad("unrecognized form".to_string())),
        }
    }

    /// Wire codec for remote candidate evaluation (`olympus worker`): the
    /// scenario travels as JSON with floats as raw bit patterns, so the
    /// value a worker reconstructs — and therefore the objective's
    /// `Debug` rendering inside every candidate cache key — is
    /// byte-identical to the coordinator's.
    pub fn to_json(&self) -> Json {
        let arrivals = match &self.arrivals {
            ArrivalProcess::ClosedLoopBatch { jobs } => {
                Json::obj(vec![("kind", "closed".into()), ("jobs", u64_to_str_json(*jobs))])
            }
            ArrivalProcess::Poisson { rate_hz, jobs } => Json::obj(vec![
                ("kind", "poisson".into()),
                ("rate_hz", f64_to_bits_json(*rate_hz)),
                ("jobs", u64_to_str_json(*jobs)),
            ]),
            ArrivalProcess::BurstyOnOff { rate_hz, on_s, off_s, jobs } => Json::obj(vec![
                ("kind", "bursty".into()),
                ("rate_hz", f64_to_bits_json(*rate_hz)),
                ("on_s", f64_to_bits_json(*on_s)),
                ("off_s", f64_to_bits_json(*off_s)),
                ("jobs", u64_to_str_json(*jobs)),
            ]),
            ArrivalProcess::Diurnal { base_hz, amplitude, period_s, jobs } => Json::obj(vec![
                ("kind", "diurnal".into()),
                ("base_hz", f64_to_bits_json(*base_hz)),
                ("amplitude", f64_to_bits_json(*amplitude)),
                ("period_s", f64_to_bits_json(*period_s)),
                ("jobs", u64_to_str_json(*jobs)),
            ]),
            ArrivalProcess::Trace { jobs } => {
                // trace jobs travel inline (integer ps, no floats): a
                // worker reconstructs the exact scenario, so trace-driven
                // cache keys never depend on which process computed them
                let arr: Vec<Json> = jobs
                    .iter()
                    .map(|j| {
                        let mut fields = vec![
                            ("at_ps", u64_to_str_json(j.at_ps)),
                            ("class", j.class.as_str().into()),
                            ("prio", u64_to_str_json(j.prio as u64)),
                        ];
                        if let Some(d) = j.deadline_ps {
                            fields.push(("deadline_ps", u64_to_str_json(d)));
                        }
                        Json::obj(fields)
                    })
                    .collect();
                Json::obj(vec![("kind", "trace".into()), ("jobs", Json::Arr(arr))])
            }
        };
        Json::obj(vec![("name", self.name.as_str().into()), ("arrivals", arrivals)])
    }

    /// Inverse of [`WorkloadScenario::to_json`]; `None` marks a value this
    /// build cannot decode (callers fail structured, never panic).
    pub fn from_json(j: &Json) -> Option<WorkloadScenario> {
        let name = j.get("name").as_str()?.to_string();
        let a = j.get("arrivals");
        let arrivals = match a.get("kind").as_str()? {
            "closed" => {
                ArrivalProcess::ClosedLoopBatch { jobs: u64_from_str_json(a.get("jobs"))? }
            }
            "poisson" => ArrivalProcess::Poisson {
                rate_hz: f64_from_bits_json(a.get("rate_hz"))?,
                jobs: u64_from_str_json(a.get("jobs"))?,
            },
            "bursty" => ArrivalProcess::BurstyOnOff {
                rate_hz: f64_from_bits_json(a.get("rate_hz"))?,
                on_s: f64_from_bits_json(a.get("on_s"))?,
                off_s: f64_from_bits_json(a.get("off_s"))?,
                jobs: u64_from_str_json(a.get("jobs"))?,
            },
            "diurnal" => ArrivalProcess::Diurnal {
                base_hz: f64_from_bits_json(a.get("base_hz"))?,
                amplitude: f64_from_bits_json(a.get("amplitude"))?,
                period_s: f64_from_bits_json(a.get("period_s"))?,
                jobs: u64_from_str_json(a.get("jobs"))?,
            },
            "trace" => {
                let mut jobs = Vec::new();
                for e in a.get("jobs").as_arr()? {
                    let deadline_ps = match e.get("deadline_ps") {
                        Json::Null => None,
                        d => Some(u64_from_str_json(d)?),
                    };
                    jobs.push(TraceJob {
                        at_ps: u64_from_str_json(e.get("at_ps"))?,
                        class: e.get("class").as_str()?.to_string(),
                        deadline_ps,
                        prio: u64_from_str_json(e.get("prio"))? as u32,
                    });
                }
                ArrivalProcess::Trace { jobs }
            }
            _ => return None,
        };
        Some(WorkloadScenario { name, arrivals })
    }

    pub fn jobs(&self) -> u64 {
        match &self.arrivals {
            ArrivalProcess::ClosedLoopBatch { jobs } => *jobs,
            ArrivalProcess::Poisson { jobs, .. } => *jobs,
            ArrivalProcess::BurstyOnOff { jobs, .. } => *jobs,
            ArrivalProcess::Diurnal { jobs, .. } => *jobs,
            ArrivalProcess::Trace { jobs } => jobs.len() as u64,
        }
    }

    /// Materialize the arrival instants (sorted, deterministic in `rng`).
    pub fn arrival_times(&self, rng: &mut Rng) -> Vec<TimePoint> {
        self.plan(rng).times
    }

    /// Materialize the full arrival plan: instants plus the per-job class,
    /// deadline and priority the engine threads through to per-class
    /// reporting. Synthetic scenarios are one anonymous `default` class;
    /// traces carry their own tags.
    pub fn plan(&self, rng: &mut Rng) -> ArrivalPlan {
        let times = match &self.arrivals {
            ArrivalProcess::ClosedLoopBatch { jobs } => {
                vec![TimePoint::ZERO; *jobs as usize]
            }
            ArrivalProcess::Poisson { rate_hz, jobs } => {
                let mut t = TimePoint::ZERO;
                (0..*jobs)
                    .map(|_| {
                        t += exp_span(rng, *rate_hz);
                        t
                    })
                    .collect()
            }
            ArrivalProcess::BurstyOnOff { rate_hz, on_s, off_s, jobs } => {
                // Draw the process in "active time" (a plain Poisson stream),
                // then stretch it onto the wall clock by inserting the off
                // windows: active time a lands at wall time
                //   floor(a/on) * (on + off) + a mod on.
                let on = on_s.max(1e-9);
                let off = off_s.max(0.0);
                let mut active = 0.0f64;
                (0..*jobs)
                    .map(|_| {
                        active += exp_secs(rng, *rate_hz);
                        let periods = (active / on).floor();
                        let wall = periods * (on + off) + (active - periods * on);
                        TimePoint::ZERO + TimeSpan::from_secs_f64(wall)
                    })
                    .collect()
            }
            ArrivalProcess::Diurnal { base_hz, amplitude, period_s, jobs } => {
                // Lewis-Shedler thinning of a homogeneous Poisson stream at
                // the peak rate: accept a candidate at time t with
                // probability rate(t)/peak.
                let peak = base_hz * (1.0 + amplitude);
                let period = period_s.max(1e-9);
                let mut t = 0.0f64;
                let mut out = Vec::with_capacity(*jobs as usize);
                while out.len() < *jobs as usize {
                    t += exp_secs(rng, peak);
                    let rate = base_hz
                        * (1.0 + amplitude * (std::f64::consts::TAU * t / period).sin());
                    if rng.f64() * peak < rate {
                        out.push(TimePoint::ZERO + TimeSpan::from_secs_f64(t));
                    }
                }
                out
            }
            ArrivalProcess::Trace { jobs } => {
                let mut ts: Vec<TimePoint> =
                    jobs.iter().map(|j| TimePoint::from_ps(j.at_ps)).collect();
                ts.sort();
                ts
            }
        };
        let n = times.len();
        if let ArrivalProcess::Trace { jobs } = &self.arrivals {
            let mut sorted: Vec<&TraceJob> = jobs.iter().collect();
            sorted.sort_by_key(|j| j.at_ps);
            let mut class_names: Vec<String> = Vec::new();
            let mut class_of = Vec::with_capacity(n);
            for j in &sorted {
                let idx = match class_names.iter().position(|c| *c == j.class) {
                    Some(i) => i,
                    None => {
                        class_names.push(j.class.clone());
                        class_names.len() - 1
                    }
                };
                class_of.push(idx as u32);
            }
            ArrivalPlan {
                times,
                class_of,
                deadlines: sorted
                    .iter()
                    .map(|j| j.deadline_ps.map(TimeSpan::from_ps))
                    .collect(),
                prios: sorted.iter().map(|j| j.prio).collect(),
                class_names,
            }
        } else {
            ArrivalPlan {
                times,
                class_of: vec![0; n],
                deadlines: vec![None; n],
                prios: vec![0; n],
                class_names: vec!["default".to_string()],
            }
        }
    }
}

/// A materialized scenario: per-job arrival instants plus the traffic tags
/// the engine carries end-to-end. All vectors are indexed by job in
/// arrival-time order.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrivalPlan {
    pub times: Vec<TimePoint>,
    /// Index into `class_names`, per job.
    pub class_of: Vec<u32>,
    /// Optional completion deadline relative to arrival, per job.
    pub deadlines: Vec<Option<TimeSpan>>,
    /// Admission priority (higher = first under backlog), per job.
    pub prios: Vec<u32>,
    pub class_names: Vec<String>,
}

/// One exponential interarrival sample, in seconds.
fn exp_secs(rng: &mut Rng, rate_hz: f64) -> f64 {
    let rate = rate_hz.max(1e-9);
    let u = rng.f64(); // [0, 1)
    -(1.0 - u).ln() / rate
}

fn exp_span(rng: &mut Rng, rate_hz: f64) -> TimeSpan {
    TimeSpan::from_secs_f64(exp_secs(rng, rate_hz))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_loop_is_all_at_zero() {
        let s = WorkloadScenario::closed_loop(5);
        let mut rng = Rng::new(1);
        let a = s.arrival_times(&mut rng);
        assert_eq!(a.len(), 5);
        assert!(a.iter().all(|t| *t == TimePoint::ZERO));
    }

    #[test]
    fn poisson_mean_interarrival_matches_rate() {
        let s = WorkloadScenario::poisson(1000.0, 4000);
        let mut rng = Rng::new(7);
        let a = s.arrival_times(&mut rng);
        assert_eq!(a.len(), 4000);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "sorted");
        let span = a.last().unwrap().as_secs_f64();
        let mean = span / 4000.0;
        // 1/rate = 1 ms; law of large numbers within 10%
        assert!((mean - 1e-3).abs() < 1e-4, "mean interarrival {mean}");
    }

    #[test]
    fn bursty_avoids_off_windows() {
        let s = WorkloadScenario::bursty(10_000.0, 0.001, 0.009, 500);
        let mut rng = Rng::new(3);
        let a = s.arrival_times(&mut rng);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "sorted");
        // every arrival must land inside an on-window of the 10 ms period
        for t in &a {
            let phase = t.as_secs_f64() % 0.010;
            assert!(phase <= 0.001 + 1e-9, "arrival in off window at phase {phase}");
        }
    }

    #[test]
    fn parse_specs_round_trip() {
        assert_eq!(WorkloadScenario::parse("closed:4").unwrap(), WorkloadScenario::closed_loop(4));
        assert_eq!(
            WorkloadScenario::parse("poisson:1000:20").unwrap(),
            WorkloadScenario::poisson(1000.0, 20)
        );
        assert_eq!(
            WorkloadScenario::parse("bursty:50000:0.0002:0.0008:20").unwrap(),
            WorkloadScenario::bursty(50_000.0, 0.0002, 0.0008, 20)
        );
        assert_eq!(
            WorkloadScenario::parse("diurnal:1000:0.8:0.01:50").unwrap(),
            WorkloadScenario::diurnal(1000.0, 0.8, 0.01, 50)
        );
        assert!(WorkloadScenario::parse("closed").is_err());
        assert!(WorkloadScenario::parse("poisson:x:20").is_err());
        assert!(WorkloadScenario::parse("weird:1").is_err());
    }

    #[test]
    fn parse_rejects_nonfinite_zero_and_negative_values() {
        for bad in [
            "poisson:inf:20",
            "poisson:nan:20",
            "poisson:0:20",
            "poisson:-5:20",
            "poisson:1000:0",
            "poisson:1000:-3",
            "poisson:1000:2.5",
            "bursty:1000:inf:0.1:20",
            "bursty:1000:0:0.1:20",
            "bursty:1000:0.1:-1:20",
            "diurnal:1000:1.5:0.01:20",
            "diurnal:1000:nan:0.01:20",
            "diurnal:1000:0.5:0:20",
            "closed:0",
        ] {
            let err = WorkloadScenario::parse(bad).unwrap_err();
            assert!(err.contains("want closed:N"), "'{bad}' -> {err}");
        }
        // trace specs point at a file parser that lives off the pure path
        assert!(WorkloadScenario::parse("trace:/tmp/x").unwrap_err().contains("trace"));
    }

    #[test]
    fn diurnal_modulates_the_rate() {
        // amplitude 1: rate peaks at t = T/4, hits zero at t = 3T/4
        let s = WorkloadScenario::diurnal(10_000.0, 1.0, 0.01, 2000);
        let mut rng = Rng::new(5);
        let plan = s.plan(&mut rng);
        assert!(plan.times.windows(2).all(|w| w[0] <= w[1]), "sorted");
        let (mut rising, mut falling) = (0u64, 0u64);
        for t in &plan.times {
            let phase = t.as_secs_f64() % 0.01 / 0.01;
            if phase < 0.5 {
                rising += 1;
            } else {
                falling += 1;
            }
        }
        // the sin-heavy half-period must carry well over half the arrivals
        assert!(
            rising > falling * 2,
            "diurnal skew missing: {rising} rising vs {falling} falling"
        );
    }

    #[test]
    fn synthetic_plans_are_one_default_class() {
        let s = WorkloadScenario::poisson(1000.0, 10);
        let plan = s.plan(&mut Rng::new(1));
        assert_eq!(plan.class_names, vec!["default".to_string()]);
        assert!(plan.class_of.iter().all(|&c| c == 0));
        assert!(plan.deadlines.iter().all(|d| d.is_none()));
        assert!(plan.prios.iter().all(|&p| p == 0));
    }

    #[test]
    fn trace_plans_carry_tags_in_arrival_order() {
        use crate::traffic::TraceJob;
        let s = WorkloadScenario {
            name: "t".into(),
            arrivals: ArrivalProcess::Trace {
                jobs: vec![
                    TraceJob { at_ps: 500, class: "b".into(), deadline_ps: None, prio: 0 },
                    TraceJob { at_ps: 100, class: "a".into(), deadline_ps: Some(900), prio: 3 },
                ],
            },
        };
        let plan = s.plan(&mut Rng::new(1));
        assert_eq!(plan.times, vec![TimePoint::from_ps(100), TimePoint::from_ps(500)]);
        assert_eq!(plan.class_names, vec!["a".to_string(), "b".to_string()]);
        assert_eq!(plan.class_of, vec![0, 1]);
        assert_eq!(plan.deadlines, vec![Some(TimeSpan::from_ps(900)), None]);
        assert_eq!(plan.prios, vec![3, 0]);
    }

    #[test]
    fn json_codec_round_trips_debug_identically() {
        use crate::traffic::TraceJob;
        for s in [
            WorkloadScenario::closed_loop(4),
            WorkloadScenario::poisson(1000.0, 20),
            WorkloadScenario::bursty(50_000.0, 0.0002, 0.0008, 20),
            WorkloadScenario::diurnal(1000.0, 0.8, 0.01, 50),
            WorkloadScenario {
                name: "trace-2job-abc".into(),
                arrivals: ArrivalProcess::Trace {
                    jobs: vec![
                        TraceJob {
                            at_ps: 0,
                            class: "interactive".into(),
                            deadline_ps: Some(5_000_000),
                            prio: 2,
                        },
                        TraceJob { at_ps: 77, class: "batch".into(), deadline_ps: None, prio: 0 },
                    ],
                },
            },
        ] {
            let back =
                WorkloadScenario::from_json(&Json::parse(&s.to_json().to_string()).unwrap())
                    .expect("decodes");
            assert_eq!(back, s);
            // the Debug rendering is the cache-key slice: must match exactly
            assert_eq!(format!("{back:?}"), format!("{s:?}"));
        }
        assert!(WorkloadScenario::from_json(&Json::parse("{}").unwrap()).is_none());
        assert!(WorkloadScenario::from_json(&Json::parse(r#"{"name": "x"}"#).unwrap()).is_none());
    }

    #[test]
    fn same_seed_same_arrivals() {
        let s = WorkloadScenario::bursty(500.0, 0.01, 0.02, 100);
        let a = s.arrival_times(&mut Rng::new(9));
        let b = s.arrival_times(&mut Rng::new(9));
        assert_eq!(a, b);
    }
}
