//! Integer simulated time.
//!
//! The event calendar orders on a `u64` picosecond counter — exact
//! comparisons, no float-time drift, and fine enough resolution that one
//! 450 MHz HBM beat is ~2222 ticks. `f64` only appears at the edges
//! (converting rates and reporting seconds).

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// Picoseconds per second.
pub const PS_PER_S: f64 = 1e12;

/// An absolute instant on the simulated clock (ps since simulation start).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct TimePoint(u64);

/// A non-negative duration (ps).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct TimeSpan(u64);

impl TimePoint {
    pub const ZERO: TimePoint = TimePoint(0);

    pub fn from_ps(ps: u64) -> TimePoint {
        TimePoint(ps)
    }

    pub fn ps(self) -> u64 {
        self.0
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / PS_PER_S
    }

    /// Duration since `earlier` (saturating: returns zero if `earlier` is
    /// actually later, rather than wrapping).
    pub fn since(self, earlier: TimePoint) -> TimeSpan {
        TimeSpan(self.0.saturating_sub(earlier.0))
    }
}

impl TimeSpan {
    pub const ZERO: TimeSpan = TimeSpan(0);

    pub fn from_ps(ps: u64) -> TimeSpan {
        TimeSpan(ps)
    }

    /// Convert seconds to a span, rounding up so positive durations never
    /// collapse to zero ticks.
    pub fn from_secs_f64(secs: f64) -> TimeSpan {
        if secs <= 0.0 {
            return TimeSpan(0);
        }
        let ps = (secs * PS_PER_S).ceil();
        // clamp: anything near u64::MAX is an upstream bug, not a duration
        TimeSpan(ps.min(u64::MAX as f64 / 2.0) as u64)
    }

    pub fn ps(self) -> u64 {
        self.0
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / PS_PER_S
    }

    pub fn is_zero(self) -> bool {
        self.0 == 0
    }

    /// At least one tick: event reschedules must make progress.
    pub fn at_least_one_tick(self) -> TimeSpan {
        TimeSpan(self.0.max(1))
    }
}

impl Add<TimeSpan> for TimePoint {
    type Output = TimePoint;
    fn add(self, rhs: TimeSpan) -> TimePoint {
        TimePoint(self.0.saturating_add(rhs.0))
    }
}

impl AddAssign<TimeSpan> for TimePoint {
    fn add_assign(&mut self, rhs: TimeSpan) {
        self.0 = self.0.saturating_add(rhs.0);
    }
}

impl Sub<TimePoint> for TimePoint {
    type Output = TimeSpan;
    fn sub(self, rhs: TimePoint) -> TimeSpan {
        self.since(rhs)
    }
}

impl Add<TimeSpan> for TimeSpan {
    type Output = TimeSpan;
    fn add(self, rhs: TimeSpan) -> TimeSpan {
        TimeSpan(self.0.saturating_add(rhs.0))
    }
}

impl AddAssign<TimeSpan> for TimeSpan {
    fn add_assign(&mut self, rhs: TimeSpan) {
        self.0 = self.0.saturating_add(rhs.0);
    }
}

impl fmt::Display for TimePoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}us", self.0 as f64 / 1e6)
    }
}

impl fmt::Display for TimeSpan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}us", self.0 as f64 / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_and_ordering() {
        let t0 = TimePoint::ZERO;
        let t1 = t0 + TimeSpan::from_ps(100);
        assert!(t1 > t0);
        assert_eq!((t1 - t0).ps(), 100);
        assert_eq!((t0 - t1).ps(), 0, "saturates instead of wrapping");
        let mut t = t1;
        t += TimeSpan::from_ps(50);
        assert_eq!(t.ps(), 150);
    }

    #[test]
    fn seconds_roundtrip() {
        let s = TimeSpan::from_secs_f64(1e-6);
        assert_eq!(s.ps(), 1_000_000);
        assert!((s.as_secs_f64() - 1e-6).abs() < 1e-18);
        assert!(TimeSpan::from_secs_f64(-1.0).is_zero());
        // sub-tick durations round UP, never to zero
        assert_eq!(TimeSpan::from_secs_f64(1e-13).ps(), 1);
    }

    #[test]
    fn one_hbm_beat_is_representable() {
        // 450 MHz -> ~2222 ps per beat; integer time must resolve it
        let beat = TimeSpan::from_secs_f64(1.0 / 450e6);
        assert!(beat.ps() > 2000 && beat.ps() < 2500);
    }
}
