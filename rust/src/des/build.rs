//! Lowered [`Architecture`] -> queueing-network description.
//!
//! Mapping (ISSUE: "model the lowered Architecture as a queueing network"):
//!
//! * each **CU** is a dedicated server — steady-state service rate II
//!   cycles/elem at the (congestion-derated) kernel clock, one pipeline
//!   fill charge per admitted job;
//! * each **data mover** is a server on a *shared-rate* resource: all
//!   movers concurrently transferring on one HBM pseudo-channel split its
//!   beat rate fairly (and the channel derates to `sustained_frac` of peak
//!   the moment it is shared — the arXiv 2010.08916 effect);
//! * each **stream FIFO** is a finite queue: a full FIFO backpressures its
//!   producer (mover stalls, CU cannot fire);
//! * **PLM/AXI endpoints** carry scalars/config: their beats count against
//!   the memory channel, but they do not flow-control kernels.

use anyhow::{bail, Result};

use crate::lower::{Architecture, Endpoint, MoverDir, MoverInst};
use crate::platform::PlatformSpec;

/// One logical array a mover carries (dedup'd Iris split fields).
#[derive(Debug, Clone)]
pub struct FlowSpec {
    /// Logical array name (host-buffer binding).
    pub base: String,
    /// Target/source FIFO; `None` = PLM or AXI endpoint (no flow control).
    pub fifo: Option<usize>,
    /// Elements of this array per app iteration.
    pub elems_per_job: u64,
    /// Memory-channel beats consumed per element (fractional when several
    /// arrays share a packed word).
    pub beats_per_elem: f64,
}

/// A data mover (or AXI port stand-in) on a shared memory channel.
#[derive(Debug, Clone)]
pub struct MoverSpec {
    pub name: String,
    pub pc: usize,
    pub read: bool,
    pub flows: Vec<FlowSpec>,
}

impl MoverSpec {
    /// Per-job elements that traverse FIFOs (job-completion accounting).
    pub fn fifo_elems_per_job(&self) -> u64 {
        self.flows.iter().filter(|f| f.fifo.is_some()).map(|f| f.elems_per_job).sum()
    }
}

/// A finite stream queue.
#[derive(Debug, Clone)]
pub struct FifoSpec {
    pub name: String,
    pub cap_elems: u64,
}

/// A kernel compute-unit server.
#[derive(Debug, Clone)]
pub struct CuSpec {
    pub name: String,
    pub in_fifos: Vec<usize>,
    pub out_fifos: Vec<usize>,
    pub ii: u64,
    pub latency: u64,
    /// For CUs with no stream inputs (all-PLM params): how many output
    /// elements one job produces.
    pub out_elems_per_job: u64,
}

impl CuSpec {
    pub fn source_like(&self) -> bool {
        self.in_fifos.is_empty()
    }
}

/// The whole network.
#[derive(Debug, Clone)]
pub struct DesNet {
    pub platform: PlatformSpec,
    pub fifos: Vec<FifoSpec>,
    pub movers: Vec<MoverSpec>,
    pub cus: Vec<CuSpec>,
    /// Per-FIFO elems one job pushes through it (hint; cap when unknown).
    pub fifo_job_elems: Vec<u64>,
}

/// Replica index encoded in a channel/array name by the replicate pass
/// (`ch0#r2` -> 2; no `#r` suffix -> 0, the original).
fn replica_index(name: &str) -> u64 {
    match name.rfind("#r") {
        Some(i) => {
            let digits: String =
                name[i + 2..].chars().take_while(|c| c.is_ascii_digit()).collect();
            digits.parse().unwrap_or(0)
        }
        None => 0,
    }
}

/// Replica `r`'s share of `total` job elements under `n` replicas (shares
/// differ by at most one and sum to `total`).
fn stripe_share(total: u64, r: u64, n: u64) -> u64 {
    total / n + u64::from(r < total % n)
}

impl DesNet {
    /// Replica-aware job striping: when the replicate pass cloned the DFG
    /// (`#rN` channel suffixes), one arriving job is split across the
    /// replicas instead of being processed in full by every copy — replica
    /// `r` carries `1/N` of each FIFO-fed stream (PLM/AXI side traffic stays
    /// full-size: every clone still loads its own configuration). This is
    /// what credits `replicate` with *throughput* in `des-score` rather than
    /// just charging it contention. Returns `None` when the net has no
    /// replicas (nothing to stripe).
    pub fn striped(&self) -> Option<DesNet> {
        let n = self
            .movers
            .iter()
            .flat_map(|m| m.flows.iter())
            .filter(|f| f.fifo.is_some())
            .map(|f| replica_index(&f.base))
            .max()
            .map(|max| max + 1)
            .unwrap_or(1);
        if n < 2 {
            return None;
        }
        let mut net = self.clone();
        for mv in net.movers.iter_mut() {
            for fl in mv.flows.iter_mut() {
                if fl.fifo.is_some() {
                    fl.elems_per_job = stripe_share(fl.elems_per_job, replica_index(&fl.base), n);
                }
            }
        }
        // re-derive the per-FIFO job payload hints from the striped flows
        net.fifo_job_elems = net.fifos.iter().map(|f| f.cap_elems).collect();
        for mv in &net.movers {
            for fl in &mv.flows {
                if let Some(fi) = fl.fifo {
                    net.fifo_job_elems[fi] = fl.elems_per_job;
                }
            }
        }
        for cu in net.cus.iter_mut() {
            if let Some(&f) = cu.out_fifos.first() {
                cu.out_elems_per_job = net.fifo_job_elems[f].max(1);
            }
        }
        Some(net)
    }
}

/// f32 elements per physical word of `width_bits`.
fn elems_per_word(width_bits: u32) -> u64 {
    (width_bits as u64 / 32).max(1)
}

fn mover_flows(arch: &Architecture, mv: &MoverInst) -> Vec<FlowSpec> {
    let spec = &arch.platform.pcs[mv.pc_id as usize];
    let beats_per_word = (mv.layout.word_bits as u64).div_ceil(spec.width_bits as u64).max(1);
    // total elems per word across all fields (Iris packs several arrays)
    let mut total_elems_per_job = 0u64;
    let mut per_base: Vec<(String, Option<usize>, u64)> = Vec::new();
    for (field, ep) in &mv.routes {
        let base = field.split('.').next().unwrap_or(field).to_string();
        // count of this field's elems per word
        let count: u64 = mv
            .layout
            .fields
            .iter()
            .filter(|f| f.array == *field)
            .map(|f| f.count as u64)
            .sum::<u64>()
            .max(1);
        let elems = count * mv.layout.depth;
        total_elems_per_job += elems;
        let fifo = match ep {
            Endpoint::Fifo(i) => Some(*i),
            _ => None,
        };
        if let Some(e) = per_base.iter_mut().find(|(b, _, _)| *b == base) {
            e.2 += elems; // split fields (`b.0`, `b.1`) accumulate into the base
        } else {
            per_base.push((base, fifo, elems));
        }
    }
    let total_beats = (mv.layout.depth * beats_per_word) as f64;
    let beats_per_elem =
        if total_elems_per_job == 0 { 1.0 } else { total_beats / total_elems_per_job as f64 };
    per_base
        .into_iter()
        .map(|(base, fifo, elems)| FlowSpec {
            base,
            fifo,
            elems_per_job: elems.max(1),
            beats_per_elem,
        })
        .collect()
}

/// Build the queueing network for `arch`.
pub fn build_network(arch: &Architecture) -> Result<DesNet> {
    let mut fifos = Vec::with_capacity(arch.fifos.len());
    for f in &arch.fifos {
        fifos.push(FifoSpec {
            name: f.name.clone(),
            cap_elems: (f.depth_words * elems_per_word(f.width_bits)).max(1),
        });
    }

    // Port sharing (the mapping phase's budget decisions): k endpoints
    // time-multiplexing one physical AXI port each see the channel through
    // a 1/k duty window — modelled as a k× beat inflation on every flow of
    // a shared endpoint. Conservative and static; k = 1 (a dedicated port,
    // the common case) leaves the flow bit-identical to the unmapped model.
    let mut movers = Vec::new();
    for mv in &arch.movers {
        if mv.pc_id as usize >= arch.platform.pcs.len() {
            bail!("mover '{}': pc {} out of range", mv.name, mv.pc_id);
        }
        let mut flows = mover_flows(arch, mv);
        let sharers = arch.mapping.sharers_of(&mv.name);
        if sharers > 1 {
            for fl in flows.iter_mut() {
                fl.beats_per_elem *= sharers as f64;
            }
        }
        movers.push(MoverSpec {
            name: mv.name.clone(),
            pc: mv.pc_id as usize,
            read: mv.dir == MoverDir::Read,
            flows,
        });
    }
    // complex channels: AXI masters contend for the channel like movers do
    for ax in &arch.axi_ports {
        let pc = ax.pc_id as usize;
        if pc >= arch.platform.pcs.len() {
            bail!("axi port '{}': pc {} out of range", ax.name, ax.pc_id);
        }
        let width = arch.platform.pcs[pc].width_bits;
        let sharers = arch.mapping.sharers_of(&format!("axi:{}", ax.name));
        movers.push(MoverSpec {
            name: format!("axi_{}", ax.name),
            pc,
            read: true,
            flows: vec![FlowSpec {
                base: ax.name.clone(),
                fifo: None,
                elems_per_job: (ax.bytes / 4).max(1),
                beats_per_elem: 32.0 / width as f64 * sharers as f64,
            }],
        });
    }

    // A FIFO gets exactly one read-side and one write-side mover: when a
    // channel is bound to several PCs (hand-written IR can do that), the
    // extra movers keep their beat accounting but stop carrying elements,
    // so the element flow stays conserved.
    let mut read_owner: Vec<bool> = vec![false; fifos.len()];
    let mut write_owner: Vec<bool> = vec![false; fifos.len()];
    for mv in movers.iter_mut() {
        let owner = if mv.read { &mut read_owner } else { &mut write_owner };
        for fl in mv.flows.iter_mut() {
            if let Some(fi) = fl.fifo {
                if owner[fi] {
                    fl.fifo = None;
                } else {
                    owner[fi] = true;
                }
            }
        }
    }

    // per-FIFO job payload: prefer the mover flow that touches it
    let mut fifo_job_elems: Vec<u64> = fifos.iter().map(|f| f.cap_elems).collect();
    for mv in &movers {
        for fl in &mv.flows {
            if let Some(fi) = fl.fifo {
                fifo_job_elems[fi] = fl.elems_per_job;
            }
        }
    }

    let mut cus = Vec::with_capacity(arch.cus.len());
    for cu in &arch.cus {
        let pick = |eps: &[Endpoint]| -> Vec<usize> {
            eps.iter()
                .filter_map(|e| match e {
                    Endpoint::Fifo(i) => Some(*i),
                    _ => None,
                })
                .collect()
        };
        let in_fifos = pick(&cu.inputs);
        let out_fifos = pick(&cu.outputs);
        let out_elems_per_job =
            out_fifos.first().map(|&f| fifo_job_elems[f]).unwrap_or(1).max(1);
        cus.push(CuSpec {
            name: cu.name.clone(),
            in_fifos,
            out_fifos,
            ii: cu.ii.max(1),
            latency: cu.latency,
            out_elems_per_job,
        });
    }

    // Bank conflicts: once more movers sit on one channel than it has
    // banks, not every stream can hide its row activates behind bank
    // interleaving — fold the platform's conflict derate into the
    // channel's sustained fraction. DES-only, like `sustained_frac`
    // itself; HBM builtins carry derate 1.0 so this is a DDR effect.
    let mut platform = arch.platform.clone();
    let mut movers_on_pc = vec![0usize; platform.pcs.len()];
    for mv in &movers {
        movers_on_pc[mv.pc] += 1;
    }
    for (pc, spec) in platform.pcs.iter_mut().enumerate() {
        if movers_on_pc[pc] > spec.banks as usize && spec.bank_conflict_derate < 1.0 {
            spec.sustained_frac *= spec.bank_conflict_derate;
        }
    }

    Ok(DesNet {
        platform,
        fifos,
        movers,
        cus,
        fifo_job_elems,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::lower::build_architecture;
    use crate::passes::manager::{parse_pipeline, PassContext};
    use crate::platform::builtin;

    fn net_for(pipeline: &str) -> DesNet {
        let mut m = fig4a_module();
        let plat = builtin("u280").unwrap();
        let mut ctx = PassContext::new(plat.clone());
        parse_pipeline(pipeline, &mut ctx).unwrap().run(&mut m, &ctx).unwrap();
        let arch = build_architecture(&m, &plat).unwrap();
        build_network(&arch).unwrap()
    }

    #[test]
    fn baseline_vecadd_network_shape() {
        let net = net_for("sanitize");
        assert_eq!(net.fifos.len(), 3);
        assert_eq!(net.movers.len(), 3);
        assert_eq!(net.cus.len(), 1);
        assert_eq!(net.cus[0].in_fifos.len(), 2);
        assert_eq!(net.cus[0].out_fifos.len(), 1);
        assert!(!net.cus[0].source_like());
        // naive scalar words: 1 beat per elem, 1024 elems per job
        for mv in &net.movers {
            assert_eq!(mv.flows.len(), 1);
            assert_eq!(mv.flows[0].elems_per_job, 1024);
            assert!((mv.flows[0].beats_per_elem - 1.0).abs() < 1e-12);
        }
        let reads = net.movers.iter().filter(|m| m.read).count();
        assert_eq!(reads, 2);
    }

    #[test]
    fn iris_bus_splits_beats_across_arrays() {
        let net = net_for("sanitize, iris, channel-reassign");
        // one read bus carrying ch0+ch1, one write bus
        assert_eq!(net.movers.len(), 2);
        let read = net.movers.iter().find(|m| m.read).unwrap();
        assert_eq!(read.flows.len(), 2);
        let total_elems: u64 = read.flows.iter().map(|f| f.elems_per_job).sum();
        assert_eq!(total_elems, 2048);
        // 8 x 32-bit slots per 256-bit word: 1/8 beat per elem
        for f in &read.flows {
            assert!((f.beats_per_elem - 0.125).abs() < 1e-9, "{f:?}");
            assert!(f.fifo.is_some());
        }
        assert_eq!(read.fifo_elems_per_job(), 2048);
    }

    #[test]
    fn replication_multiplies_network_nodes() {
        let net = net_for("sanitize, replicate{factor=2}, channel-reassign");
        assert_eq!(net.cus.len(), 2);
        assert_eq!(net.fifos.len(), 6);
        assert_eq!(net.movers.len(), 6);
    }

    #[test]
    fn fifo_capacity_accounts_for_word_packing() {
        let net = net_for("sanitize");
        for f in &net.fifos {
            assert_eq!(f.cap_elems, 1024);
        }
    }

    #[test]
    fn replica_free_net_does_not_stripe() {
        assert!(net_for("sanitize").striped().is_none());
        assert!(net_for("sanitize, iris, channel-reassign").striped().is_none());
    }

    #[test]
    fn striping_splits_job_elems_across_replicas_conserving_totals() {
        let net = net_for("sanitize, replicate{factor=2}, channel-reassign");
        let striped = net.striped().expect("2 replicas to stripe");
        assert_eq!(striped.movers.len(), net.movers.len());
        // every fifo-fed flow halves (1024 splits as 512 + 512)...
        for (mv, smv) in net.movers.iter().zip(&striped.movers) {
            for (fl, sfl) in mv.flows.iter().zip(&smv.flows) {
                if fl.fifo.is_some() {
                    assert_eq!(sfl.elems_per_job, 512, "{}", mv.name);
                } else {
                    assert_eq!(sfl.elems_per_job, fl.elems_per_job, "{}", mv.name);
                }
            }
        }
        // ...so per-replica-group totals are conserved
        let total: u64 = net
            .movers
            .iter()
            .filter(|m| m.read)
            .map(|m| m.fifo_elems_per_job())
            .sum();
        let striped_total: u64 = striped
            .movers
            .iter()
            .filter(|m| m.read)
            .map(|m| m.fifo_elems_per_job())
            .sum();
        assert_eq!(striped_total * 2, total);
    }

    #[test]
    fn stripe_shares_differ_by_at_most_one_and_sum() {
        for total in [0u64, 1, 7, 1024, 1025] {
            for n in [2u64, 3, 4, 16] {
                let shares: Vec<u64> = (0..n).map(|r| stripe_share(total, r, n)).collect();
                assert_eq!(shares.iter().sum::<u64>(), total, "total {total} n {n}");
                let mx = *shares.iter().max().unwrap();
                let mn = *shares.iter().min().unwrap();
                assert!(mx - mn <= 1, "{shares:?}");
            }
        }
    }

    #[test]
    fn replica_index_parses_suffixes() {
        assert_eq!(replica_index("ch0"), 0);
        assert_eq!(replica_index("ch0#r1"), 1);
        assert_eq!(replica_index("ch0#r12"), 12);
        assert_eq!(replica_index("bus#r3"), 3);
    }

    fn tiny_plat(axi_ports: usize, banks: u32) -> PlatformSpec {
        use crate::platform::{MemKind, PcSpec};
        PlatformSpec {
            name: "tiny".into(),
            pcs: vec![PcSpec {
                kind: MemKind::Ddr,
                width_bits: 32,
                freq_mhz: 1000.0,
                capacity_bytes: 1 << 30,
                sustained_frac: 0.9,
                banks,
                bank_conflict_derate: 0.5,
            }],
            resources: crate::dialect::ResourceVec::new(2_000_000, 1_000_000, 2_000, 100, 4_000),
            util_limit: 0.8,
            kernel_mhz: 300.0,
            axi_ports,
        }
    }

    fn net_on(plat: &PlatformSpec, pipeline: &str) -> DesNet {
        let mut m = fig4a_module();
        let mut ctx = PassContext::new(plat.clone());
        parse_pipeline(pipeline, &mut ctx).unwrap().run(&mut m, &ctx).unwrap();
        let arch = build_architecture(&m, plat).unwrap();
        build_network(&arch).unwrap()
    }

    #[test]
    fn shared_ports_inflate_beats() {
        // 3 movers on one channel through 2 ports: the two endpoints on
        // the shared port pay 2x beats, the dedicated one stays at 1x
        let net = net_on(&tiny_plat(2, 16), "sanitize");
        let mut factors: Vec<f64> =
            net.movers.iter().map(|m| m.flows[0].beats_per_elem).collect();
        factors.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(factors, vec![1.0, 2.0, 2.0]);
        // with a port per endpoint the inflation disappears
        let free = net_on(&tiny_plat(3, 16), "sanitize");
        assert!(free.movers.iter().all(|m| m.flows[0].beats_per_elem == 1.0));
    }

    #[test]
    fn bank_conflicts_derate_sustained_frac() {
        // 3 movers on a single-bank channel: sustained 0.9 x 0.5 = 0.45
        let net = net_on(&tiny_plat(3, 1), "sanitize");
        assert!((net.platform.pcs[0].sustained_frac - 0.45).abs() < 1e-12);
        // enough banks: no derate
        let free = net_on(&tiny_plat(3, 16), "sanitize");
        assert!((free.platform.pcs[0].sustained_frac - 0.9).abs() < 1e-12);
        // the architecture's own platform is never mutated
        let mut m = fig4a_module();
        let plat = tiny_plat(3, 1);
        let mut ctx = PassContext::new(plat.clone());
        parse_pipeline("sanitize", &mut ctx).unwrap().run(&mut m, &ctx).unwrap();
        let arch = build_architecture(&m, &plat).unwrap();
        let _ = build_network(&arch).unwrap();
        assert!((arch.platform.pcs[0].sustained_frac - 0.9).abs() < 1e-12);
    }

    #[test]
    fn port_sharing_slows_the_des_makespan() {
        use crate::des::{simulate, DesConfig, WorkloadScenario};
        let sc = WorkloadScenario::closed_loop(2);
        let cfg = DesConfig::default();
        let run = |ports: usize| {
            let plat = tiny_plat(ports, 16);
            let mut m = fig4a_module();
            let mut ctx = PassContext::new(plat.clone());
            parse_pipeline("sanitize", &mut ctx).unwrap().run(&mut m, &ctx).unwrap();
            let arch = build_architecture(&m, &plat).unwrap();
            simulate(&arch, &sc, &cfg).unwrap().makespan_s
        };
        let dedicated = run(3);
        let shared = run(2);
        assert!(
            shared > dedicated,
            "sharing ports must cost wall time: shared {shared} vs dedicated {dedicated}"
        );
    }
}
