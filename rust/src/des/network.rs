//! The discrete-event engine: jobs flow through the queueing network built
//! by [`super::build`], driven by a binary-heap event calendar.
//!
//! Event types:
//! * `Arrival` — a job (one app iteration) enters: read movers enqueue
//!   their chunk streams, source-like CUs gain work.
//! * `PcWake` — a shared-rate memory channel re-evaluates its in-flight
//!   transfers (the processor-sharing completion scan). Stale wakes are
//!   filtered by an epoch counter, the standard event-invalidation trick.
//! * `CuDone` — a compute unit finishes one chunk service.
//!
//! Progress guarantees (no simulated deadlock on the feed-forward DFGs the
//! passes produce): chunk sizes are clamped to FIFO capacity, a CU fires
//! with any partial chunk as long as every input has data and every output
//! has space, and write movers drain any non-empty FIFO.

use anyhow::{bail, Result};
use std::collections::{BinaryHeap, VecDeque};
use std::fmt;

use crate::lower::Architecture;
use crate::obs::TraceSink;
use crate::sim::TimingModel;
use crate::traffic::AutoscalePolicy;
use crate::util::{
    f64_from_bits_json, f64_to_bits_json, u64_from_str_json, u64_to_str_json, Json, Rng,
};

use super::build::{build_network, DesNet};
use super::calendar::{Calendar, CalendarKind};
use super::metrics::{percentile, DepthTrack, DesReport, NodeKind, NodeMetrics};
use super::scenario::{ArrivalPlan, WorkloadScenario};
use super::time::{TimePoint, TimeSpan, PS_PER_S};

/// Per-chunk CU service-time distribution. Every stochastic variant is
/// normalized to **unit mean** and scaled by the deterministic service
/// time, so swapping distributions changes the *shape* of service noise
/// without moving the offered load `rho` — exactly what the M/G/1
/// calibration tests need to compare tails at matched throughput.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ServiceDist {
    /// Exactly `II x elems` cycles per chunk (an HLS pipeline's steady
    /// state; the default).
    Deterministic,
    /// Exponentially distributed with the deterministic value as its mean
    /// (memoryless service — used by the M/M/1 calibration tests and for
    /// modeling data-dependent kernels).
    Exponential,
    /// Log-normal with unit mean and log-scale `sigma` (> 0): moderate
    /// heavy tail, the classic fit for data-dependent kernel runtimes.
    LogNormal { sigma: f64 },
    /// Pareto with unit mean and shape `alpha` (> 1, else the mean
    /// diverges): power-law tail; `alpha` near 1 is pathological,
    /// `alpha >= 2` has finite variance.
    Pareto { alpha: f64 },
}

impl ServiceDist {
    /// Wire spec (see [`DesConfig::to_json`]); parameters print with
    /// Rust's shortest-round-trip float formatting, so
    /// `parse(spec()) == self` bit-for-bit.
    pub fn spec(self) -> String {
        match self {
            ServiceDist::Deterministic => "deterministic".to_string(),
            ServiceDist::Exponential => "exponential".to_string(),
            ServiceDist::LogNormal { sigma } => format!("lognormal:{sigma}"),
            ServiceDist::Pareto { alpha } => format!("pareto:{alpha}"),
        }
    }

    /// Inverse of [`ServiceDist::spec`]. Rejects malformed, non-finite or
    /// out-of-range parameters with an error listing the accepted forms.
    pub fn parse(s: &str) -> std::result::Result<ServiceDist, String> {
        let forms = "deterministic | exponential | lognormal:SIGMA | pareto:ALPHA";
        let bad = |why: &str| format!("bad service dist '{s}': {why} (want {forms})");
        let param = |v: &str, name: &str| -> std::result::Result<f64, String> {
            let x: f64 =
                v.parse().map_err(|_| bad(&format!("{name} '{v}' is not a number")))?;
            if !x.is_finite() {
                return Err(bad(&format!("{name} must be finite")));
            }
            Ok(x)
        };
        match s.split_once(':') {
            None => match s {
                "deterministic" => Ok(ServiceDist::Deterministic),
                "exponential" => Ok(ServiceDist::Exponential),
                _ => Err(bad("unknown distribution")),
            },
            Some(("lognormal", v)) => {
                let sigma = param(v, "sigma")?;
                if sigma <= 0.0 {
                    return Err(bad("sigma must be > 0"));
                }
                Ok(ServiceDist::LogNormal { sigma })
            }
            Some(("pareto", v)) => {
                let alpha = param(v, "alpha")?;
                if alpha <= 1.0 {
                    return Err(bad("alpha must be > 1 for a finite mean"));
                }
                Ok(ServiceDist::Pareto { alpha })
            }
            Some(_) => Err(bad("unknown distribution")),
        }
    }

    /// Draw a unit-mean service multiplier.
    fn sample(self, rng: &mut Rng) -> f64 {
        match self {
            ServiceDist::Deterministic => 1.0,
            ServiceDist::Exponential => {
                // Exp(1): -ln(1 - U), U in [0,1)
                -(1.0 - rng.f64()).ln()
            }
            ServiceDist::LogNormal { sigma } => {
                // exp(sigma Z - sigma^2/2) has mean exactly 1
                (sigma * rng.gaussian() - 0.5 * sigma * sigma).exp()
            }
            ServiceDist::Pareto { alpha } => {
                // scale x_m = (alpha-1)/alpha gives mean x_m alpha/(alpha-1) = 1
                let u = 1.0 - rng.f64(); // (0, 1]
                ((alpha - 1.0) / alpha) * u.powf(-1.0 / alpha)
            }
        }
    }
}

/// Engine knobs (separate from the workload scenario).
#[derive(Clone)]
pub struct DesConfig {
    /// RNG seed for the arrival process (and service draws, when a
    /// service distribution is stochastic).
    pub seed: u64,
    /// Transfer/service granularity in elements. Smaller = finer-grained
    /// contention modeling, more events.
    pub burst_elems: u64,
    /// Fabric utilization (from `analyze_resources`) for the congestion
    /// clock derate.
    pub utilization: f64,
    /// Apply the routing-congestion derate to the kernel clock.
    pub congestion_model: bool,
    /// Hard cap on dispatched events (runaway guard).
    pub max_events: u64,
    /// Stripe each job's stream payload across DFG replicas
    /// ([`DesNet::striped`]) instead of replaying the full job on every
    /// copy. On by default: this is what makes `replicate` a throughput
    /// play under `des-score`.
    pub stripe_replicas: bool,
    /// Default CU service-time distribution (per-CU overrides below win).
    pub service_dist: ServiceDist,
    /// Per-CU service-distribution overrides: an entry matches a CU whose
    /// name equals it, or extends it at a `_` separator — so `cu_k` covers
    /// every replica/lane clone (`cu_k_0_r1_l0`, ...) the replicate and
    /// bus-widen passes generate, without `s1` accidentally matching `s10`.
    /// Lets a single data-dependent kernel go heavy-tailed while the rest
    /// of the design stays deterministic; the last matching entry wins.
    pub cu_service_dists: Vec<(String, ServiceDist)>,
    /// Elastic replicas: run an autoscaler controller inside the
    /// simulation, clocking each CU's active replica count between the
    /// policy's bounds from observed backlog (`--autoscale`). `None` =
    /// static capacity.
    pub autoscale: Option<AutoscalePolicy>,
    /// Which event-calendar implementation schedules the run
    /// (`--calendar`). Pure mechanism: both calendars produce byte-
    /// identical reports, so this knob is deliberately **excluded** from
    /// the manual `Debug` impl below (whose rendering feeds every
    /// content-addressed cache key) and from the wire codec — a cached or
    /// remotely-evaluated answer is valid under either engine.
    pub calendar: CalendarKind,
}

/// Hand-rolled to keep [`DesConfig::calendar`] out of the rendering:
/// `format!("{config:?}")` is embedded in candidate cache keys and in the
/// coordinator/worker key-parity check, and the calendar choice must never
/// split those caches. Field order and style match what `derive(Debug)`
/// produced before the knob existed, so on-disk journals stay warm.
impl fmt::Debug for DesConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("DesConfig")
            .field("seed", &self.seed)
            .field("burst_elems", &self.burst_elems)
            .field("utilization", &self.utilization)
            .field("congestion_model", &self.congestion_model)
            .field("max_events", &self.max_events)
            .field("stripe_replicas", &self.stripe_replicas)
            .field("service_dist", &self.service_dist)
            .field("cu_service_dists", &self.cu_service_dists)
            .field("autoscale", &self.autoscale)
            .finish()
    }
}

impl DesConfig {
    /// Effective service distribution for the CU named `cu_name` (see
    /// [`DesConfig::cu_service_dists`] for the matching rule).
    pub fn dist_for(&self, cu_name: &str) -> ServiceDist {
        let matches = |name: &str| {
            cu_name == name
                || cu_name
                    .strip_prefix(name)
                    .map(|rest| rest.starts_with('_'))
                    .unwrap_or(false)
        };
        self.cu_service_dists
            .iter()
            .rev()
            .find(|(name, _)| matches(name))
            .map(|(_, dist)| *dist)
            .unwrap_or(self.service_dist)
    }
}

impl DesConfig {
    /// Wire codec for remote candidate evaluation (`olympus worker`):
    /// every engine knob travels, floats as raw bit patterns, so the
    /// config a worker reconstructs `Debug`-renders — and therefore cache-
    /// keys — byte-identically to the coordinator's.
    pub fn to_json(&self) -> Json {
        let dists: Vec<Json> = self
            .cu_service_dists
            .iter()
            .map(|(cu, dist)| {
                Json::obj(vec![("cu", cu.as_str().into()), ("dist", dist.spec().into())])
            })
            .collect();
        let mut fields = vec![
            ("seed", u64_to_str_json(self.seed)),
            ("burst_elems", u64_to_str_json(self.burst_elems)),
            ("utilization", f64_to_bits_json(self.utilization)),
            ("congestion_model", self.congestion_model.into()),
            ("max_events", u64_to_str_json(self.max_events)),
            ("stripe_replicas", self.stripe_replicas.into()),
            ("service_dist", self.service_dist.spec().into()),
            ("cu_service_dists", Json::Arr(dists)),
        ];
        if let Some(p) = &self.autoscale {
            fields.push(("autoscale", p.to_json()));
        }
        Json::obj(fields)
    }

    /// Inverse of [`DesConfig::to_json`]; `None` marks a value this build
    /// cannot decode.
    pub fn from_json(j: &Json) -> Option<DesConfig> {
        let mut cu_service_dists = Vec::new();
        for e in j.get("cu_service_dists").as_arr()? {
            cu_service_dists.push((
                e.get("cu").as_str()?.to_string(),
                ServiceDist::parse(e.get("dist").as_str()?).ok()?,
            ));
        }
        let autoscale = match j.get("autoscale") {
            Json::Null => None,
            p => Some(AutoscalePolicy::from_json(p)?),
        };
        Some(DesConfig {
            seed: u64_from_str_json(j.get("seed"))?,
            burst_elems: u64_from_str_json(j.get("burst_elems"))?,
            utilization: f64_from_bits_json(j.get("utilization"))?,
            congestion_model: j.get("congestion_model").as_bool()?,
            max_events: u64_from_str_json(j.get("max_events"))?,
            stripe_replicas: j.get("stripe_replicas").as_bool()?,
            service_dist: ServiceDist::parse(j.get("service_dist").as_str()?).ok()?,
            cu_service_dists,
            autoscale,
            // deliberately not on the wire: results are calendar-invariant,
            // so the receiving process schedules on its own default
            calendar: CalendarKind::default(),
        })
    }
}

impl Default for DesConfig {
    fn default() -> Self {
        DesConfig {
            seed: 42,
            burst_elems: 64,
            utilization: 0.0,
            congestion_model: true,
            max_events: 20_000_000,
            stripe_replicas: true,
            service_dist: ServiceDist::Deterministic,
            cu_service_dists: Vec::new(),
            autoscale: None,
            calendar: CalendarKind::default(),
        }
    }
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrival { job: u64 },
    PcWake { pc: usize, epoch: u64 },
    CuDone { cu: usize, epoch: u64 },
    /// Autoscaler controller tick (at most one in flight; self-reschedules
    /// while jobs remain outstanding).
    Autoscale,
}

/// Who to poke when a FIFO changes state.
#[derive(Debug, Clone, Copy)]
enum Node {
    Mover(usize),
    Cu(usize),
}

/// Below this many beats a transfer counts as finished (float PS math).
const BEAT_EPS: f64 = 1e-6;

#[derive(Debug, Clone, Copy)]
struct Chunk {
    flow: usize,
    elems: u64,
    /// Admission priority of the job this chunk belongs to: queued chunks
    /// of a higher-priority job are started before lower-priority ones
    /// (FIFO within a level; the in-flight transfer is never preempted).
    prio: u32,
}

/// Released-but-incomplete job, ordered for completion attribution:
/// highest priority first, then earliest arrival. With uniform priorities
/// this is exactly arrival order — the pre-priority behavior.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct ReadyJob {
    prio: u32,
    idx: u64,
}

impl Ord for ReadyJob {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // max-heap: higher prio wins, then the *smaller* index
        self.prio.cmp(&other.prio).then(other.idx.cmp(&self.idx))
    }
}

impl PartialOrd for ReadyJob {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Default)]
struct MoverRt {
    /// Chunks waiting to start (read movers + flow-control-free flows).
    queue: VecDeque<Chunk>,
    active: Option<Chunk>,
    remaining_beats: f64,
    started: TimePoint,
    busy: DepthTrack,
    sojourns: Vec<f64>,
    /// Write side: FIFO-fed elements delivered to memory (job completion).
    delivered: u64,
    chunks_done: u64,
    /// Write side: round-robin start flow for the next pull.
    rr: usize,
}

impl MoverRt {
    /// Back to pre-run state, keeping queue/sojourn allocations.
    fn reset(&mut self) {
        self.queue.clear();
        self.active = None;
        self.remaining_beats = 0.0;
        self.started = TimePoint::ZERO;
        self.busy.reset();
        self.sojourns.clear();
        self.delivered = 0;
        self.chunks_done = 0;
        self.rr = 0;
    }
}

#[derive(Default)]
struct FifoRt {
    occ: u64,
    reserved: u64,
    /// (enqueue time, elems remaining of that batch) for sojourn samples.
    enq: VecDeque<(TimePoint, u64)>,
    depth: DepthTrack,
    sojourns: Vec<f64>,
    chunks_out: u64,
    producers: Vec<Node>,
    consumers: Vec<Node>,
}

impl FifoRt {
    fn reset(&mut self) {
        self.occ = 0;
        self.reserved = 0;
        self.enq.clear();
        self.depth.reset();
        self.sojourns.clear();
        self.chunks_out = 0;
        self.producers.clear();
        self.consumers.clear();
    }
}

#[derive(Default)]
struct CuRt {
    busy: bool,
    epoch: u64,
    /// Pipeline fill (`latency` cycles) is charged once per admitted job,
    /// amortized: each firing with `fills_charged < released` charges one
    /// fill, so the total fill cost equals the jobs admitted.
    fills_charged: u64,
    cur_n: u64,
    started: TimePoint,
    /// Source-like CUs: backlog of output elements to produce.
    pending_src: u64,
    busy_track: DepthTrack,
    sojourns: Vec<f64>,
    firings: u64,
}

impl CuRt {
    fn reset(&mut self) {
        self.busy = false;
        self.epoch = 0;
        self.fills_charged = 0;
        self.cur_n = 0;
        self.started = TimePoint::ZERO;
        self.pending_src = 0;
        self.busy_track.reset();
        self.sojourns.clear();
        self.firings = 0;
    }
}

struct PcRt {
    active: Vec<usize>,
    last: TimePoint,
    epoch: u64,
}

impl Default for PcRt {
    fn default() -> Self {
        PcRt { active: Vec::new(), last: TimePoint::ZERO, epoch: 0 }
    }
}

impl PcRt {
    fn reset(&mut self) {
        self.active.clear();
        self.last = TimePoint::ZERO;
        self.epoch = 0;
    }
}

/// Shrink-or-grow `v` to `n` entries, resetting survivors in place so
/// their heap allocations (queues, sojourn buffers, depth histograms)
/// carry over to the next run.
fn resize_reset<T: Default>(v: &mut Vec<T>, n: usize, reset: impl Fn(&mut T)) {
    v.truncate(n);
    for x in v.iter_mut() {
        reset(x);
    }
    v.resize_with(n, T::default);
}

/// Every piece of engine state that survives across runs: the calendar,
/// per-node runtimes, sample buffers, and scratch. [`simulate_network_arena`]
/// lets a caller own one of these and thread it through thousands of
/// candidate simulations — a DSE sweep then reuses one warm allocation set
/// instead of re-growing every queue and histogram from empty per point.
///
/// A fresh arena and a reused one produce **byte-identical** reports:
/// `reset_for` restores every field to its pre-run state; only spare
/// capacity carries over.
pub struct EngineArena {
    cal: Calendar<Ev>,
    movers: Vec<MoverRt>,
    fifos: Vec<FifoRt>,
    cus: Vec<CuRt>,
    pcs: Vec<PcRt>,
    /// Per-CU steady-state service cost, ps per element.
    service_ps_per_elem: Vec<f64>,
    /// Per-CU pipeline-fill charge, ps.
    fill_ps: Vec<f64>,
    /// Per-CU effective service distribution (config default + overrides).
    cu_dists: Vec<ServiceDist>,
    /// Released, not yet completed; completions are attributed highest-
    /// priority-first (see [`ReadyJob`]).
    ready: BinaryHeap<ReadyJob>,
    job_latency: Vec<f64>,
    /// Per-class latency samples / deadline accounting, indexed by class.
    class_lat: Vec<Vec<f64>>,
    class_deadline_jobs: Vec<u64>,
    class_deadline_misses: Vec<u64>,
    /// Active replicas per CU (all 1 without an autoscale policy); service
    /// rate scales linearly with it.
    replicas: Vec<u32>,
    /// (mover idx, fifo-fed elems per job) for write movers.
    write_quota: Vec<(usize, u64)>,
    /// Finished-transfer indices collected during a `PcWake` scan (reused
    /// so the completion sweep never allocates).
    pc_done_scratch: Vec<usize>,
}

impl Default for EngineArena {
    fn default() -> Self {
        Self::new()
    }
}

impl EngineArena {
    pub fn new() -> Self {
        EngineArena {
            cal: Calendar::new(CalendarKind::default()),
            movers: Vec::new(),
            fifos: Vec::new(),
            cus: Vec::new(),
            pcs: Vec::new(),
            service_ps_per_elem: Vec::new(),
            fill_ps: Vec::new(),
            cu_dists: Vec::new(),
            ready: BinaryHeap::new(),
            job_latency: Vec::new(),
            class_lat: Vec::new(),
            class_deadline_jobs: Vec::new(),
            class_deadline_misses: Vec::new(),
            replicas: Vec::new(),
            write_quota: Vec::new(),
            pc_done_scratch: Vec::new(),
        }
    }

    /// Restore pre-run state for a simulation of `net` under `plan`,
    /// keeping every surviving allocation's capacity.
    fn reset_for(
        &mut self,
        net: &DesNet,
        cfg: &DesConfig,
        plan: &ArrivalPlan,
        timing: &TimingModel,
    ) {
        // The calendar is rebuilt only when the configured kind changes
        // (arena pools outlive individual configs); otherwise reset keeps
        // its slot/heap storage warm.
        if self.cal.kind() != cfg.calendar {
            self.cal = Calendar::new(cfg.calendar);
        } else {
            self.cal.reset();
        }
        resize_reset(&mut self.movers, net.movers.len(), MoverRt::reset);
        resize_reset(&mut self.fifos, net.fifos.len(), FifoRt::reset);
        resize_reset(&mut self.cus, net.cus.len(), CuRt::reset);
        resize_reset(&mut self.pcs, net.platform.pcs.len(), PcRt::reset);

        self.service_ps_per_elem.clear();
        self.service_ps_per_elem
            .extend(net.cus.iter().map(|c| timing.cu_service_s(c.ii, 1) * PS_PER_S));
        self.fill_ps.clear();
        self.fill_ps.extend(net.cus.iter().map(|c| timing.cu_fill_s(c.latency) * PS_PER_S));
        self.cu_dists.clear();
        self.cu_dists.extend(net.cus.iter().map(|c| cfg.dist_for(&c.name)));

        // wire wake lists (deterministic: build order)
        for (mi, mv) in net.movers.iter().enumerate() {
            for fl in &mv.flows {
                if let Some(f) = fl.fifo {
                    if mv.read {
                        self.fifos[f].producers.push(Node::Mover(mi));
                    } else {
                        self.fifos[f].consumers.push(Node::Mover(mi));
                    }
                }
            }
        }
        for (ci, cu) in net.cus.iter().enumerate() {
            for &f in &cu.in_fifos {
                self.fifos[f].consumers.push(Node::Cu(ci));
            }
            for &f in &cu.out_fifos {
                self.fifos[f].producers.push(Node::Cu(ci));
            }
        }

        self.write_quota.clear();
        self.write_quota.extend(
            net.movers
                .iter()
                .enumerate()
                .filter(|(_, m)| !m.read)
                .map(|(i, m)| (i, m.fifo_elems_per_job()))
                .filter(|(_, q)| *q > 0),
        );

        let nclasses = plan.class_names.len();
        self.class_lat.truncate(nclasses);
        for v in self.class_lat.iter_mut() {
            v.clear();
        }
        self.class_lat.resize_with(nclasses, Vec::new);
        self.class_deadline_jobs.clear();
        self.class_deadline_jobs.resize(nclasses, 0);
        self.class_deadline_misses.clear();
        self.class_deadline_misses.resize(nclasses, 0);

        self.replicas.clear();
        self.replicas.resize(
            net.cus.len(),
            cfg.autoscale.map(|p| p.min_replicas).unwrap_or(1).max(1),
        );

        self.ready.clear();
        self.job_latency.clear();
        self.pc_done_scratch.clear();

        // Presize sample buffers from the scenario so steady-state runs
        // never grow them mid-simulation. Clamped: a pathological plan
        // must not pin gigabytes of capacity in a pooled arena.
        const PRESIZE_CAP: u64 = 65_536;
        let jobs = plan.times.len() as u64;
        self.job_latency.reserve(jobs.min(PRESIZE_CAP) as usize);
        let burst = cfg.burst_elems.max(1);
        for (mi, mv) in net.movers.iter().enumerate() {
            let chunks: u64 = mv
                .flows
                .iter()
                .map(|fl| fl.elems_per_job / burst + u64::from(fl.elems_per_job % burst != 0))
                .sum();
            let want = chunks.saturating_mul(jobs).min(PRESIZE_CAP) as usize;
            self.movers[mi].sojourns.reserve(want);
        }
        for (ci, cu) in net.cus.iter().enumerate() {
            let firings = cu.out_elems_per_job / burst + 1;
            let want = firings.saturating_mul(jobs).min(PRESIZE_CAP) as usize;
            self.cus[ci].sojourns.reserve(want);
        }
    }
}

struct Engine<'a> {
    net: &'a DesNet,
    cfg: &'a DesConfig,
    /// All reusable state — calendar, node runtimes, sample buffers —
    /// lives in the arena (named `a` for brevity in the hot path).
    a: &'a mut EngineArena,
    arrivals: Vec<TimePoint>,
    /// Per-job traffic tags from the scenario plan (class index, optional
    /// deadline, admission priority), indexed like `arrivals`.
    classes: Vec<u32>,
    deadlines: Vec<Option<TimeSpan>>,
    prios: Vec<u32>,
    class_names: Vec<String>,
    released: u64,
    completed: u64,
    last_completion: Option<TimePoint>,
    /// Service draws for stochastic distributions (decorrelated from the
    /// arrival stream so scenario and service noise are independent).
    service_rng: Rng,
    /// Optional Chrome-trace observer. Zero-perturbation: hooks only read
    /// state the engine computed anyway and never feed anything back.
    trace: Option<&'a mut TraceSink>,
}

/// Simulate `arch` under `scenario`. The report is a pure function of the
/// arguments — identical seeds give identical reports.
pub fn simulate(
    arch: &Architecture,
    scenario: &WorkloadScenario,
    cfg: &DesConfig,
) -> Result<DesReport> {
    simulate_traced(arch, scenario, cfg, None)
}

/// [`simulate`] with an optional Chrome-trace observer (`olympus des
/// --trace`). The report is bit-identical with or without the sink.
pub fn simulate_traced(
    arch: &Architecture,
    scenario: &WorkloadScenario,
    cfg: &DesConfig,
    trace: Option<&mut TraceSink>,
) -> Result<DesReport> {
    let net = build_network(arch)?;
    simulate_network_traced(&net, scenario, cfg, trace)
}

/// [`simulate`] against a caller-owned [`EngineArena`] — the warm-start
/// entry point for candidate sweeps.
pub fn simulate_arena(
    arch: &Architecture,
    scenario: &WorkloadScenario,
    cfg: &DesConfig,
    arena: &mut EngineArena,
) -> Result<DesReport> {
    let net = build_network(arch)?;
    simulate_network_in(&net, scenario, cfg, None, arena)
}

/// Simulate a pre-built network (lets DSE reuse one build).
pub fn simulate_network(
    net: &DesNet,
    scenario: &WorkloadScenario,
    cfg: &DesConfig,
) -> Result<DesReport> {
    simulate_network_traced(net, scenario, cfg, None)
}

/// [`simulate_network`] with an optional trace observer.
pub fn simulate_network_traced(
    net: &DesNet,
    scenario: &WorkloadScenario,
    cfg: &DesConfig,
    trace: Option<&mut TraceSink>,
) -> Result<DesReport> {
    simulate_network_in(net, scenario, cfg, trace, &mut EngineArena::new())
}

/// [`simulate_network`] reusing `arena`'s allocations across calls. The
/// report is byte-identical to a fresh-arena run (see [`EngineArena`]).
pub fn simulate_network_arena(
    net: &DesNet,
    scenario: &WorkloadScenario,
    cfg: &DesConfig,
    arena: &mut EngineArena,
) -> Result<DesReport> {
    simulate_network_in(net, scenario, cfg, None, arena)
}

fn simulate_network_in(
    net: &DesNet,
    scenario: &WorkloadScenario,
    cfg: &DesConfig,
    trace: Option<&mut TraceSink>,
    arena: &mut EngineArena,
) -> Result<DesReport> {
    // replica-aware job striping (no-op for replica-free nets)
    let striped_net;
    let net = if cfg.stripe_replicas {
        match net.striped() {
            Some(s) => {
                striped_net = s;
                &striped_net
            }
            None => net,
        }
    } else {
        net
    };

    let mut rng = Rng::new(cfg.seed);
    let plan = scenario.plan(&mut rng);

    let timing = TimingModel::new(&net.platform, cfg.utilization, cfg.congestion_model);
    arena.reset_for(net, cfg, &plan, &timing);

    let mut eng = Engine {
        net,
        cfg,
        a: arena,
        arrivals: plan.times,
        classes: plan.class_of,
        deadlines: plan.deadlines,
        prios: plan.prios,
        class_names: plan.class_names,
        released: 0,
        completed: 0,
        last_completion: None,
        service_rng: Rng::new(cfg.seed.rotate_left(17) ^ 0xD15E_A5ED_5EED_C0DE),
        trace,
    };

    // Name the trace lanes up front (tid 0 is the counter-track lane).
    if let Some(t) = eng.trace.as_deref_mut() {
        t.thread_name(0, "fifo depths");
        for (ci, cu) in net.cus.iter().enumerate() {
            t.thread_name(1 + ci as u64, &format!("cu {}", cu.name));
        }
        for (mi, m) in net.movers.iter().enumerate() {
            t.thread_name((1 + net.cus.len() + mi) as u64, &format!("mover {}", m.name));
        }
    }

    for j in 0..eng.arrivals.len() {
        let t = eng.arrivals[j];
        eng.a.cal.push(t, Ev::Arrival { job: j as u64 });
    }
    if let Some(p) = &cfg.autoscale {
        // degenerate nets never complete jobs mid-run, so a self-
        // rescheduling tick would spin to the event budget — skip them
        if !eng.a.write_quota.is_empty() {
            eng.a
                .cal
                .push(TimePoint::ZERO + TimeSpan::from_secs_f64(p.interval_s), Ev::Autoscale);
        }
    }

    let wall_start = std::time::Instant::now();
    while let Some((now, ev)) = eng.a.cal.pop() {
        if eng.a.cal.dispatched() > cfg.max_events {
            bail!(
                "des: event budget exhausted ({} events) — runaway simulation?",
                cfg.max_events
            );
        }
        match ev {
            Ev::Arrival { job } => eng.on_arrival(job, now),
            Ev::PcWake { pc, epoch } => {
                if eng.a.pcs[pc].epoch == epoch {
                    eng.on_pc_wake(pc, now);
                }
            }
            Ev::CuDone { cu, epoch } => {
                if eng.a.cus[cu].epoch == epoch && eng.a.cus[cu].busy {
                    eng.on_cu_done(cu, now);
                }
            }
            Ev::Autoscale => eng.on_autoscale(now),
        }
    }
    crate::obs::metrics().record_des_run(
        eng.a.cal.dispatched(),
        wall_start.elapsed(),
        cfg.calendar.as_str(),
    );

    Ok(eng.finish(scenario))
}

impl<'a> Engine<'a> {
    // ---- job admission ---------------------------------------------------

    fn on_arrival(&mut self, job: u64, now: TimePoint) {
        self.released += 1;
        let prio = self.prios.get(job as usize).copied().unwrap_or(0);
        self.a.ready.push(ReadyJob { prio, idx: job });
        for mi in 0..self.net.movers.len() {
            let mv = &self.net.movers[mi];
            // Chunk the job per flow, interleaving flows round-robin: an
            // Iris bus word carries all member arrays at once, and
            // interleaving is also what keeps a small FIFO from head-of-line
            // blocking the sibling array's data forever. Chunks are
            // generated round-major straight off the flow arithmetic —
            // round r of flow fi covers elements [r*chunk, r*chunk+n) —
            // which emits the exact sequence the old materialize-then-
            // interleave code produced without allocating per-flow queues.
            let mut round = 0u64;
            loop {
                let mut pushed = false;
                for (fi, fl) in mv.flows.iter().enumerate() {
                    // read flows stream in; flow-control-free flows
                    // (PLM/AXI) are fire-and-forget beat accounting on
                    // either side
                    if !mv.read && fl.fifo.is_some() {
                        continue; // write side pulls from its FIFO instead
                    }
                    let cap =
                        fl.fifo.map(|f| self.net.fifos[f].cap_elems).unwrap_or(u64::MAX);
                    let chunk = self.cfg.burst_elems.clamp(1, cap);
                    let off = round.saturating_mul(chunk);
                    if off < fl.elems_per_job {
                        let n = chunk.min(fl.elems_per_job - off);
                        Self::enqueue_chunk(
                            &mut self.a.movers[mi].queue,
                            Chunk { flow: fi, elems: n, prio },
                        );
                        pushed = true;
                    }
                }
                if !pushed {
                    break;
                }
                round += 1;
            }
            self.try_start_mover(mi, now);
        }
        for ci in 0..self.net.cus.len() {
            if self.net.cus[ci].source_like() {
                self.a.cus[ci].pending_src += self.net.cus[ci].out_elems_per_job;
                self.try_fire_cu(ci, now);
            }
        }
    }

    // ---- movers ----------------------------------------------------------

    /// Priority insertion into a mover's pending-chunk queue: a chunk goes
    /// ahead of every strictly-lower-priority chunk, behind equal ones. The
    /// all-equal-priority common case appends in O(1), keeping synthetic
    /// scenarios bit-identical to the pre-priority engine.
    fn enqueue_chunk(queue: &mut VecDeque<Chunk>, c: Chunk) {
        let mut pos = queue.len();
        while pos > 0 && queue[pos - 1].prio < c.prio {
            pos -= 1;
        }
        queue.insert(pos, c);
    }

    fn try_start_mover(&mut self, mi: usize, now: TimePoint) {
        if self.a.movers[mi].active.is_some() {
            return;
        }
        let read = self.net.movers[mi].read;
        // queued chunks first (read streams + flow-control-free transfers)
        if let Some(&head) = self.a.movers[mi].queue.front() {
            let fl = &self.net.movers[mi].flows[head.flow];
            if read {
                if let Some(f) = fl.fifo {
                    let fifo = &self.a.fifos[f];
                    if fifo.occ + fifo.reserved + head.elems > self.net.fifos[f].cap_elems {
                        return; // backpressure: wait for the consumer
                    }
                    self.a.fifos[f].reserved += head.elems;
                }
            }
            let beats = head.elems as f64 * fl.beats_per_elem;
            self.a.movers[mi].queue.pop_front();
            self.begin_transfer(mi, head, beats, now);
            return;
        }
        if read {
            return;
        }
        // write mover: pull a chunk from the next non-empty source FIFO
        // (rotating start index so multi-flow buses drain fairly)
        let nflows = self.net.movers[mi].flows.len();
        for k in 0..nflows {
            let fi = (self.a.movers[mi].rr + k) % nflows;
            // borrows the shared network description only — no engine-state
            // conflict, no per-pull clone
            let fl = &self.net.movers[mi].flows[fi];
            let Some(f) = fl.fifo else { continue };
            let avail = self.a.fifos[f].occ;
            if avail == 0 {
                continue;
            }
            let n = avail.min(self.cfg.burst_elems.max(1));
            self.dequeue_elems(f, n, now);
            self.wake_producers(f, now);
            let beats = n as f64 * fl.beats_per_elem;
            self.a.movers[mi].rr = (fi + 1) % nflows;
            self.begin_transfer(mi, Chunk { flow: fi, elems: n, prio: 0 }, beats, now);
            return;
        }
    }

    fn begin_transfer(&mut self, mi: usize, chunk: Chunk, beats: f64, now: TimePoint) {
        let m = &mut self.a.movers[mi];
        m.active = Some(chunk);
        m.remaining_beats = beats.max(0.0);
        m.started = now;
        m.busy.set(now, 1);
        let net = self.net;
        let tid = (1 + net.cus.len() + mi) as u64;
        if let Some(t) = self.trace.as_deref_mut() {
            t.begin(tid, &net.movers[mi].name, now.ps());
        }
        let pc = self.net.movers[mi].pc;
        self.pc_advance(pc, now);
        self.a.pcs[pc].active.push(mi);
        self.pc_reschedule(pc, now);
    }

    fn complete_transfer(&mut self, mi: usize, now: TimePoint) {
        let chunk = self.a.movers[mi].active.take().expect("completing idle mover");
        {
            let m = &mut self.a.movers[mi];
            m.busy.set(now, 0);
            m.sojourns.push((now - m.started).as_secs_f64());
            m.chunks_done += 1;
        }
        let tid = (1 + self.net.cus.len() + mi) as u64;
        if let Some(t) = self.trace.as_deref_mut() {
            t.end(tid, now.ps());
        }
        let mv = &self.net.movers[mi];
        let fl = &mv.flows[chunk.flow];
        if mv.read {
            if let Some(f) = fl.fifo {
                let r = self.a.fifos[f].reserved;
                self.a.fifos[f].reserved = r.saturating_sub(chunk.elems);
                self.enqueue_elems(f, chunk.elems, now);
                self.wake_consumers(f, now);
            }
        } else if fl.fifo.is_some() {
            self.a.movers[mi].delivered += chunk.elems;
            self.check_job_completions(now);
        }
        self.try_start_mover(mi, now);
    }

    // ---- shared-rate memory channels ------------------------------------

    /// Beats/ps each active transfer on `pc` currently receives.
    fn pc_share(&self, pc: usize) -> f64 {
        let n = self.a.pcs[pc].active.len();
        if n == 0 {
            return 0.0;
        }
        self.net.platform.pcs[pc].shared_beat_rate(n) / n as f64 / PS_PER_S
    }

    fn pc_advance(&mut self, pc: usize, now: TimePoint) {
        let dt = (now - self.a.pcs[pc].last).ps();
        self.a.pcs[pc].last = now;
        if dt == 0 || self.a.pcs[pc].active.is_empty() {
            return;
        }
        let share = self.pc_share(pc);
        for k in 0..self.a.pcs[pc].active.len() {
            let mi = self.a.pcs[pc].active[k];
            let m = &mut self.a.movers[mi];
            m.remaining_beats = (m.remaining_beats - share * dt as f64).max(0.0);
        }
    }

    fn pc_reschedule(&mut self, pc: usize, now: TimePoint) {
        self.a.pcs[pc].epoch += 1;
        if self.a.pcs[pc].active.is_empty() {
            return;
        }
        let share = self.pc_share(pc);
        let min_rem = self.a.pcs[pc]
            .active
            .iter()
            .map(|&mi| self.a.movers[mi].remaining_beats)
            .fold(f64::INFINITY, f64::min);
        let dt_ps = if share > 0.0 { (min_rem / share).ceil() } else { 1.0 };
        let span = TimeSpan::from_ps(dt_ps.clamp(1.0, 1e15) as u64);
        let epoch = self.a.pcs[pc].epoch;
        self.a.cal.push(now + span, Ev::PcWake { pc, epoch });
    }

    fn on_pc_wake(&mut self, pc: usize, now: TimePoint) {
        self.pc_advance(pc, now);
        // One retain pass splits finished from still-running transfers:
        // finished indices land in the arena scratch (in `active` order,
        // matching the old filter-then-retain pair) with no per-wake
        // allocation and no quadratic `contains` scan.
        {
            let a = &mut *self.a;
            a.pc_done_scratch.clear();
            let movers = &a.movers;
            let scratch = &mut a.pc_done_scratch;
            a.pcs[pc].active.retain(|&mi| {
                if movers[mi].remaining_beats <= BEAT_EPS {
                    scratch.push(mi);
                    false
                } else {
                    true
                }
            });
        }
        for k in 0..self.a.pc_done_scratch.len() {
            let mi = self.a.pc_done_scratch[k];
            self.complete_transfer(mi, now);
        }
        self.pc_reschedule(pc, now);
    }

    // ---- FIFOs -----------------------------------------------------------

    fn enqueue_elems(&mut self, f: usize, n: u64, now: TimePoint) {
        let q = &mut self.a.fifos[f];
        q.occ += n;
        q.enq.push_back((now, n));
        let d = q.occ;
        q.depth.set(now, d);
        let net = self.net;
        if let Some(t) = self.trace.as_deref_mut() {
            t.counter(&net.fifos[f].name, now.ps(), "elems", d);
        }
    }

    fn dequeue_elems(&mut self, f: usize, n: u64, now: TimePoint) {
        let q = &mut self.a.fifos[f];
        debug_assert!(q.occ >= n, "fifo underflow");
        q.occ -= n;
        let d = q.occ;
        q.depth.set(now, d);
        let mut left = n;
        while left > 0 {
            let Some(front) = q.enq.front_mut() else { break };
            let take = front.1.min(left);
            q.sojourns.push((now - front.0).as_secs_f64());
            left -= take;
            if front.1 > take {
                front.1 -= take;
            } else {
                q.enq.pop_front();
            }
        }
        q.chunks_out += 1;
        let net = self.net;
        if let Some(t) = self.trace.as_deref_mut() {
            t.counter(&net.fifos[f].name, now.ps(), "elems", d);
        }
    }

    fn wake_consumers(&mut self, f: usize, now: TimePoint) {
        for k in 0..self.a.fifos[f].consumers.len() {
            match self.a.fifos[f].consumers[k] {
                Node::Cu(ci) => self.try_fire_cu(ci, now),
                Node::Mover(mi) => self.try_start_mover(mi, now),
            }
        }
    }

    fn wake_producers(&mut self, f: usize, now: TimePoint) {
        for k in 0..self.a.fifos[f].producers.len() {
            match self.a.fifos[f].producers[k] {
                Node::Cu(ci) => self.try_fire_cu(ci, now),
                Node::Mover(mi) => self.try_start_mover(mi, now),
            }
        }
    }

    // ---- compute units ---------------------------------------------------

    fn try_fire_cu(&mut self, ci: usize, now: TimePoint) {
        if self.a.cus[ci].busy {
            return;
        }
        let spec = &self.net.cus[ci];
        let mut n = self.cfg.burst_elems.max(1);
        if spec.source_like() {
            n = n.min(self.a.cus[ci].pending_src);
        } else {
            for &f in &spec.in_fifos {
                n = n.min(self.a.fifos[f].occ);
            }
        }
        if n == 0 {
            return;
        }
        // clamp to available output space; any progress beats a stall
        for &f in &spec.out_fifos {
            let free = self.net.fifos[f].cap_elems
                - (self.a.fifos[f].occ + self.a.fifos[f].reserved).min(self.net.fifos[f].cap_elems);
            n = n.min(free);
        }
        if n == 0 {
            return; // output backpressure: retried when a consumer drains
        }
        // `spec` borrows the (shared) network description, not the engine
        // state, so no clones are needed in this hot path
        if spec.source_like() {
            self.a.cus[ci].pending_src -= n;
        } else {
            for &f in &spec.in_fifos {
                self.dequeue_elems(f, n, now);
            }
        }
        for &f in &spec.out_fifos {
            self.a.fifos[f].reserved += n;
        }
        // active replicas serve a chunk proportionally faster (elastic
        // capacity; `replicas` stays 1 without an autoscale policy)
        let mut service_ps =
            n as f64 * self.a.service_ps_per_elem[ci] / self.a.replicas[ci] as f64;
        // unit-mean multiplier keeps the offered load at the deterministic
        // value; Deterministic draws nothing (multiplies by exactly 1.0)
        service_ps *= self.a.cu_dists[ci].sample(&mut self.service_rng);
        if self.a.cus[ci].fills_charged < self.released {
            service_ps += self.a.fill_ps[ci];
            self.a.cus[ci].fills_charged += 1;
        }
        let cu = &mut self.a.cus[ci];
        cu.busy = true;
        cu.cur_n = n;
        cu.started = now;
        cu.busy_track.set(now, 1);
        cu.epoch += 1;
        let epoch = cu.epoch;
        let span = TimeSpan::from_ps((service_ps.ceil() as u64).max(1));
        self.a.cal.push(now + span, Ev::CuDone { cu: ci, epoch });
        let net = self.net;
        if let Some(t) = self.trace.as_deref_mut() {
            t.begin(1 + ci as u64, &net.cus[ci].name, now.ps());
        }
        // freed input space: upstream movers may now resume
        for k in 0..self.net.cus[ci].in_fifos.len() {
            let f = self.net.cus[ci].in_fifos[k];
            self.wake_producers(f, now);
        }
    }

    fn on_cu_done(&mut self, ci: usize, now: TimePoint) {
        let n = self.a.cus[ci].cur_n;
        {
            let cu = &mut self.a.cus[ci];
            cu.busy = false;
            cu.cur_n = 0;
            cu.busy_track.set(now, 0);
            cu.sojourns.push((now - cu.started).as_secs_f64());
            cu.firings += 1;
        }
        if let Some(t) = self.trace.as_deref_mut() {
            t.end(1 + ci as u64, now.ps());
        }
        for k in 0..self.net.cus[ci].out_fifos.len() {
            let f = self.net.cus[ci].out_fifos[k];
            let r = self.a.fifos[f].reserved;
            self.a.fifos[f].reserved = r.saturating_sub(n);
            self.enqueue_elems(f, n, now);
            self.wake_consumers(f, now);
        }
        self.try_fire_cu(ci, now);
    }

    // ---- autoscaler ------------------------------------------------------

    /// One controller tick: scale each CU's active replicas one step from
    /// observed backlog (input-FIFO occupancy; pending output elements for
    /// source-like CUs), then reschedule while jobs remain outstanding.
    fn on_autoscale(&mut self, now: TimePoint) {
        let Some(p) = self.cfg.autoscale else { return };
        for ci in 0..self.net.cus.len() {
            let spec = &self.net.cus[ci];
            let backlog: u64 = if spec.source_like() {
                self.a.cus[ci].pending_src
            } else {
                spec.in_fifos.iter().map(|&f| self.a.fifos[f].occ).sum()
            };
            let r = self.a.replicas[ci];
            if backlog >= p.scale_up_backlog && r < p.max_replicas {
                self.a.replicas[ci] = r + 1;
            } else if backlog <= p.scale_down_backlog && r > p.min_replicas {
                self.a.replicas[ci] = r - 1;
            }
        }
        if self.completed < self.arrivals.len() as u64 {
            self.a.cal.push(now + TimeSpan::from_secs_f64(p.interval_s), Ev::Autoscale);
        }
    }

    // ---- job accounting --------------------------------------------------

    fn check_job_completions(&mut self, now: TimePoint) {
        if self.a.write_quota.is_empty() {
            return;
        }
        let done = self
            .a
            .write_quota
            .iter()
            .map(|&(mi, quota)| self.a.movers[mi].delivered / quota)
            .min()
            .unwrap_or(0);
        while self.completed < done.min(self.released) {
            // completions are attributed highest-priority-first among the
            // released jobs (arrival order when priorities are uniform),
            // matching the admission order `enqueue_chunk` imposes
            let job = self.a.ready.pop().map(|r| r.idx).unwrap_or(self.completed) as usize;
            let lat = (now - self.arrivals[job]).as_secs_f64();
            self.a.job_latency.push(lat);
            let class = self.classes.get(job).copied().unwrap_or(0) as usize;
            self.a.class_lat[class].push(lat);
            if let Some(deadline) = self.deadlines.get(job).copied().flatten() {
                self.a.class_deadline_jobs[class] += 1;
                if now - self.arrivals[job] > deadline {
                    self.a.class_deadline_misses[class] += 1;
                }
            }
            self.completed += 1;
            self.last_completion = Some(now);
        }
    }

    // ---- report ----------------------------------------------------------

    /// Fold per-node samples into the report. Borrows the arena in place
    /// (sorting sojourn buffers where percentiles need it) — the next
    /// `reset_for` clears everything, so nothing is consumed.
    fn finish(&mut self, scenario: &WorkloadScenario) -> DesReport {
        let end = self.a.cal.now();
        // degenerate nets (no FIFO-fed write movers): everything that was
        // released counts as done when the calendar drains
        if self.a.write_quota.is_empty() {
            self.completed = self.released;
            self.last_completion = Some(end);
        }
        let mut nodes = Vec::with_capacity(
            self.net.cus.len() + self.net.fifos.len() + self.net.movers.len(),
        );
        for (ci, cu) in self.net.cus.iter().enumerate() {
            let rt = &mut self.a.cus[ci];
            let (mean, p99, max, util) = rt.busy_track.finish(end);
            let soj = &mut rt.sojourns;
            let mean_soj =
                if soj.is_empty() { 0.0 } else { soj.iter().sum::<f64>() / soj.len() as f64 };
            nodes.push(NodeMetrics {
                name: cu.name.clone(),
                kind: NodeKind::Cu,
                utilization: util,
                mean_depth: mean,
                p99_depth: p99,
                max_depth: max,
                mean_sojourn_s: mean_soj,
                p99_sojourn_s: percentile(soj, 0.99),
                completions: rt.firings,
            });
        }
        for (fi, f) in self.net.fifos.iter().enumerate() {
            let rt = &mut self.a.fifos[fi];
            let (mean, p99, max, util) = rt.depth.finish(end);
            let soj = &mut rt.sojourns;
            let mean_soj =
                if soj.is_empty() { 0.0 } else { soj.iter().sum::<f64>() / soj.len() as f64 };
            nodes.push(NodeMetrics {
                name: f.name.clone(),
                kind: NodeKind::Fifo,
                utilization: util,
                mean_depth: mean,
                p99_depth: p99,
                max_depth: max,
                mean_sojourn_s: mean_soj,
                p99_sojourn_s: percentile(soj, 0.99),
                completions: rt.chunks_out,
            });
        }
        for (mi, m) in self.net.movers.iter().enumerate() {
            let rt = &mut self.a.movers[mi];
            let (mean, p99, max, util) = rt.busy.finish(end);
            let soj = &mut rt.sojourns;
            let mean_soj =
                if soj.is_empty() { 0.0 } else { soj.iter().sum::<f64>() / soj.len() as f64 };
            nodes.push(NodeMetrics {
                name: m.name.clone(),
                kind: NodeKind::Mover,
                utilization: util,
                mean_depth: mean,
                p99_depth: p99,
                max_depth: max,
                mean_sojourn_s: mean_soj,
                p99_sojourn_s: percentile(soj, 0.99),
                completions: rt.chunks_done,
            });
        }
        let makespan_s = self
            .last_completion
            .map(|t| t.as_secs_f64())
            .unwrap_or_else(|| end.as_secs_f64());
        let mut classes = Vec::with_capacity(self.class_names.len());
        for (i, name) in self.class_names.iter().enumerate() {
            let samples = &mut self.a.class_lat[i];
            let mean = if samples.is_empty() {
                0.0
            } else {
                samples.iter().sum::<f64>() / samples.len() as f64
            };
            classes.push(super::metrics::ClassStats {
                class: name.clone(),
                jobs: samples.len() as u64,
                mean_latency_s: mean,
                p99_latency_s: percentile(samples, 0.99),
                deadline_jobs: self.a.class_deadline_jobs[i],
                deadline_misses: self.a.class_deadline_misses[i],
            });
        }
        let lat = &mut self.a.job_latency;
        let mean_lat =
            if lat.is_empty() { 0.0 } else { lat.iter().sum::<f64>() / lat.len() as f64 };
        let p50 = percentile(lat, 0.50);
        let p99 = percentile(lat, 0.99);
        let max_lat = lat.last().copied().unwrap_or(0.0);
        DesReport {
            scenario: scenario.name.clone(),
            seed: self.cfg.seed,
            nodes,
            jobs_released: self.released,
            jobs_completed: self.completed,
            makespan_s,
            mean_job_latency_s: mean_lat,
            p50_job_latency_s: p50,
            p99_job_latency_s: p99,
            max_job_latency_s: max_lat,
            throughput_jobs_per_s: if makespan_s > 0.0 {
                self.completed as f64 / makespan_s
            } else {
                0.0
            },
            events: self.a.cal.dispatched(),
            classes,
        }
    }
}
