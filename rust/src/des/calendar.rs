//! The event calendar: the scheduling core of the DES.
//!
//! Two implementations behind one [`Calendar`] front:
//!
//! * [`EventCalendar`] — the original binary-heap priority queue. O(log n)
//!   push/pop, pointer-chasing sift on every operation. Kept as the
//!   *reference implementation*: simple enough to be obviously correct.
//! * [`WheelCalendar`] — a hierarchical timing wheel (the classic calendar-
//!   queue speedup for simulators): 11 levels of 64 slots each cover the
//!   full 64-bit picosecond range, an event lands at the level where its
//!   time first diverges from the cursor's radix-64 digits, and popping is
//!   bitmap scans plus occasional cascades. Amortized O(1) per event for
//!   the near-future-heavy schedules a queueing simulation produces. This
//!   is the default engine.
//!
//! Determinism contract (upheld *identically* by both): events at equal
//! timestamps pop in *insertion order* (a monotone sequence number breaks
//! ties), scheduling in the past clamps to `now`, and the
//! `scheduled`/`dispatched` counters tick exactly once per push/pop — so a
//! simulation is a pure function of its inputs — no HashMap iteration
//! order, no wall clock, and no dependence on which calendar ran it. The
//! seeded property test at the bottom drives both with randomized
//! interleaved schedules and asserts identical pop sequences.

use std::collections::{BinaryHeap, VecDeque};

use super::time::TimePoint;

/// Which calendar implementation a run schedules on. Deliberately **not**
/// part of any cache key or wire codec: both produce byte-identical
/// reports, so the knob is pure mechanism ([`crate::des::DesConfig`]'s
/// manual `Debug` impl omits it for exactly this reason).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CalendarKind {
    /// Hierarchical timing wheel (the default).
    #[default]
    Wheel,
    /// Binary-heap reference implementation.
    Heap,
}

impl CalendarKind {
    /// Parse a `--calendar` value; the error names the accepted forms.
    pub fn parse(s: &str) -> Result<CalendarKind, String> {
        match s {
            "wheel" => Ok(CalendarKind::Wheel),
            "heap" => Ok(CalendarKind::Heap),
            _ => Err(format!("bad calendar '{s}': want wheel | heap")),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            CalendarKind::Wheel => "wheel",
            CalendarKind::Heap => "heap",
        }
    }
}

// ---- binary-heap reference implementation ---------------------------------

struct Entry<E> {
    time: TimePoint,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest-first
        (other.time, other.seq).cmp(&(self.time, self.seq))
    }
}

/// Min-heap event calendar with a monotone clock (the reference
/// implementation; see [`WheelCalendar`] for the default fast path).
pub struct EventCalendar<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: TimePoint,
    scheduled: u64,
    dispatched: u64,
}

impl<E> Default for EventCalendar<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventCalendar<E> {
    pub fn new() -> Self {
        EventCalendar {
            heap: BinaryHeap::new(),
            seq: 0,
            now: TimePoint::ZERO,
            scheduled: 0,
            dispatched: 0,
        }
    }

    /// Current simulated time (the timestamp of the last popped event).
    pub fn now(&self) -> TimePoint {
        self.now
    }

    /// Schedule `ev` at absolute time `at`. Scheduling in the past is
    /// clamped to `now` (the event fires immediately, after already-queued
    /// same-time events).
    pub fn push(&mut self, at: TimePoint, ev: E) {
        let time = at.max(self.now);
        self.heap.push(Entry { time, seq: self.seq, ev });
        self.seq += 1;
        self.scheduled += 1;
    }

    /// Pop the earliest event and advance the clock to it.
    pub fn pop(&mut self) -> Option<(TimePoint, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.time >= self.now, "calendar time went backwards");
        self.now = e.time;
        self.dispatched += 1;
        Some((e.time, e.ev))
    }

    pub fn peek_time(&self) -> Option<TimePoint> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events ever scheduled (for the events/sec bench + report).
    pub fn scheduled(&self) -> u64 {
        self.scheduled
    }

    /// Total events dispatched via [`pop`](Self::pop).
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// Empty the calendar and rewind the clock, keeping the heap's
    /// allocation (arena reuse across warm-started simulations).
    pub fn reset(&mut self) {
        self.heap.clear();
        self.seq = 0;
        self.now = TimePoint::ZERO;
        self.scheduled = 0;
        self.dispatched = 0;
    }
}

// ---- hierarchical timing wheel --------------------------------------------

/// Radix bits per wheel level: 64 slots, so one `u64` occupancy bitmap per
/// level and `trailing_zeros` finds the next slot in one instruction.
const LEVEL_BITS: u32 = 6;
const SLOTS: usize = 1 << LEVEL_BITS;
/// `ceil(64 / LEVEL_BITS)` levels cover every 64-bit picosecond timestamp.
const LEVELS: usize = 11;

/// Hierarchical timing-wheel calendar.
///
/// Geometry: level `l` is a 64-slot wheel whose slot `s` holds events whose
/// picosecond timestamps share every radix-64 digit above `l` with the
/// internal cursor and have digit `s` at level `l`. An event is filed at
/// the *highest* level where its time diverges from the cursor (level 0 if
/// equal), which makes three invariants fall out:
///
/// * a level-0 slot holds events of exactly one timestamp, so FIFO order
///   within the slot *is* (time, seq) order;
/// * the lowest nonempty slot of the lowest nonempty level holds the
///   globally earliest event (levels are strictly time-ordered);
/// * cascading a higher-level slot only ever redistributes into *empty*
///   lower levels, so every slot's deque stays seq-sorted without sorting.
///
/// Popping scans bitmaps for that slot; if it is above level 0 the cursor
/// advances to the slot's base time and the slot cascades down. Each event
/// cascades at most `LEVELS - 1` times, and the common near-future case is
/// a straight level-0 `pop_front`.
pub struct WheelCalendar<E> {
    /// `LEVELS * SLOTS` deques, level-major. Deques (not Vecs): pops come
    /// off the front while pushes append, and capacity survives `reset`.
    slots: Vec<VecDeque<(u64, u64, E)>>,
    /// Per-level slot-occupancy bitmaps.
    occ: [u64; LEVELS],
    len: usize,
    seq: u64,
    now: TimePoint,
    /// Hashing origin: every queued event time is `>= cursor`, and
    /// `cursor <= now` between pops. Advances to slot bases on cascades.
    cursor: u64,
    scheduled: u64,
    dispatched: u64,
}

impl<E> Default for WheelCalendar<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> WheelCalendar<E> {
    pub fn new() -> Self {
        WheelCalendar {
            slots: (0..LEVELS * SLOTS).map(|_| VecDeque::new()).collect(),
            occ: [0; LEVELS],
            len: 0,
            seq: 0,
            now: TimePoint::ZERO,
            cursor: 0,
            scheduled: 0,
            dispatched: 0,
        }
    }

    /// Level where `t` first diverges from the cursor's radix-64 digits.
    #[inline]
    fn level_of(&self, t: u64) -> usize {
        let d = t ^ self.cursor;
        if d == 0 {
            0
        } else {
            ((63 - d.leading_zeros()) / LEVEL_BITS) as usize
        }
    }

    #[inline]
    fn slot_of(t: u64, level: usize) -> usize {
        ((t >> (LEVEL_BITS as usize * level)) & (SLOTS as u64 - 1)) as usize
    }

    #[inline]
    fn file(&mut self, t: u64, seq: u64, ev: E) {
        let level = self.level_of(t);
        let slot = Self::slot_of(t, level);
        self.slots[level * SLOTS + slot].push_back((t, seq, ev));
        self.occ[level] |= 1 << slot;
    }

    /// Lowest nonempty (level, slot), i.e. where the earliest event lives.
    #[inline]
    fn earliest_slot(&self) -> Option<(usize, usize)> {
        for level in 0..LEVELS {
            if self.occ[level] != 0 {
                return Some((level, self.occ[level].trailing_zeros() as usize));
            }
        }
        None
    }

    /// See [`EventCalendar::now`].
    pub fn now(&self) -> TimePoint {
        self.now
    }

    /// See [`EventCalendar::push`]: past times clamp to `now`, equal times
    /// preserve insertion order via the monotone sequence number.
    pub fn push(&mut self, at: TimePoint, ev: E) {
        let t = at.max(self.now).ps();
        debug_assert!(t >= self.cursor, "event filed behind the wheel cursor");
        let seq = self.seq;
        self.seq += 1;
        self.scheduled += 1;
        self.len += 1;
        self.file(t, seq, ev);
    }

    /// See [`EventCalendar::pop`]. Cascades higher-level slots down until
    /// the earliest event sits in a level-0 slot, then pops its front.
    pub fn pop(&mut self) -> Option<(TimePoint, E)> {
        loop {
            let (level, slot) = self.earliest_slot()?;
            let idx = level * SLOTS + slot;
            if level == 0 {
                let (t, _seq, ev) = self.slots[idx].pop_front().expect("occupied bit lied");
                if self.slots[idx].is_empty() {
                    self.occ[0] &= !(1 << slot);
                }
                self.len -= 1;
                self.dispatched += 1;
                debug_assert!(t >= self.now.ps(), "calendar time went backwards");
                self.now = TimePoint::from_ps(t);
                self.cursor = t;
                return Some((self.now, ev));
            }
            // Cascade: advance the cursor to the slot's base time (its
            // digit at `level`, zeros below — never past any queued event)
            // and redistribute; every entry re-files strictly below `level`.
            let shift = LEVEL_BITS as usize * level;
            let above = u64::MAX.checked_shl((shift as u32) + LEVEL_BITS).unwrap_or(0);
            self.cursor = (self.cursor & above) | ((slot as u64) << shift);
            self.occ[level] &= !(1 << slot);
            let mut q = std::mem::take(&mut self.slots[idx]);
            for (t, seq, ev) in q.drain(..) {
                self.file(t, seq, ev);
            }
            // hand the emptied deque back so its capacity is reused
            self.slots[idx] = q;
        }
    }

    /// See [`EventCalendar::peek_time`]. Non-destructive: higher-level
    /// slots are min-scanned instead of cascaded.
    pub fn peek_time(&self) -> Option<TimePoint> {
        let (level, slot) = self.earliest_slot()?;
        let q = &self.slots[level * SLOTS + slot];
        if level == 0 {
            // single-timestamp slot: the front is the earliest
            return q.front().map(|&(t, _, _)| TimePoint::from_ps(t));
        }
        q.iter().map(|&(t, _, _)| TimePoint::from_ps(t)).min()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// See [`EventCalendar::scheduled`].
    pub fn scheduled(&self) -> u64 {
        self.scheduled
    }

    /// See [`EventCalendar::dispatched`].
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// See [`EventCalendar::reset`]: empties every occupied slot (bitmap-
    /// guided, so a drained calendar resets in 11 loads) keeping all slot
    /// allocations for the next warm-started run.
    pub fn reset(&mut self) {
        for level in 0..LEVELS {
            let mut bits = self.occ[level];
            while bits != 0 {
                let slot = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                self.slots[level * SLOTS + slot].clear();
            }
            self.occ[level] = 0;
        }
        self.len = 0;
        self.seq = 0;
        self.now = TimePoint::ZERO;
        self.cursor = 0;
        self.scheduled = 0;
        self.dispatched = 0;
    }
}

// ---- the dispatching front ------------------------------------------------

/// The calendar the engine schedules on: one of the two implementations,
/// chosen by [`CalendarKind`]. Static enum dispatch (not a trait object):
/// the hot loop's push/pop stay monomorphized and inlinable.
pub enum Calendar<E> {
    Heap(EventCalendar<E>),
    Wheel(WheelCalendar<E>),
}

impl<E> Calendar<E> {
    pub fn new(kind: CalendarKind) -> Self {
        match kind {
            CalendarKind::Heap => Calendar::Heap(EventCalendar::new()),
            CalendarKind::Wheel => Calendar::Wheel(WheelCalendar::new()),
        }
    }

    pub fn kind(&self) -> CalendarKind {
        match self {
            Calendar::Heap(_) => CalendarKind::Heap,
            Calendar::Wheel(_) => CalendarKind::Wheel,
        }
    }

    pub fn now(&self) -> TimePoint {
        match self {
            Calendar::Heap(c) => c.now(),
            Calendar::Wheel(c) => c.now(),
        }
    }

    #[inline]
    pub fn push(&mut self, at: TimePoint, ev: E) {
        match self {
            Calendar::Heap(c) => c.push(at, ev),
            Calendar::Wheel(c) => c.push(at, ev),
        }
    }

    #[inline]
    pub fn pop(&mut self) -> Option<(TimePoint, E)> {
        match self {
            Calendar::Heap(c) => c.pop(),
            Calendar::Wheel(c) => c.pop(),
        }
    }

    pub fn peek_time(&self) -> Option<TimePoint> {
        match self {
            Calendar::Heap(c) => c.peek_time(),
            Calendar::Wheel(c) => c.peek_time(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Calendar::Heap(c) => c.len(),
            Calendar::Wheel(c) => c.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn scheduled(&self) -> u64 {
        match self {
            Calendar::Heap(c) => c.scheduled(),
            Calendar::Wheel(c) => c.scheduled(),
        }
    }

    pub fn dispatched(&self) -> u64 {
        match self {
            Calendar::Heap(c) => c.dispatched(),
            Calendar::Wheel(c) => c.dispatched(),
        }
    }

    /// Empty and rewind, keeping allocations (see the per-impl `reset`s).
    pub fn reset(&mut self) {
        match self {
            Calendar::Heap(c) => c.reset(),
            Calendar::Wheel(c) => c.reset(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::time::TimeSpan;
    use crate::util::Rng;

    /// Every semantics test runs against both implementations.
    fn both() -> Vec<Calendar<&'static str>> {
        vec![Calendar::new(CalendarKind::Heap), Calendar::new(CalendarKind::Wheel)]
    }

    #[test]
    fn pops_in_time_order() {
        for mut c in both() {
            c.push(TimePoint::from_ps(30), "c");
            c.push(TimePoint::from_ps(10), "a");
            c.push(TimePoint::from_ps(20), "b");
            let order: Vec<&str> = std::iter::from_fn(|| c.pop().map(|(_, e)| e)).collect();
            assert_eq!(order, vec!["a", "b", "c"], "{:?}", c.kind());
        }
    }

    #[test]
    fn equal_times_pop_in_insertion_order() {
        for kind in [CalendarKind::Heap, CalendarKind::Wheel] {
            let mut c = Calendar::new(kind);
            for i in 0..100 {
                c.push(TimePoint::from_ps(5), i);
            }
            let order: Vec<i32> = std::iter::from_fn(|| c.pop().map(|(_, e)| e)).collect();
            assert_eq!(order, (0..100).collect::<Vec<_>>(), "{kind:?}");
        }
    }

    #[test]
    fn clock_is_monotone_and_past_pushes_clamp() {
        for mut c in both() {
            c.push(TimePoint::from_ps(100), "later");
            assert_eq!(c.pop().unwrap().0.ps(), 100);
            assert_eq!(c.now().ps(), 100);
            // schedule "in the past": fires at now, not before
            c.push(TimePoint::from_ps(10), "past");
            let (t, e) = c.pop().unwrap();
            assert_eq!(t.ps(), 100, "{:?}", c.kind());
            assert_eq!(e, "past");
            assert_eq!(c.now() + TimeSpan::ZERO, t);
        }
    }

    #[test]
    fn counters_track_throughput() {
        for kind in [CalendarKind::Heap, CalendarKind::Wheel] {
            let mut c = Calendar::new(kind);
            for i in 0..10u64 {
                c.push(TimePoint::from_ps(i), i);
            }
            assert_eq!(c.scheduled(), 10, "{kind:?}");
            while c.pop().is_some() {}
            assert_eq!(c.dispatched(), 10, "{kind:?}");
            assert!(c.is_empty());
            assert_eq!(c.len(), 0);
        }
    }

    #[test]
    fn peek_time_coherent_after_past_clamp() {
        // A push "into the past" clamps to `now`; peek_time must report the
        // clamped (fireable) time, not the stale requested one — on both
        // implementations, including when the wheel clamps across levels.
        for mut c in both() {
            let kind = c.kind();
            c.push(TimePoint::from_ps(5_000), "later");
            assert_eq!(c.pop().unwrap().0.ps(), 5_000);
            c.push(TimePoint::from_ps(7), "past");
            assert_eq!(
                c.peek_time(),
                Some(TimePoint::from_ps(5_000)),
                "{kind:?}: peek must show the clamp-to-now time"
            );
            // popping agrees with the peek, and the clock never rewinds
            let (t, e) = c.pop().unwrap();
            assert_eq!((t.ps(), e), (5_000, "past"), "{kind:?}");
            assert_eq!(c.now().ps(), 5_000);
            assert_eq!(c.peek_time(), None);
        }
    }

    #[test]
    fn wheel_cascades_across_levels() {
        // events far enough apart to land on different wheel levels, pushed
        // out of order, interleaved with pops that force cascades
        let mut c = WheelCalendar::new();
        let times =
            [1u64 << 40, 3, (1 << 40) + 77, 1 << 18, (1 << 18) + 1, 64, 65, 63, 1 << 59];
        for (i, &t) in times.iter().enumerate() {
            c.push(TimePoint::from_ps(t), i);
        }
        let mut sorted: Vec<u64> = times.to_vec();
        sorted.sort_unstable();
        let popped: Vec<u64> = std::iter::from_fn(|| c.pop().map(|(t, _)| t.ps())).collect();
        assert_eq!(popped, sorted);
        assert_eq!(c.dispatched(), times.len() as u64);
    }

    /// The determinism contract, adversarially: seeded random interleaved
    /// push/pop schedules — equal-time bursts, past-time clamps, near and
    /// far horizons (to exercise every wheel level) — must produce
    /// *identical* pop sequences, peeks and counters on both calendars.
    #[test]
    fn randomized_schedules_pop_identically_on_both_calendars() {
        for seed in 0..8u64 {
            let mut rng = Rng::new(0xCA1E_0000 + seed);
            let mut heap: Calendar<u32> = Calendar::new(CalendarKind::Heap);
            let mut wheel: Calendar<u32> = Calendar::new(CalendarKind::Wheel);
            let mut payload = 0u32;
            for _ in 0..4_000 {
                let roll = rng.next_u64() % 100;
                if roll < 60 {
                    // push: horizon spans sub-slot to multi-level jumps
                    let base = heap.now().ps();
                    let dt = match rng.next_u64() % 5 {
                        0 => 0,                                  // equal-time burst
                        1 => rng.next_u64() % 64,                     // level 0
                        2 => rng.next_u64() % 4_096,                  // level 1
                        3 => rng.next_u64() % (1 << 30),              // mid levels
                        _ => rng.next_u64() % (1 << 50),              // far future
                    };
                    // ~1 in 8 pushes aims into the past (clamps to now)
                    let at = if rng.next_u64() % 8 == 0 {
                        TimePoint::from_ps(base / 2)
                    } else {
                        TimePoint::from_ps(base.saturating_add(dt))
                    };
                    heap.push(at, payload);
                    wheel.push(at, payload);
                    payload += 1;
                } else {
                    assert_eq!(heap.peek_time(), wheel.peek_time(), "seed {seed}");
                    assert_eq!(heap.pop(), wheel.pop(), "seed {seed}");
                }
            }
            // drain: the full remaining sequences must match too
            loop {
                let (h, w) = (heap.pop(), wheel.pop());
                assert_eq!(h, w, "seed {seed}");
                if h.is_none() {
                    break;
                }
            }
            assert_eq!(heap.scheduled(), wheel.scheduled(), "seed {seed}");
            assert_eq!(heap.dispatched(), wheel.dispatched(), "seed {seed}");
            assert_eq!(heap.now(), wheel.now(), "seed {seed}");
        }
    }

    #[test]
    fn reset_reuses_without_leaking_state() {
        for mut c in both() {
            c.push(TimePoint::from_ps(999), "x");
            c.push(TimePoint::from_ps(1), "y");
            let _ = c.pop();
            c.reset();
            assert!(c.is_empty());
            assert_eq!((c.scheduled(), c.dispatched()), (0, 0));
            assert_eq!(c.now(), TimePoint::ZERO);
            assert_eq!(c.peek_time(), None);
            // a fresh schedule behaves exactly like a new calendar
            c.push(TimePoint::from_ps(2), "b");
            c.push(TimePoint::from_ps(2), "c");
            assert_eq!(c.pop(), Some((TimePoint::from_ps(2), "b")));
            assert_eq!(c.pop(), Some((TimePoint::from_ps(2), "c")));
        }
    }
}
