//! The event calendar: a binary-heap priority queue over integer time.
//!
//! Determinism contract: events at equal timestamps pop in *insertion
//! order* (a monotone sequence number breaks ties), so a simulation is a
//! pure function of its inputs — no HashMap iteration order, no wall clock.

use std::collections::BinaryHeap;

use super::time::TimePoint;

struct Entry<E> {
    time: TimePoint,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest-first
        (other.time, other.seq).cmp(&(self.time, self.seq))
    }
}

/// Min-heap event calendar with a monotone clock.
pub struct EventCalendar<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: TimePoint,
    scheduled: u64,
    dispatched: u64,
}

impl<E> Default for EventCalendar<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventCalendar<E> {
    pub fn new() -> Self {
        EventCalendar {
            heap: BinaryHeap::new(),
            seq: 0,
            now: TimePoint::ZERO,
            scheduled: 0,
            dispatched: 0,
        }
    }

    /// Current simulated time (the timestamp of the last popped event).
    pub fn now(&self) -> TimePoint {
        self.now
    }

    /// Schedule `ev` at absolute time `at`. Scheduling in the past is
    /// clamped to `now` (the event fires immediately, after already-queued
    /// same-time events).
    pub fn push(&mut self, at: TimePoint, ev: E) {
        let time = at.max(self.now);
        self.heap.push(Entry { time, seq: self.seq, ev });
        self.seq += 1;
        self.scheduled += 1;
    }

    /// Pop the earliest event and advance the clock to it.
    pub fn pop(&mut self) -> Option<(TimePoint, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.time >= self.now, "calendar time went backwards");
        self.now = e.time;
        self.dispatched += 1;
        Some((e.time, e.ev))
    }

    pub fn peek_time(&self) -> Option<TimePoint> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events ever scheduled (for the events/sec bench + report).
    pub fn scheduled(&self) -> u64 {
        self.scheduled
    }

    /// Total events dispatched via [`pop`](Self::pop).
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::time::TimeSpan;

    #[test]
    fn pops_in_time_order() {
        let mut c = EventCalendar::new();
        c.push(TimePoint::from_ps(30), "c");
        c.push(TimePoint::from_ps(10), "a");
        c.push(TimePoint::from_ps(20), "b");
        let order: Vec<&str> = std::iter::from_fn(|| c.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn equal_times_pop_in_insertion_order() {
        let mut c = EventCalendar::new();
        for i in 0..100 {
            c.push(TimePoint::from_ps(5), i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| c.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn clock_is_monotone_and_past_pushes_clamp() {
        let mut c = EventCalendar::new();
        c.push(TimePoint::from_ps(100), "later");
        assert_eq!(c.pop().unwrap().0.ps(), 100);
        assert_eq!(c.now().ps(), 100);
        // schedule "in the past": fires at now, not before
        c.push(TimePoint::from_ps(10), "past");
        let (t, e) = c.pop().unwrap();
        assert_eq!(t.ps(), 100);
        assert_eq!(e, "past");
        assert_eq!(c.now() + TimeSpan::ZERO, t);
    }

    #[test]
    fn counters_track_throughput() {
        let mut c = EventCalendar::new();
        for i in 0..10u64 {
            c.push(TimePoint::from_ps(i), i);
        }
        assert_eq!(c.scheduled(), 10);
        while c.pop().is_some() {}
        assert_eq!(c.dispatched(), 10);
        assert!(c.is_empty());
        assert_eq!(c.len(), 0);
    }
}
