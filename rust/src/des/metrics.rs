//! DES output metrics + the time-weighted accumulators that produce them.

use std::collections::BTreeMap;
use std::fmt;

use super::time::TimePoint;

/// What kind of queueing-network node a metric row describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// Kernel compute unit (dedicated server).
    Cu,
    /// Stream FIFO (finite queue).
    Fifo,
    /// Data mover (server on a shared-rate memory channel).
    Mover,
}

impl NodeKind {
    pub fn as_str(self) -> &'static str {
        match self {
            NodeKind::Cu => "cu",
            NodeKind::Fifo => "fifo",
            NodeKind::Mover => "mover",
        }
    }
}

/// Per-node steady-state metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeMetrics {
    pub name: String,
    pub kind: NodeKind,
    /// Busy fraction (servers) / non-empty fraction (queues).
    pub utilization: f64,
    /// Time-weighted mean queue depth (elems for FIFOs, 0/1 for servers).
    pub mean_depth: f64,
    /// Time-weighted p99 queue depth.
    pub p99_depth: u64,
    pub max_depth: u64,
    /// Mean sojourn (wait + service) through the node, seconds.
    pub mean_sojourn_s: f64,
    pub p99_sojourn_s: f64,
    /// Chunks served (movers/FIFOs) or firings (CUs).
    pub completions: u64,
}

/// Per-traffic-class latency and deadline accounting (one row per class in
/// the scenario's plan; synthetic scenarios have a single `default` class).
#[derive(Debug, Clone, PartialEq)]
pub struct ClassStats {
    pub class: String,
    /// Jobs of this class completed.
    pub jobs: u64,
    pub mean_latency_s: f64,
    pub p99_latency_s: f64,
    /// Completed jobs of this class that carried a deadline.
    pub deadline_jobs: u64,
    /// Of those, how many finished after it.
    pub deadline_misses: u64,
}

impl ClassStats {
    /// Fraction of deadline-carrying jobs that missed (0.0 when none
    /// carried one).
    pub fn deadline_miss_rate(&self) -> f64 {
        if self.deadline_jobs == 0 {
            0.0
        } else {
            self.deadline_misses as f64 / self.deadline_jobs as f64
        }
    }
}

/// Whole-run DES report. Everything here is a pure function of
/// (architecture, scenario, config) — the deterministic-replay tests
/// compare entire reports with `==`.
#[derive(Debug, Clone, PartialEq)]
pub struct DesReport {
    pub scenario: String,
    pub seed: u64,
    pub nodes: Vec<NodeMetrics>,
    pub jobs_released: u64,
    pub jobs_completed: u64,
    /// Completion time of the last job (s).
    pub makespan_s: f64,
    pub mean_job_latency_s: f64,
    pub p50_job_latency_s: f64,
    pub p99_job_latency_s: f64,
    pub max_job_latency_s: f64,
    /// Completed jobs per simulated second.
    pub throughput_jobs_per_s: f64,
    /// Events dispatched by the calendar.
    pub events: u64,
    /// Per-class latency/deadline stats, in class-plan order.
    pub classes: Vec<ClassStats>,
}

impl DesReport {
    /// Convenience: the worst p99 FIFO occupancy across the design (the
    /// backpressure hot-spot).
    pub fn worst_fifo_p99_depth(&self) -> u64 {
        self.nodes
            .iter()
            .filter(|n| n.kind == NodeKind::Fifo)
            .map(|n| n.p99_depth)
            .max()
            .unwrap_or(0)
    }

    /// Convenience: highest server utilization (the bottleneck node).
    pub fn bottleneck(&self) -> Option<&NodeMetrics> {
        self.nodes
            .iter()
            .filter(|n| n.kind != NodeKind::Fifo)
            .max_by(|a, b| a.utilization.partial_cmp(&b.utilization).unwrap())
    }
}

impl fmt::Display for DesReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "== des report: {} (seed {}) ==", self.scenario, self.seed)?;
        writeln!(
            f,
            "jobs {}/{} completed, makespan {:.3} ms, throughput {:.1} jobs/s",
            self.jobs_completed,
            self.jobs_released,
            self.makespan_s * 1e3,
            self.throughput_jobs_per_s
        )?;
        writeln!(
            f,
            "job latency mean {:.3} ms  p50 {:.3} ms  p99 {:.3} ms  max {:.3} ms",
            self.mean_job_latency_s * 1e3,
            self.p50_job_latency_s * 1e3,
            self.p99_job_latency_s * 1e3,
            self.max_job_latency_s * 1e3
        )?;
        writeln!(f, "{} calendar events", self.events)?;
        // per-class rows earn their space only when there is class structure
        if self.classes.len() > 1 || self.classes.iter().any(|c| c.deadline_jobs > 0) {
            for c in &self.classes {
                write!(
                    f,
                    "class {:<16} {:>6} jobs  mean {:.3} ms  p99 {:.3} ms",
                    c.class,
                    c.jobs,
                    c.mean_latency_s * 1e3,
                    c.p99_latency_s * 1e3
                )?;
                if c.deadline_jobs > 0 {
                    write!(
                        f,
                        "  deadline-miss {}/{} ({:.1}%)",
                        c.deadline_misses,
                        c.deadline_jobs,
                        c.deadline_miss_rate() * 100.0
                    )?;
                }
                writeln!(f)?;
            }
        }
        writeln!(
            f,
            "{:<30} {:>6} {:>7} {:>10} {:>9} {:>11} {:>11} {:>9}",
            "node", "kind", "util", "mean-depth", "p99-depth", "mean-soj", "p99-soj", "chunks"
        )?;
        for n in &self.nodes {
            writeln!(
                f,
                "{:<30} {:>6} {:>6.1}% {:>10.2} {:>9} {:>9.2}us {:>9.2}us {:>9}",
                n.name,
                n.kind.as_str(),
                n.utilization * 100.0,
                n.mean_depth,
                n.p99_depth,
                n.mean_sojourn_s * 1e6,
                n.p99_sojourn_s * 1e6,
                n.completions
            )?;
        }
        Ok(())
    }
}

// ---- accumulators ---------------------------------------------------------

/// Time-weighted depth histogram: how long the node sat at each depth.
#[derive(Debug, Clone, Default)]
pub(crate) struct DepthTrack {
    cur: u64,
    max: u64,
    last: TimePoint,
    /// depth -> accumulated ps at that depth.
    hist: BTreeMap<u64, u64>,
}

impl DepthTrack {
    /// Record a depth change at `now`.
    pub fn set(&mut self, now: TimePoint, depth: u64) {
        let dt = (now - self.last).ps();
        if dt > 0 {
            *self.hist.entry(self.cur).or_insert(0) += dt;
        }
        self.last = now;
        self.cur = depth;
        self.max = self.max.max(depth);
    }

    pub fn add(&mut self, now: TimePoint, delta: i64) {
        let d = if delta >= 0 {
            self.cur.saturating_add(delta as u64)
        } else {
            self.cur.saturating_sub((-delta) as u64)
        };
        self.set(now, d);
    }

    pub fn depth(&self) -> u64 {
        self.cur
    }

    /// Back to a fresh track, keeping the histogram's node allocations.
    pub fn reset(&mut self) {
        self.cur = 0;
        self.max = 0;
        self.last = TimePoint::ZERO;
        self.hist.clear();
    }

    /// Close the histogram at `end` and summarize. Non-consuming so pooled
    /// engine arenas can reuse the track; callers reset before the next run.
    pub fn finish(&mut self, end: TimePoint) -> (f64, u64, u64, f64) {
        self.set(end, self.cur);
        let total: u64 = self.hist.values().sum();
        if total == 0 {
            return (0.0, 0, self.max, 0.0);
        }
        let mean = self
            .hist
            .iter()
            .map(|(d, t)| *d as f64 * *t as f64)
            .sum::<f64>()
            / total as f64;
        let p99_target = (total as f64 * 0.99).ceil() as u64;
        let mut cum = 0u64;
        let mut p99 = self.max;
        for (d, t) in &self.hist {
            cum += t;
            if cum >= p99_target {
                p99 = *d;
                break;
            }
        }
        let busy_ps = total - self.hist.get(&0).copied().unwrap_or(0);
        let utilization = busy_ps as f64 / total as f64;
        (mean, p99, self.max, utilization)
    }
}

/// Percentile of an unsorted sample set (nearest-rank).
pub(crate) fn percentile(samples: &mut [f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((samples.len() as f64 * p).ceil() as usize).clamp(1, samples.len()) - 1;
    samples[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_track_time_weighted_mean() {
        let mut t = DepthTrack::default();
        // depth 0 for 10ps, 4 for 30ps, 2 for 60ps
        t.set(TimePoint::from_ps(10), 4);
        t.set(TimePoint::from_ps(40), 2);
        let (mean, p99, max, util) = t.finish(TimePoint::from_ps(100));
        let want = (0.0 * 10.0 + 4.0 * 30.0 + 2.0 * 60.0) / 100.0;
        assert!((mean - want).abs() < 1e-12, "mean {mean} want {want}");
        assert_eq!(max, 4);
        assert_eq!(p99, 4);
        assert!((util - 0.9).abs() < 1e-12);
    }

    #[test]
    fn depth_track_p99_picks_tail_depth() {
        let mut t = DepthTrack::default();
        // 99.5% of time at depth 1, 0.5% at depth 100
        t.set(TimePoint::from_ps(0), 1);
        t.set(TimePoint::from_ps(995), 100);
        let (_, p99, max, _) = t.finish(TimePoint::from_ps(1000));
        assert_eq!(max, 100);
        assert_eq!(p99, 1, "p99 excludes the 0.5% tail");
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&mut xs, 0.5), 50.0);
        assert_eq!(percentile(&mut xs, 0.99), 99.0);
        assert_eq!(percentile(&mut xs, 1.0), 100.0);
        assert_eq!(percentile(&mut [], 0.5), 0.0);
    }

    #[test]
    fn add_clamps_at_zero() {
        let mut t = DepthTrack::default();
        t.add(TimePoint::from_ps(5), 2);
        t.add(TimePoint::from_ps(10), -5);
        assert_eq!(t.depth(), 0);
    }
}
