//! `des` — deterministic discrete-event queueing simulator.
//!
//! The static analyses ([`crate::analysis`]) score a candidate architecture
//! by closed-form beat counting: they cannot see HBM pseudo-channel
//! contention, FIFO backpressure or bursty arrival tails. This subsystem
//! models the lowered [`crate::lower::Architecture`] as a queueing network
//! and replays workload scenarios through it on a binary-heap event
//! calendar with integer picosecond time:
//!
//! * CU = dedicated server (II cycles/element at the congestion-derated
//!   kernel clock, pipeline fill charged once per job);
//! * data mover = server on a *shared-rate* memory channel (concurrent
//!   movers split the channel's beat rate, derated to
//!   [`crate::platform::PcSpec::sustained_frac`] under contention);
//! * stream FIFO = finite queue exerting backpressure on its producer.
//!
//! Everything is deterministic: same architecture + scenario + seed gives
//! a bit-identical [`DesReport`]. The DSE (`passes::dse`) uses this as its
//! high-fidelity `des-score` objective; `examples/bursty_hbm.rs` uses the
//! scenario machinery to compare arrival patterns.

mod build;
mod calendar;
mod metrics;
mod network;
mod scenario;
mod time;

pub use build::{build_network, CuSpec, DesNet, FifoSpec, FlowSpec, MoverSpec};
pub use calendar::{Calendar, CalendarKind, EventCalendar, WheelCalendar};
pub use metrics::{ClassStats, DesReport, NodeKind, NodeMetrics};
pub use network::{
    simulate, simulate_arena, simulate_network, simulate_network_arena, simulate_network_traced,
    simulate_traced, DesConfig, EngineArena, ServiceDist,
};
pub use scenario::{ArrivalPlan, ArrivalProcess, WorkloadScenario};
pub use time::{TimePoint, TimeSpan, PS_PER_S};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_flow;
    use crate::dialect::build::fig4a_module;
    use crate::lower::Architecture;
    use crate::platform::builtin;

    fn arch_for(pipeline: &str) -> Architecture {
        let plat = builtin("u280").unwrap();
        run_flow(fig4a_module(), &plat, Some(pipeline)).unwrap().arch
    }

    /// Two read movers, no consumers: pure memory-channel behavior.
    fn two_mover_net(same_pc: bool) -> DesNet {
        let plat = builtin("u280").unwrap();
        let mk = |pc: usize, fifo: usize| MoverSpec {
            name: format!("dm{fifo}"),
            pc,
            read: true,
            flows: vec![FlowSpec {
                base: format!("b{fifo}"),
                fifo: Some(fifo),
                elems_per_job: 1024,
                beats_per_elem: 1.0,
            }],
        };
        DesNet {
            platform: plat,
            fifos: vec![
                FifoSpec { name: "f0".into(), cap_elems: 4096 },
                FifoSpec { name: "f1".into(), cap_elems: 4096 },
            ],
            movers: vec![mk(0, 0), mk(if same_pc { 0 } else { 1 }, 1)],
            cus: Vec::new(),
            fifo_job_elems: vec![1024, 1024],
        }
    }

    #[test]
    fn shared_channel_contention_slows_transfers() {
        let cfg = DesConfig::default();
        let sc = WorkloadScenario::closed_loop(1);
        let shared = simulate_network(&two_mover_net(true), &sc, &cfg).unwrap();
        let spread = simulate_network(&two_mover_net(false), &sc, &cfg).unwrap();
        // alone: 1024 beats at 450 MHz
        let solo = 1024.0 / 450e6;
        assert!(
            (spread.makespan_s - solo).abs() / solo < 0.05,
            "spread {} want {solo}",
            spread.makespan_s
        );
        // shared: 2048 beats at 0.85 x 450 MHz -> ~2.35x the spread time
        assert!(
            shared.makespan_s > 2.0 * spread.makespan_s,
            "contention must bite: shared {} spread {}",
            shared.makespan_s,
            spread.makespan_s
        );
        assert_eq!(shared.jobs_completed, 1);
    }

    /// mover -> small FIFO -> slow CU -> FIFO -> write mover.
    fn tandem_net(cap: u64, ii: u64) -> DesNet {
        let plat = builtin("generic-ddr").unwrap();
        DesNet {
            platform: plat,
            fifos: vec![
                FifoSpec { name: "in".into(), cap_elems: cap },
                FifoSpec { name: "out".into(), cap_elems: cap },
            ],
            movers: vec![
                MoverSpec {
                    name: "dm_in".into(),
                    pc: 0,
                    read: true,
                    flows: vec![FlowSpec {
                        base: "in".into(),
                        fifo: Some(0),
                        elems_per_job: 4096,
                        beats_per_elem: 1.0,
                    }],
                },
                MoverSpec {
                    name: "dm_out".into(),
                    pc: 1,
                    read: false,
                    flows: vec![FlowSpec {
                        base: "out".into(),
                        fifo: Some(1),
                        elems_per_job: 4096,
                        beats_per_elem: 1.0,
                    }],
                },
            ],
            cus: vec![CuSpec {
                name: "cu0".into(),
                in_fifos: vec![0],
                out_fifos: vec![1],
                ii,
                latency: 300,
                out_elems_per_job: 4096,
            }],
            fifo_job_elems: vec![4096, 4096],
        }
    }

    #[test]
    fn backpressure_pegs_small_fifo_and_compute_binds_makespan() {
        let cfg = DesConfig::default();
        let sc = WorkloadScenario::closed_loop(1);
        let r = simulate_network(&tandem_net(64, 8), &sc, &cfg).unwrap();
        assert_eq!(r.jobs_completed, 1);
        // compute-bound: 4096 elems x II 8 + one 300-cycle fill at 300 MHz
        let want = (4096 * 8 + 300) as f64 / 300e6;
        assert!(
            (r.makespan_s - want).abs() / want < 0.10,
            "makespan {} want ~{want}",
            r.makespan_s
        );
        // the input FIFO sits pegged near capacity (backpressure)...
        let fin = r.nodes.iter().find(|n| n.name == "in").unwrap();
        assert!(fin.p99_depth >= 32, "input fifo p99 {fin:?}");
        // ...while the read mover idles, throttled by the slow consumer
        let dm = r.nodes.iter().find(|n| n.name == "dm_in").unwrap();
        assert!(dm.utilization < 0.2, "mover should be blocked: {dm:?}");
        // and the CU is the ~100% utilized bottleneck
        let cu = r.nodes.iter().find(|n| n.name == "cu0").unwrap();
        assert!(cu.utilization > 0.9, "cu {cu:?}");
        assert_eq!(r.bottleneck().unwrap().name, "cu0");
    }

    #[test]
    fn deterministic_replay_bit_identical() {
        let arch = arch_for("sanitize, iris, channel-reassign");
        let sc = WorkloadScenario::bursty(50_000.0, 0.0002, 0.0008, 20);
        let cfg = DesConfig { seed: 7, ..DesConfig::default() };
        let a = simulate(&arch, &sc, &cfg).unwrap();
        let b = simulate(&arch, &sc, &cfg).unwrap();
        assert_eq!(a, b, "same seed must replay bit-identically");
        // a different seed shifts the arrival draw
        let c = simulate(&arch, &sc, &DesConfig { seed: 8, ..DesConfig::default() }).unwrap();
        assert_ne!(a.p99_job_latency_s, c.p99_job_latency_s);
    }

    #[test]
    fn iris_architecture_beats_naive_on_memory_bound_batch() {
        let cfg = DesConfig::default();
        let sc = WorkloadScenario::closed_loop(4);
        let base = simulate(&arch_for("sanitize"), &sc, &cfg).unwrap();
        let iris = simulate(&arch_for("sanitize, iris, channel-reassign"), &sc, &cfg).unwrap();
        assert_eq!(base.jobs_completed, 4);
        assert_eq!(iris.jobs_completed, 4);
        assert!(
            iris.makespan_s < base.makespan_s,
            "iris {} vs naive {}",
            iris.makespan_s,
            base.makespan_s
        );
    }

    #[test]
    fn report_renders_every_node() {
        let arch = arch_for("sanitize");
        let r = simulate(&arch, &WorkloadScenario::closed_loop(2), &DesConfig::default())
            .unwrap();
        assert_eq!(r.nodes.len(), 3 + 1 + 3, "3 fifos + 1 cu + 3 movers");
        let text = r.to_string();
        for n in &r.nodes {
            assert!(text.contains(&n.name), "missing {} in:\n{text}", n.name);
        }
        assert!(r.events > 0);
        assert!(r.throughput_jobs_per_s > 0.0);
        // queue-depth maxima never exceed FIFO capacity
        for n in r.nodes.iter().filter(|n| n.kind == NodeKind::Fifo) {
            assert!(n.max_depth <= 1024, "{n:?}");
        }
    }

    /// Tentpole acceptance: the timing wheel and the binary heap are the
    /// same simulator. Full [`DesReport`] equality — node tables, class
    /// stats, event counts — on both a built architecture and a raw net.
    #[test]
    fn wheel_and_heap_reports_are_identical() {
        let arch = arch_for("sanitize, iris, channel-reassign");
        let sc = WorkloadScenario::bursty(50_000.0, 0.0002, 0.0008, 20);
        let wheel =
            DesConfig { seed: 7, calendar: CalendarKind::Wheel, ..DesConfig::default() };
        let heap = DesConfig { calendar: CalendarKind::Heap, ..wheel.clone() };
        assert_eq!(
            simulate(&arch, &sc, &wheel).unwrap(),
            simulate(&arch, &sc, &heap).unwrap(),
            "calendar choice must not change the report"
        );
        let sc = WorkloadScenario::closed_loop(3);
        assert_eq!(
            simulate_network(&tandem_net(64, 8), &sc, &wheel).unwrap(),
            simulate_network(&tandem_net(64, 8), &sc, &heap).unwrap(),
            "raw-net replay too"
        );
    }

    /// The calendar is an engine knob, not a modeling knob: it must stay
    /// out of the `Debug` rendering (which feeds every DSE cache key) and
    /// out of the wire codec, so a wheel coordinator and a heap worker
    /// share one cache namespace.
    #[test]
    fn calendar_is_excluded_from_cache_keys_and_wire() {
        let wheel = DesConfig { calendar: CalendarKind::Wheel, ..DesConfig::default() };
        let heap = DesConfig { calendar: CalendarKind::Heap, ..DesConfig::default() };
        assert_eq!(format!("{wheel:?}"), format!("{heap:?}"), "Debug feeds cache keys");
        assert!(!format!("{wheel:?}").contains("calendar"));
        assert_eq!(wheel.to_json().to_string(), heap.to_json().to_string());
        let back = DesConfig::from_json(&heap.to_json()).unwrap();
        assert_eq!(back.calendar, CalendarKind::Wheel, "wire decode takes the default");
    }

    /// Warm-start acceptance: one [`EngineArena`] reused across different
    /// nets and scenarios replays each bit-identically to a fresh engine —
    /// leftover capacity must never leak into results.
    #[test]
    fn arena_reuse_is_bit_identical_across_nets() {
        let cfg = DesConfig::default();
        let mut arena = EngineArena::new();
        let runs: Vec<(DesNet, WorkloadScenario)> = vec![
            (tandem_net(64, 8), WorkloadScenario::closed_loop(2)),
            (two_mover_net(true), WorkloadScenario::closed_loop(1)),
            (tandem_net(256, 2), WorkloadScenario::poisson(1_000_000.0, 6)),
            (two_mover_net(false), WorkloadScenario::closed_loop(3)),
        ];
        for (net, sc) in &runs {
            let fresh = simulate_network(net, sc, &cfg).unwrap();
            let reused = simulate_network_arena(net, sc, &cfg, &mut arena).unwrap();
            assert_eq!(fresh, reused, "arena reuse must not move a byte");
        }
    }

    #[test]
    fn open_loop_latency_grows_under_load() {
        let arch = arch_for("sanitize");
        let cfg = DesConfig::default();
        // light load: arrivals far apart -> latency ~= isolated job latency
        let light =
            simulate(&arch, &WorkloadScenario::poisson(1_000.0, 20), &cfg).unwrap();
        // heavy load: offered rate far above service rate -> queueing delay
        let heavy =
            simulate(&arch, &WorkloadScenario::poisson(1_000_000.0, 20), &cfg).unwrap();
        assert_eq!(light.jobs_completed, 20);
        assert_eq!(heavy.jobs_completed, 20);
        assert!(
            heavy.p99_job_latency_s > 2.0 * light.p99_job_latency_s,
            "overload must queue: heavy p99 {} light p99 {}",
            heavy.p99_job_latency_s,
            light.p99_job_latency_s
        );
    }
}
