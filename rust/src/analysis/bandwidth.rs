//! Bandwidth-utilization analysis (paper §V-B, first analysis).
//!
//! Model: a physical memory channel (PC) moves `width_bits` per beat at
//! `freq_mhz`. A data channel whose layout packs `used_bits` useful bits
//! into a `word_bits` word consumes `ceil(word_bits / pc_width)` beats per
//! word — a *naive* 32-bit stream on a 256-bit HBM PC therefore wastes
//! 87.5% of every beat, which is exactly the inefficiency the paper's Iris
//! bus optimization removes.

use std::collections::BTreeMap;

use crate::dialect::Layout;
use crate::ir::Module;
use crate::platform::{MemKind, PlatformSpec};

use super::dfg::Dfg;

/// Per-PC usage summary.
#[derive(Debug, Clone)]
pub struct PcUsage {
    pub pc_id: u32,
    pub kind: MemKind,
    /// Useful payload moved per app iteration (bytes).
    pub useful_bytes: u64,
    /// Beats needed per app iteration.
    pub beats: u64,
    /// Bandwidth efficiency: useful bits / (beats × width).
    pub efficiency: f64,
    /// Seconds to move one iteration's data at peak beat rate.
    pub time_s: f64,
    /// Channels assigned here.
    pub num_channels: usize,
}

/// Whole-design bandwidth report.
#[derive(Debug, Clone)]
pub struct BandwidthReport {
    pub per_pc: Vec<PcUsage>,
    /// Useful bytes per iteration across all PCs.
    pub total_useful_bytes: u64,
    /// Weighted efficiency across used PCs.
    pub aggregate_efficiency: f64,
    /// Streaming makespan: the slowest PC's transfer time (s).
    pub makespan_s: f64,
    /// The PC that binds the makespan.
    pub bottleneck_pc: Option<u32>,
    /// Achieved aggregate bandwidth if all PCs stream concurrently (GB/s):
    /// total useful bytes / makespan.
    pub achieved_gbs: f64,
    /// Fraction of the platform's *used-PC* peak actually delivering payload.
    pub utilization: f64,
}

/// Analyze bandwidth for the current PC assignment.
///
/// Channels without PC terminals (pre-sanitize IR) are ignored; run the
/// sanitize pass first for a meaningful report.
pub fn analyze_bandwidth(m: &Module, plat: &PlatformSpec, dfg: &Dfg) -> BandwidthReport {
    // pc id -> (useful_bits, beats, channels)
    let mut acc: BTreeMap<u32, (u64, u64, usize)> = BTreeMap::new();
    for binding in &dfg.memory_channels {
        let ch = binding.channel;
        for pc in &binding.pcs {
            let pc_id = pc.id(m);
            let Some(spec) = plat.pcs.get(pc_id as usize) else { continue };
            let layout = ch
                .layout(m)
                .unwrap_or_else(|| Layout::scalar("ch", ch.elem_bits(m).max(1), ch.depth(m)));
            let word_bits = layout.word_bits.max(1);
            let used_bits_per_word = layout.used_bits().min(word_bits) as u64;
            let beats_per_word = word_bits.div_ceil(spec.width_bits) as u64;
            // When several PCs serve one channel (replication assigns clones
            // their own PC ops), each PC carries the full channel payload of
            // its clone; the layout depth already reflects that.
            let words = layout.depth;
            let e = acc.entry(pc_id).or_default();
            e.0 += used_bits_per_word * words;
            e.1 += beats_per_word * words;
            e.2 += 1;
        }
    }

    let mut per_pc = Vec::new();
    let mut total_bits = 0u64;
    let mut makespan = 0.0f64;
    let mut bottleneck = None;
    for (pc_id, (bits, beats, nch)) in acc {
        let spec = plat.pcs[pc_id as usize];
        let cap_bits = beats * spec.width_bits as u64;
        let efficiency = if cap_bits == 0 { 0.0 } else { bits as f64 / cap_bits as f64 };
        let time_s = beats as f64 / (spec.freq_mhz * 1e6);
        if time_s > makespan {
            makespan = time_s;
            bottleneck = Some(pc_id);
        }
        total_bits += bits;
        per_pc.push(PcUsage {
            pc_id,
            kind: spec.kind,
            useful_bytes: bits / 8,
            beats,
            efficiency,
            time_s,
            num_channels: nch,
        });
    }

    let total_useful_bytes = total_bits / 8;
    let used_peak_gbs: f64 =
        per_pc.iter().map(|u| plat.pcs[u.pc_id as usize].bandwidth_gbs()).sum();
    let achieved_gbs =
        if makespan > 0.0 { total_useful_bytes as f64 / makespan / 1e9 } else { 0.0 };
    let aggregate_efficiency = if per_pc.is_empty() {
        0.0
    } else {
        let total_beats_bits: u64 =
            per_pc.iter().map(|u| u.beats * plat.pcs[u.pc_id as usize].width_bits as u64).sum();
        if total_beats_bits == 0 { 0.0 } else { total_bits as f64 / total_beats_bits as f64 }
    };
    BandwidthReport {
        per_pc,
        total_useful_bytes,
        aggregate_efficiency,
        makespan_s: makespan,
        bottleneck_pc: bottleneck,
        achieved_gbs,
        utilization: if used_peak_gbs > 0.0 { achieved_gbs / used_peak_gbs } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::{DfgBuilder, ParamType};
    use crate::platform::builtin;

    /// vecadd DFG with all three channels on PC 0 (the post-sanitize default).
    fn vecadd_on_one_pc() -> (Module, Dfg) {
        let mut b = DfgBuilder::new();
        let a = b.channel(32, ParamType::Stream, 1024);
        let bb = b.channel(32, ParamType::Stream, 1024);
        let c = b.channel(32, ParamType::Stream, 1024);
        b.kernel("vecadd_1024", &[a, bb], &[c], Default::default());
        for v in [a, bb, c] {
            b.pc(v, 0);
        }
        let m = b.finish();
        let g = Dfg::build(&m);
        (m, g)
    }

    #[test]
    fn naive_32bit_stream_is_one_eighth_efficient() {
        let (m, g) = vecadd_on_one_pc();
        let plat = builtin("u280").unwrap();
        let rep = analyze_bandwidth(&m, &plat, &g);
        assert_eq!(rep.per_pc.len(), 1);
        // scalar 32-bit words on a 256-bit PC: 12.5% efficiency
        assert!((rep.per_pc[0].efficiency - 0.125).abs() < 1e-9, "{rep:?}");
        assert_eq!(rep.per_pc[0].num_channels, 3);
        assert_eq!(rep.total_useful_bytes, 3 * 1024 * 4);
    }

    #[test]
    fn spreading_channels_reduces_makespan() {
        let (m1, g1) = vecadd_on_one_pc();
        let plat = builtin("u280").unwrap();
        let rep1 = analyze_bandwidth(&m1, &plat, &g1);

        // same DFG, channels spread over PCs 0,1,2
        let mut b = DfgBuilder::new();
        let a = b.channel(32, ParamType::Stream, 1024);
        let bb = b.channel(32, ParamType::Stream, 1024);
        let c = b.channel(32, ParamType::Stream, 1024);
        b.kernel("vecadd_1024", &[a, bb], &[c], Default::default());
        for (i, v) in [a, bb, c].into_iter().enumerate() {
            b.pc(v, i as u32);
        }
        let m2 = b.finish();
        let g2 = Dfg::build(&m2);
        let rep2 = analyze_bandwidth(&m2, &plat, &g2);

        assert_eq!(rep2.per_pc.len(), 3);
        // 3 channels sharing one PC take 3x the beats of one channel
        assert!((rep1.makespan_s / rep2.makespan_s - 3.0).abs() < 1e-9);
        // aggregate achieved bandwidth triples
        assert!((rep2.achieved_gbs / rep1.achieved_gbs - 3.0).abs() < 1e-6);
    }

    #[test]
    fn packed_layout_restores_efficiency() {
        use crate::dialect::{Layout, LayoutField};
        let mut b = DfgBuilder::new();
        let a = b.channel(32, ParamType::Stream, 1024);
        b.kernel("k", &[a], &[], Default::default());
        b.pc(a, 0);
        let mut m = b.finish();
        // pack 8 × 32-bit into each 256-bit word (what Iris would emit)
        let ch = crate::dialect::ChannelView::all(&m)[0];
        ch.set_layout(
            &mut m,
            &Layout {
                word_bits: 256,
                depth: 128,
                lanes: 1,
                fields: vec![LayoutField {
                    array: "a".into(),
                    elem_bits: 32,
                    count: 8,
                    offset_bits: 0,
                }],
            },
        );
        let g = Dfg::build(&m);
        let plat = builtin("u280").unwrap();
        let rep = analyze_bandwidth(&m, &plat, &g);
        assert!((rep.per_pc[0].efficiency - 1.0).abs() < 1e-9);
        assert_eq!(rep.total_useful_bytes, 4096);
    }

    #[test]
    fn no_pcs_means_empty_report() {
        let m = crate::dialect::build::fig4a_module();
        let g = Dfg::build(&m);
        let plat = builtin("u280").unwrap();
        let rep = analyze_bandwidth(&m, &plat, &g);
        assert!(rep.per_pc.is_empty());
        assert_eq!(rep.utilization, 0.0);
    }
}
