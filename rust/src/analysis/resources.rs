//! Resource-utilization analysis (paper §V-B, second analysis).
//!
//! Sums kernel estimates (the Fig 2 attributes) plus infrastructure
//! overheads Olympus itself introduces when lowering: stream FIFOs, PLM
//! buffers for `small` channels, and per-PC AXI data movers.

use crate::dialect::{ChannelView, KernelView, ParamType, ResourceVec, OP_SUPER_NODE};
use crate::ir::Module;
use crate::platform::PlatformSpec;

use super::dfg::Dfg;

/// Resource accounting for a design on a platform.
#[derive(Debug, Clone)]
pub struct ResourceReport {
    /// Sum of kernel (and super-node member) estimates.
    pub kernels: ResourceVec,
    /// FIFO + PLM + data-mover overhead.
    pub infrastructure: ResourceVec,
    /// kernels + infrastructure.
    pub total: ResourceVec,
    /// Binding utilization fraction (max over resource classes).
    pub utilization: f64,
    /// Name of the binding resource class.
    pub binding: &'static str,
    /// Largest k such that k copies of the whole design fit under the
    /// platform's utilization limit (>= 1 when the design fits at all).
    pub replication_headroom: u64,
    /// True iff total fits under the platform limit.
    pub fits: bool,
}

/// BRAM36 blocks needed for `bits` of storage (36 Kib per block).
fn bram36_for_bits(bits: u64) -> u64 {
    bits.div_ceil(36 * 1024)
}

/// Overhead of one AXI data mover / channel adapter.
fn datamover_cost() -> ResourceVec {
    // ballpark from Vitis AXI DataMover utilization reports
    ResourceVec::new(1200, 900, 2, 0, 0)
}

/// Infrastructure cost of one channel given its role.
fn channel_cost(m: &Module, ch: &ChannelView, is_memory: bool) -> ResourceVec {
    // the fifo-sizing pass records a (smaller) physical FIFO depth
    let words = m
        .op(ch.op)
        .int_attr("fifo_depth")
        .map(|v| v.max(0) as u64)
        .unwrap_or_else(|| ch.depth(m));
    let bits = words * ch.elem_bits(m) as u64;
    let mut cost = match ch.param_type(m) {
        // stream => FIFO of `fifo_depth` (or `depth`) words
        Some(ParamType::Stream) => ResourceVec::new(100, 80, bram36_for_bits(bits), 0, 0),
        // small => PLM buffer of the full payload (random access)
        Some(ParamType::Small) => ResourceVec::new(
            150,
            120,
            bram36_for_bits(ch.depth(m) * ch.elem_bits(m) as u64),
            0,
            0,
        ),
        // complex => direct AXI port, no buffering
        Some(ParamType::Complex) | None => ResourceVec::new(200, 160, 0, 0, 0),
    };
    if is_memory {
        cost += datamover_cost();
    }
    cost
}

/// Analyze resource usage of the whole design.
pub fn analyze_resources(m: &Module, plat: &PlatformSpec, dfg: &Dfg) -> ResourceReport {
    let mut kernels = ResourceVec::ZERO;
    for &k in &dfg.kernels {
        let op = m.op(k);
        if op.name == OP_SUPER_NODE {
            for r in &op.regions {
                for &inner in &r.ops {
                    kernels += KernelView { op: inner }.resources(m);
                }
            }
        } else {
            kernels += KernelView { op: k }.resources(m);
        }
    }

    let mut infra = ResourceVec::ZERO;
    // PLM sharing (Mnemosyne) records a discount on the channel op.
    for b in &dfg.memory_channels {
        infra += channel_cost(m, &b.channel, true);
    }
    for ch in &dfg.internal_channels {
        infra += channel_cost(m, ch, false);
    }
    // Discounts recorded by the PLM-sharing pass (bram saved).
    let mut saved_bram = 0u64;
    for ch in &dfg.channels {
        if let Some(v) = m.op(ch.op).int_attr("plm_shared_bram_saved") {
            saved_bram += v.max(0) as u64;
        }
    }
    infra.bram = infra.bram.saturating_sub(saved_bram);

    let total = kernels + infra;
    let util = total.utilization(&plat.resources);
    let utilization = util.max();
    let fits = utilization <= plat.util_limit;
    let replication_headroom = if utilization <= 0.0 {
        u64::MAX
    } else {
        ((plat.util_limit / utilization).floor() as u64).max(if fits { 1 } else { 0 })
    };
    ResourceReport {
        kernels,
        infrastructure: infra,
        total,
        utilization,
        binding: util.argmax(),
        replication_headroom,
        fits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::{DfgBuilder, KernelEst, ParamType};
    use crate::platform::builtin;

    fn build(est: KernelEst) -> (Module, Dfg) {
        let mut b = DfgBuilder::new();
        let a = b.channel(32, ParamType::Stream, 1024);
        let c = b.channel(32, ParamType::Stream, 1024);
        b.kernel("k", &[a], &[c], est);
        b.pc(a, 0);
        b.pc(c, 1);
        let m = b.finish();
        let g = Dfg::build(&m);
        (m, g)
    }

    #[test]
    fn sums_kernels_and_infra() {
        let est = KernelEst {
            latency: 10,
            ii: 1,
            res: ResourceVec::new(1000, 2000, 4, 0, 8),
        };
        let (m, g) = build(est);
        let plat = builtin("u280").unwrap();
        let rep = analyze_resources(&m, &plat, &g);
        assert_eq!(rep.kernels, ResourceVec::new(1000, 2000, 4, 0, 8));
        assert!(rep.infrastructure.ff > 0);
        assert!(rep.infrastructure.bram >= 2); // two FIFOs
        assert_eq!(rep.total, rep.kernels + rep.infrastructure);
        assert!(rep.fits);
        assert!(rep.replication_headroom > 10, "tiny kernel should replicate many times");
    }

    #[test]
    fn headroom_shrinks_with_kernel_size() {
        let plat = builtin("u280").unwrap();
        let small_est =
            KernelEst { latency: 1, ii: 1, res: ResourceVec::new(10_000, 10_000, 10, 0, 10) };
        let big_est = KernelEst {
            latency: 1,
            ii: 1,
            res: ResourceVec::new(1_000_000, 600_000, 900, 0, 4000),
        };
        let small =
            analyze_resources(&build(small_est).0, &plat, &Dfg::build(&build(small_est).0));
        let big = analyze_resources(&build(big_est).0, &plat, &Dfg::build(&build(big_est).0));
        assert!(small.replication_headroom > big.replication_headroom);
        assert!(big.replication_headroom <= 2);
    }

    #[test]
    fn over_capacity_does_not_fit() {
        let plat = builtin("generic-ddr").unwrap();
        let (m, g) = build(KernelEst {
            latency: 1,
            ii: 1,
            res: ResourceVec::new(2_000_000, 2_000_000, 5_000, 0, 5_000),
        });
        let rep = analyze_resources(&m, &plat, &g);
        assert!(!rep.fits);
        assert_eq!(rep.replication_headroom, 0);
        assert!(rep.utilization > 1.0);
    }

    #[test]
    fn plm_share_discount_reduces_bram() {
        let mut b = DfgBuilder::new();
        let a = b.channel(32, ParamType::Small, 8192);
        b.kernel("k", &[a], &[], Default::default());
        b.pc(a, 0);
        let mut m = b.finish();
        let plat = builtin("u280").unwrap();
        let before = analyze_resources(&m, &plat, &Dfg::build(&m));
        let ch = ChannelView::all(&m)[0];
        m.op_mut(ch.op).set_attr("plm_shared_bram_saved", crate::ir::Attribute::Int(4));
        let after = analyze_resources(&m, &plat, &Dfg::build(&m));
        assert_eq!(before.total.bram - after.total.bram, 4);
    }
}
