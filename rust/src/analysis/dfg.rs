//! DFG extraction: a graph view over the Olympus ops in a module.

use std::collections::HashMap;

use crate::dialect::{ChannelView, KernelView, ParamType, PcView, OP_SUPER_NODE};
use crate::ir::{Module, OpId, ValueId};

/// How a channel reaches memory.
#[derive(Debug, Clone)]
pub struct ChannelBinding {
    pub channel: ChannelView,
    /// PC terminal ops attached to this channel (empty for kernel-to-kernel).
    pub pcs: Vec<PcView>,
    /// Direction seen from memory: true if kernels *read* this channel
    /// (memory → kernel), false if kernels write it (kernel → memory).
    pub is_read: bool,
}

/// Graph view of a module's dataflow.
pub struct Dfg {
    /// Kernel nodes (includes super-nodes) in program order.
    pub kernels: Vec<OpId>,
    /// All channels in program order.
    pub channels: Vec<ChannelView>,
    /// Channels bound to global memory, with their PC terminals.
    pub memory_channels: Vec<ChannelBinding>,
    /// Channels between two kernels (on-chip).
    pub internal_channels: Vec<ChannelView>,
    /// channel value -> (producer kernels, consumer kernels)
    pub endpoints: HashMap<ValueId, (Vec<OpId>, Vec<OpId>)>,
}

impl Dfg {
    /// Build the graph view. Single pass over the ops: a one-shot use map
    /// replaces per-channel `uses_of` scans (which made this quadratic —
    /// see EXPERIMENTS.md §Perf).
    pub fn build(m: &Module) -> Dfg {
        let mut kernels: Vec<OpId> = KernelView::all(m).into_iter().map(|k| k.op).collect();
        kernels.extend(m.top_ops_named(OP_SUPER_NODE));
        kernels.sort_unstable();
        let channels = ChannelView::all(m);
        let use_map = m.use_map();
        let mut memory_channels = Vec::new();
        let mut internal_channels = Vec::new();
        let mut endpoints = HashMap::new();
        for ch in &channels {
            let mut prod = Vec::new();
            let mut cons = Vec::new();
            let mut pcs: Vec<PcView> = Vec::new();
            for &(user, idx) in use_map.get(&ch.value(m)).map(|v| v.as_slice()).unwrap_or(&[]) {
                let op = m.op(user);
                match op.name.as_str() {
                    n if n == crate::dialect::OP_KERNEL || n == OP_SUPER_NODE => {
                        let (ins, _) = op.operand_segments();
                        if idx < ins.len() {
                            cons.push(user);
                        } else {
                            prod.push(user);
                        }
                    }
                    n if n == crate::dialect::OP_PC => pcs.push(PcView { op: user }),
                    _ => {}
                }
            }
            endpoints.insert(ch.value(m), (prod.clone(), cons.clone()));
            // Iris members ride a bus channel: on-chip after the unpacker.
            if m.op(ch.op).str_attr("via_bus").is_some() {
                internal_channels.push(*ch);
                continue;
            }
            // Iris bus channels carry an explicit direction attribute.
            if let Some(dir) = m.op(ch.op).str_attr("direction") {
                memory_channels.push(ChannelBinding {
                    channel: *ch,
                    pcs,
                    is_read: dir == "read",
                });
                continue;
            }
            let global = prod.is_empty() || cons.is_empty()
                || ch.param_type(m) == Some(ParamType::Complex);
            if global {
                memory_channels.push(ChannelBinding {
                    channel: *ch,
                    pcs,
                    // no producer kernel => memory feeds the consumers
                    is_read: prod.is_empty(),
                });
            } else {
                internal_channels.push(*ch);
            }
        }
        Dfg { kernels, channels, memory_channels, internal_channels, endpoints }
    }

    /// Map pc-id -> channels bound to it (only channels with PC terminals).
    pub fn pc_assignment(&self, m: &Module) -> HashMap<u32, Vec<ChannelView>> {
        let mut out: HashMap<u32, Vec<ChannelView>> = HashMap::new();
        for b in &self.memory_channels {
            for pc in &b.pcs {
                out.entry(pc.id(m)).or_default().push(b.channel);
            }
        }
        out
    }

    /// Total number of kernel nodes (flattening super-node regions).
    pub fn compute_unit_count(&self, m: &Module) -> usize {
        let mut n = 0;
        for &k in &self.kernels {
            let op = m.op(k);
            if op.name == OP_SUPER_NODE {
                n += op.regions.iter().map(|r| r.ops.len()).sum::<usize>();
            } else {
                n += 1;
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::dialect::DfgBuilder;

    #[test]
    fn fig4a_dfg() {
        let m = fig4a_module();
        let g = Dfg::build(&m);
        assert_eq!(g.kernels.len(), 1);
        assert_eq!(g.channels.len(), 3);
        assert_eq!(g.memory_channels.len(), 3);
        assert!(g.internal_channels.is_empty());
        // a, b are reads; c is a write
        assert!(g.memory_channels[0].is_read);
        assert!(g.memory_channels[1].is_read);
        assert!(!g.memory_channels[2].is_read);
    }

    #[test]
    fn pipeline_has_internal_channel() {
        let mut b = DfgBuilder::new();
        let x = b.channel(32, ParamType::Stream, 64);
        let y = b.channel(32, ParamType::Stream, 64);
        let z = b.channel(32, ParamType::Stream, 64);
        b.kernel("k1", &[x], &[y], Default::default());
        b.kernel("k2", &[y], &[z], Default::default());
        let m = b.finish();
        let g = Dfg::build(&m);
        assert_eq!(g.kernels.len(), 2);
        assert_eq!(g.memory_channels.len(), 2); // x in, z out
        assert_eq!(g.internal_channels.len(), 1); // y
        assert_eq!(g.compute_unit_count(&m), 2);
    }

    #[test]
    fn complex_channel_is_memory_even_with_both_endpoints() {
        let mut b = DfgBuilder::new();
        let x = b.channel(64, ParamType::Complex, 1 << 20);
        let y = b.channel(32, ParamType::Stream, 64);
        b.kernel("p", &[x], &[y], Default::default());
        b.kernel("q", &[y, x], &[], Default::default());
        let m = b.finish();
        let g = Dfg::build(&m);
        // x is complex => memory-bound regardless of endpoints
        assert!(g
            .memory_channels
            .iter()
            .any(|mc| mc.channel.value(&m) == x));
    }

    #[test]
    fn pc_assignment_groups_by_id() {
        let mut b = DfgBuilder::new();
        let x = b.channel(32, ParamType::Stream, 64);
        let y = b.channel(32, ParamType::Stream, 64);
        b.kernel("k", &[x], &[y], Default::default());
        b.pc(x, 3);
        b.pc(y, 3);
        let m = b.finish();
        let g = Dfg::build(&m);
        let asg = g.pc_assignment(&m);
        assert_eq!(asg[&3].len(), 2);
    }
}
