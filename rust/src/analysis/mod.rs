//! Analyses (paper §V-B): bandwidth-utilization and resource-utilization
//! estimation, plus DFG extraction shared by the transformation passes and
//! the hardware lowering.

mod bandwidth;
mod dfg;
mod resources;

pub use bandwidth::{analyze_bandwidth, BandwidthReport, PcUsage};
pub use dfg::{ChannelBinding, Dfg};
pub use resources::{analyze_resources, ResourceReport};
