//! Textual + JSON reports for the CLI and the examples.

use crate::util::Json;

use crate::passes::DseReport;

/// Machine-readable flow report (`report.json` emitted by `olympus lower`):
/// the design summary a downstream CI would diff against.
pub fn flow_report_json(r: &super::flow::FlowResult) -> Json {
    let pcs: Vec<Json> = r
        .bandwidth
        .per_pc
        .iter()
        .map(|u| {
            Json::obj(vec![
                ("pc", (u.pc_id as usize).into()),
                ("beats", (u.beats as usize).into()),
                ("useful_bytes", (u.useful_bytes as usize).into()),
                ("efficiency", u.efficiency.into()),
            ])
        })
        .collect();
    let cus: Vec<Json> = r
        .arch
        .cus
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("name", c.name.as_str().into()),
                ("callee", c.callee.as_str().into()),
                ("lane", (c.lane as usize).into()),
                ("replica", (c.replica as usize).into()),
            ])
        })
        .collect();
    Json::obj(vec![
        ("platform", r.arch.platform.name.as_str().into()),
        (
            "bandwidth",
            Json::obj(vec![
                ("aggregate_efficiency", r.bandwidth.aggregate_efficiency.into()),
                ("achieved_gbs", r.bandwidth.achieved_gbs.into()),
                ("makespan_s", r.bandwidth.makespan_s.into()),
                ("per_pc", Json::Arr(pcs)),
            ]),
        ),
        (
            "resources",
            Json::obj(vec![
                ("utilization", r.resources.utilization.into()),
                ("binding", r.resources.binding.into()),
                ("fits", r.resources.fits.into()),
                ("bram", (r.resources.total.bram as usize).into()),
                ("lut", (r.resources.total.lut as usize).into()),
                ("ff", (r.resources.total.ff as usize).into()),
                ("dsp", (r.resources.total.dsp as usize).into()),
            ]),
        ),
        (
            "architecture",
            Json::obj(vec![
                ("fifos", r.arch.fifos.len().into()),
                ("plms", r.arch.plms.len().into()),
                ("movers", r.arch.movers.len().into()),
                ("axi_ports", r.arch.axi_ports.len().into()),
                ("cus", Json::Arr(cus)),
            ]),
        ),
    ])
}

/// Render the DSE decision table (strategy × metrics).
pub fn render_dse_table(rep: &DseReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>12} {:>12} {:>8} {:>8} {:>6} {:>5}\n",
        "strategy", "makespan", "GB/s", "bw-eff", "util", "CUs", "fits"
    ));
    for c in &rep.candidates {
        out.push_str(&format!(
            "{:<16} {:>10.3}us {:>12.2} {:>7.1}% {:>7.1}% {:>6} {:>5}\n",
            c.strategy,
            c.makespan_s * 1e6,
            c.achieved_gbs,
            c.efficiency * 100.0,
            c.utilization * 100.0,
            c.compute_units,
            if c.fits { "yes" } else { "NO" }
        ));
    }
    out.push_str(&format!("best: {}\n", rep.best_strategy));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::passes::run_dse;
    use crate::platform::builtin;

    #[test]
    fn table_renders_all_candidates() {
        let rep = run_dse(&fig4a_module(), &builtin("u280").unwrap(), &[2]).unwrap();
        let t = render_dse_table(&rep);
        assert!(t.contains("baseline"));
        assert!(t.contains("best: "));
        assert!(t.lines().count() >= rep.candidates.len() + 2);
    }
}

#[cfg(test)]
mod json_tests {
    use super::*;
    use crate::coordinator::run_flow;
    use crate::dialect::build::fig4a_module;
    use crate::platform::builtin;

    #[test]
    fn flow_report_is_valid_json_with_key_fields() {
        let r = run_flow(
            fig4a_module(),
            &builtin("u280").unwrap(),
            Some("sanitize, iris, channel-reassign"),
        )
        .unwrap();
        let j = flow_report_json(&r);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("platform").as_str(), Some("u280"));
        assert!(parsed.get("bandwidth").get("aggregate_efficiency").as_f64().unwrap() > 0.9);
        assert!(parsed.get("resources").get("fits") == &Json::Bool(true));
        assert_eq!(
            parsed.get("architecture").get("cus").as_arr().unwrap().len(),
            r.arch.cus.len()
        );
    }
}
