//! Textual + JSON reports for the CLI and the examples.

use crate::util::Json;

use crate::passes::{DseCandidate, DseReport};

/// Machine-readable flow report (`report.json` emitted by `olympus lower`):
/// the design summary a downstream CI would diff against.
pub fn flow_report_json(r: &super::flow::FlowResult) -> Json {
    let pcs: Vec<Json> = r
        .bandwidth
        .per_pc
        .iter()
        .map(|u| {
            Json::obj(vec![
                ("pc", (u.pc_id as usize).into()),
                ("beats", (u.beats as usize).into()),
                ("useful_bytes", (u.useful_bytes as usize).into()),
                ("efficiency", u.efficiency.into()),
            ])
        })
        .collect();
    let cus: Vec<Json> = r
        .arch
        .cus
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("name", c.name.as_str().into()),
                ("callee", c.callee.as_str().into()),
                ("lane", (c.lane as usize).into()),
                ("replica", (c.replica as usize).into()),
            ])
        })
        .collect();
    let mut fields: Vec<(&str, Json)> = vec![
        ("platform", r.arch.platform.name.as_str().into()),
        (
            "bandwidth",
            Json::obj(vec![
                ("aggregate_efficiency", r.bandwidth.aggregate_efficiency.into()),
                ("achieved_gbs", r.bandwidth.achieved_gbs.into()),
                ("makespan_s", r.bandwidth.makespan_s.into()),
                ("per_pc", Json::Arr(pcs)),
            ]),
        ),
        (
            "resources",
            Json::obj(vec![
                ("utilization", r.resources.utilization.into()),
                ("binding", r.resources.binding.into()),
                ("fits", r.resources.fits.into()),
                ("bram", (r.resources.total.bram as usize).into()),
                ("lut", (r.resources.total.lut as usize).into()),
                ("ff", (r.resources.total.ff as usize).into()),
                ("dsp", (r.resources.total.dsp as usize).into()),
            ]),
        ),
        (
            "architecture",
            Json::obj(vec![
                ("fifos", r.arch.fifos.len().into()),
                ("plms", r.arch.plms.len().into()),
                ("movers", r.arch.movers.len().into()),
                ("axi_ports", r.arch.axi_ports.len().into()),
                ("cus", Json::Arr(cus)),
            ]),
        ),
    ];
    if let Some(des) = &r.des {
        let nodes: Vec<Json> = des
            .nodes
            .iter()
            .map(|n| {
                Json::obj(vec![
                    ("name", n.name.as_str().into()),
                    ("kind", n.kind.as_str().into()),
                    ("utilization", n.utilization.into()),
                    ("mean_depth", n.mean_depth.into()),
                    ("p99_depth", (n.p99_depth as usize).into()),
                    ("mean_sojourn_s", n.mean_sojourn_s.into()),
                    ("p99_sojourn_s", n.p99_sojourn_s.into()),
                ])
            })
            .collect();
        fields.push((
            "des",
            Json::obj(vec![
                ("scenario", des.scenario.as_str().into()),
                ("seed", (des.seed as usize).into()),
                ("jobs_released", (des.jobs_released as usize).into()),
                ("jobs_completed", (des.jobs_completed as usize).into()),
                ("makespan_s", des.makespan_s.into()),
                ("p50_job_latency_s", des.p50_job_latency_s.into()),
                ("p99_job_latency_s", des.p99_job_latency_s.into()),
                ("throughput_jobs_per_s", des.throughput_jobs_per_s.into()),
                ("events", (des.events as usize).into()),
                ("nodes", Json::Arr(nodes)),
            ]),
        ));
    }
    Json::obj(fields)
}

/// Render the DSE decision table (strategy × metrics). When the des-score
/// objective ran, two extra columns show the simulated scenario makespan
/// and p99 job latency. Cross-platform searches get a wider strategy
/// column (labels are `platform/strategy`) and one `best[platform]` row
/// per searched platform above the overall winner.
pub fn render_dse_table(rep: &DseReport) -> String {
    let has_des = rep.candidates.iter().any(|c| c.des_makespan_s.is_some());
    let w = if rep.platforms.is_empty() { 16 } else { 28 };
    let mut out = String::new();
    out.push_str(&format!(
        "{:<w$} {:>12} {:>12} {:>8} {:>8} {:>6} {:>5}",
        "strategy", "makespan", "GB/s", "bw-eff", "util", "CUs", "fits"
    ));
    if has_des {
        out.push_str(&format!(" {:>14} {:>14}", "des-makespan", "des-p99"));
    }
    out.push('\n');
    for c in &rep.candidates {
        out.push_str(&format!(
            "{:<w$} {:>10.3}us {:>12.2} {:>7.1}% {:>7.1}% {:>6} {:>5}",
            c.strategy,
            c.makespan_s * 1e6,
            c.achieved_gbs,
            c.efficiency * 100.0,
            c.utilization * 100.0,
            c.compute_units,
            if c.fits { "yes" } else { "NO" }
        ));
        if has_des {
            match (c.des_makespan_s, c.des_p99_latency_s) {
                (Some(mk), Some(p99)) => {
                    out.push_str(&format!(" {:>12.3}us {:>12.3}us", mk * 1e6, p99 * 1e6));
                }
                _ => out.push_str(&format!(" {:>14} {:>14}", "-", "-")),
            }
        }
        out.push('\n');
    }
    for name in &rep.platforms {
        // same rule the search uses: first strict minimum over finite scores
        let best = rep
            .candidates
            .iter()
            .filter(|c| c.platform.as_deref() == Some(name.as_str()) && c.score.is_finite())
            .fold(None::<&DseCandidate>, |acc, c| match acc {
                Some(b) if b.score <= c.score => Some(b),
                _ => Some(c),
            });
        match best {
            Some(b) => out.push_str(&format!("best[{name}]: {}\n", b.strategy)),
            None => out.push_str(&format!("best[{name}]: (no feasible candidate)\n")),
        }
    }
    out.push_str(&format!("best: {}\n", rep.best_strategy));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::passes::run_dse;
    use crate::platform::builtin;

    #[test]
    fn table_renders_all_candidates() {
        let rep = run_dse(&fig4a_module(), &builtin("u280").unwrap(), &[2]).unwrap();
        let t = render_dse_table(&rep);
        assert!(t.contains("baseline"));
        assert!(t.contains("best: "));
        assert!(t.lines().count() >= rep.candidates.len() + 2);
        // analytic mode: no DES columns
        assert!(!t.contains("des-makespan"));
    }

    #[test]
    fn table_shows_per_platform_winner_rows_for_cross_platform_runs() {
        use crate::passes::{run_dse_multi, DseOptions};
        let plats = [builtin("u280").unwrap(), builtin("generic-ddr").unwrap()];
        let opts = DseOptions {
            factors: vec![2],
            ..DseOptions::default()
        };
        let rep = run_dse_multi(&fig4a_module(), &plats, &opts).unwrap();
        let t = render_dse_table(&rep);
        assert!(t.contains("best[u280]: u280/"), "{t}");
        assert!(t.contains("best[generic-ddr]: generic-ddr/"), "{t}");
        assert!(t.contains(&format!("best: {}\n", rep.best_strategy)));
    }

    #[test]
    fn table_grows_des_columns_under_des_score() {
        use crate::des::{DesConfig, WorkloadScenario};
        use crate::passes::{run_dse_with, DseObjective, DseOptions};
        let opts = DseOptions {
            factors: vec![2],
            objective: DseObjective::des_score_with(
                WorkloadScenario::closed_loop(2),
                DesConfig::default(),
            ),
            threads: 1,
            ..DseOptions::default()
        };
        let rep = run_dse_with(&fig4a_module(), &builtin("u280").unwrap(), &opts).unwrap();
        let t = render_dse_table(&rep);
        assert!(t.contains("des-makespan"));
        assert!(t.contains("des-p99"));
    }
}

#[cfg(test)]
mod json_tests {
    use super::*;
    use crate::coordinator::run_flow;
    use crate::dialect::build::fig4a_module;
    use crate::platform::builtin;

    #[test]
    fn flow_report_is_valid_json_with_key_fields() {
        let r = run_flow(
            fig4a_module(),
            &builtin("u280").unwrap(),
            Some("sanitize, iris, channel-reassign"),
        )
        .unwrap();
        let j = flow_report_json(&r);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("platform").as_str(), Some("u280"));
        assert!(parsed.get("bandwidth").get("aggregate_efficiency").as_f64().unwrap() > 0.9);
        assert!(parsed.get("resources").get("fits") == &Json::Bool(true));
        assert_eq!(
            parsed.get("architecture").get("cus").as_arr().unwrap().len(),
            r.arch.cus.len()
        );
    }

    #[test]
    fn flow_report_includes_des_section_when_scenario_set() {
        use crate::coordinator::Flow;
        use crate::des::WorkloadScenario;
        let r = Flow::new(builtin("u280").unwrap())
            .with_pipeline("sanitize, channel-reassign")
            .with_scenario(WorkloadScenario::closed_loop(2))
            .run(fig4a_module(), "app")
            .unwrap();
        let j = flow_report_json(&r);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("des").get("jobs_completed").as_usize(), Some(2));
        assert!(parsed.get("des").get("nodes").as_arr().unwrap().len() >= 7);
        assert!(parsed.get("des").get("makespan_s").as_f64().unwrap() > 0.0);
    }
}
