//! End-to-end flow (paper Fig 3).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use crate::analysis::{analyze_bandwidth, analyze_resources, BandwidthReport, Dfg, ResourceReport};
use crate::des::{simulate_traced, DesConfig, DesReport, WorkloadScenario};
use crate::ir::{module_fingerprint, Module};
use crate::lower::{
    build_architecture, emit_host_driver, emit_verilog, emit_vitis_cfg, Architecture,
};
use crate::obs::TraceSink;
use crate::passes::manager::{parse_pipeline, PassContext, PassRecord};
use crate::passes::{
    run_dse_multi, run_dse_with, CandidateCache, DseObjective, DseOptions, DseReport as DseTable,
};
use crate::platform::PlatformSpec;
use crate::search::DriverKind;
use crate::service::remote::WorkerPool;
use crate::util::ContentHash;

/// Flow configuration.
pub struct Flow {
    pub platform: PlatformSpec,
    /// The platform *axis* for DSE mode (`olympus dse --platforms a,b,..`):
    /// with two or more specs the platform itself becomes a search
    /// dimension — the strategy grid is crossed with this list, every
    /// candidate is scored on its own platform, and the rest of the flow
    /// (analyses, lowering, emission, DES replay) runs on the platform
    /// that won. Empty or a single entry keeps the classic
    /// single-platform flow on [`Flow::platform`] bit-identically.
    pub platforms: Vec<PlatformSpec>,
    /// Explicit pass pipeline; `None` runs the DSE loop instead.
    pub pipeline: Option<String>,
    /// Replication factors swept by the DSE (empty = defaults).
    pub dse_factors: Vec<u64>,
    /// Search policy for DSE mode (`olympus dse --driver/--budget`; part of
    /// [`Flow::cache_key`] — two runs that search differently are different
    /// evaluations).
    pub driver: DriverKind,
    /// Objective for DSE mode (analytic or des-score).
    pub objective: DseObjective,
    /// When set, the final architecture is replayed through the
    /// discrete-event simulator and the report lands in [`FlowResult::des`].
    pub scenario: Option<WorkloadScenario>,
    /// Engine knobs for that replay.
    pub des_config: DesConfig,
    /// Worker threads for DSE candidate evaluation (0 = all cores). The
    /// result is bit-identical for any value; this only bounds parallelism
    /// (`olympus dse --jobs N`, and the serving daemon pins it per job).
    pub jobs: usize,
    /// Content-addressed candidate-evaluation memo shared across flow runs
    /// (wired in by the service; `None` = evaluate everything).
    pub cache: Option<Arc<CandidateCache>>,
    /// Remote evaluation workers (`olympus serve --workers`): DSE candidate
    /// evaluations route to the worker owning each key's consistent-hash
    /// shard, failing over to local compute when one is unreachable.
    /// Deliberately *not* part of [`Flow::cache_key`]: like `jobs`, the
    /// pool only moves where a deterministic evaluation runs, never what
    /// it produces.
    pub remote: Option<Arc<WorkerPool>>,
    /// Export the DES replay's timeline as Chrome trace-event JSON to this
    /// path (`olympus des --trace FILE`). Pure observability — the sink
    /// watches state transitions the engine performs anyway — so it is
    /// deliberately *not* part of [`Flow::cache_key`] and cannot perturb
    /// any result. Ignored when no scenario is configured.
    pub trace_path: Option<PathBuf>,
}

/// Everything the flow produces (the purple boxes of Fig 3).
pub struct FlowResult {
    /// The optimized IR.
    pub module: Module,
    /// Per-pass execution records (explicit pipelines only).
    pub records: Vec<PassRecord>,
    /// DSE decision table (DSE mode only).
    pub dse: Option<DseTable>,
    /// Lowered architecture netlist.
    pub arch: Architecture,
    /// Vitis connectivity config.
    pub cfg: String,
    /// Structural Verilog.
    pub verilog: String,
    /// Generated host driver source.
    pub driver: String,
    /// Post-optimization analyses.
    pub bandwidth: BandwidthReport,
    pub resources: ResourceReport,
    /// Discrete-event replay of the final architecture (when a scenario
    /// was configured).
    pub des: Option<DesReport>,
}

impl Flow {
    pub fn new(platform: PlatformSpec) -> Self {
        Flow {
            platform,
            platforms: Vec::new(),
            pipeline: None,
            dse_factors: Vec::new(),
            driver: DriverKind::Exhaustive,
            objective: DseObjective::Analytic,
            scenario: None,
            des_config: DesConfig::default(),
            jobs: 0,
            cache: None,
            remote: None,
            trace_path: None,
        }
    }

    pub fn with_pipeline(mut self, pipeline: &str) -> Self {
        self.pipeline = Some(pipeline.to_string());
        self
    }

    /// Make the platform a search axis (see [`Flow::platforms`]). The first
    /// spec also becomes the primary [`Flow::platform`], so a one-entry
    /// list is exactly `Flow::new(spec)`.
    pub fn with_platforms(mut self, platforms: Vec<PlatformSpec>) -> Self {
        if let Some(first) = platforms.first() {
            self.platform = first.clone();
        }
        self.platforms = platforms;
        self
    }

    pub fn with_objective(mut self, objective: DseObjective) -> Self {
        self.objective = objective;
        self
    }

    pub fn with_driver(mut self, driver: DriverKind) -> Self {
        self.driver = driver;
        self
    }

    pub fn with_scenario(mut self, scenario: WorkloadScenario) -> Self {
        self.scenario = Some(scenario);
        self
    }

    pub fn with_jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs;
        self
    }

    pub fn with_cache(mut self, cache: Arc<CandidateCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Route DSE candidate evaluations through a remote worker pool (see
    /// [`crate::service::remote`]). Results are bit-identical with or
    /// without workers; only latency and *where* the evaluation runs change.
    pub fn with_remote(mut self, pool: Arc<WorkerPool>) -> Self {
        self.remote = Some(pool);
        self
    }

    /// Write the DES replay's timeline to `path` as Chrome trace-event JSON
    /// (viewable in Perfetto / `chrome://tracing`). Zero-perturbation: the
    /// simulated results are bit-identical with or without the trace.
    pub fn with_trace(mut self, path: &Path) -> Self {
        self.trace_path = Some(path.to_path_buf());
        self
    }

    /// Attach a *persistent* candidate memo rooted at `dir` (`olympus
    /// dse/des --cache-dir`): previously journaled evaluations are loaded
    /// before the search runs and fresh ones are written through, so a
    /// repeated single-shot run re-pays nothing. Uses the same journal
    /// layout as `olympus serve --cache-dir`, so one warm store serves
    /// both; if a daemon currently owns the dir's writer lock, this run
    /// still warm-loads but skips writing (read-only).
    pub fn with_cache_dir(self, dir: &Path) -> Result<Self> {
        let (cache, _store) = crate::service::persist::open_candidate_cache(dir, 0)?;
        Ok(self.with_cache(cache))
    }

    /// Content-addressed key of the *whole* flow result for `input`: covers
    /// the module IR, platform spec, pipeline-or-objective, scenario and
    /// engine seed — everything [`Flow::run`] output depends on, and nothing
    /// it does not (worker/thread counts deliberately excluded: results are
    /// bit-identical regardless). The service keys its response cache on
    /// this.
    pub fn cache_key(&self, input: &Module) -> ContentHash {
        // v2: DSE routes carry the search driver (+ its budget/seed), so a
        // budgeted search can never serve from — or poison — an exhaustive
        // run's response entry. Factors are canonicalized here too, so
        // library callers that skip the CLI/protocol normalization still
        // share one address per search space ([4,2,2] keys like [2,4];
        // invalid lists keep their raw spelling and fail at run time).
        let route = match &self.pipeline {
            Some(p) => format!("pipeline:{p}"),
            None => {
                let factors = crate::search::normalize_factors(&self.dse_factors)
                    .unwrap_or_else(|_| self.dse_factors.clone());
                let mut route = format!(
                    "dse:{:?}:factors={:?}:driver={:?}",
                    self.objective, factors, self.driver
                );
                if self.platforms.len() >= 2 {
                    // a multi-platform search answers a different question,
                    // so the whole ordered axis joins the address. Folding
                    // the extra fingerprints into the route (rather than a
                    // new key part) keeps single-platform keys — and every
                    // journal written before this axis existed — untouched.
                    let fps: Vec<String> =
                        self.platforms.iter().map(|p| p.fingerprint()).collect();
                    route.push_str(&format!(":platforms={fps:?}"));
                }
                route
            }
        };
        let replay = match &self.scenario {
            Some(sc) => format!("{sc:?}:{:?}", self.des_config),
            None => String::new(),
        };
        ContentHash::of_parts(&[
            "olympus-flow-v2",
            &module_fingerprint(input),
            &self.platform.fingerprint(),
            &route,
            &replay,
        ])
    }

    /// Content-addressed key of the *serving-layer response* for running
    /// this flow on `input` under `verb` (`dse`/`des`/`flow`): the verb
    /// folded over [`Flow::cache_key`]. This is the address the service's
    /// response cache, disk journal, and shard router all agree on — the
    /// bytes match the keys every journal written since v1 stores, so old
    /// caches stay warm.
    pub fn response_key(&self, verb: &str, input: &Module) -> ContentHash {
        ContentHash::of_parts(&["olympus-serve-v1", verb, &self.cache_key(input).to_hex()])
    }

    /// Run optimize -> analyze -> lower -> emit (-> simulate).
    pub fn run(&self, input: Module, app_name: &str) -> Result<FlowResult> {
        let mut module = input;
        let mut records = Vec::new();
        let mut dse = None;
        match &self.pipeline {
            Some(p) => {
                let mut ctx = PassContext::new(self.platform.clone());
                let pm = parse_pipeline(p, &mut ctx)?;
                records = pm.run(&mut module, &ctx)?;
            }
            None => {
                let opts = DseOptions {
                    factors: self.dse_factors.clone(),
                    objective: self.objective.clone(),
                    threads: self.jobs,
                    cache: self.cache.clone(),
                    driver: self.driver.clone(),
                    remote: self.remote.clone(),
                };
                let rep = if self.platforms.len() >= 2 {
                    run_dse_multi(&module, &self.platforms, &opts)?
                } else {
                    run_dse_with(&module, &self.platform, &opts)?
                };
                module = rep.best.clone();
                dse = Some(rep);
            }
        }
        // in a cross-platform search the winning candidate carries its
        // platform stamp; everything downstream of the search lowers onto
        // that platform. Single-platform runs (stamp absent) fall back to
        // the primary spec, bit-identically with the pre-axis flow.
        let plat = dse
            .as_ref()
            .and_then(|rep| {
                let win = rep
                    .candidates
                    .iter()
                    .find(|c| c.strategy == rep.best_strategy)?;
                let name = win.platform.as_deref()?;
                self.platforms.iter().find(|p| p.name == name)
            })
            .unwrap_or(&self.platform);
        let dfg = Dfg::build(&module);
        let bandwidth = analyze_bandwidth(&module, plat, &dfg);
        let resources = analyze_resources(&module, plat, &dfg);
        let arch = build_architecture(&module, plat)?;
        let cfg = emit_vitis_cfg(&arch);
        let verilog = emit_verilog(&arch);
        let driver = emit_host_driver(&arch, app_name);
        let des = match &self.scenario {
            Some(sc) => {
                let mut dcfg = self.des_config.clone();
                dcfg.utilization = resources.utilization;
                let mut sink = self.trace_path.as_deref().map(|_| TraceSink::new());
                let report = simulate_traced(&arch, sc, &dcfg, sink.as_mut())?;
                if let (Some(path), Some(sink)) = (self.trace_path.as_deref(), &sink) {
                    sink.write_to(path)?;
                    crate::obs::info(
                        "des-trace-written",
                        &[
                            ("path", path.display().to_string().into()),
                            ("events", sink.len().into()),
                        ],
                    );
                }
                Some(report)
            }
            None => None,
        };
        Ok(FlowResult {
            module,
            records,
            dse,
            arch,
            cfg,
            verilog,
            driver,
            bandwidth,
            resources,
            des,
        })
    }
}

/// One-call convenience: pipeline `None` = DSE.
pub fn run_flow(
    input: Module,
    platform: &PlatformSpec,
    pipeline: Option<&str>,
) -> Result<FlowResult> {
    let mut flow = Flow::new(platform.clone());
    if let Some(p) = pipeline {
        flow = flow.with_pipeline(p);
    }
    flow.run(input, "app")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::platform::builtin;

    #[test]
    fn explicit_pipeline_flow() {
        let r = run_flow(
            fig4a_module(),
            &builtin("u280").unwrap(),
            Some("sanitize, iris, channel-reassign"),
        )
        .unwrap();
        assert_eq!(r.records.len(), 3);
        assert!(r.dse.is_none());
        assert!(r.des.is_none());
        assert!(!r.cfg.is_empty());
        assert!(!r.verilog.is_empty());
        assert!(r.bandwidth.aggregate_efficiency > 0.9);
        assert!(r.resources.fits);
    }

    #[test]
    fn dse_flow_picks_nontrivial_strategy() {
        let r = run_flow(fig4a_module(), &builtin("u280").unwrap(), None).unwrap();
        let dse = r.dse.expect("dse table");
        assert!(dse.candidates.len() >= 6);
        assert_ne!(dse.best_strategy, "baseline");
        assert!(!r.arch.cus.is_empty());
    }

    #[test]
    fn scenario_flow_attaches_des_report() {
        use crate::des::WorkloadScenario;
        let r = Flow::new(builtin("u280").unwrap())
            .with_pipeline("sanitize, iris, channel-reassign")
            .with_scenario(WorkloadScenario::closed_loop(2))
            .run(fig4a_module(), "app")
            .unwrap();
        let des = r.des.expect("des report");
        assert_eq!(des.jobs_completed, 2);
        assert!(des.makespan_s > 0.0);
        assert!(!des.nodes.is_empty());
    }

    #[test]
    fn cache_key_round_trips_driver_and_budget() {
        use crate::search::DriverKind;
        let m = fig4a_module();
        let base = Flow::new(builtin("u280").unwrap());
        let exhaustive = base.cache_key(&m);
        let sh = Flow::new(builtin("u280").unwrap())
            .with_driver(DriverKind::SuccessiveHalving { budget: 3 })
            .cache_key(&m);
        let sh4 = Flow::new(builtin("u280").unwrap())
            .with_driver(DriverKind::SuccessiveHalving { budget: 4 })
            .cache_key(&m);
        assert_ne!(exhaustive, sh, "driver must be part of the response address");
        assert_ne!(sh, sh4, "budget must be part of the response address");
        // factor lists canonicalize inside the key, not just at the edges
        let mut messy = Flow::new(builtin("u280").unwrap());
        messy.dse_factors = vec![4, 2, 2];
        let mut clean = Flow::new(builtin("u280").unwrap());
        clean.dse_factors = vec![2, 4];
        assert_eq!(messy.cache_key(&m), clean.cache_key(&m));
        // explicit pipelines ignore the driver: same key either way
        let p1 = Flow::new(builtin("u280").unwrap())
            .with_pipeline("sanitize, iris, channel-reassign")
            .cache_key(&m);
        let p2 = Flow::new(builtin("u280").unwrap())
            .with_pipeline("sanitize, iris, channel-reassign")
            .with_driver(DriverKind::SuccessiveHalving { budget: 3 })
            .cache_key(&m);
        assert_eq!(p1, p2);
    }

    #[test]
    fn response_key_is_the_verb_folded_over_the_flow_key() {
        // pinned: this exact composition is what every response journal on
        // disk is keyed by — changing it cold-starts the world's caches
        let m = fig4a_module();
        let flow = Flow::new(builtin("u280").unwrap());
        let manual = ContentHash::of_parts(&[
            "olympus-serve-v1",
            "dse",
            &flow.cache_key(&m).to_hex(),
        ]);
        assert_eq!(flow.response_key("dse", &m), manual);
        assert_ne!(flow.response_key("dse", &m), flow.response_key("des", &m));
    }

    #[test]
    fn multi_platform_flow_lowers_on_the_winning_platform() {
        // primary (first-listed) platform is generic-ddr, but the fig4a
        // workload streams three channels — u280's HBM spread wins the
        // search, and the whole back half of the flow must follow it
        let r = Flow::new(builtin("generic-ddr").unwrap())
            .with_platforms(vec![builtin("generic-ddr").unwrap(), builtin("u280").unwrap()])
            .run(fig4a_module(), "app")
            .unwrap();
        let dse = r.dse.expect("dse table");
        assert_eq!(dse.platforms, ["generic-ddr", "u280"]);
        assert!(
            dse.best_strategy.starts_with("u280/"),
            "expected a u280 winner, got {}",
            dse.best_strategy
        );
        assert_eq!(r.arch.platform.name, "u280", "lowering follows the winner");
        assert!(!r.arch.cus.is_empty());
        assert!(!r.cfg.is_empty());
    }

    #[test]
    fn cache_key_covers_the_platform_axis() {
        let m = fig4a_module();
        let single = Flow::new(builtin("u280").unwrap()).cache_key(&m);
        // a one-entry axis IS the classic single-platform flow: same key,
        // so journals written before the axis existed stay warm
        let one = Flow::new(builtin("u280").unwrap())
            .with_platforms(vec![builtin("u280").unwrap()])
            .cache_key(&m);
        assert_eq!(single, one);
        let two = Flow::new(builtin("u280").unwrap())
            .with_platforms(vec![builtin("u280").unwrap(), builtin("generic-ddr").unwrap()])
            .cache_key(&m);
        assert_ne!(single, two, "the axis changes what a response means");
        let reordered = Flow::new(builtin("u280").unwrap())
            .with_platforms(vec![builtin("generic-ddr").unwrap(), builtin("u280").unwrap()])
            .cache_key(&m);
        assert_ne!(two, reordered, "axis order breaks ties, so it is addressed");
        // explicit pipelines never search, so the axis is ignored there
        let p1 = Flow::new(builtin("u280").unwrap())
            .with_pipeline("sanitize")
            .cache_key(&m);
        let p2 = Flow::new(builtin("u280").unwrap())
            .with_platforms(vec![builtin("u280").unwrap(), builtin("generic-ddr").unwrap()])
            .with_pipeline("sanitize")
            .cache_key(&m);
        assert_eq!(p1, p2);
    }

    #[test]
    fn cache_dir_warm_starts_the_candidate_memo() {
        let dir = std::env::temp_dir().join(format!(
            "olympus_flow_cache_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let m = fig4a_module();
        let cold = Flow::new(builtin("u280").unwrap())
            .with_cache_dir(&dir)
            .unwrap()
            .run(m.clone(), "app")
            .unwrap();
        let cold_dse = cold.dse.as_ref().expect("dse table");
        assert!(cold_dse.full_evals > 0);
        // a brand-new Flow (what a fresh process is) over the same dir
        // replays every candidate from the journal and computes nothing
        let warm = Flow::new(builtin("u280").unwrap())
            .with_cache_dir(&dir)
            .unwrap()
            .run(m, "app")
            .unwrap();
        let warm_dse = warm.dse.as_ref().expect("dse table");
        assert_eq!(warm_dse.full_evals, 0, "warm start computes nothing");
        assert_eq!(warm_dse.best_strategy, cold_dse.best_strategy);
        assert_eq!(
            crate::ir::print_module(&warm.module),
            crate::ir::print_module(&cold.module),
            "winning module bit-identical across the warm start"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sh_driver_flow_end_to_end() {
        use crate::search::DriverKind;
        let r = Flow::new(builtin("u280").unwrap())
            .with_driver(DriverKind::SuccessiveHalving { budget: 3 })
            .run(fig4a_module(), "app")
            .unwrap();
        let dse = r.dse.expect("dse table");
        assert_eq!(dse.driver, "successive-halving");
        assert_eq!(dse.full_evals, 3);
        assert!(dse.screened >= dse.candidates.len());
        assert!(!r.arch.cus.is_empty());
    }

    #[test]
    fn des_score_flow_end_to_end() {
        use crate::des::{DesConfig, WorkloadScenario};
        let r = Flow::new(builtin("u280").unwrap())
            .with_objective(DseObjective::des_score_with(
                WorkloadScenario::closed_loop(2),
                DesConfig::default(),
            ))
            .with_scenario(WorkloadScenario::closed_loop(2))
            .run(fig4a_module(), "app")
            .unwrap();
        let dse = r.dse.expect("dse table");
        // every feasible candidate carries DES metrics
        assert!(dse
            .candidates
            .iter()
            .any(|c| c.des_makespan_s.is_some() && c.score.is_finite()));
        assert!(r.des.is_some());
    }
}
