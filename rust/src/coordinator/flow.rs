//! End-to-end flow (paper Fig 3).

use anyhow::Result;

use crate::analysis::{analyze_bandwidth, analyze_resources, BandwidthReport, Dfg, ResourceReport};
use crate::ir::Module;
use crate::lower::{build_architecture, emit_host_driver, emit_verilog, emit_vitis_cfg, Architecture};
use crate::passes::manager::{parse_pipeline, PassContext, PassRecord};
use crate::passes::{run_dse, DseReport};
use crate::platform::PlatformSpec;

/// Flow configuration.
pub struct Flow {
    pub platform: PlatformSpec,
    /// Explicit pass pipeline; `None` runs the DSE loop instead.
    pub pipeline: Option<String>,
    /// Replication factors swept by the DSE (empty = defaults).
    pub dse_factors: Vec<u64>,
}

/// Everything the flow produces (the purple boxes of Fig 3).
pub struct FlowResult {
    /// The optimized IR.
    pub module: Module,
    /// Per-pass execution records (explicit pipelines only).
    pub records: Vec<PassRecord>,
    /// DSE decision table (DSE mode only).
    pub dse: Option<DseReport>,
    /// Lowered architecture netlist.
    pub arch: Architecture,
    /// Vitis connectivity config.
    pub cfg: String,
    /// Structural Verilog.
    pub verilog: String,
    /// Generated host driver source.
    pub driver: String,
    /// Post-optimization analyses.
    pub bandwidth: BandwidthReport,
    pub resources: ResourceReport,
}

impl Flow {
    pub fn new(platform: PlatformSpec) -> Self {
        Flow { platform, pipeline: None, dse_factors: Vec::new() }
    }

    pub fn with_pipeline(mut self, pipeline: &str) -> Self {
        self.pipeline = Some(pipeline.to_string());
        self
    }

    /// Run optimize -> analyze -> lower -> emit.
    pub fn run(&self, input: Module, app_name: &str) -> Result<FlowResult> {
        let mut module = input;
        let mut records = Vec::new();
        let mut dse = None;
        match &self.pipeline {
            Some(p) => {
                let mut ctx = PassContext::new(self.platform.clone());
                let pm = parse_pipeline(p, &mut ctx)?;
                records = pm.run(&mut module, &ctx)?;
            }
            None => {
                let rep = run_dse(&module, &self.platform, &self.dse_factors)?;
                module = rep.best.clone();
                dse = Some(rep);
            }
        }
        let dfg = Dfg::build(&module);
        let bandwidth = analyze_bandwidth(&module, &self.platform, &dfg);
        let resources = analyze_resources(&module, &self.platform, &dfg);
        let arch = build_architecture(&module, &self.platform)?;
        let cfg = emit_vitis_cfg(&arch);
        let verilog = emit_verilog(&arch);
        let driver = emit_host_driver(&arch, app_name);
        Ok(FlowResult { module, records, dse, arch, cfg, verilog, driver, bandwidth, resources })
    }
}

/// One-call convenience: pipeline `None` = DSE.
pub fn run_flow(input: Module, platform: &PlatformSpec, pipeline: Option<&str>) -> Result<FlowResult> {
    let mut flow = Flow::new(platform.clone());
    if let Some(p) = pipeline {
        flow = flow.with_pipeline(p);
    }
    flow.run(input, "app")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dialect::build::fig4a_module;
    use crate::platform::builtin;

    #[test]
    fn explicit_pipeline_flow() {
        let r = run_flow(
            fig4a_module(),
            &builtin("u280").unwrap(),
            Some("sanitize, iris, channel-reassign"),
        )
        .unwrap();
        assert_eq!(r.records.len(), 3);
        assert!(r.dse.is_none());
        assert!(!r.cfg.is_empty());
        assert!(!r.verilog.is_empty());
        assert!(r.bandwidth.aggregate_efficiency > 0.9);
        assert!(r.resources.fits);
    }

    #[test]
    fn dse_flow_picks_nontrivial_strategy() {
        let r = run_flow(fig4a_module(), &builtin("u280").unwrap(), None).unwrap();
        let dse = r.dse.expect("dse table");
        assert!(dse.candidates.len() >= 6);
        assert_ne!(dse.best_strategy, "baseline");
        assert!(!r.arch.cus.is_empty());
    }
}
