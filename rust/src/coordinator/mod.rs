//! The Fig 3 flow coordinator: Olympus MLIR + platform info + kernel
//! implementations in; optimized architecture, `.cfg`, Verilog, host driver
//! and a simulated execution out.

mod flow;
mod report;

pub use flow::{run_flow, Flow, FlowResult};
pub use report::{flow_report_json, render_dse_table};
