//! Structural verifier: SSA and module-shape invariants that hold for any
//! dialect. Dialect-specific rules live in `dialect::verify`.

use std::collections::HashSet;
use std::fmt;

use super::module::{Module, OpId};
use super::value::ValueDef;

/// A verifier diagnostic.
#[derive(Debug, PartialEq)]
pub enum VerifyError {
    DanglingOperand(OpId, String, usize),
    BadResultDef(OpId, String, usize),
    DetachedValue(u32),
    DuplicateOp(OpId),
    UseBeforeDef(OpId, String),
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VerifyError::DanglingOperand(id, name, i) => write!(
                f,
                "op {id:?} ('{name}') operand {i} refers to an erased/unknown defining op"
            ),
            VerifyError::BadResultDef(id, name, i) => {
                write!(f, "op {id:?} ('{name}') result {i} does not point back to the op")
            }
            VerifyError::DetachedValue(v) => write!(f, "value {v} is detached (no defining op)"),
            VerifyError::DuplicateOp(id) => write!(f, "op {id:?} appears twice in op lists"),
            VerifyError::UseBeforeDef(id, name) => write!(
                f,
                "op {id:?} ('{name}') uses value defined *after* it in program order"
            ),
        }
    }
}

impl std::error::Error for VerifyError {}

/// Verify structural invariants; returns all violations (empty == ok).
pub fn verify_module(m: &Module) -> Vec<VerifyError> {
    let mut errs = Vec::new();

    // 1. No op appears twice across top + regions.
    let mut seen: HashSet<OpId> = HashSet::new();
    let mut order: Vec<OpId> = Vec::new();
    let mut walk = |id: OpId, errs: &mut Vec<VerifyError>, order: &mut Vec<OpId>| {
        if !seen.insert(id) {
            errs.push(VerifyError::DuplicateOp(id));
        }
        order.push(id);
    };
    // program order: top-level, with region ops immediately after their parent
    fn visit(
        m: &Module,
        id: OpId,
        f: &mut impl FnMut(OpId, &mut Vec<VerifyError>, &mut Vec<OpId>),
        errs: &mut Vec<VerifyError>,
        order: &mut Vec<OpId>,
    ) {
        f(id, errs, order);
        for r in &m.op(id).regions {
            for &inner in &r.ops {
                visit(m, inner, f, errs, order);
            }
        }
    }
    for id in m.top.clone() {
        visit(m, id, &mut walk, &mut errs, &mut order);
    }

    // position in program order for use-before-def checking
    let pos: std::collections::HashMap<OpId, usize> =
        order.iter().enumerate().map(|(i, &o)| (o, i)).collect();

    for &id in &order {
        let op = m.op(id);
        // 2. operands' defining ops exist and precede the user
        for (i, &v) in op.operands.iter().enumerate() {
            match m.value_def(v) {
                ValueDef::Detached => errs.push(VerifyError::DetachedValue(v.0)),
                ValueDef::OpResult { op: def_op, .. } => {
                    if !m.op_exists(def_op) || !pos.contains_key(&def_op) {
                        errs.push(VerifyError::DanglingOperand(id, op.name.clone(), i));
                    } else if pos[&def_op] >= pos[&id] {
                        errs.push(VerifyError::UseBeforeDef(id, op.name.clone()));
                    }
                }
            }
        }
        // 3. results point back to this op with the right index
        for (i, &r) in op.results.iter().enumerate() {
            match m.value_def(r) {
                ValueDef::OpResult { op: def_op, idx } if def_op == id && idx as usize == i => {}
                _ => errs.push(VerifyError::BadResultDef(id, op.name.clone(), i)),
            }
        }
    }
    errs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::OpBuilder;
    use crate::ir::op::Operation;
    use crate::ir::types::Type;

    #[test]
    fn clean_module_verifies() {
        let mut m = Module::new();
        let mut b = OpBuilder::new(&mut m);
        let (_, ch) = b
            .op("olympus.make_channel")
            .result(Type::channel_of(Type::int(32)))
            .build();
        b.op("olympus.pc").operand(ch[0]).attr("id", 0i64).build();
        assert!(verify_module(&m).is_empty());
    }

    #[test]
    fn catches_dangling_operand() {
        let mut m = Module::new();
        let mut b = OpBuilder::new(&mut m);
        let (cid, ch) = b
            .op("olympus.make_channel")
            .result(Type::channel_of(Type::int(32)))
            .build();
        b.op("olympus.pc").operand(ch[0]).build();
        m.erase_op(cid);
        let errs = verify_module(&m);
        assert!(
            errs.iter().any(|e| matches!(e, VerifyError::DanglingOperand(..))),
            "{errs:?}"
        );
    }

    #[test]
    fn catches_use_before_def() {
        let mut m = Module::new();
        let mut b = OpBuilder::new(&mut m);
        let (_, ch) = b
            .op("olympus.make_channel")
            .result(Type::channel_of(Type::int(32)))
            .build();
        // insert a user *before* the def in program order
        b.op("olympus.pc").operand(ch[0]).at(0).build();
        let errs = verify_module(&m);
        assert!(errs.iter().any(|e| matches!(e, VerifyError::UseBeforeDef(..))), "{errs:?}");
    }

    #[test]
    fn catches_detached_value() {
        let mut m = Module::new();
        let v = m.new_detached_value(Type::int(8));
        let mut op = Operation::new("olympus.pc");
        op.operands.push(v);
        m.push_top(op);
        let errs = verify_module(&m);
        assert!(errs.iter().any(|e| matches!(e, VerifyError::DetachedValue(_))), "{errs:?}");
    }

    #[test]
    fn catches_bad_result_def() {
        let mut m = Module::new();
        let id = m.push_top(Operation::new("olympus.make_channel"));
        let v = m.new_detached_value(Type::int(8));
        m.op_mut(id).results.push(v); // def not fixed up
        let errs = verify_module(&m);
        assert!(errs.iter().any(|e| matches!(e, VerifyError::BadResultDef(..))), "{errs:?}");
    }
}
