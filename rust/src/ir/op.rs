//! Generic operations and regions.

use super::attr::{AttrMap, Attribute};
use super::module::OpId;
use super::types::Type;
use super::value::ValueId;

/// A region: a list of nested operations (single implicit block — the
/// Olympus dialect never needs block arguments or multi-block CFGs; the one
/// consumer of regions is the bus-widening super-node).
#[derive(Debug, Clone, Default)]
pub struct Region {
    pub ops: Vec<OpId>,
}

/// A generic operation in MLIR's universal form:
/// `results = "dialect.name"(operands) {attrs} : (in-types) -> (out-types)`.
#[derive(Debug, Clone)]
pub struct Operation {
    /// Fully-qualified name, e.g. `"olympus.make_channel"`.
    pub name: String,
    pub operands: Vec<ValueId>,
    pub results: Vec<ValueId>,
    pub attrs: AttrMap,
    pub regions: Vec<Region>,
}

impl Operation {
    pub fn new(name: impl Into<String>) -> Self {
        Operation {
            name: name.into(),
            operands: Vec::new(),
            results: Vec::new(),
            attrs: AttrMap::new(),
            regions: Vec::new(),
        }
    }

    /// Dialect prefix (`olympus` of `olympus.kernel`).
    pub fn dialect(&self) -> &str {
        self.name.split('.').next().unwrap_or("")
    }

    /// Attribute accessor.
    pub fn attr(&self, key: &str) -> Option<&Attribute> {
        self.attrs.get(key)
    }

    pub fn set_attr(&mut self, key: &str, value: Attribute) {
        self.attrs.insert(key.to_string(), value);
    }

    pub fn int_attr(&self, key: &str) -> Option<i64> {
        self.attr(key)?.as_int()
    }

    pub fn str_attr(&self, key: &str) -> Option<&str> {
        self.attr(key)?.as_str()
    }

    pub fn type_attr(&self, key: &str) -> Option<&Type> {
        self.attr(key)?.as_type()
    }

    /// Split operands into (inputs, outputs) using `operand_segment_sizes`
    /// when present; otherwise all operands are inputs.
    pub fn operand_segments(&self) -> (Vec<ValueId>, Vec<ValueId>) {
        match self.attr("operand_segment_sizes").and_then(|a| a.as_dense_i32()) {
            Some(seg) if seg.len() == 2 => {
                let n_in = seg[0].max(0) as usize;
                let ins = self.operands.iter().take(n_in).copied().collect();
                let outs = self.operands.iter().skip(n_in).copied().collect();
                (ins, outs)
            }
            _ => (self.operands.clone(), Vec::new()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dialect_prefix() {
        assert_eq!(Operation::new("olympus.kernel").dialect(), "olympus");
        assert_eq!(Operation::new("weird").dialect(), "weird");
    }

    #[test]
    fn attr_roundtrip() {
        let mut op = Operation::new("olympus.make_channel");
        op.set_attr("depth", Attribute::Int(20));
        op.set_attr("paramType", "stream".into());
        assert_eq!(op.int_attr("depth"), Some(20));
        assert_eq!(op.str_attr("paramType"), Some("stream"));
        assert_eq!(op.int_attr("missing"), None);
    }

    #[test]
    fn segments_default_all_inputs() {
        let mut op = Operation::new("olympus.kernel");
        op.operands = vec![ValueId(0), ValueId(1)];
        let (ins, outs) = op.operand_segments();
        assert_eq!(ins.len(), 2);
        assert!(outs.is_empty());
    }

    #[test]
    fn segments_split() {
        let mut op = Operation::new("olympus.kernel");
        op.operands = vec![ValueId(0), ValueId(1), ValueId(2)];
        op.set_attr("operand_segment_sizes", Attribute::DenseI32(vec![2, 1]));
        let (ins, outs) = op.operand_segments();
        assert_eq!(ins, vec![ValueId(0), ValueId(1)]);
        assert_eq!(outs, vec![ValueId(2)]);
    }
}
