//! SSA values: handles, definitions and metadata.

use super::types::Type;

/// Handle to an SSA value in a [`crate::ir::Module`]'s value arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ValueId(pub u32);

impl ValueId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Where a value is defined.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValueDef {
    /// Result `idx` of operation `op`.
    OpResult { op: super::module::OpId, idx: u32 },
    /// Detached (created but not yet attached to an op; transient during
    /// construction — the verifier rejects modules that still contain one).
    Detached,
}

/// Metadata stored per value.
#[derive(Debug, Clone)]
pub struct ValueInfo {
    pub ty: Type,
    pub def: ValueDef,
}
