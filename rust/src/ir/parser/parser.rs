//! Recursive-descent parser for MLIR generic operation syntax.
//!
//! Grammar (the slice we support — enough for the paper's figures plus
//! regions, arrays, dicts and dense arrays):
//!
//! ```text
//! module   ::= (`module` `{` op* `}`)? op* EOF
//! op       ::= (res (`,` res)* `=`)? str-lit `(` operands? `)`
//!              region-list? attr-dict? `:` fn-type
//! region-list ::= `(` `{` op* `}` (`,` `{` op* `}`)* `)`
//! attr-dict ::= `{` (ident `=` attr (`,` ident `=` attr)*)? `}`
//! attr     ::= int | float | str | bool | type | `[` attrs `]`
//!            | `{` dict `}` | `array` `<` `i32` (`:` int (`,` int)*)? `>`
//!            | `dense` `<` `[` ints `]` `>` `:` type
//! type     ::= `iN` | `f16|bf16|f32|f64` | `index` | `none`
//!            | `!` dialect-ident (`<` type `>`)?
//! fn-type  ::= `(` types? `)` `->` (`(` types? `)` | type)
//! ```

use std::collections::HashMap;
use std::fmt;

use crate::ir::attr::{AttrMap, Attribute};
use crate::ir::module::{Module, OpId};
use crate::ir::op::{Operation, Region};
use crate::ir::types::{FloatKind, Type};
use crate::ir::value::{ValueDef, ValueId};

use super::lexer::{Lexer, Token, TokenKind};

/// Parse error with location.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "parse error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    lx: Lexer<'a>,
    tok: Token,
    /// SSA name -> value id.
    env: HashMap<String, ValueId>,
    m: Module,
}

type PResult<T> = Result<T, ParseError>;

impl<'a> Parser<'a> {
    fn new(src: &'a str) -> PResult<Self> {
        let mut lx = Lexer::new(src);
        let tok = lx.next_token().map_err(Self::lex_err)?;
        Ok(Parser { lx, tok, env: HashMap::new(), m: Module::new() })
    }

    fn lex_err(msg: String) -> ParseError {
        // lexer errors embed "line:col: msg"
        let mut parts = msg.splitn(3, ':');
        let line = parts.next().and_then(|s| s.parse().ok()).unwrap_or(0);
        let col = parts.next().and_then(|s| s.trim().parse().ok()).unwrap_or(0);
        let msg = parts.next().unwrap_or(&msg).trim().to_string();
        ParseError { line, col, msg }
    }

    fn err<T>(&self, msg: impl Into<String>) -> PResult<T> {
        Err(ParseError { line: self.tok.line, col: self.tok.col, msg: msg.into() })
    }

    fn bump(&mut self) -> PResult<Token> {
        let next = self.lx.next_token().map_err(Self::lex_err)?;
        Ok(std::mem::replace(&mut self.tok, next))
    }

    fn eat(&mut self, kind: &TokenKind) -> PResult<()> {
        if &self.tok.kind == kind {
            self.bump()?;
            Ok(())
        } else {
            self.err(format!("expected '{kind}', found '{}'", self.tok.kind))
        }
    }

    fn at(&self, kind: &TokenKind) -> bool {
        &self.tok.kind == kind
    }

    fn at_ident(&self, s: &str) -> bool {
        matches!(&self.tok.kind, TokenKind::Ident(i) if i == s)
    }

    // ---- types ---------------------------------------------------------

    fn parse_type(&mut self) -> PResult<Type> {
        match self.tok.kind.clone() {
            TokenKind::Ident(id) => {
                self.bump()?;
                self.builtin_type(&id)
            }
            TokenKind::Bang(name) => {
                self.bump()?;
                let (dialect, tail) = match name.split_once('.') {
                    Some((d, t)) => (d.to_string(), t.to_string()),
                    None => (name.clone(), String::new()),
                };
                let mut inner = None;
                if self.at(&TokenKind::Less) {
                    self.bump()?;
                    inner = Some(self.parse_type()?);
                    self.eat(&TokenKind::Greater)?;
                }
                if dialect == "olympus" && tail == "channel" {
                    let elem = inner
                        .ok_or(())
                        .or_else(|_| self.err("!olympus.channel requires an element type"))?;
                    Ok(Type::Channel(Box::new(elem)))
                } else {
                    Ok(Type::Opaque {
                        dialect,
                        name: tail,
                        body: inner.map(|t| t.to_string()).unwrap_or_default(),
                    })
                }
            }
            TokenKind::LParen => {
                let (ins, outs) = self.parse_fn_type()?;
                Ok(Type::Function(ins, outs))
            }
            other => self.err(format!("expected a type, found '{other}'")),
        }
    }

    fn builtin_type(&mut self, id: &str) -> PResult<Type> {
        match id {
            "index" => Ok(Type::Index),
            "none" => Ok(Type::None),
            "f16" => Ok(Type::Float(FloatKind::F16)),
            "bf16" => Ok(Type::Float(FloatKind::BF16)),
            "f32" => Ok(Type::Float(FloatKind::F32)),
            "f64" => Ok(Type::Float(FloatKind::F64)),
            _ if id.starts_with('i') && id[1..].chars().all(|c| c.is_ascii_digit()) => {
                let w: u32 = id[1..]
                    .parse()
                    .map_err(|_| ())
                    .or_else(|_| self.err(format!("bad integer type '{id}'")))?;
                if w == 0 || w > 1_048_576 {
                    return self.err(format!("unsupported integer width {w}"));
                }
                Ok(Type::Integer(w))
            }
            _ => self.err(format!("unknown type '{id}'")),
        }
    }

    fn parse_type_list_parens(&mut self) -> PResult<Vec<Type>> {
        self.eat(&TokenKind::LParen)?;
        let mut tys = Vec::new();
        if !self.at(&TokenKind::RParen) {
            loop {
                tys.push(self.parse_type()?);
                if self.at(&TokenKind::Comma) {
                    self.bump()?;
                } else {
                    break;
                }
            }
        }
        self.eat(&TokenKind::RParen)?;
        Ok(tys)
    }

    fn parse_fn_type(&mut self) -> PResult<(Vec<Type>, Vec<Type>)> {
        let ins = self.parse_type_list_parens()?;
        self.eat(&TokenKind::Arrow)?;
        let outs = if self.at(&TokenKind::LParen) {
            self.parse_type_list_parens()?
        } else {
            vec![self.parse_type()?]
        };
        Ok((ins, outs))
    }

    // ---- attributes ------------------------------------------------------

    fn parse_attr(&mut self) -> PResult<Attribute> {
        match self.tok.kind.clone() {
            TokenKind::Int(v) => {
                self.bump()?;
                // optional `: iN` type suffix — width recorded only as value
                if self.at(&TokenKind::Colon) {
                    self.bump()?;
                    self.parse_type()?;
                }
                Ok(Attribute::Int(v))
            }
            TokenKind::Float(v) => {
                self.bump()?;
                if self.at(&TokenKind::Colon) {
                    self.bump()?;
                    self.parse_type()?;
                }
                Ok(Attribute::Float(v))
            }
            TokenKind::Str(s) => {
                self.bump()?;
                Ok(Attribute::Str(s))
            }
            TokenKind::LBracket => {
                self.bump()?;
                let mut items = Vec::new();
                if !self.at(&TokenKind::RBracket) {
                    loop {
                        items.push(self.parse_attr()?);
                        if self.at(&TokenKind::Comma) {
                            self.bump()?;
                        } else {
                            break;
                        }
                    }
                }
                self.eat(&TokenKind::RBracket)?;
                Ok(Attribute::Array(items))
            }
            TokenKind::LBrace => {
                let dict = self.parse_attr_dict()?;
                Ok(Attribute::Dict(dict))
            }
            TokenKind::Ident(id) => match id.as_str() {
                "true" => {
                    self.bump()?;
                    Ok(Attribute::Bool(true))
                }
                "false" => {
                    self.bump()?;
                    Ok(Attribute::Bool(false))
                }
                "unit" => {
                    self.bump()?;
                    Ok(Attribute::Unit)
                }
                "array" => self.parse_dense_array(),
                "dense" => self.parse_dense_legacy(),
                _ => {
                    let t = self.parse_type()?;
                    Ok(Attribute::Type(t))
                }
            },
            TokenKind::Bang(_) => Ok(Attribute::Type(self.parse_type()?)),
            other => self.err(format!("expected an attribute, found '{other}'")),
        }
    }

    /// `array<i32: 2, 1>` (modern MLIR DenseArrayAttr).
    fn parse_dense_array(&mut self) -> PResult<Attribute> {
        self.bump()?; // array
        self.eat(&TokenKind::Less)?;
        if !self.at_ident("i32") && !self.at_ident("i64") {
            return self.err("expected i32/i64 in array<...>");
        }
        self.bump()?;
        let mut vals = Vec::new();
        if self.at(&TokenKind::Colon) {
            self.bump()?;
            loop {
                match self.tok.kind {
                    TokenKind::Int(v) => {
                        vals.push(v as i32);
                        self.bump()?;
                    }
                    _ => return self.err("expected integer in dense array"),
                }
                if self.at(&TokenKind::Comma) {
                    self.bump()?;
                } else {
                    break;
                }
            }
        }
        self.eat(&TokenKind::Greater)?;
        Ok(Attribute::DenseI32(vals))
    }

    /// `dense<[2, 1]> : tensor<2xi32>` (legacy operand_segment_sizes form).
    fn parse_dense_legacy(&mut self) -> PResult<Attribute> {
        self.bump()?; // dense
        self.eat(&TokenKind::Less)?;
        self.eat(&TokenKind::LBracket)?;
        let mut vals = Vec::new();
        if !self.at(&TokenKind::RBracket) {
            loop {
                match self.tok.kind {
                    TokenKind::Int(v) => {
                        vals.push(v as i32);
                        self.bump()?;
                    }
                    _ => return self.err("expected integer in dense<[...]>"),
                }
                if self.at(&TokenKind::Comma) {
                    self.bump()?;
                } else {
                    break;
                }
            }
        }
        self.eat(&TokenKind::RBracket)?;
        self.eat(&TokenKind::Greater)?;
        // `: tensor<2xi32>` suffix — consume loosely
        self.eat(&TokenKind::Colon)?;
        if let TokenKind::Ident(_) = self.tok.kind {
            self.bump()?;
            if self.at(&TokenKind::Less) {
                // swallow `<2xi32>` as raw tokens
                let mut depth = 1;
                self.bump()?;
                while depth > 0 {
                    match self.tok.kind {
                        TokenKind::Less => depth += 1,
                        TokenKind::Greater => depth -= 1,
                        TokenKind::Eof => return self.err("unterminated tensor type"),
                        _ => {}
                    }
                    self.bump()?;
                }
            }
        }
        Ok(Attribute::DenseI32(vals))
    }

    fn parse_attr_dict(&mut self) -> PResult<AttrMap> {
        self.eat(&TokenKind::LBrace)?;
        let mut map = AttrMap::new();
        if !self.at(&TokenKind::RBrace) {
            loop {
                let key = match &self.tok.kind {
                    TokenKind::Ident(s) => s.clone(),
                    TokenKind::Str(s) => s.clone(),
                    other => return self.err(format!("expected attribute name, found '{other}'")),
                };
                self.bump()?;
                if self.at(&TokenKind::Equal) {
                    self.bump()?;
                    let v = self.parse_attr()?;
                    map.insert(key, v);
                } else {
                    // presence-only unit attribute
                    map.insert(key, Attribute::Unit);
                }
                if self.at(&TokenKind::Comma) {
                    self.bump()?;
                } else {
                    break;
                }
            }
        }
        self.eat(&TokenKind::RBrace)?;
        Ok(map)
    }

    // ---- operations -------------------------------------------------------

    /// Returns true if the current token could begin an operation.
    fn at_op_start(&self) -> bool {
        matches!(self.tok.kind, TokenKind::Percent(_) | TokenKind::Str(_))
    }

    fn parse_op(&mut self) -> PResult<OpId> {
        // results
        let mut result_names = Vec::new();
        if let TokenKind::Percent(_) = self.tok.kind {
            loop {
                match self.tok.kind.clone() {
                    TokenKind::Percent(name) => {
                        result_names.push(name);
                        self.bump()?;
                    }
                    _ => return self.err("expected %value"),
                }
                if self.at(&TokenKind::Comma) {
                    self.bump()?;
                } else {
                    break;
                }
            }
            self.eat(&TokenKind::Equal)?;
        }
        // op name
        let name = match self.tok.kind.clone() {
            TokenKind::Str(s) => {
                self.bump()?;
                s
            }
            other => return self.err(format!("expected op name string, found '{other}'")),
        };
        // operands
        self.eat(&TokenKind::LParen)?;
        let mut operand_names = Vec::new();
        if !self.at(&TokenKind::RParen) {
            loop {
                match self.tok.kind.clone() {
                    TokenKind::Percent(n) => {
                        operand_names.push((n, self.tok.line, self.tok.col));
                        self.bump()?;
                    }
                    other => return self.err(format!("expected %operand, found '{other}'")),
                }
                if self.at(&TokenKind::Comma) {
                    self.bump()?;
                } else {
                    break;
                }
            }
        }
        self.eat(&TokenKind::RParen)?;

        // optional region-list: `({ ... }, { ... })`
        let mut regions: Vec<Vec<OpId>> = Vec::new();
        if self.at(&TokenKind::LParen) {
            self.bump()?;
            loop {
                self.eat(&TokenKind::LBrace)?;
                let mut ops = Vec::new();
                while self.at_op_start() {
                    ops.push(self.parse_op()?);
                }
                self.eat(&TokenKind::RBrace)?;
                regions.push(ops);
                if self.at(&TokenKind::Comma) {
                    self.bump()?;
                } else {
                    break;
                }
            }
            self.eat(&TokenKind::RParen)?;
        }

        // optional attr-dict
        let attrs =
            if self.at(&TokenKind::LBrace) { self.parse_attr_dict()? } else { AttrMap::new() };

        // `:` fn-type
        self.eat(&TokenKind::Colon)?;
        let (in_tys, out_tys) = self.parse_fn_type()?;

        if in_tys.len() != operand_names.len() {
            return self.err(format!(
                "op '{name}': {} operands but {} operand types",
                operand_names.len(),
                in_tys.len()
            ));
        }
        if out_tys.len() != result_names.len() {
            return self.err(format!(
                "op '{name}': {} results but {} result types",
                result_names.len(),
                out_tys.len()
            ));
        }

        // resolve operands
        let mut operands = Vec::with_capacity(operand_names.len());
        for ((n, line, col), ty) in operand_names.into_iter().zip(in_tys.iter()) {
            let Some(&v) = self.env.get(&n) else {
                return Err(ParseError {
                    line,
                    col,
                    msg: format!("use of undefined value %{n}"),
                });
            };
            if self.m.value_type(v) != ty {
                return Err(ParseError {
                    line,
                    col,
                    msg: format!(
                        "type mismatch for %{n}: declared {}, but defined as {}",
                        ty,
                        self.m.value_type(v)
                    ),
                });
            }
            operands.push(v);
        }

        let mut op = Operation::new(name);
        op.operands = operands;
        op.attrs = attrs;
        for ops in regions {
            op.regions.push(Region { ops });
        }
        let id = self.m.insert_op(op);

        // materialize results and bind names
        let mut results = Vec::with_capacity(result_names.len());
        for (i, (rname, ty)) in result_names.into_iter().zip(out_tys.into_iter()).enumerate() {
            let v = self.m.new_detached_value(ty);
            self.m.set_value_def(v, ValueDef::OpResult { op: id, idx: i as u32 });
            if self.env.insert(rname.clone(), v).is_some() {
                return self.err(format!("redefinition of %{rname}"));
            }
            results.push(v);
        }
        self.m.op_mut(id).results = results;
        Ok(id)
    }

    fn parse_module_body(&mut self) -> PResult<()> {
        // optional `module {` wrapper
        let wrapped = if self.at_ident("module") {
            self.bump()?;
            self.eat(&TokenKind::LBrace)?;
            true
        } else {
            false
        };
        while self.at_op_start() {
            let id = self.parse_op()?;
            self.m.top.push(id);
        }
        if wrapped {
            self.eat(&TokenKind::RBrace)?;
        }
        if !self.at(&TokenKind::Eof) {
            return self.err(format!("unexpected token '{}'", self.tok.kind));
        }
        Ok(())
    }
}

/// Parse MLIR generic-syntax text into a [`Module`].
pub fn parse_module(src: &str) -> Result<Module, ParseError> {
    let mut p = Parser::new(src)?;
    p.parse_module_body()?;
    Ok(p.m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::printer::print_module;

    /// The paper's Figure 1, verbatim (modulo whitespace).
    const FIG1: &str = r#"
%2 = "olympus.make_channel"() {
 encapsulatedType = i32,
 paramType = "stream",
 depth = 20
} : () -> (
 !olympus.channel<i32>
)
"#;

    #[test]
    fn parses_fig1() {
        let m = parse_module(FIG1).unwrap();
        assert_eq!(m.top.len(), 1);
        let op = m.op(m.top[0]);
        assert_eq!(op.name, "olympus.make_channel");
        assert_eq!(op.int_attr("depth"), Some(20));
        assert_eq!(op.str_attr("paramType"), Some("stream"));
        assert_eq!(op.type_attr("encapsulatedType"), Some(&Type::int(32)));
        assert_eq!(m.value_type(op.results[0]), &Type::channel_of(Type::int(32)));
    }

    #[test]
    fn parses_fig2_style_kernel() {
        let src = r#"
%2 = "olympus.make_channel"() {encapsulatedType = i32, paramType = "stream", depth = 20} : () -> (!olympus.channel<i32>)
%3 = "olympus.make_channel"() {encapsulatedType = i32, paramType = "stream", depth = 20} : () -> (!olympus.channel<i32>)
%4 = "olympus.make_channel"() {encapsulatedType = i32, paramType = "stream", depth = 20} : () -> (!olympus.channel<i32>)
"olympus.kernel"(%2, %3, %4) {
  callee = "vadd", latency = 142, ii = 1,
  ff = 4316, lut = 5admissible = 0
} : (!olympus.channel<i32>, !olympus.channel<i32>, !olympus.channel<i32>) -> ()
"#;
        // NOTE: the funky `5admissible` would be a lex error — use the clean version:
        let src = src.replace(
            "ff = 4316, lut = 5admissible = 0",
            "ff = 4316, lut = 5373, bram = 2, uram = 0, dsp = 0, \
             operand_segment_sizes = array<i32: 2, 1>",
        );
        let m = parse_module(&src).unwrap();
        let kernels = m.top_ops_named("olympus.kernel");
        assert_eq!(kernels.len(), 1);
        let k = m.op(kernels[0]);
        assert_eq!(k.str_attr("callee"), Some("vadd"));
        assert_eq!(k.int_attr("latency"), Some(142));
        let (ins, outs) = k.operand_segments();
        assert_eq!(ins.len(), 2);
        assert_eq!(outs.len(), 1);
    }

    #[test]
    fn parses_legacy_dense_segments() {
        let src = r#"
%0 = "olympus.make_channel"() {depth = 4} : () -> (!olympus.channel<i64>)
"olympus.kernel"(%0) {operand_segment_sizes = dense<[0, 1]> : tensor<2xi32>} : (!olympus.channel<i64>) -> ()
"#;
        let m = parse_module(src).unwrap();
        let k = m.top_ops_named("olympus.kernel")[0];
        let (ins, outs) = m.op(k).operand_segments();
        assert_eq!(ins.len(), 0);
        assert_eq!(outs.len(), 1);
    }

    #[test]
    fn parses_regions() {
        let src = r#"
%0 = "olympus.make_channel"() {depth = 2} : () -> (!olympus.channel<i64>)
"olympus.super_node"(%0) ({
  "olympus.kernel"(%0) {callee = "k0"} : (!olympus.channel<i64>) -> ()
  "olympus.kernel"(%0) {callee = "k1"} : (!olympus.channel<i64>) -> ()
}) {lanes = 2} : (!olympus.channel<i64>) -> ()
"#;
        let m = parse_module(src).unwrap();
        let sn = m.top_ops_named("olympus.super_node")[0];
        assert_eq!(m.op(sn).regions.len(), 1);
        assert_eq!(m.op(sn).regions[0].ops.len(), 2);
        assert_eq!(m.top.len(), 2); // nested kernels are not top-level
    }

    #[test]
    fn module_wrapper_accepted() {
        let src = "module {\n%0 = \"olympus.make_channel\"() {depth = 1} : () -> (!olympus.channel<i8>)\n}";
        assert!(parse_module(src).is_ok());
    }

    #[test]
    fn undefined_value_is_error() {
        let e = parse_module(r#""olympus.pc"(%9) {id = 0} : (!olympus.channel<i8>) -> ()"#)
            .unwrap_err();
        assert!(e.msg.contains("undefined value"), "{e}");
    }

    #[test]
    fn type_mismatch_is_error() {
        let src = r#"
%0 = "olympus.make_channel"() {depth = 1} : () -> (!olympus.channel<i8>)
"olympus.pc"(%0) {id = 0} : (!olympus.channel<i32>) -> ()
"#;
        let e = parse_module(src).unwrap_err();
        assert!(e.msg.contains("type mismatch"), "{e}");
    }

    #[test]
    fn redefinition_is_error() {
        let src = r#"
%0 = "olympus.make_channel"() {depth = 1} : () -> (!olympus.channel<i8>)
%0 = "olympus.make_channel"() {depth = 1} : () -> (!olympus.channel<i8>)
"#;
        assert!(parse_module(src).is_err());
    }

    #[test]
    fn arity_mismatch_is_error() {
        let src = r#"%0, %1 = "olympus.make_channel"() {depth = 1} : () -> (!olympus.channel<i8>)"#;
        assert!(parse_module(src).is_err());
    }

    #[test]
    fn roundtrip_print_parse() {
        let m = parse_module(FIG1).unwrap();
        let text = print_module(&m);
        let m2 = parse_module(&text).unwrap();
        assert_eq!(print_module(&m2), text);
    }
}
