//! Lexer for MLIR generic syntax.

use std::fmt;

/// Token kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum TokenKind {
    /// `%name` — SSA value reference (name without the `%`).
    Percent(String),
    /// Bare identifier / keyword (`depth`, `i32`, `module`, `true`…).
    Ident(String),
    /// `"..."` string literal (unescaped content).
    Str(String),
    /// Integer literal (possibly negative).
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `!dialect.name` — dialect type prefix (content without `!`).
    Bang(String),
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Less,
    Greater,
    Comma,
    Colon,
    Equal,
    Arrow,
    Eof,
}

impl fmt::Display for TokenKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TokenKind::Percent(s) => write!(f, "%{s}"),
            TokenKind::Ident(s) => write!(f, "{s}"),
            TokenKind::Str(s) => write!(f, "\"{s}\""),
            TokenKind::Int(v) => write!(f, "{v}"),
            TokenKind::Float(v) => write!(f, "{v}"),
            TokenKind::Bang(s) => write!(f, "!{s}"),
            TokenKind::LParen => write!(f, "("),
            TokenKind::RParen => write!(f, ")"),
            TokenKind::LBrace => write!(f, "{{"),
            TokenKind::RBrace => write!(f, "}}"),
            TokenKind::LBracket => write!(f, "["),
            TokenKind::RBracket => write!(f, "]"),
            TokenKind::Less => write!(f, "<"),
            TokenKind::Greater => write!(f, ">"),
            TokenKind::Comma => write!(f, ","),
            TokenKind::Colon => write!(f, ":"),
            TokenKind::Equal => write!(f, "="),
            TokenKind::Arrow => write!(f, "->"),
            TokenKind::Eof => write!(f, "<eof>"),
        }
    }
}

/// A token with its source location.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokenKind,
    pub line: usize,
    pub col: usize,
}

/// Streaming lexer.
pub struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: usize,
    col: usize,
}

impl<'a> Lexer<'a> {
    pub fn new(src: &'a str) -> Self {
        Lexer { src: src.as_bytes(), pos: 0, line: 1, col: 1 }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        if c == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }

    fn skip_ws_and_comments(&mut self) {
        loop {
            match self.peek() {
                Some(b' ') | Some(b'\t') | Some(b'\n') | Some(b'\r') => {
                    self.bump();
                }
                Some(b'/') if self.src.get(self.pos + 1) == Some(&b'/') => {
                    while let Some(c) = self.bump() {
                        if c == b'\n' {
                            break;
                        }
                    }
                }
                _ => return,
            }
        }
    }

    fn ident_tail(&mut self, first: u8) -> String {
        let mut s = String::new();
        s.push(first as char);
        while let Some(c) = self.peek() {
            if c.is_ascii_alphanumeric() || c == b'_' || c == b'.' || c == b'$' || c == b'-' {
                // '-' only valid inside identifiers like `operand-segment`? MLIR idents
                // don't contain '-'; keep it out to avoid eating `->`.
                if c == b'-' {
                    break;
                }
                s.push(c as char);
                self.bump();
            } else {
                break;
            }
        }
        s
    }

    /// Produce the next token.
    pub fn next_token(&mut self) -> Result<Token, String> {
        self.skip_ws_and_comments();
        let (line, col) = (self.line, self.col);
        let tok = |kind| Ok(Token { kind, line, col });
        let Some(c) = self.peek() else {
            return tok(TokenKind::Eof);
        };
        match c {
            b'(' => {
                self.bump();
                tok(TokenKind::LParen)
            }
            b')' => {
                self.bump();
                tok(TokenKind::RParen)
            }
            b'{' => {
                self.bump();
                tok(TokenKind::LBrace)
            }
            b'}' => {
                self.bump();
                tok(TokenKind::RBrace)
            }
            b'[' => {
                self.bump();
                tok(TokenKind::LBracket)
            }
            b']' => {
                self.bump();
                tok(TokenKind::RBracket)
            }
            b'<' => {
                self.bump();
                tok(TokenKind::Less)
            }
            b'>' => {
                self.bump();
                tok(TokenKind::Greater)
            }
            b',' => {
                self.bump();
                tok(TokenKind::Comma)
            }
            b':' => {
                self.bump();
                tok(TokenKind::Colon)
            }
            b'=' => {
                self.bump();
                tok(TokenKind::Equal)
            }
            b'%' => {
                self.bump();
                let mut s = String::new();
                while let Some(c) = self.peek() {
                    if c.is_ascii_alphanumeric() || c == b'_' {
                        s.push(c as char);
                        self.bump();
                    } else {
                        break;
                    }
                }
                if s.is_empty() {
                    return Err(format!("{line}:{col}: bare '%'"));
                }
                tok(TokenKind::Percent(s))
            }
            b'!' => {
                self.bump();
                let first = self.bump().ok_or(format!("{line}:{col}: bare '!'"))?;
                if !(first.is_ascii_alphabetic() || first == b'_') {
                    return Err(format!("{line}:{col}: bad dialect type"));
                }
                let s = self.ident_tail(first);
                tok(TokenKind::Bang(s))
            }
            b'"' => {
                self.bump();
                let mut s = String::new();
                loop {
                    match self.bump() {
                        None => return Err(format!("{line}:{col}: unterminated string")),
                        Some(b'"') => break,
                        Some(b'\\') => match self.bump() {
                            Some(b'n') => s.push('\n'),
                            Some(b't') => s.push('\t'),
                            Some(b'"') => s.push('"'),
                            Some(b'\\') => s.push('\\'),
                            other => {
                                return Err(format!(
                                    "{line}:{col}: bad escape {:?}",
                                    other.map(|c| c as char)
                                ))
                            }
                        },
                        Some(c) => s.push(c as char),
                    }
                }
                tok(TokenKind::Str(s))
            }
            b'-' => {
                self.bump();
                if self.peek() == Some(b'>') {
                    self.bump();
                    return tok(TokenKind::Arrow);
                }
                // negative number
                self.number(true, line, col)
            }
            c if c.is_ascii_digit() => self.number(false, line, col),
            c if c.is_ascii_alphabetic() || c == b'_' => {
                self.bump();
                let s = self.ident_tail(c);
                tok(TokenKind::Ident(s))
            }
            c => Err(format!("{line}:{col}: unexpected character '{}'", c as char)),
        }
    }

    fn number(&mut self, neg: bool, line: usize, col: usize) -> Result<Token, String> {
        let mut s = String::new();
        if neg {
            s.push('-');
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() {
                s.push(c as char);
                self.bump();
            } else if c == b'.' && !is_float {
                // lookahead: require a digit after '.' (else it's something else)
                if self.src.get(self.pos + 1).is_some_and(|d| d.is_ascii_digit()) {
                    is_float = true;
                    s.push('.');
                    self.bump();
                } else {
                    break;
                }
            } else if (c == b'e' || c == b'E')
                && self
                    .src
                    .get(self.pos + 1)
                    .is_some_and(|d| d.is_ascii_digit() || *d == b'-' || *d == b'+')
            {
                is_float = true;
                s.push(c as char);
                self.bump();
                if matches!(self.peek(), Some(b'-') | Some(b'+')) {
                    s.push(self.bump().unwrap() as char);
                }
            } else {
                break;
            }
        }
        if s == "-" {
            return Err(format!("{line}:{col}: lone '-'"));
        }
        if is_float {
            s.parse::<f64>()
                .map(|v| Token { kind: TokenKind::Float(v), line, col })
                .map_err(|e| format!("{line}:{col}: bad float: {e}"))
        } else {
            s.parse::<i64>()
                .map(|v| Token { kind: TokenKind::Int(v), line, col })
                .map_err(|e| format!("{line}:{col}: bad int: {e}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokenKind> {
        let mut lx = Lexer::new(src);
        let mut out = Vec::new();
        loop {
            let t = lx.next_token().unwrap();
            if t.kind == TokenKind::Eof {
                break;
            }
            out.push(t.kind);
        }
        out
    }

    #[test]
    fn lexes_fig1_line() {
        let toks =
            kinds(r#"%2 = "olympus.make_channel"() {depth = 20} : () -> (!olympus.channel<i32>)"#);
        assert_eq!(toks[0], TokenKind::Percent("2".into()));
        assert_eq!(toks[1], TokenKind::Equal);
        assert_eq!(toks[2], TokenKind::Str("olympus.make_channel".into()));
        assert!(toks.contains(&TokenKind::Bang("olympus.channel".into())));
        assert!(toks.contains(&TokenKind::Arrow));
        assert!(toks.contains(&TokenKind::Int(20)));
    }

    #[test]
    fn lexes_negative_and_float() {
        assert_eq!(kinds("-3"), vec![TokenKind::Int(-3)]);
        assert_eq!(kinds("-3.5"), vec![TokenKind::Float(-3.5)]);
        assert_eq!(kinds("1e3"), vec![TokenKind::Float(1000.0)]);
        assert_eq!(kinds("2 -> 3"), vec![TokenKind::Int(2), TokenKind::Arrow, TokenKind::Int(3)]);
    }

    #[test]
    fn skips_comments() {
        assert_eq!(
            kinds("a // comment\n b"),
            vec![TokenKind::Ident("a".into()), TokenKind::Ident("b".into())]
        );
    }

    #[test]
    fn string_escapes() {
        assert_eq!(kinds(r#""a\nb""#), vec![TokenKind::Str("a\nb".into())]);
        assert_eq!(kinds(r#""q\"w""#), vec![TokenKind::Str("q\"w".into())]);
    }

    #[test]
    fn error_on_garbage() {
        let mut lx = Lexer::new("@");
        assert!(lx.next_token().is_err());
    }

    #[test]
    fn tracks_locations() {
        let mut lx = Lexer::new("a\n  b");
        let a = lx.next_token().unwrap();
        assert_eq!((a.line, a.col), (1, 1));
        let b = lx.next_token().unwrap();
        assert_eq!((b.line, b.col), (2, 3));
    }
}
