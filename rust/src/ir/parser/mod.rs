//! Parser for the MLIR generic operation syntax (paper Figures 1–2).

mod lexer;
#[allow(clippy::module_inception)]
mod parser;

pub use lexer::{Lexer, Token, TokenKind};
pub use parser::{parse_module, ParseError};
