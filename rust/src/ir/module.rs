//! The [`Module`]: arena-allocated operations + SSA value table.
//!
//! Ops live in a slab (`Vec<Option<Operation>>`); erasing leaves a tombstone
//! so [`OpId`]s stay stable across pass pipelines. Top-level op order is the
//! program order used by the printer and the lowering.

use super::op::{Operation, Region};
use super::types::Type;
use super::value::{ValueDef, ValueId, ValueInfo};

/// Handle to an operation in a module's arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub u32);

impl OpId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// A module: the IR unit the parser returns and passes transform.
#[derive(Debug, Clone, Default)]
pub struct Module {
    ops: Vec<Option<Operation>>,
    /// Top-level operation order.
    pub top: Vec<OpId>,
    values: Vec<ValueInfo>,
}

impl Module {
    pub fn new() -> Self {
        Self::default()
    }

    // ---- op accessors -------------------------------------------------

    pub fn op(&self, id: OpId) -> &Operation {
        self.ops[id.index()].as_ref().expect("op erased")
    }

    pub fn op_mut(&mut self, id: OpId) -> &mut Operation {
        self.ops[id.index()].as_mut().expect("op erased")
    }

    pub fn op_exists(&self, id: OpId) -> bool {
        self.ops.get(id.index()).map(|o| o.is_some()).unwrap_or(false)
    }

    /// All live op ids, in arena order (use [`Module::top`] for program order).
    pub fn all_ops(&self) -> impl Iterator<Item = OpId> + '_ {
        self.ops
            .iter()
            .enumerate()
            .filter_map(|(i, o)| o.as_ref().map(|_| OpId(i as u32)))
    }

    /// Top-level ops in program order.
    pub fn top_ops(&self) -> impl Iterator<Item = OpId> + '_ {
        self.top.iter().copied()
    }

    /// Number of live operations (including ops nested in regions).
    pub fn num_ops(&self) -> usize {
        self.ops.iter().filter(|o| o.is_some()).count()
    }

    // ---- construction --------------------------------------------------

    /// Insert a detached op into the arena (not yet in `top`).
    pub fn insert_op(&mut self, op: Operation) -> OpId {
        let id = OpId(self.ops.len() as u32);
        self.ops.push(Some(op));
        id
    }

    /// Insert and append to the top-level op list.
    pub fn push_top(&mut self, op: Operation) -> OpId {
        let id = self.insert_op(op);
        self.top.push(id);
        id
    }

    /// Insert `op` at `pos` in the top-level list.
    pub fn insert_top_at(&mut self, pos: usize, op: Operation) -> OpId {
        let id = self.insert_op(op);
        self.top.insert(pos.min(self.top.len()), id);
        id
    }

    /// Create a fresh SSA value of type `ty`, defined by (`op`, `idx`).
    pub fn new_result(&mut self, op: OpId, idx: u32, ty: Type) -> ValueId {
        let v = ValueId(self.values.len() as u32);
        self.values.push(ValueInfo { ty, def: ValueDef::OpResult { op, idx } });
        v
    }

    /// Create a detached value (parser fixes the def up afterwards).
    pub fn new_detached_value(&mut self, ty: Type) -> ValueId {
        let v = ValueId(self.values.len() as u32);
        self.values.push(ValueInfo { ty, def: ValueDef::Detached });
        v
    }

    pub fn set_value_def(&mut self, v: ValueId, def: ValueDef) {
        self.values[v.index()].def = def;
    }

    // ---- value accessors ------------------------------------------------

    pub fn value_type(&self, v: ValueId) -> &Type {
        &self.values[v.index()].ty
    }

    pub fn value_def(&self, v: ValueId) -> ValueDef {
        self.values[v.index()].def
    }

    /// The op defining `v`, if attached.
    pub fn defining_op(&self, v: ValueId) -> Option<OpId> {
        match self.value_def(v) {
            ValueDef::OpResult { op, .. } => Some(op),
            ValueDef::Detached => None,
        }
    }

    pub fn num_values(&self) -> usize {
        self.values.len()
    }

    /// All (op, operand_index) uses of `v`, scanning top-level and nested ops.
    ///
    /// O(total operands). Callers that query many values should build a
    /// [`Module::use_map`] once instead.
    pub fn uses_of(&self, v: ValueId) -> Vec<(OpId, usize)> {
        let mut out = Vec::new();
        for id in self.all_ops() {
            for (i, o) in self.op(id).operands.iter().enumerate() {
                if *o == v {
                    out.push((id, i));
                }
            }
        }
        out
    }

    /// One-pass use map: value -> all (op, operand index) uses. Build this
    /// once per analysis/pass instead of calling [`Module::uses_of`] per
    /// value (which makes whole-module traversals quadratic).
    pub fn use_map(&self) -> std::collections::HashMap<ValueId, Vec<(OpId, usize)>> {
        let mut map: std::collections::HashMap<ValueId, Vec<(OpId, usize)>> =
            std::collections::HashMap::with_capacity(self.values.len());
        for id in self.all_ops() {
            for (i, &o) in self.op(id).operands.iter().enumerate() {
                map.entry(o).or_default().push((id, i));
            }
        }
        map
    }

    // ---- mutation -------------------------------------------------------

    /// Erase an op (tombstone) and remove it from the top-level list and any
    /// region op lists. Its results become dangling; callers must rewrite
    /// uses first (the verifier catches violations).
    pub fn erase_op(&mut self, id: OpId) {
        self.top.retain(|&o| o != id);
        // remove from any region (skip the common region-less ops — this
        // runs once per erased op and must stay cheap)
        for (i, slot) in self.ops.iter_mut().enumerate() {
            if i == id.index() {
                continue;
            }
            if let Some(op) = slot {
                if !op.regions.is_empty() {
                    for r in &mut op.regions {
                        r.ops.retain(|&o| o != id);
                    }
                }
            }
        }
        self.ops[id.index()] = None;
    }

    /// Replace every use of `from` with `to` across all ops.
    pub fn replace_all_uses(&mut self, from: ValueId, to: ValueId) {
        for slot in self.ops.iter_mut().flatten() {
            for o in &mut slot.operands {
                if *o == from {
                    *o = to;
                }
            }
        }
    }

    /// Move a top-level op into a region of another op.
    pub fn move_into_region(&mut self, op: OpId, parent: OpId, region_idx: usize) {
        self.top.retain(|&o| o != op);
        let p = self.op_mut(parent);
        while p.regions.len() <= region_idx {
            p.regions.push(Region::default());
        }
        p.regions[region_idx].ops.push(op);
    }

    /// Ops of `name` in program order (top level only).
    pub fn top_ops_named(&self, name: &str) -> Vec<OpId> {
        self.top.iter().copied().filter(|&id| self.op(id).name == name).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::attr::Attribute;

    fn mk_channel(m: &mut Module) -> (OpId, ValueId) {
        let mut op = Operation::new("olympus.make_channel");
        op.set_attr("depth", Attribute::Int(8));
        let id = m.push_top(op);
        let v = m.new_result(id, 0, Type::channel_of(Type::int(32)));
        m.op_mut(id).results.push(v);
        (id, v)
    }

    #[test]
    fn build_and_access() {
        let mut m = Module::new();
        let (cid, v) = mk_channel(&mut m);
        assert_eq!(m.num_ops(), 1);
        assert_eq!(m.value_type(v), &Type::channel_of(Type::int(32)));
        assert_eq!(m.defining_op(v), Some(cid));
    }

    #[test]
    fn uses_and_replace() {
        let mut m = Module::new();
        let (_, v1) = mk_channel(&mut m);
        let (_, v2) = mk_channel(&mut m);
        let mut k = Operation::new("olympus.kernel");
        k.operands.push(v1);
        let kid = m.push_top(k);
        assert_eq!(m.uses_of(v1), vec![(kid, 0)]);
        assert!(m.uses_of(v2).is_empty());
        m.replace_all_uses(v1, v2);
        assert!(m.uses_of(v1).is_empty());
        assert_eq!(m.uses_of(v2), vec![(kid, 0)]);
    }

    #[test]
    fn erase_removes_from_top() {
        let mut m = Module::new();
        let (cid, _) = mk_channel(&mut m);
        assert_eq!(m.top.len(), 1);
        m.erase_op(cid);
        assert_eq!(m.top.len(), 0);
        assert_eq!(m.num_ops(), 0);
        assert!(!m.op_exists(cid));
    }

    #[test]
    fn move_into_region() {
        let mut m = Module::new();
        let (c1, _) = mk_channel(&mut m);
        let super_node = m.push_top(Operation::new("olympus.super_node"));
        m.move_into_region(c1, super_node, 0);
        assert_eq!(m.top.len(), 1);
        assert_eq!(m.op(super_node).regions[0].ops, vec![c1]);
        // erase of nested op cleans the region list
        m.erase_op(c1);
        assert!(m.op(super_node).regions[0].ops.is_empty());
    }
}
