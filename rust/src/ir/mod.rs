//! MLIR-subset IR core.
//!
//! Implements exactly the slice of MLIR the Olympus dialect needs, built
//! from scratch (no MLIR C++ / bindings):
//!
//! * [`Type`] — builtin integer/float/index types plus dialect types such as
//!   `!olympus.channel<i32>`;
//! * [`Attribute`] — integers, strings, types, arrays, dictionaries and
//!   dense integer arrays (`operand_segment_sizes`);
//! * [`Operation`] / [`Module`] — arena-allocated generic operations in SSA
//!   form, with optional nested regions (used by bus-widening super-nodes);
//! * a lexer/parser for the MLIR *generic* operation syntax used in the
//!   paper's Figures 1–2, a printer producing the same syntax, and a
//!   structural verifier.
//!
//! The IR is deliberately printable→parsable round-trip stable; proptest-style
//! randomized tests in `rust/tests/` rely on that.

pub mod attr;
pub mod builder;
pub mod module;
pub mod op;
pub mod parser;
pub mod printer;
pub mod types;
pub mod value;
pub mod verifier;

pub use attr::{AttrMap, Attribute};
pub use builder::OpBuilder;
pub use module::{Module, OpId};
pub use op::{Operation, Region};
pub use parser::{parse_module, ParseError};
pub use printer::{module_fingerprint, print_module};
pub use types::{FloatKind, Type};
pub use value::{ValueDef, ValueId, ValueInfo};
pub use verifier::{verify_module, VerifyError};
