//! Convenience builder for constructing IR programmatically (examples,
//! tests and workload generators use this instead of parsing text).

use super::attr::{AttrMap, Attribute};
use super::module::{Module, OpId};
use super::op::Operation;
use super::types::Type;
use super::value::ValueId;

/// Fluent op builder bound to a module.
pub struct OpBuilder<'m> {
    pub module: &'m mut Module,
}

impl<'m> OpBuilder<'m> {
    pub fn new(module: &'m mut Module) -> Self {
        OpBuilder { module }
    }

    /// Start building an op with the given fully-qualified name.
    pub fn op(&mut self, name: &str) -> OpCtor<'_, 'm> {
        OpCtor {
            b: self,
            op: Operation::new(name),
            result_types: Vec::new(),
            at: None,
        }
    }
}

/// In-flight operation under construction.
pub struct OpCtor<'a, 'm> {
    b: &'a mut OpBuilder<'m>,
    op: Operation,
    result_types: Vec<Type>,
    at: Option<usize>,
}

impl OpCtor<'_, '_> {
    pub fn operand(mut self, v: ValueId) -> Self {
        self.op.operands.push(v);
        self
    }

    pub fn operands(mut self, vs: &[ValueId]) -> Self {
        self.op.operands.extend_from_slice(vs);
        self
    }

    pub fn attr(mut self, key: &str, value: impl Into<Attribute>) -> Self {
        self.op.attrs.insert(key.to_string(), value.into());
        self
    }

    pub fn attrs(mut self, map: AttrMap) -> Self {
        self.op.attrs.extend(map);
        self
    }

    pub fn result(mut self, ty: Type) -> Self {
        self.result_types.push(ty);
        self
    }

    /// Insert at a specific top-level position instead of appending.
    pub fn at(mut self, pos: usize) -> Self {
        self.at = Some(pos);
        self
    }

    /// Finish: insert into the module, materialize result values.
    pub fn build(self) -> (OpId, Vec<ValueId>) {
        let OpCtor { b, op, result_types, at } = self;
        let id = match at {
            Some(pos) => b.module.insert_top_at(pos, op),
            None => b.module.push_top(op),
        };
        let mut results = Vec::with_capacity(result_types.len());
        for (i, ty) in result_types.into_iter().enumerate() {
            let v = b.module.new_result(id, i as u32, ty);
            results.push(v);
        }
        b.module.op_mut(id).results = results.clone();
        (id, results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_channel_and_kernel() {
        let mut m = Module::new();
        let mut b = OpBuilder::new(&mut m);
        let (_, ch) = b
            .op("olympus.make_channel")
            .attr("encapsulatedType", Type::int(32))
            .attr("paramType", "stream")
            .attr("depth", 20i64)
            .result(Type::channel_of(Type::int(32)))
            .build();
        let (kid, _) = b
            .op("olympus.kernel")
            .operand(ch[0])
            .attr("callee", "vecadd_1024")
            .build();
        assert_eq!(m.top.len(), 2);
        assert_eq!(m.op(kid).operands.len(), 1);
        assert_eq!(m.uses_of(ch[0]), vec![(kid, 0)]);
    }

    #[test]
    fn insert_at_position() {
        let mut m = Module::new();
        let mut b = OpBuilder::new(&mut m);
        let (first, _) = b.op("a.x").build();
        let (second, _) = b.op("a.y").at(0).build();
        assert_eq!(m.top, vec![second, first]);
    }
}
