//! IR types: the builtin slice used by Olympus plus dialect types.

use std::fmt;

/// Builtin float kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FloatKind {
    F16,
    BF16,
    F32,
    F64,
}

impl FloatKind {
    pub fn bitwidth(self) -> u32 {
        match self {
            FloatKind::F16 | FloatKind::BF16 => 16,
            FloatKind::F32 => 32,
            FloatKind::F64 => 64,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FloatKind::F16 => "f16",
            FloatKind::BF16 => "bf16",
            FloatKind::F32 => "f32",
            FloatKind::F64 => "f64",
        }
    }
}

/// An IR type.
///
/// The paper's dialect encodes *all* element data as signless integers of
/// the data's bitwidth (`encapsulatedType = i32` for an f32, a Q10.22
/// fixed-point, or an i32 alike) — only the width matters for bandwidth
/// planning, so [`Type::Integer`] carries just a width.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Type {
    /// `iN` — signless integer of width N.
    Integer(u32),
    /// `f32` etc.
    Float(FloatKind),
    /// `index`.
    Index,
    /// `none`.
    None,
    /// `!olympus.channel<T>` — a dataflow channel carrying elements of T.
    Channel(Box<Type>),
    /// `(T, ...) -> (U, ...)` — function type (used in op signatures).
    Function(Vec<Type>, Vec<Type>),
    /// `!dialect.name<body>` — any other dialect type, kept opaque.
    Opaque {
        dialect: String,
        name: String,
        /// Raw text between `<` and `>` (empty when absent).
        body: String,
    },
}

impl Type {
    /// Shorthand for `iN`.
    pub fn int(width: u32) -> Type {
        Type::Integer(width)
    }

    /// Shorthand for `!olympus.channel<iN>`.
    pub fn channel_of(elem: Type) -> Type {
        Type::Channel(Box::new(elem))
    }

    /// Bitwidth of a data type, if meaningful.
    pub fn bitwidth(&self) -> Option<u32> {
        match self {
            Type::Integer(w) => Some(*w),
            Type::Float(k) => Some(k.bitwidth()),
            Type::Channel(e) => e.bitwidth(),
            _ => None,
        }
    }

    /// Element type of a channel type.
    pub fn channel_elem(&self) -> Option<&Type> {
        match self {
            Type::Channel(e) => Some(e),
            _ => None,
        }
    }

    pub fn is_channel(&self) -> bool {
        matches!(self, Type::Channel(_))
    }
}

impl fmt::Display for Type {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Type::Integer(w) => write!(f, "i{w}"),
            Type::Float(k) => write!(f, "{}", k.name()),
            Type::Index => write!(f, "index"),
            Type::None => write!(f, "none"),
            Type::Channel(e) => write!(f, "!olympus.channel<{e}>"),
            Type::Function(ins, outs) => {
                write!(f, "(")?;
                for (i, t) in ins.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{t}")?;
                }
                write!(f, ") -> (")?;
                for (i, t) in outs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{t}")?;
                }
                write!(f, ")")
            }
            Type::Opaque { dialect, name, body } => {
                if body.is_empty() {
                    write!(f, "!{dialect}.{name}")
                } else {
                    write!(f, "!{dialect}.{name}<{body}>")
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_integer() {
        assert_eq!(Type::int(32).to_string(), "i32");
        assert_eq!(Type::int(1).to_string(), "i1");
        assert_eq!(Type::int(512).to_string(), "i512");
    }

    #[test]
    fn display_channel() {
        assert_eq!(Type::channel_of(Type::int(64)).to_string(), "!olympus.channel<i64>");
        assert_eq!(
            Type::channel_of(Type::channel_of(Type::int(8))).to_string(),
            "!olympus.channel<!olympus.channel<i8>>"
        );
    }

    #[test]
    fn bitwidths() {
        assert_eq!(Type::int(256).bitwidth(), Some(256));
        assert_eq!(Type::Float(FloatKind::BF16).bitwidth(), Some(16));
        assert_eq!(Type::channel_of(Type::int(32)).bitwidth(), Some(32));
        assert_eq!(Type::Index.bitwidth(), None);
    }

    #[test]
    fn display_function_type() {
        let t = Type::Function(vec![Type::int(32), Type::Index], vec![Type::int(1)]);
        assert_eq!(t.to_string(), "(i32, index) -> (i1)");
    }

    #[test]
    fn channel_elem_access() {
        let c = Type::channel_of(Type::int(128));
        assert_eq!(c.channel_elem(), Some(&Type::int(128)));
        assert!(c.is_channel());
        assert!(!Type::int(8).is_channel());
    }
}
