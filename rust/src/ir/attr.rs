//! Attributes: compile-time-constant op metadata.
//!
//! Covers the attribute kinds the Olympus dialect uses (Figures 1–2 of the
//! paper) plus arrays/dicts so layouts and platform annotations compose:
//! `depth = 20`, `paramType = "stream"`, `encapsulatedType = i32`,
//! `operand_segment_sizes = array<i32: 2, 1>`, nested layout dictionaries.

use std::collections::BTreeMap;
use std::fmt;

use super::types::Type;

/// Attribute map with deterministic (sorted) iteration order.
pub type AttrMap = BTreeMap<String, Attribute>;

/// An attribute value.
#[derive(Debug, Clone, PartialEq)]
pub enum Attribute {
    /// `42 : i64` (the type suffix is implicit i64 when printed bare).
    Int(i64),
    /// `1.5 : f64`.
    Float(f64),
    /// `"stream"`.
    Str(String),
    /// `true` / `false`.
    Bool(bool),
    /// A type used as an attribute, e.g. `encapsulatedType = i32`.
    Type(Type),
    /// `[a, b, c]`.
    Array(Vec<Attribute>),
    /// `{k = v, ...}`.
    Dict(AttrMap),
    /// `array<i32: 2, 1>` — dense integer array (operand_segment_sizes).
    DenseI32(Vec<i32>),
    /// Unit attribute (presence-only flag).
    Unit,
}

impl Attribute {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Attribute::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_int().filter(|v| *v >= 0).map(|v| v as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_int().filter(|v| *v >= 0).map(|v| v as usize)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Attribute::Float(v) => Some(*v),
            Attribute::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Attribute::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_type(&self) -> Option<&Type> {
        match self {
            Attribute::Type(t) => Some(t),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Attribute]> {
        match self {
            Attribute::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_dict(&self) -> Option<&AttrMap> {
        match self {
            Attribute::Dict(d) => Some(d),
            _ => None,
        }
    }

    pub fn as_dense_i32(&self) -> Option<&[i32]> {
        match self {
            Attribute::DenseI32(v) => Some(v),
            _ => None,
        }
    }
}

impl From<i64> for Attribute {
    fn from(v: i64) -> Self {
        Attribute::Int(v)
    }
}
impl From<usize> for Attribute {
    fn from(v: usize) -> Self {
        Attribute::Int(v as i64)
    }
}
impl From<u32> for Attribute {
    fn from(v: u32) -> Self {
        Attribute::Int(v as i64)
    }
}
impl From<&str> for Attribute {
    fn from(v: &str) -> Self {
        Attribute::Str(v.to_string())
    }
}
impl From<String> for Attribute {
    fn from(v: String) -> Self {
        Attribute::Str(v)
    }
}
impl From<bool> for Attribute {
    fn from(v: bool) -> Self {
        Attribute::Bool(v)
    }
}
impl From<Type> for Attribute {
    fn from(v: Type) -> Self {
        Attribute::Type(v)
    }
}
impl From<f64> for Attribute {
    fn from(v: f64) -> Self {
        Attribute::Float(v)
    }
}

fn escape_str(s: &str, out: &mut fmt::Formatter<'_>) -> fmt::Result {
    write!(out, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(out, "\\\"")?,
            '\\' => write!(out, "\\\\")?,
            '\n' => write!(out, "\\n")?,
            '\t' => write!(out, "\\t")?,
            c => write!(out, "{c}")?,
        }
    }
    write!(out, "\"")
}

impl fmt::Display for Attribute {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Attribute::Int(v) => write!(f, "{v}"),
            Attribute::Float(v) => {
                if v.fract() == 0.0 && v.is_finite() {
                    write!(f, "{v:.6e}")
                } else {
                    write!(f, "{v}")
                }
            }
            Attribute::Str(s) => escape_str(s, f),
            Attribute::Bool(b) => write!(f, "{b}"),
            Attribute::Type(t) => write!(f, "{t}"),
            Attribute::Array(a) => {
                write!(f, "[")?;
                for (i, x) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Attribute::Dict(d) => {
                write!(f, "{{")?;
                for (i, (k, v)) in d.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{k} = {v}")?;
                }
                write!(f, "}}")
            }
            Attribute::DenseI32(v) => {
                // MLIR dense-array syntax: `array<i32: 2, 1>` (empty: `array<i32>`).
                write!(f, "array<i32")?;
                for (i, x) in v.iter().enumerate() {
                    if i == 0 {
                        write!(f, ": {x}")?;
                    } else {
                        write!(f, ", {x}")?;
                    }
                }
                write!(f, ">")
            }
            Attribute::Unit => write!(f, "unit"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        assert_eq!(Attribute::Int(7).as_int(), Some(7));
        assert_eq!(Attribute::Int(-1).as_u64(), None);
        assert_eq!(Attribute::Str("s".into()).as_str(), Some("s"));
        assert_eq!(Attribute::Type(Type::int(32)).as_type(), Some(&Type::int(32)));
        assert_eq!(Attribute::DenseI32(vec![2, 1]).as_dense_i32(), Some(&[2, 1][..]));
    }

    #[test]
    fn display_dense_array() {
        assert_eq!(Attribute::DenseI32(vec![2, 1]).to_string(), "array<i32: 2, 1>");
        assert_eq!(Attribute::DenseI32(vec![]).to_string(), "array<i32>");
    }

    #[test]
    fn display_scalars() {
        assert_eq!(Attribute::Int(20).to_string(), "20");
        assert_eq!(Attribute::Str("stream".into()).to_string(), "\"stream\"");
        assert_eq!(Attribute::Bool(true).to_string(), "true");
        assert_eq!(Attribute::Type(Type::int(32)).to_string(), "i32");
    }

    #[test]
    fn display_nested() {
        let a = Attribute::Array(vec![Attribute::Int(1), Attribute::Str("x".into())]);
        assert_eq!(a.to_string(), "[1, \"x\"]");
        let mut d = AttrMap::new();
        d.insert("width".into(), Attribute::Int(32));
        d.insert("depth".into(), Attribute::Int(20));
        // BTreeMap: sorted keys
        assert_eq!(Attribute::Dict(d).to_string(), "{depth = 20, width = 32}");
    }
}
