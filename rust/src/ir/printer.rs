//! Printer: emits MLIR *generic* operation syntax, the same form as the
//! paper's Figures 1–2:
//!
//! ```text
//! %2 = "olympus.make_channel"() {depth = 20, ...} : () -> (!olympus.channel<i32>)
//! "olympus.kernel"(%2, %3) {callee = "k", ...} : (!olympus.channel<i32>, ...) -> ()
//! ```
//!
//! Values are renumbered sequentially in program order, so printing is a
//! canonicalization: two structurally-equal modules print identically.

use std::collections::HashMap;
use std::fmt::Write;

use super::module::{Module, OpId};
use super::value::ValueId;

struct Printer<'m> {
    m: &'m Module,
    names: HashMap<ValueId, usize>,
    next: usize,
    out: String,
}

impl<'m> Printer<'m> {
    fn name_of(&mut self, v: ValueId) -> usize {
        if let Some(&n) = self.names.get(&v) {
            return n;
        }
        let n = self.next;
        self.next += 1;
        self.names.insert(v, n);
        n
    }

    fn print_op(&mut self, id: OpId, indent: usize) {
        let op = self.m.op(id).clone();
        let pad = "  ".repeat(indent);
        self.out.push_str(&pad);
        if !op.results.is_empty() {
            let names: Vec<String> =
                op.results.iter().map(|&r| format!("%{}", self.name_of(r))).collect();
            let _ = write!(self.out, "{} = ", names.join(", "));
        }
        let _ = write!(self.out, "\"{}\"(", op.name);
        let opnds: Vec<String> =
            op.operands.iter().map(|&o| format!("%{}", self.name_of(o))).collect();
        self.out.push_str(&opnds.join(", "));
        self.out.push(')');
        // regions (MLIR generic: region-list before attr-dict)
        if !op.regions.is_empty() {
            self.out.push_str(" (");
            for (ri, r) in op.regions.iter().enumerate() {
                if ri > 0 {
                    self.out.push_str(", ");
                }
                self.out.push_str("{\n");
                for &inner in &r.ops {
                    self.print_op(inner, indent + 1);
                }
                self.out.push_str(&pad);
                self.out.push('}');
            }
            self.out.push(')');
        }
        if !op.attrs.is_empty() {
            self.out.push_str(" {");
            let attrs: Vec<String> =
                op.attrs.iter().map(|(k, v)| format!("{k} = {v}")).collect();
            self.out.push_str(&attrs.join(", "));
            self.out.push('}');
        }
        // function type
        let in_tys: Vec<String> =
            op.operands.iter().map(|&o| self.m.value_type(o).to_string()).collect();
        let out_tys: Vec<String> =
            op.results.iter().map(|&r| self.m.value_type(r).to_string()).collect();
        let _ = write!(self.out, " : ({}) -> ({})", in_tys.join(", "), out_tys.join(", "));
        self.out.push('\n');
    }
}

/// Print a module in generic syntax (top-level ops, no `module {}` wrapper —
/// the parser accepts both).
pub fn print_module(m: &Module) -> String {
    let mut p = Printer { m, names: HashMap::new(), next: 0, out: String::new() };
    for id in m.top.clone() {
        p.print_op(id, 0);
    }
    p.out
}

/// Stable content fingerprint of a module: the printed canonical form
/// (values renumbered in program order, attributes sorted) hashed with the
/// process-independent [`crate::util::ContentHash`]. Structurally equal
/// modules fingerprint identically; this is the module component of the
/// service's content-addressed cache keys.
pub fn module_fingerprint(m: &Module) -> String {
    crate::util::ContentHash::of_parts(&["olympus-ir-v1", &print_module(m)]).to_hex()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::OpBuilder;
    use crate::ir::types::Type;

    #[test]
    fn prints_fig1_shape() {
        let mut m = Module::new();
        let mut b = OpBuilder::new(&mut m);
        b.op("olympus.make_channel")
            .attr("encapsulatedType", Type::int(32))
            .attr("paramType", "stream")
            .attr("depth", 20i64)
            .result(Type::channel_of(Type::int(32)))
            .build();
        let text = print_module(&m);
        assert_eq!(
            text.trim(),
            "%0 = \"olympus.make_channel\"() {depth = 20, encapsulatedType = i32, paramType = \"stream\"} : () -> (!olympus.channel<i32>)"
        );
    }

    #[test]
    fn prints_operands_and_results() {
        let mut m = Module::new();
        let mut b = OpBuilder::new(&mut m);
        let (_, ch) = b
            .op("olympus.make_channel")
            .result(Type::channel_of(Type::int(64)))
            .build();
        b.op("olympus.pc").operand(ch[0]).attr("id", 0i64).build();
        let text = print_module(&m);
        assert!(text.contains("\"olympus.pc\"(%0) {id = 0} : (!olympus.channel<i64>) -> ()"));
    }

    #[test]
    fn deterministic() {
        let mut m = Module::new();
        let mut b = OpBuilder::new(&mut m);
        for i in 0..5 {
            b.op("olympus.make_channel")
                .attr("depth", i as i64)
                .result(Type::channel_of(Type::int(32)))
                .build();
        }
        assert_eq!(print_module(&m), print_module(&m));
    }
}
