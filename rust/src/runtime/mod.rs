//! Kernel execution runtime, driven by `artifacts/manifest.json` (written
//! by `python/compile/aot.py`).
//!
//! Kernel compute units in the platform simulator call
//! [`KernelRegistry::execute`] with the `callee` attribute of their
//! `olympus.kernel` op; python never runs at this point. By default kernels
//! execute on an in-tree native backend whose semantics mirror the
//! pure-jnp oracles in `python/compile/kernels/ref.py`; the opt-in `pjrt`
//! cargo feature swaps in the real PJRT CPU client, the only place the
//! `xla` crate is touched.

mod pjrt;
mod registry;

pub use pjrt::{CompiledKernel, PjrtRuntime};
pub use registry::{KernelManifest, KernelRegistry, ManifestEntry};
