//! PJRT runtime: loads AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client.
//!
//! This is the only place the `xla` crate is touched. Kernel compute units in
//! the platform simulator call [`KernelRegistry::execute`] with the `callee`
//! attribute of their `olympus.kernel` op; python never runs at this point.

mod pjrt;
mod registry;

pub use pjrt::{CompiledKernel, PjrtRuntime};
pub use registry::{KernelManifest, KernelRegistry, ManifestEntry};
