//! Thin wrapper over the `xla` crate: PJRT CPU client, HLO-text loading,
//! compile-once/execute-many. Mirrors /opt/xla-example/load_hlo.rs.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

/// A compiled, ready-to-run kernel executable.
pub struct CompiledKernel {
    exe: xla::PjRtLoadedExecutable,
    /// Name the kernel was registered under (the `callee` attribute).
    pub name: String,
}

impl CompiledKernel {
    /// Execute with f32 input buffers; returns the flat f32 outputs.
    ///
    /// All our AOT artifacts are lowered with `return_tuple=True`, so the
    /// single result literal is a tuple; each element is returned flattened.
    pub fn execute_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .with_context(|| format!("reshape input for kernel {}", self.name))?;
            lits.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let elems = result.to_tuple()?;
        let mut out = Vec::with_capacity(elems.len());
        for e in elems {
            out.push(e.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// PJRT CPU runtime holding the client and a cache of compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<CompiledKernel>>>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Human-readable platform string, e.g. `"cpu"`.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it, caching by `name`.
    pub fn load_hlo_text(
        &self,
        name: &str,
        path: &Path,
    ) -> Result<std::sync::Arc<CompiledKernel>> {
        if let Some(k) = self.cache.lock().unwrap().get(name) {
            return Ok(k.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile kernel '{name}'"))?;
        let k = std::sync::Arc::new(CompiledKernel { exe, name: name.to_string() });
        self.cache.lock().unwrap().insert(name.to_string(), k.clone());
        Ok(k)
    }
}
