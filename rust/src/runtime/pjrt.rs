//! Kernel execution backends.
//!
//! Two interchangeable implementations behind one API:
//!
//! * **`pjrt` feature on** — thin wrapper over the `xla` crate: PJRT CPU
//!   client, HLO-text loading, compile-once/execute-many. Requires the
//!   un-vendored `xla` dependency plus `make artifacts`.
//! * **default (offline)** — a native interpreter for the kernel families
//!   shipped in `artifacts/manifest.json`. Semantics mirror the pure-jnp
//!   oracles in `python/compile/kernels/ref.py` exactly, so the simulator's
//!   functional plane stays a correctness signal without any foreign
//!   runtime. Kernels are resolved by `callee` name (`vecadd_1024`,
//!   `jacobi2d_64_x4`, ...); the HLO artifact files are not read.

#[cfg(feature = "pjrt")]
mod backend {
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::Mutex;

    use anyhow::{Context, Result};

    /// A compiled, ready-to-run kernel executable.
    pub struct CompiledKernel {
        exe: xla::PjRtLoadedExecutable,
        /// Name the kernel was registered under (the `callee` attribute).
        pub name: String,
    }

    impl CompiledKernel {
        /// Execute with f32 input buffers; returns the flat f32 outputs.
        ///
        /// All our AOT artifacts are lowered with `return_tuple=True`, so the
        /// single result literal is a tuple; each element is returned flattened.
        pub fn execute_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            let mut lits = Vec::with_capacity(inputs.len());
            for (data, shape) in inputs {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .with_context(|| format!("reshape input for kernel {}", self.name))?;
                lits.push(lit);
            }
            let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
            let elems = result.to_tuple()?;
            let mut out = Vec::with_capacity(elems.len());
            for e in elems {
                out.push(e.to_vec::<f32>()?);
            }
            Ok(out)
        }
    }

    /// PJRT CPU runtime holding the client and a cache of compiled executables.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<String, std::sync::Arc<CompiledKernel>>>,
    }

    impl PjrtRuntime {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Self { client, cache: Mutex::new(HashMap::new()) })
        }

        /// Human-readable platform string, e.g. `"cpu"`.
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact and compile it, caching by `name`.
        pub fn load_hlo_text(
            &self,
            name: &str,
            path: &Path,
        ) -> Result<std::sync::Arc<CompiledKernel>> {
            if let Some(k) = self.cache.lock().unwrap().get(name) {
                return Ok(k.clone());
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("PJRT compile kernel '{name}'"))?;
            let k = std::sync::Arc::new(CompiledKernel { exe, name: name.to_string() });
            self.cache.lock().unwrap().insert(name.to_string(), k.clone());
            Ok(k)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::{Arc, Mutex};

    use anyhow::{bail, Context, Result};

    /// Kernel families the native backend understands (python/compile/model.py
    /// VARIANTS, shape-polymorphic where PJRT executables are monomorphic).
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    enum KernelKind {
        /// `c = a + b`
        VecAdd,
        /// `y' = alpha[0] * x + y`
        Saxpy,
        /// `y = x * scale[0] + offset[0]`
        ScaleOffset,
        /// `[sum(a * b)]`
        Dot,
        /// `[sum(x where x > t[0]), count(x > t[0])]`
        FilterSum,
        /// 5-point Jacobi relaxation sweeps over an (N, N) grid.
        Jacobi2d { sweeps: u32 },
        /// `(M, K) x (K, N)` matmul, f32 accumulation.
        MatMul,
    }

    fn resolve(name: &str) -> Result<KernelKind> {
        let kind = if name.starts_with("vecadd") {
            KernelKind::VecAdd
        } else if name.starts_with("saxpy") {
            KernelKind::Saxpy
        } else if name.starts_with("scale_offset") {
            KernelKind::ScaleOffset
        } else if name.starts_with("dot") {
            KernelKind::Dot
        } else if name.starts_with("filter_sum") {
            KernelKind::FilterSum
        } else if name.starts_with("jacobi2d") {
            // fused-sweep variants carry an `_x<N>` suffix (jacobi2d_64_x4)
            let sweeps = name
                .rsplit("_x")
                .next()
                .filter(|_| name.contains("_x"))
                .and_then(|s| s.parse::<u32>().ok())
                .unwrap_or(1);
            KernelKind::Jacobi2d { sweeps }
        } else if name.starts_with("matmul") {
            KernelKind::MatMul
        } else {
            bail!("native kernel backend: unknown kernel family for '{name}'")
        };
        Ok(kind)
    }

    fn jacobi_sweep(grid: &[f32], n: usize) -> Vec<f32> {
        let mut out = grid.to_vec();
        for i in 1..n.saturating_sub(1) {
            for j in 1..n - 1 {
                out[i * n + j] = 0.25
                    * (grid[(i - 1) * n + j]
                        + grid[(i + 1) * n + j]
                        + grid[i * n + j - 1]
                        + grid[i * n + j + 1]);
            }
        }
        out
    }

    /// A resolved, ready-to-run kernel (native interpreter).
    pub struct CompiledKernel {
        kind: KernelKind,
        /// Name the kernel was registered under (the `callee` attribute).
        pub name: String,
    }

    impl CompiledKernel {
        /// Execute with f32 input buffers; returns the flat f32 outputs.
        /// Matches the PJRT backend's contract: one `Vec<f32>` per result.
        pub fn execute_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            let need = |n: usize| -> Result<()> {
                if inputs.len() != n {
                    bail!("kernel '{}': got {} inputs, want {n}", self.name, inputs.len());
                }
                Ok(())
            };
            match self.kind {
                KernelKind::VecAdd => {
                    need(2)?;
                    let (a, b) = (inputs[0].0, inputs[1].0);
                    if a.len() != b.len() {
                        bail!("kernel '{}': input length mismatch", self.name);
                    }
                    Ok(vec![a.iter().zip(b).map(|(x, y)| x + y).collect()])
                }
                KernelKind::Saxpy => {
                    need(3)?;
                    let alpha = *inputs[0].0.first().context("saxpy: empty alpha")?;
                    let (x, y) = (inputs[1].0, inputs[2].0);
                    if x.len() != y.len() {
                        bail!("kernel '{}': input length mismatch", self.name);
                    }
                    Ok(vec![x.iter().zip(y).map(|(a, b)| alpha * a + b).collect()])
                }
                KernelKind::ScaleOffset => {
                    need(3)?;
                    let x = inputs[0].0;
                    let s = *inputs[1].0.first().context("scale_offset: empty scale")?;
                    let o = *inputs[2].0.first().context("scale_offset: empty offset")?;
                    Ok(vec![x.iter().map(|v| v * s + o).collect()])
                }
                KernelKind::Dot => {
                    need(2)?;
                    let (a, b) = (inputs[0].0, inputs[1].0);
                    if a.len() != b.len() {
                        bail!("kernel '{}': input length mismatch", self.name);
                    }
                    let s: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
                    Ok(vec![vec![s]])
                }
                KernelKind::FilterSum => {
                    need(2)?;
                    let x = inputs[0].0;
                    let t = *inputs[1].0.first().context("filter_sum: empty threshold")?;
                    let mut s = 0.0f32;
                    let mut c = 0.0f32;
                    for &v in x {
                        if v > t {
                            s += v;
                            c += 1.0;
                        }
                    }
                    Ok(vec![vec![s, c]])
                }
                KernelKind::Jacobi2d { sweeps } => {
                    need(1)?;
                    let shape = inputs[0].1;
                    let n = if shape.len() == 2 && shape[0] == shape[1] {
                        shape[0]
                    } else {
                        // flat buffer: infer a square grid
                        let n = (inputs[0].0.len() as f64).sqrt() as usize;
                        if n * n != inputs[0].0.len() {
                            bail!("kernel '{}': non-square grid", self.name);
                        }
                        n
                    };
                    let mut g = inputs[0].0.to_vec();
                    for _ in 0..sweeps.max(1) {
                        g = jacobi_sweep(&g, n);
                    }
                    Ok(vec![g])
                }
                KernelKind::MatMul => {
                    need(2)?;
                    let (a, sa) = inputs[0];
                    let (b, sb) = inputs[1];
                    let (m, k) = match sa {
                        [m, k] => (*m, *k),
                        _ => bail!("kernel '{}': lhs is not 2-D", self.name),
                    };
                    let (k2, n) = match sb {
                        [k2, n] => (*k2, *n),
                        _ => bail!("kernel '{}': rhs is not 2-D", self.name),
                    };
                    if k != k2 || a.len() != m * k || b.len() != k * n {
                        bail!("kernel '{}': shape mismatch ({m}x{k}) x ({k2}x{n})", self.name);
                    }
                    let mut out = vec![0.0f32; m * n];
                    for i in 0..m {
                        for kk in 0..k {
                            let av = a[i * k + kk];
                            let row = &b[kk * n..(kk + 1) * n];
                            let dst = &mut out[i * n..(i + 1) * n];
                            for (d, bv) in dst.iter_mut().zip(row) {
                                *d += av * bv;
                            }
                        }
                    }
                    Ok(vec![out])
                }
            }
        }
    }

    /// Native stand-in for the PJRT CPU runtime: resolves kernels by name,
    /// caching the resolution. The artifact path is accepted (same call
    /// shape as the PJRT backend) but never read.
    pub struct PjrtRuntime {
        cache: Mutex<HashMap<String, Arc<CompiledKernel>>>,
    }

    impl PjrtRuntime {
        /// Create the native CPU backend (infallible; kept `Result` for
        /// call-site compatibility with the PJRT backend).
        pub fn cpu() -> Result<Self> {
            Ok(Self { cache: Mutex::new(HashMap::new()) })
        }

        /// Human-readable platform string.
        pub fn platform(&self) -> String {
            "native-cpu".to_string()
        }

        /// Resolve kernel `name` to a native implementation, caching by name.
        pub fn load_hlo_text(&self, name: &str, _path: &Path) -> Result<Arc<CompiledKernel>> {
            if let Some(k) = self.cache.lock().unwrap().get(name) {
                return Ok(k.clone());
            }
            let kind = resolve(name)?;
            let k = Arc::new(CompiledKernel { kind, name: name.to_string() });
            self.cache.lock().unwrap().insert(name.to_string(), k.clone());
            Ok(k)
        }
    }
}

pub use backend::{CompiledKernel, PjrtRuntime};

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;
    use std::path::Path;

    fn exec(name: &str, inputs: &[(&[f32], &[usize])]) -> Vec<Vec<f32>> {
        let rt = PjrtRuntime::cpu().unwrap();
        let k = rt.load_hlo_text(name, Path::new("unused")).unwrap();
        k.execute_f32(inputs).unwrap()
    }

    #[test]
    fn vecadd_adds() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [10.0f32, 20.0, 30.0];
        let out = exec("vecadd_1024", &[(&a, &[3]), (&b, &[3])]);
        assert_eq!(out, vec![vec![11.0, 22.0, 33.0]]);
    }

    #[test]
    fn saxpy_and_scale_offset() {
        let alpha = [2.0f32];
        let x = [1.0f32, 2.0];
        let y = [3.0f32, 4.0];
        let out = exec("saxpy_1024", &[(&alpha, &[1]), (&x, &[2]), (&y, &[2])]);
        assert_eq!(out[0], vec![5.0, 8.0]);
        let s = [3.0f32];
        let o = [1.0f32];
        let out = exec("scale_offset_1024", &[(&x, &[2]), (&s, &[1]), (&o, &[1])]);
        assert_eq!(out[0], vec![4.0, 7.0]);
    }

    #[test]
    fn dot_and_filter_sum_reduce() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 5.0, 6.0];
        assert_eq!(exec("dot_1024", &[(&a, &[3]), (&b, &[3])])[0], vec![32.0]);
        let t = [1.5f32];
        let out = exec("filter_sum_1024", &[(&a, &[3]), (&t, &[1])]);
        assert_eq!(out[0], vec![5.0, 2.0]);
    }

    #[test]
    fn jacobi_interior_average_boundary_passthrough() {
        let n = 4usize;
        let g: Vec<f32> = (0..n * n).map(|i| i as f32).collect();
        let out = exec("jacobi2d_64", &[(&g, &[n, n])]);
        let o = &out[0];
        for j in 0..n {
            assert_eq!(o[j], g[j]);
            assert_eq!(o[(n - 1) * n + j], g[(n - 1) * n + j]);
        }
        let want = 0.25 * (g[1] + g[9] + g[4] + g[6]);
        assert!((o[5] - want).abs() < 1e-6);
    }

    #[test]
    fn jacobi_x4_is_four_sweeps() {
        let n = 4usize;
        let g: Vec<f32> = (0..n * n).map(|i| (i as f32).sin()).collect();
        let one = exec("jacobi2d_64", &[(&g, &[n, n])]);
        let twice = exec("jacobi2d_64", &[(&one[0], &[n, n])]);
        let thrice = exec("jacobi2d_64", &[(&twice[0], &[n, n])]);
        let four = exec("jacobi2d_64", &[(&thrice[0], &[n, n])]);
        let fused = exec("jacobi2d_64_x4", &[(&g, &[n, n])]);
        for (a, b) in fused[0].iter().zip(&four[0]) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let a = [1.0f32, 2.0, 3.0, 4.0]; // 2x2
        let b = [5.0f32, 6.0, 7.0, 8.0];
        let out = exec("matmul_128", &[(&a, &[2, 2]), (&b, &[2, 2])]);
        assert_eq!(out[0], vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn unknown_family_rejected() {
        let rt = PjrtRuntime::cpu().unwrap();
        assert!(rt.load_hlo_text("fancy_fft_1024", Path::new("unused")).is_err());
    }
}
