//! Kernel registry: maps `callee` names from `olympus.kernel` ops to
//! compiled PJRT executables, driven by `artifacts/manifest.json`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::util::Json;

use super::pjrt::{CompiledKernel, PjrtRuntime};

/// One entry of `artifacts/manifest.json` (written by python/compile/aot.py).
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    /// Kernel name == the `callee` attribute value it serves.
    pub name: String,
    /// HLO text file, relative to the manifest's directory.
    pub hlo: String,
    /// Input shapes (row-major), one per operand.
    pub input_shapes: Vec<Vec<usize>>,
    /// Output shapes, one per result.
    pub output_shapes: Vec<Vec<usize>>,
    /// Element dtype (always "f32" in this build).
    pub dtype: String,
}

impl ManifestEntry {
    fn from_json(v: &Json) -> Result<Self> {
        let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
            v.get(key)
                .as_arr()
                .context("shapes not an array")?
                .iter()
                .map(|s| {
                    s.as_arr()
                        .context("shape not an array")?
                        .iter()
                        .map(|d| d.as_usize().context("dim not a usize"))
                        .collect()
                })
                .collect()
        };
        Ok(ManifestEntry {
            name: v.get("name").as_str().context("missing name")?.to_string(),
            hlo: v.get("hlo").as_str().context("missing hlo")?.to_string(),
            input_shapes: shapes("input_shapes")?,
            output_shapes: shapes("output_shapes")?,
            dtype: v.get("dtype").as_str().unwrap_or("f32").to_string(),
        })
    }

    /// Total f32 element count of one input.
    pub fn input_len(&self, i: usize) -> usize {
        self.input_shapes[i].iter().product()
    }

    /// Total f32 element count of one output.
    pub fn output_len(&self, i: usize) -> usize {
        self.output_shapes[i].iter().product()
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct KernelManifest {
    pub kernels: Vec<ManifestEntry>,
}

impl KernelManifest {
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text).context("manifest.json is not valid JSON")?;
        let kernels = v
            .get("kernels")
            .as_arr()
            .context("manifest.json missing 'kernels' array")?
            .iter()
            .map(ManifestEntry::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(KernelManifest { kernels })
    }
}

/// Registry of AOT kernels, lazily compiled on first use.
pub struct KernelRegistry {
    runtime: Arc<PjrtRuntime>,
    root: PathBuf,
    entries: HashMap<String, ManifestEntry>,
}

impl KernelRegistry {
    /// Load `manifest.json` from `root` (usually `artifacts/`).
    pub fn load(runtime: Arc<PjrtRuntime>, root: &Path) -> Result<Self> {
        let manifest_path = root.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {}", manifest_path.display()))?;
        let manifest = KernelManifest::parse(&text)
            .with_context(|| format!("parse {}", manifest_path.display()))?;
        let mut entries = HashMap::new();
        for e in manifest.kernels {
            entries.insert(e.name.clone(), e);
        }
        Ok(Self { runtime, root: root.to_path_buf(), entries })
    }

    /// Kernel names available in the manifest.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    /// Manifest metadata for `name`.
    pub fn entry(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.get(name)
    }

    /// Compile (or fetch cached) and return the executable for `name`.
    pub fn get(&self, name: &str) -> Result<Arc<CompiledKernel>> {
        let Some(e) = self.entries.get(name) else {
            bail!("kernel '{name}' not in manifest (have: {:?})", self.names())
        };
        self.runtime.load_hlo_text(name, &self.root.join(&e.hlo))
    }

    /// Execute kernel `name` on flat f32 inputs using the manifest shapes.
    pub fn execute(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let e = self
            .entries
            .get(name)
            .with_context(|| format!("kernel '{name}' not in manifest"))?
            .clone();
        if inputs.len() != e.input_shapes.len() {
            bail!(
                "kernel '{name}': got {} inputs, manifest expects {}",
                inputs.len(),
                e.input_shapes.len()
            );
        }
        for (i, (data, shape)) in inputs.iter().zip(e.input_shapes.iter()).enumerate() {
            let want: usize = shape.iter().product();
            if data.len() != want {
                bail!("kernel '{name}' input {i}: got {} elems, expected {want}", data.len());
            }
        }
        let k = self.get(name)?;
        let args: Vec<(&[f32], &[usize])> = inputs
            .iter()
            .zip(e.input_shapes.iter())
            .map(|(d, s)| (*d, s.as_slice()))
            .collect();
        k.execute_f32(&args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = KernelManifest::parse(
            r#"{"kernels": [{"name": "k", "hlo": "k.hlo.txt",
                "input_shapes": [[4], [4]], "output_shapes": [[4]], "dtype": "f32"}]}"#,
        )
        .unwrap();
        assert_eq!(m.kernels.len(), 1);
        assert_eq!(m.kernels[0].name, "k");
        assert_eq!(m.kernels[0].input_len(0), 4);
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(KernelManifest::parse("{}").is_err());
        assert!(KernelManifest::parse(r#"{"kernels": [{"name": "k"}]}"#).is_err());
    }
}
