//! The Iris data-layout algorithm (Soldavini, Sciuto & Pilato, ASPDAC'23 —
//! paper reference [14]): packs multiple arrays onto a single wide bus by
//! chunking and interleaving them, so that nearly every bit of every beat
//! carries payload.
//!
//! The paper quotes >95% bandwidth efficiency for Iris layouts vs ~45% for
//! naive (one array per padded word) layouts; `benches/bench_iris.rs`
//! regenerates that comparison.

mod packing;

pub use packing::{pack, ArraySpec, BusPlan, Packing};
