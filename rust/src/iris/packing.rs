//! Iris packing: unit-granular chunking + interleaved layout construction.
//!
//! Model: let `g = gcd(word_bits, elem_bits...)` be the *chunk* granularity.
//! Every array is a stream of g-bit units (`units_i = n_i * b_i / g`); a bus
//! word holds `cap = word_bits / g` units. Array `i` receives `u_i >= 1`
//! unit slots per word and its units stream round-robin through them, so it
//! finishes after `ceil(units_i / u_i)` words and the bus needs
//! `words = max_i ceil(units_i / u_i)` beats for
//! `sum_i n_i * b_i` useful bits:
//!
//! `efficiency = sum(n_i*b_i) / (words * word_bits)`.
//!
//! Splitting an element across multiple unit slots (or across consecutive
//! words) is exactly the "array broken up to achieve the most compact
//! result" of the paper's Fig 8 — the generated adapters reassemble
//! elements on the kernel side. With unit granularity the packer reaches
//! ~100% efficiency minus end-of-stream tails, which is where the paper's
//! ">95% vs ~45% naive" claim comes from (`benches/bench_iris.rs`).
//!
//! Buses hold at most `cap` members (each member needs >= 1 slot); larger
//! groups spill to additional buses, balanced by unit count.

use crate::dialect::{Layout, LayoutField};

/// One array to pack.
#[derive(Debug, Clone, PartialEq)]
pub struct ArraySpec {
    pub name: String,
    pub elem_bits: u32,
    pub num_elems: u64,
}

impl ArraySpec {
    pub fn new(name: &str, elem_bits: u32, num_elems: u64) -> Self {
        ArraySpec { name: name.to_string(), elem_bits, num_elems }
    }

    pub fn total_bits(&self) -> u64 {
        self.elem_bits as u64 * self.num_elems
    }
}

/// One bus produced by the packer.
#[derive(Debug, Clone)]
pub struct BusPlan {
    /// Members (indices into the input array list).
    pub members: Vec<usize>,
    /// Steady-state unit slots per word per member (parallel to `members`).
    /// The unit is `gcd`-bits wide; a member whose element is wider than one
    /// unit is split across its slots / consecutive words, and slots are
    /// time-multiplexed between members once one drains (see [`plan_bus`]).
    pub slots: Vec<u32>,
    /// Chunk granularity in bits.
    pub unit_bits: u32,
    /// Words (beats) this bus needs.
    pub words: u64,
    /// The interleaved layout (field `array` names are `"<name>.<k>"` when
    /// an array holds several slots, like the paper's Fig 8b).
    pub layout: Layout,
}

impl BusPlan {
    /// Useful bits over capacity for the whole transfer.
    pub fn efficiency(&self, arrays: &[ArraySpec]) -> f64 {
        let useful: u64 = self.members.iter().map(|&i| arrays[i].total_bits()).sum();
        let cap = self.words * self.layout.word_bits as u64;
        if cap == 0 {
            0.0
        } else {
            useful as f64 / cap as f64
        }
    }
}

/// Full packing result.
#[derive(Debug, Clone)]
pub struct Packing {
    pub buses: Vec<BusPlan>,
    pub word_bits: u32,
}

impl Packing {
    /// Aggregate efficiency across buses (beat-weighted).
    pub fn efficiency(&self, arrays: &[ArraySpec]) -> f64 {
        let useful: u64 = arrays.iter().map(|a| a.total_bits()).sum();
        let cap: u64 = self.buses.iter().map(|b| b.words * self.word_bits as u64).sum();
        if cap == 0 {
            0.0
        } else {
            useful as f64 / cap as f64
        }
    }

    /// Total beats across buses (proxy for transfer time on one PC).
    pub fn total_words(&self) -> u64 {
        self.buses.iter().map(|b| b.words).sum()
    }
}

fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Plan one bus. The bus needs `words = ceil(total_units / cap)` beats: the
/// Iris adapters time-multiplex slots across words (an array that exhausts
/// its share frees its slots for the others — the per-word placement varies
/// over the stream, which is how the real tool reaches ~100% occupancy).
/// The recorded layout is the steady-state template: a largest-remainder
/// apportionment of the `cap` slots proportional to each member's units.
fn plan_bus(arrays: &[ArraySpec], members: Vec<usize>, word_bits: u32, unit_bits: u32) -> BusPlan {
    let cap = (word_bits / unit_bits) as u64;
    debug_assert!(members.len() as u64 <= cap);
    let units: Vec<u64> = members
        .iter()
        .map(|&i| (arrays[i].total_bits()).div_ceil(unit_bits as u64))
        .collect();
    let total: u64 = units.iter().sum();
    let words = total.div_ceil(cap).max(1);

    // largest-remainder apportionment of `cap` slots, each member >= 1
    let mut slots: Vec<u64> = units.iter().map(|&u| (u * cap / total).max(1)).collect();
    while slots.iter().sum::<u64>() > cap {
        // over-allocated by the `.max(1)` floors: trim the largest
        let i = (0..slots.len()).max_by_key(|&i| slots[i]).unwrap();
        slots[i] -= 1;
    }
    while slots.iter().sum::<u64>() < cap {
        // hand leftover slots to the largest fractional remainder
        let i = (0..slots.len())
            .max_by_key(|&i| units[i] * cap % total)
            .unwrap_or(0);
        slots[i] += 1;
    }
    let slots: Vec<u32> = slots.iter().map(|&s| s as u32).collect();

    // layout fields: one g-bit field per slot, named `name` (single slot) or
    // `name.k` (split across k slots)
    let mut fields = Vec::new();
    let mut offset = 0u32;
    for (mi, &ai) in members.iter().enumerate() {
        let a = &arrays[ai];
        for k in 0..slots[mi] {
            let array =
                if slots[mi] == 1 { a.name.clone() } else { format!("{}.{k}", a.name) };
            fields.push(LayoutField { array, elem_bits: unit_bits, count: 1, offset_bits: offset });
            offset += unit_bits;
        }
    }
    let layout = Layout { word_bits, depth: words.max(1), lanes: 1, fields };
    BusPlan { members, slots, unit_bits, words, layout }
}

/// Pack `arrays` onto buses of `word_bits`. Arrays wider than the word are
/// rejected (`None`) — the caller routes those as `complex` traffic instead.
pub fn pack(arrays: &[ArraySpec], word_bits: u32) -> Option<Packing> {
    if arrays.is_empty() {
        return Some(Packing { buses: Vec::new(), word_bits });
    }
    if arrays.iter().any(|a| a.elem_bits == 0 || a.elem_bits > word_bits || a.num_elems == 0) {
        return None;
    }
    let mut g = word_bits as u64;
    for a in arrays {
        g = gcd(g, a.elem_bits as u64);
    }
    let unit_bits = g as u32;
    let cap = (word_bits as u64 / g) as usize;

    // spill: at most `cap` members per bus; balance by unit count
    let mut order: Vec<usize> = (0..arrays.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(arrays[i].total_bits()));
    let n_buses = arrays.len().div_ceil(cap);
    let mut bins: Vec<(Vec<usize>, u64)> = vec![(Vec::new(), 0); n_buses];
    for i in order {
        // emptiest bin with member space
        let bin = bins
            .iter_mut()
            .filter(|(m, _)| m.len() < cap)
            .min_by_key(|(_, load)| *load)
            .expect("n_buses sized to fit all members");
        bin.0.push(i);
        bin.1 += arrays[i].total_bits();
    }
    let buses = bins
        .into_iter()
        .filter(|(m, _)| !m.is_empty())
        .map(|(members, _)| plan_bus(arrays, members, word_bits, unit_bits))
        .collect();
    Some(Packing { buses, word_bits })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_two_arrays_on_128() {
        // paper Fig 8: a and b interleaved on a 128-bit bus, with b split to
        // fill the word. b has 3x the elements of a:
        let arrays = vec![ArraySpec::new("a", 32, 256), ArraySpec::new("b", 32, 768)];
        let p = pack(&arrays, 128).unwrap();
        assert_eq!(p.buses.len(), 1);
        let bus = &p.buses[0];
        // b gets 3 slots (b.0..b.2), a gets 1 -> both finish in 256 words
        assert_eq!(bus.words, 256);
        assert!((bus.efficiency(&arrays) - 1.0).abs() < 1e-9);
        let names: Vec<&str> = bus.layout.fields.iter().map(|f| f.array.as_str()).collect();
        assert!(names.contains(&"a"));
        assert!(names.contains(&"b.0") && names.contains(&"b.2"));
        assert!(bus.layout.is_valid());
    }

    #[test]
    fn equal_arrays_fill_word() {
        // 8 x 32-bit arrays of equal length on a 256-bit bus: perfect fit
        let arrays: Vec<_> =
            (0..8).map(|i| ArraySpec::new(&format!("x{i}"), 32, 1024)).collect();
        let p = pack(&arrays, 256).unwrap();
        assert_eq!(p.buses.len(), 1);
        assert!((p.efficiency(&arrays) - 1.0).abs() < 1e-9);
        assert_eq!(p.buses[0].words, 1024);
    }

    #[test]
    fn single_narrow_array_gets_split_slots() {
        // one 32-bit array on a 256-bit bus: Iris gives it all 8 slots
        let arrays = vec![ArraySpec::new("a", 32, 4096)];
        let p = pack(&arrays, 256).unwrap();
        assert_eq!(p.buses[0].slots, vec![8]);
        assert_eq!(p.buses[0].words, 512);
        assert!((p.efficiency(&arrays) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mixed_widths_beat_95_percent() {
        // the paper's headline: mixed-width struct-of-arrays >95% efficient
        let arrays = vec![
            ArraySpec::new("pos", 64, 10_000),
            ArraySpec::new("vel", 64, 10_000),
            ArraySpec::new("rho", 32, 10_000),
            ArraySpec::new("flags", 16, 10_000),
            ArraySpec::new("idx", 48, 10_000),
        ];
        let p = pack(&arrays, 256).unwrap();
        let e = p.efficiency(&arrays);
        assert!(e > 0.95, "expected >95% (paper claim), got {e}");
    }

    #[test]
    fn odd_single_array_is_dense() {
        // naive: a 112-bit struct padded into 256-bit words -> 43.75%.
        // Iris chunks it (gcd(112, 256) = 16) and fills the word.
        let arrays = vec![ArraySpec::new("s", 112, 4096)];
        let p = pack(&arrays, 256).unwrap();
        let e = p.efficiency(&arrays);
        assert!(e > 0.99, "got {e}");
        let naive = 112.0 / 256.0;
        assert!(e / naive > 2.2, "iris >2x naive on this shape");
    }

    #[test]
    fn oversize_elem_rejected() {
        assert!(pack(&[ArraySpec::new("big", 512, 4)], 256).is_none());
        assert!(pack(&[ArraySpec::new("z", 0, 4)], 256).is_none());
        assert!(pack(&[ArraySpec::new("e", 32, 0)], 256).is_none());
    }

    #[test]
    fn spills_when_members_exceed_capacity() {
        // 20 x 32-bit arrays, 256-bit word -> 8 slots/word -> 3 buses
        let arrays: Vec<_> =
            (0..20).map(|i| ArraySpec::new(&format!("w{i}"), 32, 100)).collect();
        let p = pack(&arrays, 256).unwrap();
        assert_eq!(p.buses.len(), 3);
        let total_members: usize = p.buses.iter().map(|b| b.members.len()).sum();
        assert_eq!(total_members, 20);
        for b in &p.buses {
            assert!(b.members.len() <= 8);
            assert!(b.layout.is_valid());
        }
    }

    #[test]
    fn tail_waste_shrinks_with_length() {
        // efficiency loss is only the end-of-stream tail; longer arrays are
        // asymptotically perfect
        let short = vec![ArraySpec::new("a", 48, 10)];
        let long = vec![ArraySpec::new("a", 48, 100_000)];
        let es = pack(&short, 256).unwrap().efficiency(&short);
        let el = pack(&long, 256).unwrap().efficiency(&long);
        assert!(el >= es);
        assert!(el > 0.999, "got {el}");
    }

    #[test]
    fn layouts_always_valid_and_within_word() {
        use crate::util::{prop, Rng};
        prop::check("iris-layout-valid", 60, 12, |rng: &mut Rng, size| {
            let n = 1 + rng.range(0, size.max(1));
            let arrays: Vec<ArraySpec> = (0..n)
                .map(|i| {
                    ArraySpec::new(
                        &format!("a{i}"),
                        *rng.pick(&[8u32, 16, 24, 32, 48, 64, 96, 128]),
                        rng.range(1, 10_000) as u64,
                    )
                })
                .collect();
            let p = pack(&arrays, 256).ok_or("pack failed on valid input")?;
            // every array appears exactly once across buses
            let mut seen = vec![false; arrays.len()];
            for b in &p.buses {
                if !b.layout.is_valid() {
                    return Err(format!("invalid layout {:?}", b.layout));
                }
                for &mi in &b.members {
                    if seen[mi] {
                        return Err(format!("array {mi} packed twice"));
                    }
                    seen[mi] = true;
                }
                // total bus capacity covers the members' total units, and
                // every member owns at least one template slot
                let total_units: u64 = b
                    .members
                    .iter()
                    .map(|&mi| arrays[mi].total_bits().div_ceil(b.unit_bits as u64))
                    .sum();
                let word_cap = (b.layout.word_bits / b.unit_bits) as u64;
                if b.words * word_cap < total_units {
                    return Err("bus undersized for its members".into());
                }
                if b.slots.iter().any(|&s| s == 0) {
                    return Err("member with zero template slots".into());
                }
                // overall efficiency is sane
                let e = b.efficiency(&arrays);
                if !(0.0..=1.0 + 1e-9).contains(&e) {
                    return Err(format!("efficiency out of range: {e}"));
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err("array lost in packing".into());
            }
            Ok(())
        });
    }
}
