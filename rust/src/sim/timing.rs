//! Timing model: beat/cycle accounting + congestion derating.

use crate::platform::PlatformSpec;

/// Routing-congestion clock derate (paper §V-B: "a high degree of
/// replication reaching near 100% utilization of a resource induces routing
/// congestion and therefore a longer critical path").
///
/// Piecewise-linear: full clock up to 70% utilization, then a linear fall
/// to 72% of the nominal clock at 100% — calibrated to the commonly
/// reported 20–30% Fmax drop of near-full UltraScale+ designs.
pub fn congestion_derate(utilization: f64) -> f64 {
    const KNEE: f64 = 0.70;
    const FLOOR: f64 = 0.72;
    if utilization <= KNEE {
        1.0
    } else {
        let t = ((utilization - KNEE) / (1.0 - KNEE)).min(1.0);
        1.0 - t * (1.0 - FLOOR)
    }
}

/// Analytic timing over a run's beat/cycle tallies.
#[derive(Debug, Clone)]
pub struct TimingModel {
    pub kernel_mhz: f64,
    pub effective_mhz: f64,
}

impl TimingModel {
    pub fn new(plat: &PlatformSpec, utilization: f64, congestion: bool) -> Self {
        let derate = if congestion { congestion_derate(utilization) } else { 1.0 };
        TimingModel { kernel_mhz: plat.kernel_mhz, effective_mhz: plat.kernel_mhz * derate }
    }

    /// HLS pipeline time: latency + (elems-1) * II cycles at the effective
    /// kernel clock.
    pub fn cu_time_s(&self, latency: u64, ii: u64, elems: u64) -> (u64, f64) {
        let cycles = latency + elems.saturating_sub(1) * ii;
        (cycles, cycles as f64 / (self.effective_mhz * 1e6))
    }

    /// Memory channel transfer time for `beats` on channel `pc_id`.
    pub fn pc_time_s(&self, plat: &PlatformSpec, pc_id: u32, beats: u64) -> f64 {
        let spec = &plat.pcs[pc_id as usize];
        beats as f64 / (spec.freq_mhz * 1e6)
    }

    /// Seconds per kernel-clock cycle at the (derated) effective clock.
    pub fn cycle_s(&self) -> f64 {
        1.0 / (self.effective_mhz * 1e6)
    }

    /// Steady-state service time for one `elems`-element chunk through an
    /// II-pipelined CU (no fill latency — that is charged once per job by
    /// the discrete-event simulator).
    pub fn cu_service_s(&self, ii: u64, elems: u64) -> f64 {
        (ii.max(1) * elems) as f64 * self.cycle_s()
    }

    /// Pipeline-fill time: `latency` cycles at the effective clock.
    pub fn cu_fill_s(&self, latency: u64) -> f64 {
        latency as f64 * self.cycle_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::builtin;

    #[test]
    fn derate_is_flat_then_linear() {
        assert_eq!(congestion_derate(0.0), 1.0);
        assert_eq!(congestion_derate(0.7), 1.0);
        assert!((congestion_derate(1.0) - 0.72).abs() < 1e-12);
        let mid = congestion_derate(0.85);
        assert!(mid < 1.0 && mid > 0.72);
        // monotone non-increasing
        let mut prev = 1.0;
        for i in 0..=20 {
            let d = congestion_derate(i as f64 / 20.0);
            assert!(d <= prev + 1e-12);
            prev = d;
        }
    }

    #[test]
    fn cu_time_matches_hls_formula() {
        let plat = builtin("u280").unwrap();
        let t = TimingModel::new(&plat, 0.1, true);
        let (cycles, secs) = t.cu_time_s(100, 1, 1024);
        assert_eq!(cycles, 100 + 1023);
        assert!((secs - cycles as f64 / 300e6).abs() < 1e-15);
    }

    #[test]
    fn congestion_slows_kernels() {
        let plat = builtin("u280").unwrap();
        let fast = TimingModel::new(&plat, 0.5, true);
        let slow = TimingModel::new(&plat, 0.98, true);
        assert!(slow.effective_mhz < fast.effective_mhz);
        let off = TimingModel::new(&plat, 0.98, false);
        assert_eq!(off.effective_mhz, off.kernel_mhz);
    }

    #[test]
    fn cu_service_helpers_match_cycle_math() {
        let plat = builtin("u280").unwrap();
        let t = TimingModel::new(&plat, 0.1, false);
        assert!((t.cycle_s() - 1.0 / 300e6).abs() < 1e-18);
        // II=2, 64 elems -> 128 cycles
        assert!((t.cu_service_s(2, 64) - 128.0 / 300e6).abs() < 1e-15);
        // II=0 clamps to 1
        assert!((t.cu_service_s(0, 64) - 64.0 / 300e6).abs() < 1e-15);
        assert!((t.cu_fill_s(300) - 1e-6).abs() < 1e-15);
    }

    #[test]
    fn pc_time_uses_channel_frequency() {
        let plat = builtin("u280").unwrap();
        let t = TimingModel::new(&plat, 0.1, true);
        // 450e6 beats on an HBM PC = 1 second
        let s = t.pc_time_s(&plat, 0, 450_000_000);
        assert!((s - 1.0).abs() < 1e-9);
    }
}
