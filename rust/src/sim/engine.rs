//! The simulation engine: functional data plane + timing accounting.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::{bail, Context, Result};
use fxhash::FxHashMap;

use crate::analysis::ResourceReport;
use crate::lower::{Architecture, CuInst, Endpoint, MoverDir};
use crate::runtime::KernelRegistry;

use super::metrics::{CuMetrics, PcMetrics, SimMetrics};
use super::timing::TimingModel;

/// Result of one simulated app iteration.
pub struct SimOutput {
    /// Memory-write channel name -> produced data.
    pub outputs: HashMap<String, Vec<f32>>,
    pub metrics: SimMetrics,
}

/// The simulator. Borrows the architecture and the kernel registry; host
/// buffers come in per run.
pub struct Simulator<'a> {
    pub arch: &'a Architecture,
    pub registry: &'a KernelRegistry,
    /// Apply the routing-congestion clock derate (on by default).
    pub congestion_model: bool,
    /// Resource utilization (from `analyze_resources`) for the derate.
    pub utilization: f64,
}

/// Per-CU staged output when lanes share one FIFO (merged on drain).
/// Keyed by internal indices, hashed with the keyless [`fxhash`] hasher:
/// the firing loop probes these maps per chunk, and nothing iterates them
/// without sorting first.
type LaneStage = FxHashMap<(usize, usize), Vec<f32>>; // (fifo idx, lane) -> data

/// Reusable staging buffers for [`Simulator::fire`] — the hot loop runs one
/// firing per chunk, so the per-input argument vectors are cleared and
/// refilled instead of reallocated every firing.
#[derive(Default)]
struct FireScratch {
    /// One staged input buffer per CU input slot.
    args: Vec<Vec<f32>>,
}

impl<'a> Simulator<'a> {
    pub fn new(arch: &'a Architecture, registry: &'a KernelRegistry) -> Self {
        Simulator { arch, registry, congestion_model: true, utilization: 0.0 }
    }

    pub fn with_resources(mut self, report: &ResourceReport) -> Self {
        self.utilization = report.utilization;
        self
    }

    /// Validate that every CU's callee exists in the manifest with matching
    /// arity (the "load the correct implementation" step of paper §IV).
    pub fn validate(&self) -> Result<()> {
        for cu in &self.arch.cus {
            let e = self.registry.entry(&cu.callee).with_context(|| {
                format!("CU '{}': callee '{}' not in manifest", cu.name, cu.callee)
            })?;
            if e.input_shapes.len() != cu.inputs.len() {
                bail!(
                    "CU '{}': {} wired inputs but kernel '{}' takes {}",
                    cu.name,
                    cu.inputs.len(),
                    cu.callee,
                    e.input_shapes.len()
                );
            }
            if e.output_shapes.len() != cu.outputs.len() {
                bail!(
                    "CU '{}': {} wired outputs but kernel '{}' yields {}",
                    cu.name,
                    cu.outputs.len(),
                    cu.callee,
                    e.output_shapes.len()
                );
            }
        }
        Ok(())
    }

    /// Run one app iteration.
    ///
    /// `buffers` maps logical memory-channel names (the channel `name`
    /// attributes) to host data. Read channels must be present; write
    /// channels are produced into [`SimOutput::outputs`].
    pub fn run(&self, buffers: &HashMap<String, Vec<f32>>) -> Result<SimOutput> {
        let wall0 = Instant::now();
        self.validate()?;
        let a = self.arch;

        // ---- functional: read movers fill on-chip endpoints -------------
        let mut fifos: Vec<VecDeque<f32>> = vec![VecDeque::new(); a.fifos.len()];
        let mut plms: Vec<Vec<f32>> = vec![Vec::new(); a.plms.len()];
        let mut pc_beats: FxHashMap<u32, (u64, u64)> = FxHashMap::default(); // id -> (beats, useful bits)

        for mv in &a.movers {
            if mv.dir != MoverDir::Read {
                continue;
            }
            // deliver each *base* field exactly once (split fields `x.0`,
            // `x.1` are slots of the same logical array)
            let mut delivered: Vec<&str> = Vec::new();
            for (field, ep) in &mv.routes {
                let base = field.split('.').next().unwrap_or(field);
                if delivered.contains(&base) {
                    continue;
                }
                delivered.push(base);
                let data = buffers
                    .get(base)
                    .with_context(|| format!("missing host buffer for read channel '{base}'"))?;
                match ep {
                    Endpoint::Fifo(i) => fifos[*i].extend(data.iter().copied()),
                    Endpoint::Plm(i) => plms[*i] = data.clone(),
                    Endpoint::Axi(_) => {}
                }
            }
            self.account_mover(mv, buffers, &mut pc_beats);
        }
        // AXI (complex) channels: kernels read host buffers directly
        let mut axi_data: Vec<Vec<f32>> = vec![Vec::new(); a.axi_ports.len()];
        for (i, ax) in a.axi_ports.iter().enumerate() {
            if let Some(data) = buffers.get(&ax.name) {
                axi_data[i] = data.clone();
                let bits = data.len() as u64 * 32;
                let spec = &a.platform.pcs[ax.pc_id as usize];
                let e = pc_beats.entry(ax.pc_id).or_default();
                e.0 += bits.div_ceil(spec.width_bits as u64);
                e.1 += bits;
            }
        }

        // ---- functional: fire CUs to quiescence --------------------------
        let mut lane_stage: LaneStage = LaneStage::default();
        let mut cu_elems: Vec<u64> = vec![0; a.cus.len()];
        let mut cu_firings: Vec<u64> = vec![0; a.cus.len()];
        // lane CUs pre-slice their shared input FIFOs once
        let mut lane_inputs: FxHashMap<(usize, usize), VecDeque<f32>> = FxHashMap::default();
        for (ci, cu) in a.cus.iter().enumerate() {
            if cu.lanes > 1 {
                for ep in &cu.inputs {
                    if let Endpoint::Fifo(fi) = ep {
                        lane_inputs.entry((ci, *fi)).or_default();
                    }
                }
            }
        }
        // slice shared FIFOs round-robin across lanes (Fig 7: element i of
        // the original stream belongs to lane i % lanes)
        {
            let mut sliced: Vec<usize> = Vec::new();
            for cu in a.cus.iter() {
                if cu.lanes <= 1 {
                    continue;
                }
                for ep in &cu.inputs {
                    if let Endpoint::Fifo(fi) = ep {
                        if sliced.contains(fi) {
                            continue;
                        }
                        sliced.push(*fi);
                        let data: Vec<f32> = fifos[*fi].drain(..).collect();
                        // all lane CUs reading this fifo
                        for (cj, cu2) in a.cus.iter().enumerate() {
                            if cu2.lanes <= 1 || !cu2.inputs.contains(&Endpoint::Fifo(*fi)) {
                                continue;
                            }
                            let q = lane_inputs.entry((cj, *fi)).or_default();
                            for (k, v) in data.iter().enumerate() {
                                if k as u32 % cu2.lanes == cu2.lane {
                                    q.push_back(*v);
                                }
                            }
                        }
                    }
                }
            }
        }

        let mut safety = 0u64;
        let mut scratch = FireScratch::default();
        loop {
            // phase 1: fire on full chunks until quiescent
            loop {
                let mut progress = false;
                for (ci, cu) in a.cus.iter().enumerate() {
                    while self
                        .can_fire(cu, ci, &fifos, &plms, &axi_data, &lane_inputs, cu_firings[ci])?
                    {
                        self.fire(
                            cu,
                            ci,
                            false,
                            &mut fifos,
                            &mut plms,
                            &axi_data,
                            &mut lane_inputs,
                            &mut lane_stage,
                            &mut cu_elems,
                            &mut cu_firings,
                            &mut scratch,
                        )?;
                        progress = true;
                        safety += 1;
                        if safety > 1_000_000 {
                            bail!("simulation did not quiesce (1M firings)");
                        }
                    }
                }
                if !progress {
                    break;
                }
            }
            // phase 2: no producer can make progress — drain partial chunks
            // (bus-widened lanes and stream tails: the monomorphic AOT kernel
            // is fed a zero-padded chunk and its output truncated, exactly
            // how a variable-length HLS stream kernel behaves)
            let mut drained = false;
            for (ci, cu) in a.cus.iter().enumerate() {
                let has_partial = cu.inputs.iter().any(|ep| match ep {
                    Endpoint::Fifo(i) => {
                        let len = if cu.lanes > 1 {
                            lane_inputs.get(&(ci, *i)).map(|q| q.len()).unwrap_or(0)
                        } else {
                            fifos[*i].len()
                        };
                        len > 0
                    }
                    _ => false,
                });
                if has_partial {
                    self.fire(
                        cu,
                        ci,
                        true,
                        &mut fifos,
                        &mut plms,
                        &axi_data,
                        &mut lane_inputs,
                        &mut lane_stage,
                        &mut cu_elems,
                        &mut cu_firings,
                        &mut scratch,
                    )?;
                    drained = true;
                    safety += 1;
                    if safety > 1_000_000 {
                        bail!("simulation did not quiesce in drain (1M firings)");
                    }
                }
            }
            if !drained {
                break;
            }
        }

        // merge lane output stages into their FIFOs (element i%L from lane i)
        {
            // grouping only — each fifo's lanes are sorted below, and
            // distinct fifos' outputs are independent, so map order is moot
            let mut by_fifo: FxHashMap<usize, Vec<(usize, Vec<f32>)>> = FxHashMap::default();
            for ((fi, lane), data) in lane_stage.drain() {
                by_fifo.entry(fi).or_default().push((lane, data));
            }
            for (fi, mut lanes) in by_fifo {
                lanes.sort_by_key(|(l, _)| *l);
                let n: usize = lanes.iter().map(|(_, d)| d.len()).sum();
                let l = lanes.len();
                for i in 0..n {
                    let (lane, idx) = (i % l, i / l);
                    if let Some(v) = lanes[lane].1.get(idx) {
                        fifos[fi].push_back(*v);
                    }
                }
            }
        }

        // ---- functional: write movers drain to outputs -------------------
        let mut outputs = HashMap::new();
        for mv in &a.movers {
            if mv.dir != MoverDir::Write {
                continue;
            }
            let mut drained: Vec<&str> = Vec::new();
            for (field, ep) in &mv.routes {
                let base = field.split('.').next().unwrap_or(field);
                if drained.contains(&base) {
                    continue;
                }
                drained.push(base);
                let data: Vec<f32> = match ep {
                    Endpoint::Fifo(i) => fifos[*i].drain(..).collect(),
                    Endpoint::Plm(i) => plms[*i].clone(),
                    Endpoint::Axi(i) => axi_data[*i].clone(),
                };
                outputs.insert(base.to_string(), data);
            }
            self.account_mover_out(mv, &outputs, &mut pc_beats);
        }

        // ---- timing -------------------------------------------------------
        let timing = TimingModel::new(&a.platform, self.utilization, self.congestion_model);
        let mut per_pc = Vec::new();
        let mut mem_time: f64 = 0.0;
        let mut total_bits = 0u64;
        let mut cap_bits = 0u64;
        let mut ids: Vec<u32> = pc_beats.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            let (beats, bits) = pc_beats[&id];
            let spec = &a.platform.pcs[id as usize];
            let time_s = timing.pc_time_s(&a.platform, id, beats);
            mem_time = mem_time.max(time_s);
            total_bits += bits;
            cap_bits += beats * spec.width_bits as u64;
            per_pc.push(PcMetrics {
                pc_id: id,
                beats,
                useful_bytes: bits / 8,
                efficiency: if beats == 0 {
                    0.0
                } else {
                    bits as f64 / (beats * spec.width_bits as u64) as f64
                },
                time_s,
            });
        }
        let mut per_cu = Vec::new();
        let mut compute_time: f64 = 0.0;
        for (ci, cu) in a.cus.iter().enumerate() {
            let (cycles, time_s) = timing.cu_time_s(cu.latency, cu.ii, cu_elems[ci]);
            compute_time = compute_time.max(time_s);
            per_cu.push(CuMetrics {
                name: cu.name.clone(),
                callee: cu.callee.clone(),
                firings: cu_firings[ci],
                elems_in: cu_elems[ci],
                cycles,
                time_s,
            });
        }
        // dataflow overlap: streams + compute pipeline concurrently; the
        // longer side dominates, plus one kernel latency of pipeline fill
        let fill = a
            .cus
            .iter()
            .map(|c| c.latency as f64 / (timing.effective_mhz * 1e6))
            .fold(0.0, f64::max);
        let makespan = mem_time.max(compute_time) + fill;
        let total_bytes = total_bits / 8;
        let metrics = SimMetrics {
            per_pc,
            per_cu,
            total_bytes,
            mem_time_s: mem_time,
            compute_time_s: compute_time,
            makespan_s: makespan,
            achieved_gbs: if makespan > 0.0 { total_bytes as f64 / makespan / 1e9 } else { 0.0 },
            efficiency: if cap_bits == 0 { 0.0 } else { total_bits as f64 / cap_bits as f64 },
            utilization: self.utilization,
            effective_mhz: timing.effective_mhz,
            sim_wall_s: wall0.elapsed().as_secs_f64(),
        };
        Ok(SimOutput { outputs, metrics })
    }

    /// Account a read mover's beats/bits against its PC.
    fn account_mover(
        &self,
        mv: &crate::lower::MoverInst,
        buffers: &HashMap<String, Vec<f32>>,
        pc_beats: &mut FxHashMap<u32, (u64, u64)>,
    ) {
        let spec = &self.arch.platform.pcs[mv.pc_id as usize];
        let beats_per_word = (mv.layout.word_bits as u64).div_ceil(spec.width_bits as u64);
        let mut bases: Vec<&str> = Vec::new();
        let mut bits = 0u64;
        for (field, _) in &mv.routes {
            let base = field.split('.').next().unwrap_or(field);
            if bases.contains(&base) {
                continue;
            }
            bases.push(base);
            bits += buffers.get(base).map(|d| d.len() as u64 * 32).unwrap_or(0);
        }
        let e = pc_beats.entry(mv.pc_id).or_default();
        e.0 += mv.layout.depth * beats_per_word;
        e.1 += bits;
    }

    /// Account a write mover (same math, data from outputs).
    fn account_mover_out(
        &self,
        mv: &crate::lower::MoverInst,
        outputs: &HashMap<String, Vec<f32>>,
        pc_beats: &mut FxHashMap<u32, (u64, u64)>,
    ) {
        let spec = &self.arch.platform.pcs[mv.pc_id as usize];
        let beats_per_word = (mv.layout.word_bits as u64).div_ceil(spec.width_bits as u64);
        let mut bases: Vec<&str> = Vec::new();
        let mut bits = 0u64;
        for (field, _) in &mv.routes {
            let base = field.split('.').next().unwrap_or(field);
            if bases.contains(&base) {
                continue;
            }
            bases.push(base);
            bits += outputs.get(base).map(|d| d.len() as u64 * 32).unwrap_or(0);
        }
        let e = pc_beats.entry(mv.pc_id).or_default();
        e.0 += mv.layout.depth * beats_per_word;
        e.1 += bits;
    }

    #[allow(clippy::too_many_arguments)]
    fn can_fire(
        &self,
        cu: &CuInst,
        ci: usize,
        fifos: &[VecDeque<f32>],
        plms: &[Vec<f32>],
        axi: &[Vec<f32>],
        lane_inputs: &FxHashMap<(usize, usize), VecDeque<f32>>,
        firings: u64,
    ) -> Result<bool> {
        let e = self.registry.entry(&cu.callee).context("validated")?;
        for (k, ep) in cu.inputs.iter().enumerate() {
            let need = e.input_len(k);
            let have = match ep {
                Endpoint::Fifo(i) => {
                    if cu.lanes > 1 {
                        lane_inputs.get(&(ci, *i)).map(|q| q.len()).unwrap_or(0)
                    } else {
                        fifos[*i].len()
                    }
                }
                Endpoint::Plm(i) => plms[*i].len(),
                Endpoint::Axi(i) => axi[*i].len().saturating_sub(firings as usize * need),
            };
            if have < need {
                return Ok(false);
            }
        }
        // CU with only PLM/AXI inputs fires exactly once per iteration
        if cu.inputs.iter().all(|e| !matches!(e, Endpoint::Fifo(_))) && firings > 0 {
            return Ok(false);
        }
        Ok(true)
    }

    #[allow(clippy::too_many_arguments)]
    fn fire(
        &self,
        cu: &CuInst,
        ci: usize,
        allow_partial: bool,
        fifos: &mut [VecDeque<f32>],
        plms: &mut [Vec<f32>],
        axi: &[Vec<f32>],
        lane_inputs: &mut FxHashMap<(usize, usize), VecDeque<f32>>,
        lane_stage: &mut LaneStage,
        cu_elems: &mut [u64],
        cu_firings: &mut [u64],
        scratch: &mut FireScratch,
    ) -> Result<()> {
        let e = self.registry.entry(&cu.callee).context("validated")?;
        if scratch.args.len() < cu.inputs.len() {
            scratch.args.resize_with(cu.inputs.len(), Vec::new);
        }
        // fraction of a full chunk actually consumed (partial-drain firings)
        let mut frac: f64 = 1.0;
        for (k, ep) in cu.inputs.iter().enumerate() {
            let need = e.input_len(k);
            let data = &mut scratch.args[k];
            data.clear();
            match ep {
                Endpoint::Fifo(i) => {
                    let q = if cu.lanes > 1 {
                        lane_inputs.get_mut(&(ci, *i)).unwrap()
                    } else {
                        &mut fifos[*i]
                    };
                    let take = need.min(q.len());
                    data.extend(q.drain(..take));
                }
                Endpoint::Plm(i) => data.extend(plms[*i].iter().take(need).copied()),
                Endpoint::Axi(i) => {
                    let off = cu_firings[ci] as usize * need;
                    data.extend(axi[*i].iter().skip(off).take(need).copied());
                }
            }
            cu_elems[ci] += data.len() as u64;
            if data.len() < need {
                if !allow_partial && matches!(ep, Endpoint::Fifo(_)) {
                    bail!("CU '{}' fired without a full chunk on input {k}", cu.name);
                }
                if matches!(ep, Endpoint::Fifo(_)) && need > 1 {
                    frac = frac.min(data.len() as f64 / need as f64);
                }
                data.resize(need, 0.0); // zero padding
            }
        }
        let arg_refs: Vec<&[f32]> =
            scratch.args[..cu.inputs.len()].iter().map(|d| d.as_slice()).collect();
        let results = self
            .registry
            .execute(&cu.callee, &arg_refs)
            .with_context(|| format!("executing kernel '{}' for CU '{}'", cu.callee, cu.name))?;
        for (k, ep) in cu.outputs.iter().enumerate() {
            let out_len = results[k].len();
            // truncate proportionally on partial chunks (1:1 streaming map)
            let take = if frac < 1.0 {
                ((out_len as f64 * frac).round() as usize).max(1)
            } else {
                out_len
            };
            let data = &results[k][..take.min(out_len)];
            match ep {
                Endpoint::Fifo(i) => {
                    if cu.lanes > 1 {
                        lane_stage
                            .entry((*i, cu.lane as usize))
                            .or_default()
                            .extend_from_slice(data);
                    } else {
                        fifos[*i].extend(data.iter().copied());
                    }
                }
                Endpoint::Plm(i) => plms[*i] = data.to_vec(),
                Endpoint::Axi(_) => {}
            }
        }
        cu_firings[ci] += 1;
        Ok(())
    }
}
