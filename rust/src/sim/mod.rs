//! Cycle-approximate platform simulator — the Alveo-card stand-in
//! (DESIGN.md §2, substitution 1).
//!
//! Two planes:
//! * **functional**: bytes actually move — host buffers stream through data
//!   movers into FIFOs/PLMs, kernel compute units execute their AOT
//!   HLO via PJRT ([`crate::runtime`]), results stream back. This proves
//!   the generated architecture (incl. Iris routing and lane demuxing)
//!   computes the right answer.
//! * **timing**: beat/cycle accounting per physical memory channel and per
//!   compute unit, with a dataflow-overlap makespan model and a routing-
//!   congestion derate near full fabric utilization (paper §V-B,
//!   replication caveat).

mod engine;
mod metrics;
mod timing;

pub use engine::{SimOutput, Simulator};
pub use metrics::{CuMetrics, PcMetrics, SimMetrics};
pub use timing::{congestion_derate, TimingModel};
