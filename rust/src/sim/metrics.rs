//! Simulation metrics.

use std::fmt;

/// Per-physical-memory-channel accounting.
#[derive(Debug, Clone)]
pub struct PcMetrics {
    pub pc_id: u32,
    /// Beats issued on this channel.
    pub beats: u64,
    /// Useful payload bytes moved.
    pub useful_bytes: u64,
    /// Bandwidth efficiency (useful bits / beats × width).
    pub efficiency: f64,
    /// Transfer time at peak beat rate (s).
    pub time_s: f64,
}

/// Per-compute-unit accounting.
#[derive(Debug, Clone)]
pub struct CuMetrics {
    pub name: String,
    pub callee: String,
    pub firings: u64,
    pub elems_in: u64,
    /// Pipeline cycles: latency + (elems - 1) × II.
    pub cycles: u64,
    /// Compute time at the (derated) kernel clock (s).
    pub time_s: f64,
}

/// Whole-run metrics.
#[derive(Debug, Clone)]
pub struct SimMetrics {
    pub per_pc: Vec<PcMetrics>,
    pub per_cu: Vec<CuMetrics>,
    /// Total useful bytes across PCs.
    pub total_bytes: u64,
    /// Memory-bound time: slowest PC (s).
    pub mem_time_s: f64,
    /// Compute-bound time: slowest CU (s).
    pub compute_time_s: f64,
    /// Dataflow makespan: max(mem, compute) + pipeline fill (s).
    pub makespan_s: f64,
    /// Useful bytes / makespan, GB/s.
    pub achieved_gbs: f64,
    /// Aggregate bandwidth efficiency across used PCs.
    pub efficiency: f64,
    /// Fabric utilization (binding resource class fraction).
    pub utilization: f64,
    /// Kernel clock after congestion derating (MHz).
    pub effective_mhz: f64,
    /// Wall-clock the simulator itself spent (s) — for §Perf.
    pub sim_wall_s: f64,
}

impl fmt::Display for SimMetrics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "== simulation report ==")?;
        writeln!(
            f,
            "makespan {:.3} ms  (memory {:.3} ms, compute {:.3} ms)",
            self.makespan_s * 1e3,
            self.mem_time_s * 1e3,
            self.compute_time_s * 1e3
        )?;
        writeln!(
            f,
            "moved {} useful bytes  ->  {:.2} GB/s achieved, {:.1}% bandwidth efficiency",
            self.total_bytes,
            self.achieved_gbs,
            self.efficiency * 100.0
        )?;
        writeln!(
            f,
            "fabric utilization {:.1}%, kernel clock {:.0} MHz",
            self.utilization * 100.0,
            self.effective_mhz
        )?;
        writeln!(f, "-- memory channels --")?;
        for pc in &self.per_pc {
            writeln!(
                f,
                "  pc{:<3} beats {:<10} useful {:<12} eff {:>6.1}%  {:.3} ms",
                pc.pc_id,
                pc.beats,
                pc.useful_bytes,
                pc.efficiency * 100.0,
                pc.time_s * 1e3
            )?;
        }
        writeln!(f, "-- compute units --")?;
        for cu in &self.per_cu {
            writeln!(
                f,
                "  {:<28} firings {:<6} elems {:<10} cycles {:<12} {:.3} ms",
                cu.name,
                cu.firings,
                cu.elems_in,
                cu.cycles,
                cu.time_s * 1e3
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_renders() {
        let m = SimMetrics {
            per_pc: vec![PcMetrics {
                pc_id: 0,
                beats: 100,
                useful_bytes: 3200,
                efficiency: 1.0,
                time_s: 1e-6,
            }],
            per_cu: vec![CuMetrics {
                name: "cu0".into(),
                callee: "vecadd_1024".into(),
                firings: 1,
                elems_in: 1024,
                cycles: 2083,
                time_s: 7e-6,
            }],
            total_bytes: 3200,
            mem_time_s: 1e-6,
            compute_time_s: 7e-6,
            makespan_s: 7.1e-6,
            achieved_gbs: 0.45,
            efficiency: 1.0,
            utilization: 0.1,
            effective_mhz: 300.0,
            sim_wall_s: 0.01,
        };
        let s = m.to_string();
        assert!(s.contains("makespan"));
        assert!(s.contains("pc0"));
        assert!(s.contains("cu0"));
    }
}
