//! Channel layouts (paper Figs 4c, 7b, 8b).
//!
//! A layout describes how logical arrays are organized in the physical words
//! flowing through a channel:
//!
//! * after **sanitize** (Fig 4c): one field, one element per word —
//!   `word_bits == elem_bits`, depth = channel depth;
//! * after **bus widening** (Fig 7b): `lanes > 1`, each lane carrying one
//!   replica's elements side by side;
//! * after **Iris** (Fig 8b): several fields of *different* arrays
//!   interleaved in one word, possibly with an array split across positions.
//!
//! Serialized as a `layout` dictionary attribute on `olympus.make_channel`,
//! so layouts survive the IR print/parse round-trip.

use crate::ir::{AttrMap, Attribute};

/// One array's slots within the layout word.
#[derive(Debug, Clone, PartialEq)]
pub struct LayoutField {
    /// Logical array name (e.g. `"a"`, or `"b.0"` for an Iris-split chunk).
    pub array: String,
    /// Element width in bits.
    pub elem_bits: u32,
    /// Number of consecutive elements of this array per word.
    pub count: u32,
    /// Bit offset of the field's first element within the word.
    pub offset_bits: u32,
}

/// A channel data layout.
#[derive(Debug, Clone, PartialEq)]
pub struct Layout {
    /// Physical word width in bits.
    pub word_bits: u32,
    /// Number of words.
    pub depth: u64,
    /// Parallel lanes (bus widening replicates kernels per lane).
    pub lanes: u32,
    /// Field placements within one word.
    pub fields: Vec<LayoutField>,
}

impl Layout {
    /// The sanitize-stage layout: one element of width `elem_bits` per word.
    pub fn scalar(array: &str, elem_bits: u32, depth: u64) -> Layout {
        Layout {
            word_bits: elem_bits,
            depth,
            lanes: 1,
            fields: vec![LayoutField {
                array: array.to_string(),
                elem_bits,
                count: 1,
                offset_bits: 0,
            }],
        }
    }

    /// Occupied bits per word.
    pub fn used_bits(&self) -> u32 {
        self.fields.iter().map(|f| f.elem_bits * f.count).sum()
    }

    /// Bandwidth efficiency: occupied / word width (the paper's Iris metric).
    pub fn efficiency(&self) -> f64 {
        if self.word_bits == 0 {
            return 0.0;
        }
        self.used_bits() as f64 / self.word_bits as f64
    }

    /// True iff no two fields overlap and all fit in the word.
    pub fn is_valid(&self) -> bool {
        let mut spans: Vec<(u32, u32)> = self
            .fields
            .iter()
            .map(|f| (f.offset_bits, f.offset_bits + f.elem_bits * f.count))
            .collect();
        spans.sort_unstable();
        for w in spans.windows(2) {
            if w[0].1 > w[1].0 {
                return false;
            }
        }
        spans.last().map(|&(_, end)| end <= self.word_bits).unwrap_or(true)
    }

    // ---- attribute (de)serialization -------------------------------------

    pub fn to_attr(&self) -> Attribute {
        let mut d = AttrMap::new();
        d.insert("word_bits".into(), Attribute::Int(self.word_bits as i64));
        d.insert("depth".into(), Attribute::Int(self.depth as i64));
        d.insert("lanes".into(), Attribute::Int(self.lanes as i64));
        let fields = self
            .fields
            .iter()
            .map(|f| {
                let mut fd = AttrMap::new();
                fd.insert("array".into(), Attribute::Str(f.array.clone()));
                fd.insert("elem_bits".into(), Attribute::Int(f.elem_bits as i64));
                fd.insert("count".into(), Attribute::Int(f.count as i64));
                fd.insert("offset_bits".into(), Attribute::Int(f.offset_bits as i64));
                Attribute::Dict(fd)
            })
            .collect();
        d.insert("fields".into(), Attribute::Array(fields));
        Attribute::Dict(d)
    }

    pub fn from_attr(attr: &Attribute) -> Option<Layout> {
        let d = attr.as_dict()?;
        let word_bits = d.get("word_bits")?.as_int()? as u32;
        let depth = d.get("depth")?.as_int()? as u64;
        let lanes = d.get("lanes")?.as_int()? as u32;
        let mut fields = Vec::new();
        for f in d.get("fields")?.as_array()? {
            let fd = f.as_dict()?;
            fields.push(LayoutField {
                array: fd.get("array")?.as_str()?.to_string(),
                elem_bits: fd.get("elem_bits")?.as_int()? as u32,
                count: fd.get("count")?.as_int()? as u32,
                offset_bits: fd.get("offset_bits")?.as_int()? as u32,
            });
        }
        Some(Layout { word_bits, depth, lanes, fields })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_layout_fig4c() {
        let l = Layout::scalar("a", 32, 20);
        assert_eq!(l.word_bits, 32);
        assert_eq!(l.depth, 20);
        assert_eq!(l.lanes, 1);
        assert_eq!(l.efficiency(), 1.0);
        assert!(l.is_valid());
    }

    #[test]
    fn interleaved_fig8b() {
        // a (32b) + b split into two 48b chunks on a 128-bit bus
        let l = Layout {
            word_bits: 128,
            depth: 100,
            lanes: 1,
            fields: vec![
                LayoutField { array: "a".into(), elem_bits: 32, count: 1, offset_bits: 0 },
                LayoutField { array: "b.0".into(), elem_bits: 48, count: 1, offset_bits: 32 },
                LayoutField { array: "b.1".into(), elem_bits: 48, count: 1, offset_bits: 80 },
            ],
        };
        assert!(l.is_valid());
        assert_eq!(l.used_bits(), 128);
        assert_eq!(l.efficiency(), 1.0);
    }

    #[test]
    fn overlap_is_invalid() {
        let l = Layout {
            word_bits: 64,
            depth: 1,
            lanes: 1,
            fields: vec![
                LayoutField { array: "a".into(), elem_bits: 40, count: 1, offset_bits: 0 },
                LayoutField { array: "b".into(), elem_bits: 40, count: 1, offset_bits: 32 },
            ],
        };
        assert!(!l.is_valid());
    }

    #[test]
    fn overflow_is_invalid() {
        let l = Layout {
            word_bits: 32,
            depth: 1,
            lanes: 1,
            fields: vec![LayoutField {
                array: "a".into(),
                elem_bits: 64,
                count: 1,
                offset_bits: 0,
            }],
        };
        assert!(!l.is_valid());
    }

    #[test]
    fn attr_roundtrip() {
        let l = Layout {
            word_bits: 256,
            depth: 1024,
            lanes: 4,
            fields: vec![
                LayoutField { array: "a".into(), elem_bits: 64, count: 2, offset_bits: 0 },
                LayoutField { array: "b".into(), elem_bits: 32, count: 1, offset_bits: 128 },
            ],
        };
        let attr = l.to_attr();
        let l2 = Layout::from_attr(&attr).unwrap();
        assert_eq!(l, l2);
    }

    #[test]
    fn naive_padding_efficiency() {
        // the paper's ~45% naive case: a 112-bit struct padded into 256-bit words
        let l = Layout {
            word_bits: 256,
            depth: 10,
            lanes: 1,
            fields: vec![LayoutField {
                array: "s".into(),
                elem_bits: 112,
                count: 1,
                offset_bits: 0,
            }],
        };
        assert!((l.efficiency() - 0.4375).abs() < 1e-9);
    }
}
