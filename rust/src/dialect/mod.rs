//! The Olympus dialect (paper §IV).
//!
//! Operations:
//! * `olympus.make_channel` — creates a `!olympus.channel<iN>` edge of the
//!   DFG; attributes `encapsulatedType`, `paramType`
//!   (`"stream" | "small" | "complex"`), `depth`, and (after sanitize) a
//!   `layout` dictionary.
//! * `olympus.kernel` — a DFG node; attributes `callee`, `latency`, `ii`,
//!   resource estimates (`ff`, `lut`, `bram`, `uram`, `dsp`) and
//!   `operand_segment_sizes` splitting operands into inputs/outputs.
//! * `olympus.pc` — terminal for channels touching global memory; attribute
//!   `id` selects the physical pseudo-channel.
//! * `olympus.super_node` — post-bus-widening container holding replicated
//!   kernels in its region (paper Fig 7).
//!
//! [`verify_dialect`] layers Olympus-specific rules on the structural
//! verifier; typed views ([`ChannelView`], [`KernelView`], [`PcView`]) give
//! passes ergonomic access without stringly-typed attribute code.

pub mod build;
pub mod layout;
pub mod ops;
pub mod resources;
pub mod verify;

pub use build::{DfgBuilder, KernelEst};
pub use layout::{Layout, LayoutField};
pub use ops::{
    ChannelView, KernelView, ParamType, PcView, OP_KERNEL, OP_MAKE_CHANNEL, OP_PC, OP_SUPER_NODE,
};
pub use resources::ResourceVec;
pub use verify::{verify_dialect, DialectError};
